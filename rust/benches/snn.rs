//! Experiment E8 — §7.2 spiking-neural-network execution.
//!
//! The microcircuit use case as a benchmark: host wall-clock per
//! simulated second, spike throughput, HLO kernel executions and
//! mapping cost as the network scales.
//!
//! ```sh
//! make artifacts && cargo bench --bench snn
//! ```

use std::time::Instant;

use spinntools::apps::networks::{build_microcircuit, firing_rates};
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};

fn main() -> anyhow::Result<()> {
    if !spinntools::runtime::Runtime::default_dir().join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    println!("# E8: scaled Potjans-Diesmann microcircuit execution");
    println!(
        "{:<8} {:>8} {:>7} {:>7} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "scale", "neurons", "cores", "chips", "map wall", "run wall", "spikes", "mean rate", "HLO execs"
    );
    let run_ms = 100u64;
    for scale in [0.005f64, 0.01, 0.02, 0.04] {
        let spec = if scale > 0.05 {
            MachineSpec::Boards(3)
        } else {
            MachineSpec::Spinn5
        };
        let mut tools = SpiNNTools::new(ToolsConfig::new(spec).with_artifacts())?;
        let t_map = Instant::now();
        let circuit = build_microcircuit(&mut tools, scale, 99, true)?;
        // First tick triggers mapping+loading inside run_ticks; separate
        // them by running 1 tick first.
        tools.run_ticks(1)?;
        let map_wall = t_map.elapsed();
        let t_run = Instant::now();
        tools.run_ms(run_ms - 1)?;
        let run_wall = t_run.elapsed();

        let n: u32 = circuit.sizes.values().sum();
        let rates = firing_rates(&tools, &circuit, run_ms as f64);
        let mean_rate: f64 = rates.values().sum::<f64>() / rates.len() as f64;
        let prov = tools.provenance();
        let spikes = prov.counter_total("spikes_out");
        let execs = tools.runtime().map(|r| r.execs.get()).unwrap_or(0);
        let mapping = tools.mapping().unwrap();
        println!(
            "{:<8} {:>8} {:>7} {:>7} {:>10.2?} {:>10.2?} {:>10} {:>9.2}Hz {:>10}",
            scale,
            n,
            mapping.placements.len(),
            mapping.placements.used_chips().len(),
            map_wall,
            run_wall,
            spikes,
            mean_rate,
            execs,
        );
        assert!(mean_rate > 0.1 && mean_rate < 100.0, "implausible dynamics");
        tools.stop()?;
    }
    println!("\n# shape: spikes scale ~linearly with network size; rates stay biological");
    Ok(())
}
