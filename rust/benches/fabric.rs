//! Experiment E11 — fabric throughput: the fast packet fabric (flat
//! chip arena, per-chip route cache, calendar event queue) against the
//! pre-change fabric (`BTreeMap` chip store, per-packet first-match
//! TCAM scans, `BinaryHeap` event queue), on the paper's two workload
//! shapes (§7.1 Conway, §7.2 microcircuit topology).
//!
//! The legacy path is not a remembered number: `FabricMode::Legacy`
//! still runs the original data structures, so every row here is a
//! same-binary, same-workload A/B measurement — and the two runs must
//! agree on a full behavioural digest, or the speedup is meaningless.
//!
//! Results go to `BENCH_fabric.json` at the repository root. Target
//! (ISSUE 2): ≥ 3x packets/sec on the Conway workload.
//!
//! ```sh
//! cargo bench --bench fabric
//! ```

use std::collections::BTreeMap;

use spinntools::front::fabric_probe::{run_fabric_probe, ProbeResult, ProbeWorkload};
use spinntools::simulator::FabricMode;
use spinntools::util::json::Json;

const TARGET_SPEEDUP: f64 = 3.0;

fn print_row(r: &ProbeResult) {
    println!(
        "{:<24} {:>7} {:>7} ticks {:>9.3}s {:>12.0} ev/s {:>12.0} hops/s {:>11.0} pkts/s",
        r.workload,
        r.mode_name(),
        r.ticks,
        r.wall_seconds,
        r.events_per_sec(),
        r.hops_per_sec(),
        r.sent_per_sec(),
    );
}

fn bench_workload(workload: ProbeWorkload, ticks: u64) -> anyhow::Result<Json> {
    let legacy = run_fabric_probe(workload, ticks, FabricMode::Legacy)?;
    print_row(&legacy);
    let fast = run_fabric_probe(workload, ticks, FabricMode::Fast)?;
    print_row(&fast);

    let equivalent = fast.digest == legacy.digest;
    // The acceptance criterion (ISSUE 2 / E11) is packets/sec; with
    // identical behaviour the packet, hop and event counts are equal
    // across modes, so all three ratios reduce to the wall-clock ratio —
    // but the recorded gate is the named metric.
    let speedup = fast.sent_per_sec() / legacy.sent_per_sec().max(1e-9);
    println!(
        "   packets/sec speedup {speedup:.2}x | cache hit rate {:.1}% | behaviour identical: {equivalent}",
        100.0 * fast.cache_hits as f64 / (fast.cache_hits + fast.cache_misses).max(1) as f64,
    );
    assert!(
        equivalent,
        "{}: fast and legacy fabrics diverged (digest {:016x} vs {:016x})",
        fast.workload, fast.digest, legacy.digest
    );

    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(fast.workload.clone()));
    o.insert("legacy".to_string(), legacy.to_json());
    o.insert("fast".to_string(), fast.to_json());
    o.insert("speedup_packets_per_sec".to_string(), Json::Num(speedup));
    o.insert(
        "speedup_hops_per_sec".to_string(),
        Json::Num(fast.hops_per_sec() / legacy.hops_per_sec().max(1e-9)),
    );
    o.insert(
        "speedup_events_per_sec".to_string(),
        Json::Num(fast.events_per_sec() / legacy.events_per_sec().max(1e-9)),
    );
    o.insert("behaviour_identical".to_string(), Json::Bool(equivalent));
    o.insert(
        "meets_target".to_string(),
        Json::Bool(equivalent && speedup >= TARGET_SPEEDUP),
    );
    Ok(Json::Obj(o))
}

fn main() -> anyhow::Result<()> {
    println!("# E11: packet-fabric throughput, fast vs legacy (same binary, same workload)");

    // §7.1 at scale: 4096 cells on a 576-chip (12-board) machine.
    let conway = bench_workload(ProbeWorkload::Conway { side: 64, boards: 12 }, 24)?;
    // §7.2 topology at quarter scale on 3 boards, ~30% firing rate.
    let storm =
        bench_workload(ProbeWorkload::MicrocircuitStorm { scale: 0.25, boards: 3 }, 48)?;

    let conway_speedup = conway
        .get("speedup_packets_per_sec")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    println!(
        "\n# conway packets/sec speedup {conway_speedup:.2}x (target ≥ {TARGET_SPEEDUP}x): {}",
        if conway_speedup >= TARGET_SPEEDUP { "MET" } else { "NOT MET" }
    );

    let mut root = BTreeMap::new();
    root.insert(
        "experiment".to_string(),
        Json::Str("E11_fabric_throughput".to_string()),
    );
    root.insert("target_speedup".to_string(), Json::Num(TARGET_SPEEDUP));
    root.insert(
        "meets_target".to_string(),
        Json::Bool(conway_speedup >= TARGET_SPEEDUP),
    );
    root.insert("workloads".to_string(), Json::Arr(vec![conway, storm]));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_fabric.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("results written to {}", out.display());
    Ok(())
}
