//! Experiment E18 — SpiNNaker2-scale mapping and fabric (DESIGN.md §12).
//!
//! The paper's pipeline has only ever been exercised here at 576 chips;
//! SpiNNaker 2 raises the target by orders of magnitude. This bench
//! streams the full mapping pipeline (hierarchical placement, NER
//! routing, table generation, tag allocation) over wafer-scale toroids
//! at 1k/10k/100k chips — measuring wall time and allocated bytes via
//! [`spinntools::util::mem::AllocCounter`] installed as the global
//! allocator — then runs a multicast traffic workload on the booted
//! fast fabric at each scale to get packets/sec. At 1M chips, machine
//! construction + hierarchical placement run mapping-only.
//!
//! Results go to `BENCH_scale.json` at the repository root.
//!
//! ```sh
//! cargo bench --bench scale
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use spinntools::graph::{
    DataGenContext, DataRegion, MachineGraph, MachineVertexImpl, ResourceRequirements,
};
use spinntools::machine::{Machine, MachineBuilder};
use spinntools::mapping::{map_graph, placer, MappingConfig, MappingOptions};
use spinntools::simulator::{scamp, CoreApp, CoreCtx, SimConfig, SimMachine};
use spinntools::util::json::Json;
use spinntools::util::mem::AllocCounter;

#[global_allocator]
static ALLOC: AllocCounter = AllocCounter::new();

/// Full-pipeline scales; 1M chips runs construction + placement only.
const MAP_SCALES: [u32; 3] = [1_000, 10_000, 100_000];
const MILLION: u32 = 1_000_000;
/// Cores sending multicast traffic in the fabric phase, and for how
/// many timer ticks.
const SENDERS: usize = 1024;
const TICKS: u64 = 20;

/// A label-free vertex: at a million vertices, even one stored `String`
/// per vertex would dominate the graph's footprint.
#[derive(Debug)]
struct ScaleVertex {
    idx: u32,
}

impl MachineVertexImpl for ScaleVertex {
    fn label(&self) -> String {
        format!("s{}", self.idx)
    }
    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements::with_sdram(1024)
    }
    fn binary_name(&self) -> String {
        "scale.aplx".into()
    }
    fn generate_data(&self, _ctx: &DataGenContext) -> Vec<DataRegion> {
        vec![]
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// One vertex per chip in a ring, with a longer-range chord from every
/// 16th vertex so some routes cross many chips and real tables appear.
fn ring_graph(n_vertices: u32, with_edges: bool) -> MachineGraph {
    let mut g = MachineGraph::new();
    let ids: Vec<_> = (0..n_vertices)
        .map(|idx| g.add_vertex(Arc::new(ScaleVertex { idx })))
        .collect();
    if with_edges && n_vertices > 1 {
        let n = ids.len();
        for (i, v) in ids.iter().enumerate() {
            g.add_edge(*v, ids[(i + 1) % n], "ring");
            if i % 16 == 0 {
                g.add_edge(*v, ids[(i + 136) % n], "ring");
            }
        }
    }
    g
}

/// Sends one multicast packet per timer tick on the vertex's key.
#[derive(Debug)]
struct Ticker {
    key: u32,
}

impl CoreApp for Ticker {
    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        ctx.send_mc(self.key, None);
        Ok(())
    }
}

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

fn json_num(v: f64) -> Json {
    Json::Num(v)
}

/// Build + map + fabric at one scale.
fn bench_scale(n_chips: u32) -> anyhow::Result<Json> {
    // Machine construction, with its allocation footprint isolated.
    let live0 = ALLOC.live_bytes();
    let t = Instant::now();
    let machine: Machine = MachineBuilder::wafer(n_chips).build();
    let build_ms = ms(t);
    let machine_bytes = ALLOC.live_bytes().saturating_sub(live0);
    let per_chip = machine_bytes as f64 / machine.n_chips() as f64;
    println!(
        "\n## {} chips ({}x{} torus): built in {build_ms:.1} ms, {machine_bytes} bytes \
         ({per_chip:.0} B/chip)",
        machine.n_chips(),
        machine.width,
        machine.height
    );

    // Full mapping pipeline, one vertex per chip, peak bytes attributed.
    let graph = ring_graph(machine.n_chips() as u32, true);
    let config = MappingConfig {
        options: MappingOptions::with_threads(0),
        ..Default::default()
    };
    ALLOC.reset_peak();
    let map_live0 = ALLOC.live_bytes();
    let t = Instant::now();
    let mapping = map_graph(&machine, &graph, &config)?;
    let map_ms = ms(t);
    let map_peak = ALLOC.peak_bytes().saturating_sub(map_live0);
    println!(
        "   map_graph: {} vertices in {map_ms:.1} ms, peak +{map_peak} bytes, {} tables",
        graph.n_vertices(),
        mapping.tables.len()
    );

    // Fabric: boot the fast fabric, install the mapped tables, put a
    // Ticker on the first SENDERS placed vertices and run TICKS ticks.
    let t = Instant::now();
    let mut sim = SimMachine::boot(machine, SimConfig::default());
    let boot_ms = ms(t);
    for (chip, table) in &mapping.tables {
        scamp::load_routing_table(&mut sim, *chip, table.clone())?;
    }
    let senders: Vec<_> = graph.vertex_ids().take(SENDERS).collect();
    for v in &senders {
        let loc = mapping.placements.of(*v).expect("sender placed");
        let key = mapping.keys[&(*v, "ring".to_string())].base;
        let app = Box::new(Ticker { key });
        scamp::load_app(&mut sim, loc, app, BTreeMap::new(), BTreeMap::new())?;
    }
    scamp::signal_start(&mut sim)?;
    let sent0 = sim.stats.mc_sent;
    let t = Instant::now();
    sim.start_run_cycle(TICKS);
    sim.run_until_idle()?;
    let run_s = t.elapsed().as_secs_f64();
    let sent = sim.stats.mc_sent - sent0;
    let pkts_per_sec = sent as f64 / run_s.max(1e-9);
    println!(
        "   fabric: boot {boot_ms:.1} ms, {} senders x {TICKS} ticks -> {sent} packets, \
         {pkts_per_sec:.0} pkts/s",
        senders.len()
    );

    let mut o = BTreeMap::new();
    o.insert("chips".into(), json_num(sim.machine.n_chips() as f64));
    o.insert("machine_build_ms".into(), json_num(build_ms));
    o.insert("machine_bytes".into(), json_num(machine_bytes as f64));
    o.insert("machine_bytes_per_chip".into(), json_num(per_chip));
    o.insert("vertices".into(), json_num(graph.n_vertices() as f64));
    o.insert("map_ms".into(), json_num(map_ms));
    o.insert("map_peak_bytes".into(), json_num(map_peak as f64));
    o.insert("tables".into(), json_num(mapping.tables.len() as f64));
    o.insert("fabric_boot_ms".into(), json_num(boot_ms));
    o.insert("fabric_packets".into(), json_num(sent as f64));
    o.insert("fabric_packets_per_sec".into(), json_num(pkts_per_sec));
    Ok(Json::Obj(o))
}

/// 1M chips: construction + hierarchical placement, mapping-only.
fn bench_million() -> anyhow::Result<Json> {
    let live0 = ALLOC.live_bytes();
    let t = Instant::now();
    let machine = MachineBuilder::wafer(MILLION).build();
    let build_ms = ms(t);
    let machine_bytes = ALLOC.live_bytes().saturating_sub(live0);
    let per_chip = machine_bytes as f64 / machine.n_chips() as f64;
    println!(
        "\n## {} chips ({}x{} torus): built in {build_ms:.1} ms, {machine_bytes} bytes \
         ({per_chip:.0} B/chip)",
        machine.n_chips(),
        machine.width,
        machine.height
    );

    let graph = ring_graph(MILLION, false); // placement-only: no edges
    ALLOC.reset_peak();
    let place_live0 = ALLOC.live_bytes();
    let t = Instant::now();
    let placements = placer::place_hierarchical(
        &machine,
        &graph,
        &std::collections::BTreeSet::new(),
        0,
    )?;
    let place_ms = ms(t);
    let place_peak = ALLOC.peak_bytes().saturating_sub(place_live0);
    println!(
        "   hierarchical placement: {} vertices in {place_ms:.1} ms, peak +{place_peak} bytes",
        placements.len()
    );
    assert_eq!(placements.len(), MILLION as usize);

    let mut o = BTreeMap::new();
    o.insert("chips".into(), json_num(machine.n_chips() as f64));
    o.insert("machine_build_ms".into(), json_num(build_ms));
    o.insert("machine_bytes".into(), json_num(machine_bytes as f64));
    o.insert("machine_bytes_per_chip".into(), json_num(per_chip));
    o.insert("vertices".into(), json_num(MILLION as f64));
    o.insert("place_ms".into(), json_num(place_ms));
    o.insert("place_peak_bytes".into(), json_num(place_peak as f64));
    o.insert("mapping_only".into(), Json::Bool(true));
    Ok(Json::Obj(o))
}

fn main() -> anyhow::Result<()> {
    println!("# E18: SpiNNaker2-scale mapping + fabric (wafer toroids, allocation-counted)");

    let mut scales = Vec::new();
    for n in MAP_SCALES {
        scales.push(bench_scale(n)?);
    }
    let million = bench_million()?;

    let mut root = BTreeMap::new();
    root.insert(
        "experiment".to_string(),
        Json::Str("E18_spinnaker2_scale".to_string()),
    );
    root.insert("senders".to_string(), json_num(SENDERS as f64));
    root.insert("ticks".to_string(), json_num(TICKS as f64));
    root.insert("scales".to_string(), Json::Arr(scales));
    root.insert("million_chips_mapping_only".to_string(), million);

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_scale.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
