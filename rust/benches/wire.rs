//! Experiment E16 — the cost of surviving an unreliable wire
//! (DESIGN.md §10).
//!
//! Three questions, answered in `BENCH_wire.json`:
//!
//! 1. **What does reliability cost when nothing goes wrong?** Nothing:
//!    the lossless wire takes the draw-free fast path, and this bench
//!    *asserts* zero retries/timeouts on it.
//! 2. **What does loss cost when it happens?** SCAMP and bulk-plane
//!    transfers, plus a whole Conway workload, run at 0‰ / 10‰ / 50‰
//!    frame loss; the simulated-time overhead ratios quantify the
//!    retry/backoff/re-request tax. Results stay byte-identical at
//!    every loss level.
//! 3. **How fast does silence turn into a heal?** A board that stops
//!    answering mid-run is escalated and healed around; the bench
//!    records the virtual time from first timeout to escalation and
//!    the wall-clock heal latency from the `HealReport`.
//!
//! ```sh
//! cargo bench --bench wire
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{
    BootFaults, DataPlaneOptions, FastPath, HealPolicy, MachineSpec, SpiNNTools,
    SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::{ChipCoord, MachineBuilder};
use spinntools::simulator::{
    scamp, ChaosPlan, Fault, SimConfig, SimMachine, WireFaults, WireStats,
};
use spinntools::util::json::Json;
use spinntools::util::SplitMix64;

const SEED: u64 = 0xE16;
const ROWS: u32 = 6;
const TICKS: u64 = 6;

fn pattern(len: usize, seed: u64) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

fn picker() -> impl FnMut(ChipCoord) -> Option<u8> {
    let mut used: BTreeMap<ChipCoord, u8> = BTreeMap::new();
    move |chip| {
        let next = used.entry(chip).or_insert(17);
        let c = *next;
        *next -= 1;
        Some(c)
    }
}

/// SCAMP + bulk-plane transfers at one loss level; returns the JSON row
/// plus (scamp virtual ns, bulk virtual ns).
fn transfer_row(loss_permille: u16) -> (BTreeMap<String, Json>, u64, u64) {
    let faults = if loss_permille == 0 {
        WireFaults::none()
    } else {
        WireFaults::lossy(SEED, loss_permille)
    };
    let mut config = SimConfig::default();
    config.wire.faults = faults;
    let mut sim = SimMachine::boot(MachineBuilder::spinn5().build(), config);
    let chip = (4, 4);
    let data = pattern(64 * 1024, SEED);

    let t0 = sim.now_ns();
    let a = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
    scamp::write_sdram(&mut sim, chip, a, &data).unwrap();
    let scamp_back = scamp::read_sdram(&mut sim, chip, a, data.len()).unwrap();
    let scamp_ns = sim.now_ns() - t0;
    assert_eq!(scamp_back, data, "SCAMP image diverged at {loss_permille} permille");

    let fp = FastPath::install(&mut sim, &[chip], picker(), &DataPlaneOptions::default())
        .unwrap();
    scamp::signal_start(&mut sim).unwrap();
    let bulk = pattern(256 * 1024, SEED ^ 1);
    let b = scamp::alloc_sdram(&mut sim, chip, bulk.len() as u32).unwrap();
    let t0 = sim.now_ns();
    fp.write(&mut sim, chip, b, &bulk).unwrap();
    let back = fp.read(&mut sim, chip, b, bulk.len()).unwrap();
    let bulk_ns = sim.now_ns() - t0;
    assert_eq!(back, bulk, "bulk image diverged at {loss_permille} permille");

    let stats = sim.wire_stats();
    if loss_permille == 0 {
        assert_eq!(
            stats,
            WireStats::default(),
            "the lossless wire must record zero transport work"
        );
    }
    let mut row = BTreeMap::new();
    row.insert("loss_permille".into(), Json::Num(loss_permille as f64));
    row.insert("scamp_virtual_ns".into(), Json::Num(scamp_ns as f64));
    row.insert("bulk_virtual_ns".into(), Json::Num(bulk_ns as f64));
    row.insert("scp_retries".into(), Json::Num(stats.scp_retries as f64));
    row.insert("scp_timeouts".into(), Json::Num(stats.scp_timeouts as f64));
    row.insert("frames_lost".into(), Json::Num(stats.frames_lost as f64));
    row.insert("backoff_wait_ns".into(), Json::Num(stats.backoff_wait_ns as f64));
    (row, scamp_ns, bulk_ns)
}

/// Build the Conway grid (same shape as `tests/wire.rs`).
fn build_grid(tools: &mut SpiNNTools) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r * 31 + c * 17) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..ROWS {
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < ROWS as i64)
            .then_some((r * ROWS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..ROWS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) != (0, 0) {
                        if let Some(n) = idx(r + dr, c + dc) {
                            tools
                                .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                                .unwrap();
                        }
                    }
                }
            }
        }
    }
    ids
}

/// A whole workload at one loss level: (recordings, wall ms, stats).
fn workload_row(loss_permille: u16) -> (Vec<Vec<u8>>, f64, WireStats) {
    let faults = if loss_permille == 0 {
        WireFaults::none()
    } else {
        WireFaults::lossy(SEED, loss_permille)
    };
    let t = Instant::now();
    let mut tools =
        SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5).with_wire_faults(faults)).unwrap();
    let ids = build_grid(&mut tools);
    tools.run_ticks(TICKS).unwrap();
    let wall_ms = t.elapsed().as_secs_f64() * 1e3;
    let recs = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();
    (recs, wall_ms, tools.provenance().wire)
}

fn main() -> anyhow::Result<()> {
    println!("# E16: reliable transport over an unreliable wire");
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("E16_unreliable_wire".to_string()));

    // ---- retry overhead at 0 / 10 / 50 permille loss -------------------
    let mut rows = Vec::new();
    let mut base = (0u64, 0u64);
    for loss in [0u16, 10, 50] {
        let (mut row, scamp_ns, bulk_ns) = transfer_row(loss);
        if loss == 0 {
            base = (scamp_ns, bulk_ns);
        }
        let scamp_ratio = scamp_ns as f64 / base.0.max(1) as f64;
        let bulk_ratio = bulk_ns as f64 / base.1.max(1) as f64;
        row.insert("scamp_overhead_ratio".into(), Json::Num(scamp_ratio));
        row.insert("bulk_overhead_ratio".into(), Json::Num(bulk_ratio));
        println!(
            "loss {loss:>2} permille: scamp x{scamp_ratio:.3}, bulk x{bulk_ratio:.3} \
             simulated-time overhead"
        );
        rows.push(Json::Obj(row));
    }
    root.insert("transfer_rows".to_string(), Json::Arr(rows));

    // ---- whole workload at the same loss levels ------------------------
    let mut rows = Vec::new();
    let mut reference: Option<Vec<Vec<u8>>> = None;
    for loss in [0u16, 10, 50] {
        let (recs, wall_ms, stats) = workload_row(loss);
        match &reference {
            None => {
                assert_eq!(stats, WireStats::default());
                reference = Some(recs);
            }
            Some(r) => assert_eq!(
                &recs, r,
                "workload diverged from the lossless twin at {loss} permille"
            ),
        }
        println!(
            "workload at {loss:>2} permille: {wall_ms:.1} ms wall, {} retries, {} frames lost",
            stats.scp_retries, stats.frames_lost
        );
        let mut row = BTreeMap::new();
        row.insert("loss_permille".into(), Json::Num(loss as f64));
        row.insert("wall_ms".into(), Json::Num(wall_ms));
        row.insert("scp_retries".into(), Json::Num(stats.scp_retries as f64));
        row.insert("frames_lost".into(), Json::Num(stats.frames_lost as f64));
        row.insert("byte_identical".into(), Json::Bool(true));
        rows.push(Json::Obj(row));
    }
    root.insert("workload_rows".to_string(), Json::Arr(rows));

    // ---- escalation latency: silence -> error --------------------------
    let mut sim = SimMachine::boot(MachineBuilder::spinn5().build(), SimConfig::default());
    sim.apply_fault(Fault::BoardSilent { board: (0, 0), duration_ns: u64::MAX })?;
    let t0 = sim.now_ns();
    let err = scamp::read_sdram(&mut sim, (2, 2), 0x6000_0000, 64)
        .expect_err("silent board must escalate");
    let escalate_ns = sim.now_ns() - t0;
    assert!(err.to_string().contains("escalated"));
    println!(
        "silence -> escalation: {:.3} ms virtual ({} timeouts)",
        escalate_ns as f64 / 1e6,
        sim.wire_stats().scp_timeouts
    );
    root.insert("escalation_virtual_ns".to_string(), Json::Num(escalate_ns as f64));
    root.insert(
        "escalation_timeouts".to_string(),
        Json::Num(sim.wire_stats().scp_timeouts as f64),
    );

    // ---- escalation -> heal: a board dies under a supervised run -------
    let spec = MachineSpec::Boards(3);
    let template = spec.template();
    let boards: Vec<ChipCoord> = template.ethernet_chips().map(|c| (c.x, c.y)).collect();
    let root_board = boards[0];
    let banished: Vec<ChipCoord> = template
        .chip_coords()
        .filter(|c| template.nearest_ethernet(*c) == Some(root_board) && *c != root_board)
        .collect();
    let boot = BootFaults { chips: banished, ..Default::default() };
    let supervision = SupervisorConfig {
        poll_interval_ticks: 1,
        policy: HealPolicy::Remap,
        max_heals: 4,
    };
    // Probe for a used non-root board.
    let dark = {
        let mut probe =
            SpiNNTools::new(ToolsConfig::new(spec).with_boot_faults(boot.clone())).unwrap();
        let ids = build_grid(&mut probe);
        probe.run_ticks(1).unwrap();
        let mapping = probe.mapping().unwrap();
        ids.iter()
            .filter_map(|v| mapping.placement(*v))
            .filter_map(|loc| template.nearest_ethernet(loc.chip()))
            .find(|b| *b != root_board)
            .expect("workload spans a non-root board")
    };
    let t = Instant::now();
    let mut tools = SpiNNTools::new(
        ToolsConfig::new(spec)
            .with_boot_faults(boot)
            .with_supervision(supervision),
    )
    .unwrap();
    build_grid(&mut tools);
    tools.inject_chaos(
        ChaosPlan::new().with(2, Fault::BoardSilent { board: dark, duration_ns: u64::MAX }),
    );
    tools.run_ticks(TICKS)?;
    let run_ms = t.elapsed().as_secs_f64() * 1e3;
    let heals = tools.heal_reports();
    assert_eq!(heals.len(), 1, "expected exactly one heal");
    let heal = &heals[0];
    println!(
        "silent board {dark:?}: healed in {:.1} ms ({} vertices moved, whole run {run_ms:.1} ms)",
        heal.heal_elapsed_us as f64 / 1e3,
        heal.vertices_moved
    );
    root.insert("heal_elapsed_us".to_string(), Json::Num(heal.heal_elapsed_us as f64));
    root.insert("heal_map_us".to_string(), Json::Num(heal.map_elapsed_us as f64));
    root.insert("heal_vertices_moved".to_string(), Json::Num(heal.vertices_moved as f64));
    root.insert("heal_run_wall_ms".to_string(), Json::Num(run_ms));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_wire.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
