//! Experiment E10 — §6.7 routing-table compression (Mundy et al. 2016).
//!
//! Regenerates the order-exploiting minimization result: tables that
//! overflow the 1024-entry TCAM compress to fit. Three workload shapes:
//! single-key entries from a large SNN fan-in, aligned power-of-two
//! blocks (the allocator's native output), and adversarial interleaved
//! routes.
//!
//! ```sh
//! cargo bench --bench compression
//! ```

use std::time::Instant;

use spinntools::machine::router::{Route, RoutingEntry, RoutingTable};
use spinntools::machine::Direction;
use spinntools::mapping::compress::compress_with_stats;
use spinntools::util::SplitMix64;

fn route(i: u64) -> Route {
    // A plausible route word: 1-2 links + 0-2 processors.
    let mut r = Route::EMPTY.with_link(match i % 6 {
        0 => Direction::East,
        1 => Direction::NorthEast,
        2 => Direction::North,
        3 => Direction::West,
        4 => Direction::SouthWest,
        _ => Direction::South,
    });
    if i % 3 == 0 {
        r.add_processor((i % 17) as u8 + 1);
    }
    r
}

fn bench(name: &str, table: RoutingTable) {
    let t = Instant::now();
    let (compressed, stats) = compress_with_stats(&table);
    let dt = t.elapsed();
    println!(
        "{:<26} {:>8} {:>8} {:>7.3} {:>6} {:>10.2?}",
        name,
        stats.before,
        stats.after,
        stats.ratio(),
        if compressed.fits() { "yes" } else { "NO" },
        dt,
    );
}

fn main() {
    println!("# E10: order-exploiting routing table minimization");
    println!(
        "{:<26} {:>8} {:>8} {:>7} {:>6} {:>10}",
        "workload", "before", "after", "ratio", "fits", "time"
    );

    // 1. SNN fan-in: thousands of single-key entries, few distinct
    //    routes, arriving in contiguous runs (population slices placed
    //    near each other route the same way) — the structure the
    //    order-exploiting minimizer exploits on real tables.
    let mut rng = SplitMix64::new(42);
    for n in [512usize, 2048, 4096] {
        let mut entries = Vec::new();
        let mut base = 0u32;
        while entries.len() < n {
            let run = 16 + rng.below(112);
            let r = route(rng.next_u64() % 4);
            for _ in 0..run.min(n - entries.len()) {
                entries.push(RoutingEntry::new(base, !0, r));
                base += 1;
            }
        }
        bench(&format!("snn_fanin_{n}_4routes"), RoutingTable::from_entries(entries));
    }

    // 2. Allocator-native: aligned power-of-two blocks per partition.
    for n_parts in [256usize, 1024, 2048] {
        let mut entries = Vec::new();
        let mut cursor = 0u32;
        let mut rng = SplitMix64::new(7);
        for _ in 0..n_parts {
            let block = 1u32 << (rng.below(6) + 1);
            cursor = cursor.div_ceil(block) * block;
            entries.push(RoutingEntry::new(cursor, !(block - 1), route(rng.next_u64() % 6)));
            cursor += block;
        }
        bench(
            &format!("aligned_blocks_{n_parts}_6routes"),
            RoutingTable::from_entries(entries),
        );
    }

    // 3. Adversarial: alternating routes on adjacent keys (little to merge).
    let mut entries = Vec::new();
    for k in 0..1500u32 {
        entries.push(RoutingEntry::new(
            k,
            !0,
            if k % 2 == 0 {
                Route::EMPTY.with_link(Direction::East)
            } else {
                Route::EMPTY.with_link(Direction::North)
            },
        ));
    }
    bench("interleaved_1500_2routes", RoutingTable::from_entries(entries));

    // 4. Conway-style: every chip entry already unique route -> near-
    //    incompressible but small.
    let mut entries = Vec::new();
    for k in 0..300u32 {
        entries.push(RoutingEntry::new(k * 4, !3, route(k as u64)));
    }
    bench("conway_like_300", RoutingTable::from_entries(entries));

    // 5. Whole-machine sharded minimisation: 64 oversubscribed per-chip
    //    tables compressed on the §6.3.2 worker pool. Per-chip tables
    //    are independent, so this is the compression half of the E9
    //    parallel-mapping experiment in isolation.
    println!("\n# sharded whole-machine compression (64 SNN-shaped tables)");
    println!("{:>8} {:>12} {:>8}", "threads", "wall", "speedup");
    let tables: Vec<RoutingTable> = (0..64u64)
        .map(|chip| {
            let mut rng = SplitMix64::new(0x600D + chip);
            let mut entries = Vec::new();
            let mut base = 0u32;
            while entries.len() < 2048 {
                let run = 16 + rng.below(112);
                let r = route(rng.next_u64() % 4);
                for _ in 0..run.min(2048 - entries.len()) {
                    entries.push(RoutingEntry::new(base, !0, r));
                    base += 1;
                }
            }
            RoutingTable::from_entries(entries)
        })
        .collect();
    let mut serial_ms = 0.0f64;
    let mut serial_sizes: Vec<usize> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let t = Instant::now();
        let done = spinntools::util::par::par_map(threads, &tables, |_, table| {
            compress_with_stats(table).0
        });
        let wall = t.elapsed().as_secs_f64() * 1e3;
        let sizes: Vec<usize> = done.iter().map(|t| t.len()).collect();
        if threads == 1 {
            serial_ms = wall;
            serial_sizes = sizes;
            println!("{:>8} {:>10.1}ms {:>8}", threads, wall, "1.00x");
        } else {
            assert_eq!(serial_sizes, sizes, "sharded compression diverged");
            println!("{:>8} {:>10.1}ms {:>7.2}x", threads, wall, serial_ms / wall);
        }
    }

    println!("\n# headline: oversubscribed SNN tables fit the 1024-entry TCAM after compression");
}
