//! Experiment E11 — §6.10 dropped-packet reinjection.
//!
//! Under increasing congestion, compares delivered-packet fractions with
//! the reinjector on vs off, and counts the unrecoverable losses from
//! the single hardware dropped-packet register.
//!
//! ```sh
//! cargo bench --bench reinjection
//! ```

use spinntools::machine::router::{Route, RoutingEntry, RoutingTable};
use spinntools::machine::{CoreLocation, Direction, MachineBuilder};
use spinntools::simulator::{scamp, CoreApp, CoreCtx, SimConfig, SimMachine};

/// Sends `burst` packets per tick, all over the same link.
struct Burster {
    key: u32,
    burst: u32,
}

impl CoreApp for Burster {
    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        for _ in 0..self.burst {
            ctx.send_mc(self.key, None);
        }
        Ok(())
    }
}

#[derive(Default)]
struct Counter {
    received: std::rc::Rc<std::cell::Cell<u64>>,
}

impl CoreApp for Counter {
    fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }
    fn on_mc_packet(&mut self, _k: u32, _p: Option<u32>, _c: &mut CoreCtx) -> anyhow::Result<()> {
        self.received.set(self.received.get() + 1);
        Ok(())
    }
}

fn run(burst: u32, senders: u8, reinjection: bool) -> anyhow::Result<(u64, u64, u64, u64)> {
    let machine = MachineBuilder::spinn3().build();
    let mut config = SimConfig::default();
    // Congested regime: short patience, bursty cores.
    config.drop_wait_ns = 2_000;
    config.send_spacing_ns = 0;
    config.link_queue_depth = 4;
    config.reinjection = reinjection;
    let mut sim = SimMachine::boot(machine, config);
    scamp::load_routing_table(
        &mut sim,
        (0, 0),
        RoutingTable::from_entries(vec![RoutingEntry::new(
            0,
            0,
            Route::EMPTY.with_link(Direction::East),
        )]),
    )?;
    scamp::load_routing_table(
        &mut sim,
        (1, 0),
        RoutingTable::from_entries(vec![RoutingEntry::new(
            0,
            0,
            Route::EMPTY.with_processor(1),
        )]),
    )?;
    let received = std::rc::Rc::new(std::cell::Cell::new(0));
    scamp::load_app(
        &mut sim,
        CoreLocation::new(1, 0, 1),
        Box::new(Counter { received: received.clone() }),
        Default::default(),
        Default::default(),
    )?;
    for p in 1..=senders {
        scamp::load_app(
            &mut sim,
            CoreLocation::new(0, 0, p),
            Box::new(Burster { key: p as u32, burst }),
            Default::default(),
            Default::default(),
        )?;
    }
    scamp::signal_start(&mut sim)?;
    let ticks = 10;
    sim.start_run_cycle(ticks);
    sim.run_until_idle()?;
    let sent = burst as u64 * senders as u64 * ticks;
    let stats = sim.router_stats((0, 0)).unwrap();
    Ok((sent, received.get(), stats.mc_reinjected, stats.mc_lost_forever))
}

fn main() -> anyhow::Result<()> {
    println!("# E11: dropped-packet reinjection under congestion");
    println!(
        "{:<8} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "burst", "senders", "sent", "delivered", "reinject", "lost", "delivered%"
    );
    for reinjection in [true, false] {
        println!("## reinjection {}", if reinjection { "ON" } else { "OFF" });
        for (burst, senders) in [(4u32, 4u8), (8, 8), (16, 8), (32, 16)] {
            let (sent, delivered, reinjected, lost) = run(burst, senders, reinjection)?;
            println!(
                "{:<8} {:>8} {:>10} {:>10} {:>10} {:>10} {:>9.1}%",
                burst,
                senders,
                sent,
                delivered,
                reinjected,
                lost,
                delivered as f64 / sent as f64 * 100.0
            );
            if reinjection {
                // §6.10 invariant: every packet is delivered or counted
                // as unrecoverable — nothing vanishes silently.
                assert_eq!(delivered + lost, sent, "silent packet loss");
            }
        }
    }
    println!("\n# shape: reinjection recovers register-held drops; only");
    println!("# second-drops-while-occupied are lost (and are reported).");
    Ok(())
}
