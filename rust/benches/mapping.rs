//! Experiment E9 — §6.3.2 mapping-phase scaling.
//!
//! §1: "the time taken to execute this mapping is critical; if it takes
//! too long, it will dwarf the computational execution time of the
//! problem itself." This bench measures host wall-clock for each
//! mapping phase (split, place, route, keys, tables, compress) as the
//! graph and machine grow.
//!
//! ```sh
//! cargo bench --bench mapping
//! ```

use std::time::Instant;

use spinntools::graph::MachineGraph;
use spinntools::machine::{Machine, MachineBuilder};
use spinntools::mapping::{self, MappingConfig};

/// A Conway-style grid graph of cells directly as machine vertices.
fn grid_graph(rows: u32, cols: u32) -> MachineGraph {
    use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
    let mut g = MachineGraph::new();
    let mut ids = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            ids.push(g.add_vertex(ConwayCellVertex::arc(r, c, (r + c) % 3 == 0)));
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < rows as i64 && c < cols as i64)
            .then_some((r * cols as i64 + c) as usize)
    };
    for r in 0..rows as i64 {
        for c in 0..cols as i64 {
            for dr in -1..=1i64 {
                for dc in -1..=1i64 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        g.add_edge(
                            spinntools::graph::VertexId(idx(r, c).unwrap() as u32),
                            spinntools::graph::VertexId(n as u32),
                            STATE_PARTITION,
                        );
                    }
                }
            }
        }
    }
    g
}

fn bench_one(name: &str, machine: &Machine, graph: &MachineGraph) -> anyhow::Result<()> {
    let config = MappingConfig::default();

    let t = Instant::now();
    let placements = mapping::placer::place(machine, graph)?;
    let t_place = t.elapsed();

    let t = Instant::now();
    let forest = mapping::router::route(machine, graph, &placements)?;
    let t_route = t.elapsed();

    let t = Instant::now();
    let keys = mapping::keys::allocate_keys(graph)?;
    let t_keys = t.elapsed();

    let t = Instant::now();
    let tables = mapping::tables::build_tables(machine, graph, &forest, &keys, &config)?;
    let t_tables = t.elapsed();

    let total_entries: usize = tables.values().map(|t| t.len()).sum();
    let max_entries = tables.values().map(|t| t.len()).max().unwrap_or(0);

    println!(
        "{:<16} {:>8} {:>8} {:>10.2?} {:>10.2?} {:>10.2?} {:>10.2?} {:>8} {:>8}",
        name,
        graph.n_vertices(),
        graph.n_edges(),
        t_place,
        t_route,
        t_keys,
        t_tables,
        total_entries,
        max_entries,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("# E9: mapping phase wall-clock scaling (Conway grids, one cell/core)");
    println!(
        "{:<16} {:>8} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "workload", "verts", "edges", "place", "route", "keys", "tables", "entries", "max/chip"
    );

    // One board: growing grids.
    let spinn5 = MachineBuilder::spinn5().build();
    for side in [8u32, 16, 24, 28] {
        bench_one(&format!("spinn5/{side}x{side}"), &spinn5, &grid_graph(side, side))?;
    }
    // Multi-board machines: a full-ish machine per size.
    for boards in [3u32, 12] {
        let machine = MachineBuilder::boards(boards).build();
        // ~60% of application cores.
        let cores = (machine.n_application_cores() as f64 * 0.6) as u32;
        let side = (cores as f64).sqrt() as u32;
        bench_one(
            &format!("{boards}boards/{side}x{side}"),
            &machine,
            &grid_graph(side, side),
        )?;
    }

    // §6.3.1 sizing: application-graph split cost.
    println!("\n# application graph splitting (LIF populations)");
    let t = Instant::now();
    let mut app = spinntools::graph::ApplicationGraph::new();
    use spinntools::apps::neuron::{LifParams, LifPopulationVertex};
    for i in 0..64 {
        app.add_vertex(LifPopulationVertex::arc(
            &format!("pop{i}"),
            1000,
            LifParams::default(),
            false,
        ));
    }
    let (mg, _) = mapping::splitter::split_graph(&app, &spinn5)?;
    println!(
        "split 64 populations x 1000 atoms -> {} machine vertices in {:.2?}",
        mg.n_vertices(),
        t.elapsed()
    );
    Ok(())
}
