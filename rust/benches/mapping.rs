//! Experiment E9 — §6.3.2 mapping-phase scaling, serial *and* sharded.
//!
//! §1: "the time taken to execute this mapping is critical; if it takes
//! too long, it will dwarf the computational execution time of the
//! problem itself." This bench measures host wall-clock for the
//! shardable mapping phases (NER routing, table generation,
//! ordered-covering compression) on a 576-chip (12-board) virtual
//! machine at 1/2/4/8 worker threads, for the paper's two workload
//! shapes (§7.1 Conway grid, §7.2 microcircuit), and records the results
//! to `BENCH_mapping.json` at the repository root.
//!
//! The compression phase runs the ordered-covering pass over *every*
//! generated table (offline whole-machine minimisation, Mundy et al.
//! 2016) so the phase has real work even when no single table
//! oversubscribes its TCAM.
//!
//! ```sh
//! cargo bench --bench mapping
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use spinntools::apps::networks::{conway_machine_graph, microcircuit_machine_graph};
use spinntools::graph::MachineGraph;
use spinntools::machine::{Machine, MachineBuilder};
use spinntools::mapping::{compress, keys, placer, router, tables, MappingConfig, MappingOptions};
use spinntools::util::json::Json;
use spinntools::util::par;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

struct PhaseTimes {
    threads: usize,
    route_ms: f64,
    tables_ms: f64,
    compress_ms: f64,
    /// Summary of the outputs, compared across thread counts as a
    /// cheap determinism guard (the test suite does the strict one).
    summary: (usize, usize, usize),
}

impl PhaseTimes {
    fn tables_plus_compress_ms(&self) -> f64 {
        self.tables_ms + self.compress_ms
    }
}

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

fn run_once(
    machine: &Machine,
    graph: &MachineGraph,
    placements: &placer::Placements,
    key_map: &BTreeMap<(spinntools::graph::VertexId, String), spinntools::graph::KeyRange>,
    threads: usize,
) -> anyhow::Result<PhaseTimes> {
    let config = MappingConfig {
        options: MappingOptions::with_threads(threads),
        ..Default::default()
    };

    let t = Instant::now();
    let forest = router::route_sharded(machine, graph, placements, threads)?;
    let route_ms = ms(t);

    let t = Instant::now();
    let built = tables::build_tables(machine, graph, &forest, key_map, &config)?;
    let tables_ms = ms(t);

    // Offline whole-machine minimisation: compress every table.
    let inputs: Vec<_> = built.values().collect();
    let t = Instant::now();
    let compressed = par::par_map(threads, &inputs, |_, table| compress::compress(table));
    let compress_ms = ms(t);

    let total_links: usize = forest.trees.values().map(|tr| tr.n_links()).sum();
    let entries_before: usize = built.values().map(|t| t.len()).sum();
    let entries_after: usize = compressed.iter().map(|t| t.len()).sum();
    Ok(PhaseTimes {
        threads,
        route_ms,
        tables_ms,
        compress_ms,
        summary: (total_links, entries_before, entries_after),
    })
}

fn bench_workload(
    name: &str,
    machine: &Machine,
    graph: &MachineGraph,
) -> anyhow::Result<Json> {
    // Place + key once: both phases are serial and shared by every run.
    let placements = placer::place(machine, graph)?;
    let key_map = keys::allocate_keys(graph)?;

    println!(
        "\n## {name}: {} vertices, {} edges, {} partitions",
        graph.n_vertices(),
        graph.n_edges(),
        graph.n_partitions()
    );
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>14}",
        "threads", "route", "tables", "compress", "tables+comp"
    );

    let mut runs = Vec::new();
    for threads in THREAD_SWEEP {
        let r = run_once(machine, graph, &placements, &key_map, threads)?;
        println!(
            "{:>8} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>12.1}ms",
            r.threads,
            r.route_ms,
            r.tables_ms,
            r.compress_ms,
            r.tables_plus_compress_ms()
        );
        runs.push(r);
    }

    let deterministic = runs.iter().all(|r| r.summary == runs[0].summary);
    let serial = &runs[0];
    let best_tc = runs
        .iter()
        .skip(1)
        .map(|r| r.tables_plus_compress_ms())
        .fold(f64::INFINITY, f64::min);
    let best_route = runs
        .iter()
        .skip(1)
        .map(|r| r.route_ms)
        .fold(f64::INFINITY, f64::min);
    // .max(1e-6): keep the ratio finite even if a phase rounds to 0 ms.
    let tc_speedup = serial.tables_plus_compress_ms() / best_tc.max(1e-6);
    let route_speedup = serial.route_ms / best_route.max(1e-6);
    println!(
        "   best multi-thread speedup: route {route_speedup:.2}x, tables+compress {tc_speedup:.2}x \
         | outputs identical across widths: {deterministic}"
    );
    if tc_speedup <= 1.0 {
        println!("   WARNING: multi-threaded tables+compress not faster than serial on this host");
    }

    let mut obj = BTreeMap::new();
    obj.insert("name".to_string(), Json::Str(name.to_string()));
    obj.insert("vertices".to_string(), Json::Num(graph.n_vertices() as f64));
    obj.insert("edges".to_string(), Json::Num(graph.n_edges() as f64));
    obj.insert("partitions".to_string(), Json::Num(graph.n_partitions() as f64));
    obj.insert(
        "table_entries_before_compression".to_string(),
        Json::Num(serial.summary.1 as f64),
    );
    obj.insert(
        "table_entries_after_compression".to_string(),
        Json::Num(serial.summary.2 as f64),
    );
    obj.insert(
        "runs".to_string(),
        Json::Arr(
            runs.iter()
                .map(|r| {
                    let mut run = BTreeMap::new();
                    run.insert("threads".to_string(), Json::Num(r.threads as f64));
                    run.insert("route_ms".to_string(), Json::Num(r.route_ms));
                    run.insert("tables_ms".to_string(), Json::Num(r.tables_ms));
                    run.insert("compress_ms".to_string(), Json::Num(r.compress_ms));
                    run.insert(
                        "tables_plus_compress_ms".to_string(),
                        Json::Num(r.tables_plus_compress_ms()),
                    );
                    Json::Obj(run)
                })
                .collect(),
        ),
    );
    obj.insert("route_speedup_best".to_string(), Json::Num(route_speedup));
    obj.insert(
        "tables_plus_compress_speedup_best".to_string(),
        Json::Num(tc_speedup),
    );
    obj.insert(
        "multithreaded_strictly_better".to_string(),
        Json::Bool(tc_speedup > 1.0),
    );
    obj.insert("deterministic_summary".to_string(), Json::Bool(deterministic));
    Ok(Json::Obj(obj))
}

fn main() -> anyhow::Result<()> {
    println!("# E9: sharded mapping back-end on a 576-chip (12-board) virtual machine");
    let machine = MachineBuilder::boards(12).build();
    assert_eq!(machine.n_chips(), 576, "expected the 24x24 triad torus");
    println!(
        "machine: {}x{} torus, {} chips, {} application cores, {} hardware threads here",
        machine.width,
        machine.height,
        machine.n_chips(),
        machine.n_application_cores(),
        par::effective_threads(0)
    );

    // §7.1: one Conway cell per core over ~80% of the machine.
    let conway = conway_machine_graph(88, 88, |r, c| (r + c) % 3 == 0);
    // §7.2: the full-scale Potjans–Diesmann microcircuit.
    let micro = microcircuit_machine_graph(&machine, 1.0, 0xE9)?;

    let workloads = vec![
        bench_workload("conway_88x88", &machine, &conway)?,
        bench_workload("microcircuit_full", &machine, &micro)?,
    ];

    let mut root = BTreeMap::new();
    root.insert(
        "experiment".to_string(),
        Json::Str("E9_parallel_sharded_mapping".to_string()),
    );
    root.insert("machine_chips".to_string(), Json::Num(machine.n_chips() as f64));
    root.insert("machine_boards".to_string(), Json::Num(12.0));
    root.insert(
        "host_hardware_threads".to_string(),
        Json::Num(par::effective_threads(0) as f64),
    );
    root.insert("thread_sweep".to_string(), Json::Arr(
        THREAD_SWEEP.iter().map(|t| Json::Num(*t as f64)).collect(),
    ));
    root.insert("workloads".to_string(), Json::Arr(workloads));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_mapping.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
