//! Experiment E15 — checkpoint/restore: heal-from-snapshot vs tick-0
//! replay (DESIGN.md §9).
//!
//! A supervised Conway workload on the 576-chip (12-board) virtual
//! machine loses a chip near the end of its run. With
//! [`ToolsConfig::checkpoint`] set, the supervisor restores the newest
//! run snapshot and replays only the short tail after it; without it,
//! the heal restarts the whole history from tick 0. This bench measures
//! the *recovery cost* — faulted-run wall time minus the matching
//! clean-run wall time — in three configurations:
//!
//! 1. checkpointed, short run (`T1` ticks, fault near the end);
//! 2. checkpointed, 4x run (`T2 = 4*T1` ticks, same-length tail) —
//!    recovery must stay flat, i.e. independent of elapsed ticks;
//! 3. un-checkpointed, 4x run — the tick-0 replay the snapshot path is
//!    measured against, target ≥ 2x slower than (2).
//!
//! Correctness ride-along: the checkpointed and un-checkpointed healed
//! runs must produce byte-identical recordings (FNV digests) — restore
//! plus tail-replay is equivalent to full replay. Results land in
//! `BENCH_checkpoint.json` at the repository root.
//!
//! ```sh
//! cargo bench --bench checkpoint
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{
    CheckpointConfig, HealPolicy, MachineSpec, SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::simulator::{ChaosPlan, Fault};
use spinntools::util::fnv1a_64;
use spinntools::util::json::Json;

const ROWS: u32 = 88;
const COLS: u32 = 88;
const BOARDS: u32 = 12;

/// Short run length; the long run is `4 * T1`. Both faults strike
/// `TAIL` ticks before the end so the snapshot path replays the same
/// tail at either length.
const T1: u64 = 8;
const T2: u64 = 4 * T1;
const TAIL: u64 = 2;

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// The E9/E13/E14 Conway workload, built through the tools API.
fn build_grid(tools: &mut SpiNNTools) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r + c) % 3 == 0;
    let mut ids = Vec::new();
    let mut map = BTreeMap::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            let id = tools
                .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                .unwrap();
            map.insert((r, c), id);
            ids.push(id);
        }
    }
    for (&(r, c), &id) in &map {
        for dr in -1..=1i64 {
            for dc in -1..=1i64 {
                if (dr, dc) == (0, 0) {
                    continue;
                }
                let (nr, nc) = (r as i64 + dr, c as i64 + dc);
                if nr >= 0 && nc >= 0 && (nr as u32) < ROWS && (nc as u32) < COLS {
                    tools
                        .add_machine_edge(id, map[&(nr as u32, nc as u32)], STATE_PARTITION)
                        .unwrap();
                }
            }
        }
    }
    ids
}

fn config(checkpointed: bool) -> ToolsConfig {
    let base = ToolsConfig::new(MachineSpec::Boards(BOARDS)).with_supervision(SupervisorConfig {
        poll_interval_ticks: TAIL,
        policy: HealPolicy::Remap,
        max_heals: 4,
    });
    if checkpointed {
        base.with_checkpoint(CheckpointConfig { interval_ticks: TAIL, keep: 2 })
    } else {
        base
    }
}

/// One timed run: build, optionally schedule a chip death, run `ticks`.
/// Returns (wall ms, recording digest, restored_from_tick of the first
/// heal if any heal happened).
fn timed_run(
    checkpointed: bool,
    fault: Option<(u64, spinntools::machine::ChipCoord)>,
    ticks: u64,
) -> (f64, u64, Option<Option<u64>>) {
    let mut tools = SpiNNTools::new(config(checkpointed)).unwrap();
    let ids = build_grid(&mut tools);
    if let Some((at, chip)) = fault {
        tools.inject_chaos(ChaosPlan::new().with(at, Fault::ChipDeath(chip)));
    }
    let t = Instant::now();
    tools.run_ticks(ticks).unwrap();
    let elapsed = ms(t);
    let mut digest = 0u64;
    for (i, id) in ids.iter().enumerate() {
        digest ^= fnv1a_64(tools.recording(*id)).rotate_left((i % 61) as u32);
    }
    let restored = tools
        .heal_reports()
        .first()
        .map(|r| r.restored_from_tick);
    if fault.is_some() {
        assert_eq!(tools.heal_reports().len(), 1, "exactly one heal expected");
    } else {
        assert!(tools.heal_reports().is_empty(), "clean run must not heal");
    }
    (elapsed, digest, restored)
}

fn main() -> anyhow::Result<()> {
    println!(
        "# E15: heal-from-snapshot vs tick-0 replay on a {}-chip ({BOARDS}-board) machine",
        MachineSpec::Boards(BOARDS).template().n_chips()
    );
    let machine = MachineSpec::Boards(BOARDS).template();
    assert_eq!(machine.n_chips(), 576);

    // Probe run: find a non-Ethernet chip the workload occupies (the
    // mapping is deterministic, so the victim is stable across runs).
    let mut probe = SpiNNTools::new(ToolsConfig::new(MachineSpec::Boards(BOARDS))).unwrap();
    let pids = build_grid(&mut probe);
    probe.run_ticks(1).unwrap();
    let victim = pids
        .iter()
        .map(|v| probe.mapping().unwrap().placement(*v).unwrap().chip())
        .find(|c| !machine.chip(*c).unwrap().is_ethernet())
        .expect("workload spans more than the Ethernet chips");
    drop(probe);
    println!(
        "workload: {ROWS}x{COLS} Conway ({} vertices); victim chip {victim:?}",
        ROWS * COLS
    );

    // Clean baselines, one per configuration, so the faulted runs can
    // be reduced to pure recovery cost (the checkpointed baselines also
    // absorb the steady-state capture overhead).
    let (clean_short_ckpt, _, _) = timed_run(true, None, T1);
    println!("clean {T1}-tick run, checkpointed:    {clean_short_ckpt:.1} ms");
    let (clean_long_ckpt, _, _) = timed_run(true, None, T2);
    println!("clean {T2}-tick run, checkpointed:   {clean_long_ckpt:.1} ms");
    let (clean_long_plain, _, _) = timed_run(false, None, T2);
    println!("clean {T2}-tick run, no checkpoint:  {clean_long_plain:.1} ms");

    // Faulted runs: the chip dies TAIL ticks before the end.
    let (faulted_short_ckpt, _, restored_short) = timed_run(true, Some((T1 - TAIL, victim)), T1);
    println!("faulted {T1}-tick run, checkpointed:  {faulted_short_ckpt:.1} ms");
    let (faulted_long_ckpt, digest_ckpt, restored_long) =
        timed_run(true, Some((T2 - TAIL, victim)), T2);
    println!("faulted {T2}-tick run, checkpointed: {faulted_long_ckpt:.1} ms");
    let (faulted_long_plain, digest_plain, restored_plain) =
        timed_run(false, Some((T2 - TAIL, victim)), T2);
    println!("faulted {T2}-tick run, no checkpoint: {faulted_long_plain:.1} ms");

    // The snapshot path restored from the tick the fault struck at
    // (captured on the clean poll just before), at either run length;
    // the plain path replayed from tick 0.
    assert_eq!(restored_short, Some(Some(T1 - TAIL)), "short heal missed its snapshot");
    assert_eq!(restored_long, Some(Some(T2 - TAIL)), "long heal missed its snapshot");
    assert_eq!(restored_plain, Some(None), "un-checkpointed heal cannot restore");

    // Correctness: restore + tail-replay must be byte-identical to the
    // full tick-0 replay of the same faulted run.
    assert_eq!(
        digest_ckpt, digest_plain,
        "checkpointed heal diverged from the tick-0-replay heal"
    );
    println!("recordings: checkpointed heal EQUAL to tick-0-replay heal");

    let recovery_short = (faulted_short_ckpt - clean_short_ckpt).max(1e-6);
    let recovery_long = (faulted_long_ckpt - clean_long_ckpt).max(1e-6);
    let recovery_tick0 = (faulted_long_plain - clean_long_plain).max(1e-6);
    let independence_ratio = recovery_long / recovery_short;
    let speedup = recovery_tick0 / recovery_long;
    let independent = independence_ratio < 2.0;
    let target_met = speedup >= 2.0;
    println!(
        "recovery cost: {recovery_short:.1} ms at {T1} ticks, {recovery_long:.1} ms at {T2} \
         ticks (ratio {independence_ratio:.2} — {})",
        if independent { "independent of elapsed ticks" } else { "NOT flat" }
    );
    println!(
        "tick-0 replay recovery: {recovery_tick0:.1} ms; snapshot speedup {speedup:.2}x \
         (target >= 2x at {T2} ticks: {})",
        if target_met { "MET" } else { "MISSED" }
    );

    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("E15_checkpoint_restore".to_string()));
    root.insert("machine_chips".to_string(), Json::Num(machine.n_chips() as f64));
    root.insert("vertices".to_string(), Json::Num((ROWS * COLS) as f64));
    root.insert("short_run_ticks".to_string(), Json::Num(T1 as f64));
    root.insert("long_run_ticks".to_string(), Json::Num(T2 as f64));
    root.insert("replay_tail_ticks".to_string(), Json::Num(TAIL as f64));
    root.insert("checkpoint_interval_ticks".to_string(), Json::Num(TAIL as f64));
    root.insert("clean_short_ckpt_ms".to_string(), Json::Num(clean_short_ckpt));
    root.insert("clean_long_ckpt_ms".to_string(), Json::Num(clean_long_ckpt));
    root.insert("clean_long_plain_ms".to_string(), Json::Num(clean_long_plain));
    root.insert("faulted_short_ckpt_ms".to_string(), Json::Num(faulted_short_ckpt));
    root.insert("faulted_long_ckpt_ms".to_string(), Json::Num(faulted_long_ckpt));
    root.insert("faulted_long_plain_ms".to_string(), Json::Num(faulted_long_plain));
    root.insert("recovery_short_ms".to_string(), Json::Num(recovery_short));
    root.insert("recovery_long_ms".to_string(), Json::Num(recovery_long));
    root.insert("recovery_tick0_ms".to_string(), Json::Num(recovery_tick0));
    root.insert("independence_ratio".to_string(), Json::Num(independence_ratio));
    root.insert("independent_of_elapsed_ticks".to_string(), Json::Bool(independent));
    root.insert("speedup_vs_tick0".to_string(), Json::Num(speedup));
    root.insert("target_speedup".to_string(), Json::Num(2.0));
    root.insert("target_met".to_string(), Json::Bool(target_met));
    root.insert("digests_equal".to_string(), Json::Bool(true));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_checkpoint.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
