//! Experiment E1 — Figure 11 / §6.8 data-extraction throughput.
//!
//! Regenerates the paper's headline numbers: SCAMP SDP reads run at
//! ~8 Mb/s from the Ethernet chip and ~2 Mb/s from any other chip; the
//! multicast streaming protocol reaches ~40 Mb/s from *any* chip (no
//! non-Ethernet penalty). Throughput is measured in *simulated* time —
//! the protocol cost models are the thing under test.
//!
//! ```sh
//! cargo bench --bench extraction
//! ```

use spinntools::front::{DataPlaneOptions, FastPath};
use spinntools::machine::{ChipCoord, MachineBuilder};
use spinntools::simulator::{scamp, SimConfig, SimMachine};

fn mbps(bytes: usize, ns: u64) -> f64 {
    bytes as f64 * 8.0 / (ns as f64 / 1e9) / 1e6
}

fn bench_scamp(sim: &mut SimMachine, chip: ChipCoord, len: usize) -> anyhow::Result<f64> {
    let addr = scamp::alloc_sdram(sim, chip, len as u32)?;
    let t0 = sim.now_ns();
    scamp::read_sdram(sim, chip, addr, len)?;
    Ok(mbps(len, sim.now_ns() - t0))
}

fn bench_fast(
    sim: &mut SimMachine,
    fp: &FastPath,
    chip: ChipCoord,
    len: usize,
) -> anyhow::Result<f64> {
    let addr = scamp::alloc_sdram(sim, chip, len as u32)?;
    let t0 = sim.now_ns();
    let data = fp.read(sim, chip, addr, len)?;
    assert_eq!(data.len(), len);
    Ok(mbps(len, sim.now_ns() - t0))
}

fn main() -> anyhow::Result<()> {
    let len = 1024 * 1024; // 1 MiB per read
    let machine = MachineBuilder::spinn5().build();
    let mut sim = SimMachine::boot(machine, SimConfig::default());

    let eth: ChipCoord = (0, 0);
    let near: ChipCoord = (1, 0);
    let far: ChipCoord = (7, 7);

    let mut picker_state = std::collections::BTreeMap::new();
    let fp = FastPath::install(
        &mut sim,
        &[eth, near, far],
        move |chip| {
            let next = picker_state.entry(chip).or_insert(17u8);
            let c = *next;
            *next -= 1;
            Some(c)
        },
        &DataPlaneOptions::default(),
    )?;
    scamp::signal_start(&mut sim)?;

    println!("# E1 / Figure 11: data extraction throughput (1 MiB reads)");
    println!("#   paper: SCAMP eth ~8 Mb/s, SCAMP far ~2 Mb/s, stream ~40 Mb/s any chip");
    println!("{:<28} {:>10} {:>12}", "path", "chip", "Mb/s");

    let wall = std::time::Instant::now();
    let scamp_eth = bench_scamp(&mut sim, eth, len)?;
    let scamp_near = bench_scamp(&mut sim, near, len)?;
    let scamp_far = bench_scamp(&mut sim, far, len)?;
    let fast_eth = bench_fast(&mut sim, &fp, eth, len)?;
    let fast_near = bench_fast(&mut sim, &fp, near, len)?;
    let fast_far = bench_fast(&mut sim, &fp, far, len)?;

    println!("{:<28} {:>10} {:>12.2}", "scamp_sdp (Fig11 mid)", "0,0 (eth)", scamp_eth);
    println!("{:<28} {:>10} {:>12.2}", "scamp_sdp", "1,0", scamp_near);
    println!("{:<28} {:>10} {:>12.2}", "scamp_sdp", "7,7", scamp_far);
    println!("{:<28} {:>10} {:>12.2}", "mc_stream (Fig11 bottom)", "0,0 (eth)", fast_eth);
    println!("{:<28} {:>10} {:>12.2}", "mc_stream", "1,0", fast_near);
    println!("{:<28} {:>10} {:>12.2}", "mc_stream", "7,7", fast_far);

    println!("\n# shape checks");
    println!(
        "fast/scamp speedup at eth chip:  {:.1}x (paper ~5x)",
        fast_eth / scamp_eth
    );
    println!(
        "fast/scamp speedup at far chip:  {:.1}x (paper ~20x)",
        fast_far / scamp_far
    );
    println!(
        "fast-path far/eth ratio:         {:.2} (paper: ~1.0, 'no penalty')",
        fast_far / fast_eth
    );
    println!("host wall time: {:.2?}", wall.elapsed());

    assert!(scamp_eth > scamp_far, "eth chip must be faster over SCAMP");
    assert!(fast_far > 4.0 * scamp_eth, "stream must beat SCAMP everywhere");
    Ok(())
}
