//! Experiment E17 — multi-tenant machine service: aggregate throughput
//! at 1 vs 16 vs 64 tenants on the 576-chip (12-board) virtual machine
//! (DESIGN.md §11).
//!
//! Every tenant runs the same one-board Conway workload for the same
//! number of ticks, so the service's job is pure multiplexing: carve
//! board partitions, round-robin the machine one quantum at a time,
//! queue what does not fit (at 16 and 64 tenants only 12 partitions
//! exist), free and re-carve boards as jobs finish. Reported per
//! scenario: wall time, job-ticks/second, and the per-job multiplexing
//! overhead relative to the single-tenant run.
//!
//! Correctness ride-along: every tenant's recording digest — at every
//! tenancy level — must equal the solo run's digest on a private
//! machine. Results land in `BENCH_service.json` at the repository
//! root.
//!
//! ```sh
//! cargo bench --bench service
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::{MachineService, MachineSpec, SpiNNTools, ToolsConfig};
use spinntools::graph::VertexId;
use spinntools::util::fnv1a_64;
use spinntools::util::json::Json;

const ROWS: u32 = 8;
const COLS: u32 = 8;
const BOARDS: u32 = 12;
const TICKS: u64 = 6;
const QUANTUM: u64 = 3;
const TENANCIES: [usize; 3] = [1, 16, 64];

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// The per-tenant workload: an 8x8 Conway torus-free grid, one board.
fn build_grid(tools: &mut SpiNNTools) -> anyhow::Result<Vec<VertexId>> {
    let alive = |r: u32, c: u32| (r + c) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..COLS {
            ids.push(tools.add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))?);
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < COLS as i64)
            .then_some((r * COLS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..COLS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools.add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)?;
                    }
                }
            }
        }
    }
    Ok(ids)
}

fn digest(recordings: impl Iterator<Item = Vec<u8>>) -> u64 {
    let mut d = 0u64;
    for (i, rec) in recordings.enumerate() {
        d ^= fnv1a_64(&rec).rotate_left((i % 61) as u32);
    }
    d
}

/// One scenario: `n_jobs` identical tenants through one service.
/// Returns (wall ms of `run_to_completion`, per-job digests, rounds).
fn scenario(n_jobs: usize) -> anyhow::Result<(f64, Vec<u64>, u64)> {
    let mut svc =
        MachineService::new(ToolsConfig::new(MachineSpec::Boards(BOARDS)), QUANTUM)?;
    let mut jobs = Vec::new();
    for i in 0..n_jobs {
        jobs.push(svc.submit(&format!("job{i}"), 1, TICKS, build_grid)?);
    }
    let t = Instant::now();
    svc.run_to_completion()?;
    let wall = ms(t);
    let digests = jobs
        .iter()
        .map(|&id| {
            assert!(svc.is_finished(id), "job {id} did not finish");
            digest(svc.vertices(id).to_vec().iter().map(|v| svc.recording(id, *v).to_vec()))
        })
        .collect();
    let report = svc.report();
    assert!(report.key_windows_disjoint(), "tenant key windows overlap");
    assert_eq!(report.boards_retired, 0, "no board should die in a clean bench");
    Ok((wall, digests, report.rounds))
}

fn main() -> anyhow::Result<()> {
    let machine = MachineSpec::Boards(BOARDS).template();
    assert_eq!(machine.n_chips(), 576);
    println!(
        "# E17: multi-tenant service throughput on a {}-chip ({BOARDS}-board) machine",
        machine.n_chips()
    );
    println!(
        "workload per tenant: {ROWS}x{COLS} Conway ({} vertices), {TICKS} ticks, \
         1 board, quantum {QUANTUM}",
        ROWS * COLS
    );

    // The oracle: the same job alone on a private one-board machine.
    let solo = {
        let mut tools = SpiNNTools::new(ToolsConfig::virtual_spinn5(1))?;
        let ids = build_grid(&mut tools)?;
        tools.run_ticks(TICKS)?;
        digest(ids.iter().map(|v| tools.recording(*v).to_vec()))
    };

    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("E17_multi_tenant_service".to_string()));
    root.insert("machine_chips".to_string(), Json::Num(machine.n_chips() as f64));
    root.insert("boards".to_string(), Json::Num(BOARDS as f64));
    root.insert("vertices_per_tenant".to_string(), Json::Num((ROWS * COLS) as f64));
    root.insert("ticks_per_tenant".to_string(), Json::Num(TICKS as f64));
    root.insert("quantum_ticks".to_string(), Json::Num(QUANTUM as f64));

    let mut per_job_ms_1 = 0.0;
    let mut all_private = true;
    for n in TENANCIES {
        let (wall, digests, rounds) = scenario(n)?;
        let private = digests.iter().all(|d| *d == solo);
        all_private &= private;
        let throughput = (n as u64 * TICKS) as f64 / (wall / 1e3);
        let per_job = wall / n as f64;
        if n == 1 {
            per_job_ms_1 = per_job;
        }
        let overhead = per_job / per_job_ms_1;
        println!(
            "{n:>3} tenant(s): {wall:>9.1} ms, {throughput:>8.1} job-ticks/s, \
             {per_job:>8.1} ms/job (x{overhead:.2} vs solo), {rounds} rounds, \
             recordings {}",
            if private { "PRIVATE (== solo digest)" } else { "DIVERGED" }
        );
        assert!(private, "{n}-tenant scenario: a tenant diverged from the solo oracle");
        root.insert(format!("wall_ms_{n}"), Json::Num(wall));
        root.insert(format!("throughput_job_ticks_per_s_{n}"), Json::Num(throughput));
        root.insert(format!("per_job_ms_{n}"), Json::Num(per_job));
        root.insert(format!("overhead_vs_solo_{n}"), Json::Num(overhead));
        root.insert(format!("rounds_{n}"), Json::Num(rounds as f64));
    }
    root.insert("recordings_private".to_string(), Json::Bool(all_private));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_service.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
