//! Experiment E7 — §7.1 Conway scaling (Figure 13's archetype claim).
//!
//! "Graphs of this form are highly scalable on the SpiNNaker system,
//! since the computation to be performed at each node is fixed, and the
//! communication forms a regular pattern which does not increase as the
//! size of the board grows." — the per-cell packet count and the
//! per-tick simulated latency should stay flat as the board grows; only
//! host wall-clock grows (more cells to simulate).
//!
//! ```sh
//! cargo bench --bench conway
//! ```

use std::time::Instant;

use spinntools::apps::networks::build_conway_grid;
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};

fn main() -> anyhow::Result<()> {
    println!("# E7: Conway scaling on a simulated SpiNN-5 board");
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>12} {:>14} {:>12} {:>10}",
        "grid", "cells", "chips", "steps", "packets", "pkts/cell/step", "wall", "wall/step"
    );
    let steps = 16u64;
    for side in [6u32, 10, 16, 20, 28] {
        let spec = if side * side <= 51 {
            MachineSpec::Spinn3
        } else {
            MachineSpec::Spinn5
        };
        let mut tools = SpiNNTools::new(ToolsConfig::new(spec))?;
        let live: Vec<(u32, u32)> = (0..side)
            .flat_map(|r| (0..side).map(move |c| (r, c)))
            .filter(|(r, c)| (r * 7 + c * 3) % 5 < 2)
            .collect();
        build_conway_grid(&mut tools, side, side, &live)?;
        let t0 = Instant::now();
        tools.run_ticks(steps)?;
        let wall = t0.elapsed();
        let sent = tools.sim_mut().map(|s| s.stats.mc_sent).unwrap();
        let chips = tools.mapping().unwrap().placements.used_chips().len();
        let cells = (side * side) as u64;
        println!(
            "{:<10} {:>8} {:>8} {:>8} {:>12} {:>14.2} {:>12.2?} {:>10.2?}",
            format!("{side}x{side}"),
            cells,
            chips,
            steps,
            sent,
            sent as f64 / cells as f64 / steps as f64,
            wall,
            wall / steps as u32,
        );
        let prov = tools.provenance();
        assert_eq!(
            prov.counter_total("missed_neighbour_states"),
            0,
            "phase synchronisation broke at {side}x{side}"
        );
        tools.stop()?;
    }
    println!("\n# shape: pkts/cell/step constant (== 1), missed phases == 0 at every size");
    Ok(())
}
