//! Experiment E14 — self-healing: heal-time vs full re-map
//! (DESIGN.md §8).
//!
//! When a chip dies under a running workload, the supervisor re-maps
//! *incrementally*: survivors stay pinned, the key allocator is a cache
//! hit, and only the trees/tables the dead chip invalidated are
//! rebuilt. This bench measures that heal re-map against a full
//! from-scratch re-map of the same graph on the same degraded machine,
//! on the 576-chip (12-board) 88x88 Conway workload the E9/E13 benches
//! use — target: heal-map strictly faster, aiming ≥ 2x.
//!
//! A second, smaller end-to-end section drives the whole supervised
//! tools flow: a mid-run chip death on a SpiNN-5 board, healed and then
//! checked byte-identical (FNV digests) against a fresh run on the
//! equivalently boot-degraded machine, with the `HealReport` timings
//! recorded. Results land in `BENCH_chaos.json` at the repository root.
//!
//! ```sh
//! cargo bench --bench chaos
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::apps::networks::conway_machine_graph;
use spinntools::front::{
    BootFaults, HealPolicy, MachineSpec, SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::machine::MachineBuilder;
use spinntools::mapping::{
    map_graph_incremental, tables::check_tables, MappingConfig, PipelineState,
};
use spinntools::simulator::{ChaosPlan, Fault};
use spinntools::util::json::Json;
use spinntools::util::{fnv1a_64, SplitMix64};

const ROWS: u32 = 88;
const COLS: u32 = 88;

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// E2 oracle over a seeded sample of partitions (as in the E13 bench).
fn check_sampled_routing(
    machine: &spinntools::machine::Machine,
    graph: &spinntools::graph::MachineGraph,
    mapping: &spinntools::mapping::Mapping,
    samples: usize,
    seed: u64,
) {
    let partitions: Vec<_> = graph.partitions().collect();
    let mut rng = SplitMix64::new(seed);
    for _ in 0..samples {
        let p = partitions[rng.below(partitions.len())];
        let src = mapping.placement(p.pre).expect("source placed");
        let key = mapping.keys[&(p.pre, p.id.clone())];
        let expected: Vec<_> = graph
            .partition_targets(p)
            .into_iter()
            .map(|t| {
                let l = mapping.placement(t).expect("target placed");
                (l.chip(), l.p)
            })
            .collect();
        check_tables(machine, &mapping.tables, src.chip(), key.base, &expected)
            .expect("healed mapping routes a sampled partition wrongly");
    }
}

/// End-to-end: supervised 8x8 Conway run on SpiNN-5, chip death at tick
/// 2, healed, digest-compared against the boot-degraded twin. Returns
/// (digests equal, heal report fields).
fn end_to_end_heal() -> (bool, u64, u64, usize, usize) {
    let rows = 8u32;
    let alive = |r: u32, c: u32| (r * 31 + c * 17) % 3 == 0;
    let build = |tools: &mut SpiNNTools| -> Vec<VertexId> {
        let mut ids = Vec::new();
        let mut map = BTreeMap::new();
        for r in 0..rows {
            for c in 0..rows {
                let id = tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap();
                map.insert((r, c), id);
                ids.push(id);
            }
        }
        for (&(r, c), &id) in &map {
            for dr in -1..=1i64 {
                for dc in -1..=1i64 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    let (nr, nc) = (r as i64 + dr, c as i64 + dc);
                    if nr >= 0 && nc >= 0 && (nr as u32) < rows && (nc as u32) < rows {
                        tools
                            .add_machine_edge(id, map[&(nr as u32, nc as u32)], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
        ids
    };
    let supervision = SupervisorConfig {
        poll_interval_ticks: 1,
        policy: HealPolicy::Remap,
        max_heals: 4,
    };

    // Probe for a non-Ethernet chip the workload uses.
    let mut probe = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5)).unwrap();
    let pids = build(&mut probe);
    probe.run_ticks(1).unwrap();
    let machine = MachineSpec::Spinn5.template();
    let victim = pids
        .iter()
        .map(|v| probe.mapping().unwrap().placement(*v).unwrap().chip())
        .find(|c| !machine.chip(*c).unwrap().is_ethernet())
        .expect("workload spans more than the Ethernet chip");

    let mut healed = SpiNNTools::new(
        ToolsConfig::new(MachineSpec::Spinn5).with_supervision(supervision),
    )
    .unwrap();
    let hids = build(&mut healed);
    healed.inject_chaos(ChaosPlan::new().with(2, Fault::ChipDeath(victim)));
    healed.run_ticks(8).unwrap();
    let report = healed.heal_reports()[0].clone();

    let mut fresh = SpiNNTools::new(
        ToolsConfig::new(MachineSpec::Spinn5)
            .with_supervision(supervision)
            .with_boot_faults(BootFaults { chips: vec![victim], ..Default::default() }),
    )
    .unwrap();
    let fids = build(&mut fresh);
    fresh.run_ticks(8).unwrap();

    let digest = |tools: &SpiNNTools, ids: &[VertexId]| -> u64 {
        let mut h = 0u64;
        for (i, id) in ids.iter().enumerate() {
            h ^= fnv1a_64(tools.recording(*id)).rotate_left((i % 61) as u32);
        }
        h
    };
    let equal = digest(&healed, &hids) == digest(&fresh, &fids);
    (
        equal,
        report.heal_elapsed_us,
        report.map_elapsed_us,
        report.vertices_moved,
        report.tables_rewritten,
    )
}

fn main() -> anyhow::Result<()> {
    println!("# E14: heal-time vs full re-map on a 576-chip (12-board) virtual machine");
    let machine = MachineBuilder::boards(12).build();
    assert_eq!(machine.n_chips(), 576);
    let config = MappingConfig::default();
    let graph = conway_machine_graph(ROWS, COLS, |r, c| (r + c) % 3 == 0);

    // Warm pipeline: the state a running workload would hold.
    let mut state = PipelineState::new();
    let t = Instant::now();
    let first = map_graph_incremental(
        &mut state, &machine, &graph, &config, &Default::default(), &Default::default(),
    )?;
    let initial_ms = ms(t);
    println!(
        "initial full map: {initial_ms:.1} ms ({} vertices, {} tables)",
        graph.n_vertices(),
        first.mapping.tables.len()
    );

    // The fault: kill the chip hosting the middle vertex.
    let dead = first
        .mapping
        .placement(VertexId((ROWS / 2) * COLS + COLS / 2))
        .expect("middle vertex placed")
        .chip();
    let victims = graph
        .vertex_ids()
        .filter(|v| first.mapping.placement(*v).map(|l| l.chip()) == Some(dead))
        .count();
    let mut degraded = machine.clone();
    degraded.remove_chip(dead);
    let mut forbidden = BTreeSet::new();
    forbidden.insert(dead);
    println!("fault: chip {dead:?} died ({victims} resident vertices displaced)");

    // Heal re-map against the warm state (what the supervisor runs).
    let t = Instant::now();
    let heal = map_graph_incremental(
        &mut state, &degraded, &graph, &config, &Default::default(), &forbidden,
    )?;
    let heal_ms = ms(t);
    let cached = heal.stages.iter().filter(|s| s.cached).count();
    println!(
        "heal re-map: {heal_ms:.1} ms ({cached} stages cached, {} tables to reinstall)",
        heal.install_chips.len()
    );

    // Full from-scratch re-map of the same graph on the same degraded
    // machine (what a heal-less toolchain would have to do).
    let mut fresh_state = PipelineState::new();
    let t = Instant::now();
    let full = map_graph_incremental(
        &mut fresh_state, &degraded, &graph, &config, &Default::default(), &forbidden,
    )?;
    let full_ms = ms(t);
    println!("full re-map on degraded machine: {full_ms:.1} ms");

    // Soundness: survivors pinned, victims off the dead chip, oracle ok.
    let mut moved = 0usize;
    for v in graph.vertex_ids() {
        let was = first.mapping.placement(v).unwrap();
        let now = heal.mapping.placement(v).unwrap();
        assert_ne!(now.chip(), dead, "vertex left on the dead chip");
        if was.chip() == dead {
            moved += 1;
        } else {
            assert_eq!(was, now, "survivor moved during heal");
        }
    }
    assert_eq!(moved, victims);
    assert_eq!(
        heal.mapping.placements.len(),
        full.mapping.placements.len()
    );
    check_sampled_routing(&degraded, &graph, &heal.mapping, 150, 0xE14);

    let speedup = full_ms / heal_ms.max(1e-6);
    let target_met = speedup >= 2.0 && heal_ms < full_ms;
    println!(
        "heal speedup over full re-map: {speedup:.2}x (heal < full: {}; target >= 2x: {})",
        heal_ms < full_ms,
        if target_met { "MET" } else { "MISSED" }
    );

    // End-to-end supervised heal at SpiNN-5 scale.
    let (digests_equal, heal_us, map_us, e2e_moved, e2e_tables) = end_to_end_heal();
    println!(
        "end-to-end heal: recordings {} (heal {heal_us} us, map {map_us} us, \
         {e2e_moved} vertices moved, {e2e_tables} tables rewritten)",
        if digests_equal { "EQUAL to boot-degraded twin" } else { "DIVERGED" }
    );
    assert!(digests_equal, "healed run diverged from the boot-degraded twin");

    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("E14_self_healing".to_string()));
    root.insert("machine_chips".to_string(), Json::Num(machine.n_chips() as f64));
    root.insert("vertices".to_string(), Json::Num(graph.n_vertices() as f64));
    root.insert("dead_chip_residents".to_string(), Json::Num(victims as f64));
    root.insert("initial_full_map_ms".to_string(), Json::Num(initial_ms));
    root.insert("heal_remap_ms".to_string(), Json::Num(heal_ms));
    root.insert("full_remap_ms".to_string(), Json::Num(full_ms));
    root.insert("speedup".to_string(), Json::Num(speedup));
    root.insert("target_speedup".to_string(), Json::Num(2.0));
    root.insert("target_met".to_string(), Json::Bool(target_met));
    root.insert("stages_cached".to_string(), Json::Num(cached as f64));
    root.insert("stages_total".to_string(), Json::Num(heal.stages.len() as f64));
    root.insert(
        "tables_reinstalled".to_string(),
        Json::Num(heal.install_chips.len() as f64),
    );
    root.insert("e2e_recording_digests_equal".to_string(), Json::Bool(digests_equal));
    root.insert("e2e_heal_elapsed_us".to_string(), Json::Num(heal_us as f64));
    root.insert("e2e_heal_map_us".to_string(), Json::Num(map_us as f64));
    root.insert("e2e_vertices_moved".to_string(), Json::Num(e2e_moved as f64));
    root.insert("e2e_tables_rewritten".to_string(), Json::Num(e2e_tables as f64));
    root.insert(
        "stages".to_string(),
        Json::Arr(
            heal.stages
                .iter()
                .map(|s| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(s.name.clone()));
                    o.insert("cached".to_string(), Json::Bool(s.cached));
                    o.insert("elapsed_us".to_string(), Json::Num(s.elapsed_us as f64));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_chaos.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
