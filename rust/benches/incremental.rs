//! Experiment E13 — incremental re-mapping speedup (DESIGN.md §7).
//!
//! The §6.5 "graph changed" path exists so a small graph delta costs a
//! small re-map. This bench measures exactly that on the 576-chip
//! (12-board) workload the E9 mapping bench uses: a Conway 88x88 grid
//! (~7.7k vertices), mutated by removing the top ~10% of rows (a
//! contiguous -vertex delta, the shape a parameter sweep produces), and
//! compares
//!
//! - a full from-scratch map of the mutated graph, vs
//! - an incremental re-map against the persistent pipeline state
//!   (pinned placements, reused trees/keys, per-chip table merging),
//!
//! with a target of ≥ 5x. Mapping equivalence is checked with the E2
//! routing oracle on a seeded sample of partitions, and end-to-end
//! recording equality (incremental ≡ from-scratch, FNV digests) is
//! proven on a smaller end-to-end instance. Results land in
//! `BENCH_incremental.json` at the repository root.
//!
//! ```sh
//! cargo bench --bench incremental
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::apps::networks::conway_machine_graph;
use spinntools::front::{MachineSpec, SpiNNTools, ToolsConfig};
use spinntools::graph::{MachineGraph, VertexId};
use spinntools::machine::MachineBuilder;
use spinntools::mapping::{
    map_graph_incremental, tables::check_tables, MappingConfig, PipelineState,
};
use spinntools::util::json::Json;
use spinntools::util::{fnv1a_64, SplitMix64};

const ROWS: u32 = 88;
const COLS: u32 = 88;
/// Rows removed by the delta (top of the grid): 9/88 ≈ 10.2%.
const CUT_ROWS: u32 = 9;

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// Remove the top `CUT_ROWS` rows (row-major vertex ids).
fn apply_cut(graph: &mut MachineGraph) -> usize {
    let mut removed = 0;
    for r in (ROWS - CUT_ROWS)..ROWS {
        for c in 0..COLS {
            graph.remove_vertex(VertexId(r * COLS + c)).unwrap();
            removed += 1;
        }
    }
    removed
}

/// E2 oracle over a seeded sample of partitions.
fn check_sampled_routing(
    machine: &spinntools::machine::Machine,
    graph: &MachineGraph,
    mapping: &spinntools::mapping::Mapping,
    samples: usize,
    seed: u64,
) {
    let partitions: Vec<_> = graph.partitions().collect();
    let mut rng = SplitMix64::new(seed);
    for _ in 0..samples {
        let p = partitions[rng.below(partitions.len())];
        let src = mapping.placement(p.pre).expect("source placed");
        let key = mapping.keys[&(p.pre, p.id.clone())];
        let expected: Vec<_> = graph
            .partition_targets(p)
            .into_iter()
            .map(|t| {
                let l = mapping.placement(t).expect("target placed");
                (l.chip(), l.p)
            })
            .collect();
        check_tables(machine, &mapping.tables, src.chip(), key.base, &expected)
            .expect("incremental mapping routes a sampled partition wrongly");
    }
}

/// End-to-end digest check at a smaller scale: recordings after
/// `run; cut; run` must digest-match a fresh build of the cut graph.
fn end_to_end_digests() -> (u64, u64) {
    let rows = 16u32;
    let cut = 2u32; // 12.5%
    let alive = |r: u32, c: u32| (r * 31 + c * 17) % 3 == 0;

    let build = |tools: &mut SpiNNTools, skip_top: u32| -> Vec<(u32, u32, VertexId)> {
        let mut ids = Vec::new();
        let mut map = BTreeMap::new();
        for r in 0..(rows - skip_top) {
            for c in 0..rows {
                let id = tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap();
                map.insert((r, c), id);
                ids.push((r, c, id));
            }
        }
        for (&(r, c), &id) in &map {
            for dr in -1..=1i64 {
                for dc in -1..=1i64 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    let (nr, nc) = (r as i64 + dr, c as i64 + dc);
                    if nr >= 0 && nc >= 0 && (nr as u32) < rows - skip_top && (nc as u32) < rows {
                        tools
                            .add_machine_edge(id, map[&(nr as u32, nc as u32)], STATE_PARTITION)
                            .unwrap();
                    }
                }
            }
        }
        ids
    };

    // Incremental: full grid, run, cut the top rows, run again.
    let mut inc = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5)).unwrap();
    let ids = build(&mut inc, 0);
    inc.run_ticks(2).unwrap();
    for (r, _, id) in &ids {
        if *r >= rows - cut {
            inc.remove_machine_vertex(*id).unwrap();
        }
    }
    inc.run_ticks(4).unwrap();
    let mut inc_digest = 0u64;
    for (r, _, id) in &ids {
        if *r < rows - cut {
            inc_digest ^= fnv1a_64(inc.recording(*id)).rotate_left((*r % 61) as u32);
        }
    }

    // From scratch: the cut grid directly.
    let mut fresh = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5)).unwrap();
    let fids = build(&mut fresh, cut);
    fresh.run_ticks(4).unwrap();
    let mut fresh_digest = 0u64;
    for (r, _, id) in &fids {
        fresh_digest ^= fnv1a_64(fresh.recording(*id)).rotate_left((*r % 61) as u32);
    }
    (inc_digest, fresh_digest)
}

fn main() -> anyhow::Result<()> {
    println!("# E13: incremental re-mapping on a 576-chip (12-board) virtual machine");
    let machine = MachineBuilder::boards(12).build();
    assert_eq!(machine.n_chips(), 576);
    let config = MappingConfig::default();

    // Baseline state: map the full grid once (this also warms the
    // persistent pipeline the incremental pass will diff against).
    let mut graph = conway_machine_graph(ROWS, COLS, |r, c| (r + c) % 3 == 0);
    let mut state = PipelineState::new();
    let t = Instant::now();
    let first = map_graph_incremental(&mut state, &machine, &graph, &config, &Default::default(), &Default::default())?;
    let initial_ms = ms(t);
    println!(
        "initial full map: {:.1} ms ({} vertices, {} tables)",
        initial_ms,
        graph.n_vertices(),
        first.mapping.tables.len()
    );

    // The delta: cut the top ~10% of rows.
    let removed = apply_cut(&mut graph);
    println!("delta: removed {removed} vertices ({:.1}%)", 100.0 * removed as f64 / (ROWS * COLS) as f64);

    // Incremental re-map against the warm state.
    let t = Instant::now();
    let inc = map_graph_incremental(&mut state, &machine, &graph, &config, &Default::default(), &Default::default())?;
    let incremental_ms = ms(t);
    let cached = inc.stages.iter().filter(|s| s.cached).count();
    println!(
        "incremental re-map: {:.1} ms ({} stages cached, {} tables reinstalled)",
        incremental_ms,
        cached,
        inc.install_chips.len()
    );

    // Full from-scratch map of the mutated graph (fresh state).
    let mut fresh_state = PipelineState::new();
    let t = Instant::now();
    let full =
        map_graph_incremental(&mut fresh_state, &machine, &graph, &config, &Default::default(), &Default::default())?;
    let full_ms = ms(t);
    println!("from-scratch map of mutated graph: {full_ms:.1} ms");

    // Equivalence: the incremental mapping must route every sampled
    // partition exactly like the oracle demands, and pins must hold.
    check_sampled_routing(&machine, &graph, &inc.mapping, 150, 0xE13);
    let pins_held = graph
        .vertex_ids()
        .all(|v| inc.mapping.placement(v) == first.mapping.placement(v));
    let same_placement_count = inc.mapping.placements.len() == full.mapping.placements.len();
    assert!(pins_held, "a surviving vertex moved during incremental re-map");
    assert!(same_placement_count);

    let speedup = full_ms / incremental_ms.max(1e-6);
    let target_met = speedup >= 5.0;
    println!("remap speedup: {speedup:.2}x (target >= 5x: {})", if target_met { "MET" } else { "MISSED" });

    // End-to-end recording digests (smaller instance).
    let (inc_digest, fresh_digest) = end_to_end_digests();
    let digests_equal = inc_digest == fresh_digest;
    println!(
        "end-to-end recording digests: incremental {inc_digest:#018x} vs from-scratch {fresh_digest:#018x} ({})",
        if digests_equal { "EQUAL" } else { "DIVERGED" }
    );
    assert!(digests_equal, "incremental run diverged from from-scratch run");

    let mut root = BTreeMap::new();
    root.insert(
        "experiment".to_string(),
        Json::Str("E13_incremental_remapping".to_string()),
    );
    root.insert("machine_chips".to_string(), Json::Num(machine.n_chips() as f64));
    root.insert("vertices_before".to_string(), Json::Num((ROWS * COLS) as f64));
    root.insert("vertices_removed".to_string(), Json::Num(removed as f64));
    root.insert("initial_full_map_ms".to_string(), Json::Num(initial_ms));
    root.insert("incremental_remap_ms".to_string(), Json::Num(incremental_ms));
    root.insert("from_scratch_remap_ms".to_string(), Json::Num(full_ms));
    root.insert("speedup".to_string(), Json::Num(speedup));
    root.insert("target_speedup".to_string(), Json::Num(5.0));
    root.insert("target_met".to_string(), Json::Bool(target_met));
    root.insert("stages_cached".to_string(), Json::Num(cached as f64));
    root.insert(
        "stages_total".to_string(),
        Json::Num(inc.stages.len() as f64),
    );
    root.insert(
        "tables_reinstalled".to_string(),
        Json::Num(inc.install_chips.len() as f64),
    );
    root.insert(
        "tables_total".to_string(),
        Json::Num(inc.mapping.tables.len() as f64),
    );
    root.insert("pins_held".to_string(), Json::Bool(pins_held));
    root.insert("recording_digests_equal".to_string(), Json::Bool(digests_equal));
    root.insert(
        "stages".to_string(),
        Json::Arr(
            inc.stages
                .iter()
                .map(|s| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(s.name.clone()));
                    o.insert("cached".to_string(), Json::Bool(s.cached));
                    o.insert("elapsed_us".to_string(), Json::Num(s.elapsed_us as f64));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_incremental.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
