//! Experiment E12 — bulk data plane throughput: fast data-in versus
//! (batched) SCAMP writes, and multi-board parallel extraction versus a
//! single board, in *simulated* time (the protocol cost models are the
//! thing under test, exactly as in E1).
//!
//! Every transfer is digest-checked (FNV-1a) against its source or its
//! slow-path twin — a speedup over corrupted data would be meaningless.
//!
//! Results go to `BENCH_dataplane.json` at the repository root.
//! Targets (ISSUE 3): fast data-in ≥ 3x over batched SCAMP writes, and
//! multi-board extraction scaling ≥ 2x over one board.
//!
//! ```sh
//! cargo bench --bench dataplane
//! ```

use std::collections::BTreeMap;

use spinntools::front::{DataPlaneOptions, FastPath};
use spinntools::machine::{ChipCoord, Machine, MachineBuilder};
use spinntools::simulator::{scamp, SimConfig, SimMachine};
use spinntools::util::json::Json;
use spinntools::util::{fnv1a_64, SplitMix64};

/// Payload per covered chip.
const CHIP_BYTES: usize = 256 * 1024;
/// Chips covered per machine.
const N_CHIPS: usize = 12;
const IN_TARGET: f64 = 3.0;
const SCALE_TARGET: f64 = 2.0;

fn mbps(bytes: u64, ns: u64) -> f64 {
    bytes as f64 * 8.0 / (ns as f64 / 1e9).max(1e-12) / 1e6
}

/// `n` chips spread evenly over the machine (and so over its boards).
fn spread_chips(machine: &Machine, n: usize) -> Vec<ChipCoord> {
    let coords: Vec<ChipCoord> = machine.chip_coords().collect();
    (0..n).map(|i| coords[i * coords.len() / n]).collect()
}

struct MachineResult {
    label: String,
    n_eth: usize,
    naive_in_mbps: f64,
    batched_in_mbps: f64,
    fast_in_mbps: f64,
    scamp_out_mbps: f64,
    fast_out_mbps: f64,
}

impl MachineResult {
    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("label".to_string(), Json::Str(self.label.clone()));
        o.insert("ethernet_chips".to_string(), Json::Num(self.n_eth as f64));
        o.insert("chips_covered".to_string(), Json::Num(N_CHIPS as f64));
        o.insert("bytes_per_chip".to_string(), Json::Num(CHIP_BYTES as f64));
        o.insert("naive_scamp_in_mbps".to_string(), Json::Num(self.naive_in_mbps));
        o.insert("batched_scamp_in_mbps".to_string(), Json::Num(self.batched_in_mbps));
        o.insert("fast_in_mbps".to_string(), Json::Num(self.fast_in_mbps));
        o.insert("scamp_out_mbps".to_string(), Json::Num(self.scamp_out_mbps));
        o.insert("fast_out_mbps".to_string(), Json::Num(self.fast_out_mbps));
        o.insert(
            "fast_in_vs_batched".to_string(),
            Json::Num(self.fast_in_mbps / self.batched_in_mbps.max(1e-9)),
        );
        Json::Obj(o)
    }
}

/// Verify the stored image of every chip against its source pattern.
fn check_digests(
    sim: &mut SimMachine,
    chips: &[ChipCoord],
    addrs: &[u32],
    datas: &[Vec<u8>],
    what: &str,
) -> anyhow::Result<()> {
    for ((chip, addr), data) in chips.iter().zip(addrs).zip(datas) {
        let got = scamp::read_sdram(sim, *chip, *addr, data.len())?;
        anyhow::ensure!(
            fnv1a_64(&got) == fnv1a_64(data),
            "{what}: digest mismatch on {chip:?}"
        );
    }
    Ok(())
}

fn bench_machine(label: &str, machine: Machine, seed: u64) -> anyhow::Result<MachineResult> {
    let n_eth = machine.ethernet_chips().count();
    let mut sim = SimMachine::boot(machine.clone(), SimConfig::default());
    let chips = spread_chips(&machine, N_CHIPS);
    let total = (N_CHIPS * CHIP_BYTES) as u64;

    let mut rng = SplitMix64::new(seed);
    let mut fresh_patterns = |salt: u64| -> Vec<Vec<u8>> {
        (0..N_CHIPS)
            .map(|_| {
                let mut rng2 = SplitMix64::new(rng.next_u64() ^ salt);
                (0..CHIP_BYTES).map(|_| (rng2.next_u64() & 0xff) as u8).collect()
            })
            .collect()
    };
    let addrs: Vec<u32> = chips
        .iter()
        .map(|c| scamp::alloc_sdram(&mut sim, *c, CHIP_BYTES as u32))
        .collect::<anyhow::Result<_>>()?;

    // Data-in, slow: one acknowledged round trip per 256-byte chunk.
    let datas = fresh_patterns(1);
    let t0 = sim.now_ns();
    for ((chip, addr), data) in chips.iter().zip(&addrs).zip(&datas) {
        scamp::write_sdram(&mut sim, *chip, *addr, data)?;
    }
    let naive_in_mbps = mbps(total, sim.now_ns() - t0);
    check_digests(&mut sim, &chips, &addrs, &datas, "naive scamp write")?;

    // Data-in, batched slow path (the fallback the fast path is gated on).
    let datas = fresh_patterns(2);
    let t0 = sim.now_ns();
    for ((chip, addr), data) in chips.iter().zip(&addrs).zip(&datas) {
        scamp::write_sdram_batched(&mut sim, *chip, *addr, data)?;
    }
    let batched_in_mbps = mbps(total, sim.now_ns() - t0);
    check_digests(&mut sim, &chips, &addrs, &datas, "batched scamp write")?;

    // Install the plane (one gatherer + dispatcher per board).
    let mut used: BTreeMap<ChipCoord, u8> = BTreeMap::new();
    let fp = FastPath::install(
        &mut sim,
        &chips,
        move |chip| {
            let next = used.entry(chip).or_insert(17u8);
            let c = *next;
            *next -= 1;
            Some(c)
        },
        &DataPlaneOptions::default(),
    )?;
    scamp::signal_start(&mut sim)?;
    assert_eq!(fp.n_boards(), n_eth, "a plane on every board");

    // Data-in, fast: multi-board streamed load.
    let datas = fresh_patterns(3);
    let reqs: Vec<(ChipCoord, u32, &[u8])> = chips
        .iter()
        .zip(&addrs)
        .zip(&datas)
        .map(|((c, a), d)| (*c, *a, d.as_slice()))
        .collect();
    let t0 = sim.now_ns();
    let stats = fp.write_many(&mut sim, &reqs)?;
    let fast_in_mbps = mbps(total, sim.now_ns() - t0);
    assert_eq!(stats.frames_resent, 0, "lossless fabric should not re-send");
    check_digests(&mut sim, &chips, &addrs, &datas, "fast data-in")?;

    // Extraction, slow: SCAMP reads of the stored image.
    let t0 = sim.now_ns();
    let mut slow_reads = Vec::new();
    for ((chip, addr), data) in chips.iter().zip(&addrs).zip(&datas) {
        slow_reads.push(scamp::read_sdram(&mut sim, *chip, *addr, data.len())?);
    }
    let scamp_out_mbps = mbps(total, sim.now_ns() - t0);

    // Extraction, fast: per-board parallel drains.
    let read_reqs: Vec<(ChipCoord, u32, usize)> = chips
        .iter()
        .zip(&addrs)
        .map(|(c, a)| (*c, *a, CHIP_BYTES))
        .collect();
    let t0 = sim.now_ns();
    let fast_reads = fp.read_many(&mut sim, &read_reqs)?;
    let fast_out_mbps = mbps(total, sim.now_ns() - t0);
    for ((slow, fast), chip) in slow_reads.iter().zip(&fast_reads).zip(&chips) {
        anyhow::ensure!(
            fnv1a_64(slow) == fnv1a_64(fast),
            "extraction: fast ≠ scamp on {chip:?}"
        );
    }

    println!(
        "{label:<24} eth {n_eth:>2} | in: naive {naive_in_mbps:>7.2} batched {batched_in_mbps:>7.2} fast {fast_in_mbps:>8.2} Mb/s | out: scamp {scamp_out_mbps:>7.2} fast {fast_out_mbps:>8.2} Mb/s"
    );
    Ok(MachineResult {
        label: label.to_string(),
        n_eth,
        naive_in_mbps,
        batched_in_mbps,
        fast_in_mbps,
        scamp_out_mbps,
        fast_out_mbps,
    })
}

fn main() -> anyhow::Result<()> {
    println!("# E12: bulk data plane throughput (simulated time), {N_CHIPS} chips x {CHIP_BYTES} B");

    let single = bench_machine("1-board", MachineBuilder::boards(1).build(), 0xE12_0001)?;
    // `boards(4)` rounds up to whole triads (6 boards / 6 Ethernet
    // chips), as the physical machines do.
    let multi = bench_machine("4-board (2 triads)", MachineBuilder::boards(4).build(), 0xE12_0004)?;

    let in_speedup = (single.fast_in_mbps / single.batched_in_mbps.max(1e-9))
        .min(multi.fast_in_mbps / multi.batched_in_mbps.max(1e-9));
    let out_scaling = multi.fast_out_mbps / single.fast_out_mbps.max(1e-9);
    let in_scaling = multi.fast_in_mbps / single.fast_in_mbps.max(1e-9);
    let meets = in_speedup >= IN_TARGET && out_scaling >= SCALE_TARGET;
    println!(
        "\n# fast data-in vs batched SCAMP: {in_speedup:.2}x (target ≥ {IN_TARGET}x)\n\
         # multi-board extraction scaling: {out_scaling:.2}x (target ≥ {SCALE_TARGET}x); loading scaling {in_scaling:.2}x\n\
         # {}",
        if meets { "MET" } else { "NOT MET" }
    );

    let mut root = BTreeMap::new();
    root.insert(
        "experiment".to_string(),
        Json::Str("E12_bulk_data_plane".to_string()),
    );
    root.insert("target_in_speedup".to_string(), Json::Num(IN_TARGET));
    root.insert("target_out_scaling".to_string(), Json::Num(SCALE_TARGET));
    root.insert("fast_in_vs_batched".to_string(), Json::Num(in_speedup));
    root.insert("multi_board_out_scaling".to_string(), Json::Num(out_scaling));
    root.insert("multi_board_in_scaling".to_string(), Json::Num(in_scaling));
    root.insert("digests_checked".to_string(), Json::Bool(true));
    root.insert("meets_target".to_string(), Json::Bool(meets));
    root.insert(
        "machines".to_string(),
        Json::Arr(vec![single.to_json(), multi.to_json()]),
    );

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_dataplane.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("results written to {}", out.display());
    Ok(())
}
