//! Experiment E19 — run-event bus throughput and overhead
//! (DESIGN.md §13).
//!
//! Two questions, answered in `BENCH_bus.json`:
//!
//! 1. **How fast does the hub fan out?** A 576-chip (12-board)
//!    microcircuit storm sizes a realistic event stream; that many
//!    typed events are then pumped through an [`EventBus`] with 1 / 4 /
//!    16 ring sinks attached, measuring events/sec (plus the 0-sink
//!    counter-bump baseline).
//! 2. **What does observation cost a run?** The supervised Conway
//!    workload A/B: 0 sinks vs 16 sinks on the same seeded run,
//!    recordings asserted byte-identical, wall-clock ratio recorded.
//!
//! ```sh
//! cargo bench --bench bus
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
use spinntools::front::fabric_probe::{run_fabric_probe, ProbeWorkload};
use spinntools::front::{
    EventBus, HealPolicy, LiveEvent, LiveSource, MachineSpec, Metrics, RingSink, RunEvent,
    SpiNNTools, SupervisorConfig, ToolsConfig,
};
use spinntools::graph::VertexId;
use spinntools::simulator::FabricMode;
use spinntools::util::json::Json;

const SEED: u64 = 0xE19;
const ROWS: u32 = 6;
const TICKS: u64 = 8;

/// A representative mix of bus traffic: mostly live spikes, with
/// metrics, checkpoint and fault lines threaded through.
fn synth_event(i: u64) -> RunEvent {
    match i % 8 {
        0 => RunEvent::CheckpointCaptured { tick: i },
        1 => RunEvent::Metrics(Metrics {
            tick: i,
            sim_ns: i * 1_000_000,
            ticks_per_sec: 1234.5,
            packets_per_sec: 67_890.0,
            packets: i,
            wire_retries: 0,
            tenant: None,
            quantum_latency_us: None,
        }),
        2 => RunEvent::Fault { description: format!("synthetic fault {i}") },
        _ => RunEvent::Live(LiveEvent {
            source: LiveSource::Known {
                vertex: "pop_l4e".to_string(),
                partition: "spikes".to_string(),
                atom: (i % 512) as u32,
            },
            payload: Some(i as u32),
        }),
    }
}

/// Pump `n` synthetic events through a bus with `sinks` ring sinks.
fn fanout_row(n: u64, sinks: usize) -> (f64, f64) {
    let bus = EventBus::new();
    let rings: Vec<RingSink> = (0..sinks).map(|_| RingSink::new(4096)).collect();
    for r in &rings {
        bus.attach(Box::new(r.clone()));
    }
    let t0 = Instant::now();
    for i in 0..n {
        bus.emit(synth_event(i));
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    assert_eq!(bus.seq(), n);
    if sinks > 0 {
        assert_eq!(rings[0].len(), 4096.min(n as usize), "ring did not keep up");
    }
    (wall * 1e3, n as f64 / wall)
}

/// Build the Conway grid (same shape as `tests/bus.rs`).
fn build_grid(tools: &mut SpiNNTools) -> Vec<VertexId> {
    let alive = |r: u32, c: u32| (r * 31 + c * 17) % 3 == 0;
    let mut ids = Vec::new();
    for r in 0..ROWS {
        for c in 0..ROWS {
            ids.push(
                tools
                    .add_machine_vertex(ConwayCellVertex::arc(r, c, alive(r, c)))
                    .unwrap(),
            );
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < ROWS as i64 && c < ROWS as i64)
            .then_some((r * ROWS as i64 + c) as usize)
    };
    for r in 0..ROWS as i64 {
        for c in 0..ROWS as i64 {
            for dr in -1..=1 {
                for dc in -1..=1 {
                    if (dr, dc) != (0, 0) {
                        if let Some(n) = idx(r + dr, c + dc) {
                            tools
                                .add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)
                                .unwrap();
                        }
                    }
                }
            }
        }
    }
    ids
}

/// The supervised Conway workload with `sinks` ring sinks watching:
/// (recordings, wall ms, events published).
fn watched_workload(sinks: usize) -> (Vec<Vec<u8>>, f64, u64) {
    let t = Instant::now();
    let mut tools = SpiNNTools::new(ToolsConfig::new(MachineSpec::Spinn5).with_supervision(
        SupervisorConfig { poll_interval_ticks: 1, policy: HealPolicy::Remap, max_heals: 4 },
    ))
    .unwrap();
    let rings: Vec<RingSink> = (0..sinks).map(|_| RingSink::new(1 << 14)).collect();
    for r in &rings {
        tools.bus().attach(Box::new(r.clone()));
    }
    let ids = build_grid(&mut tools);
    tools.run_ticks(TICKS).unwrap();
    let wall_ms = t.elapsed().as_secs_f64() * 1e3;
    let recs = ids.iter().map(|v| tools.recording(*v).to_vec()).collect();
    (recs, wall_ms, tools.bus().seq())
}

fn main() -> anyhow::Result<()> {
    println!("# E19: run-event bus fan-out and observation overhead");
    let mut root = BTreeMap::new();
    root.insert("experiment".to_string(), Json::Str("E19_event_bus".to_string()));

    // ---- size a realistic stream: the 576-chip storm -------------------
    let probe = run_fabric_probe(
        ProbeWorkload::MicrocircuitStorm { scale: 0.1, boards: 12 },
        16,
        FabricMode::Fast,
    )?;
    // One live event per delivered packet is the worst-case stream an
    // LPG tap of the whole machine would produce over the timed window.
    let stream = probe.mc_delivered.clamp(100_000, 2_000_000);
    println!(
        "storm on 576 chips: {} packets sent, {} delivered -> stream of {stream} events",
        probe.mc_sent, probe.mc_delivered
    );
    root.insert("storm_workload".to_string(), Json::Str(probe.workload.clone()));
    root.insert("storm_mc_sent".to_string(), Json::Num(probe.mc_sent as f64));
    root.insert("storm_mc_delivered".to_string(), Json::Num(probe.mc_delivered as f64));
    root.insert("stream_events".to_string(), Json::Num(stream as f64));

    // ---- hub fan-out at 0 / 1 / 4 / 16 sinks ---------------------------
    let mut rows = Vec::new();
    for sinks in [0usize, 1, 4, 16] {
        let (wall_ms, events_per_sec) = fanout_row(stream, sinks);
        println!("{sinks:>3} sinks: {events_per_sec:>12.0} events/sec ({wall_ms:.1} ms)");
        let mut row = BTreeMap::new();
        row.insert("sinks".into(), Json::Num(sinks as f64));
        row.insert("events".into(), Json::Num(stream as f64));
        row.insert("wall_ms".into(), Json::Num(wall_ms));
        row.insert("events_per_sec".into(), Json::Num(events_per_sec));
        rows.push(Json::Obj(row));
    }
    root.insert("fanout_rows".to_string(), Json::Arr(rows));

    // ---- observation overhead on a real supervised run -----------------
    let (plain, unwatched_ms, _) = watched_workload(0);
    let (watched, watched_ms, published) = watched_workload(16);
    assert_eq!(
        watched, plain,
        "observation changed the run — the bus is not observation-only"
    );
    let ratio = watched_ms / unwatched_ms.max(1e-9);
    println!(
        "supervised conway: {unwatched_ms:.1} ms unwatched, {watched_ms:.1} ms with 16 sinks \
         (x{ratio:.3}, {published} events published, byte-identical)"
    );
    let mut overhead = BTreeMap::new();
    overhead.insert("wall_ms_unwatched".to_string(), Json::Num(unwatched_ms));
    overhead.insert("wall_ms_16_sinks".to_string(), Json::Num(watched_ms));
    overhead.insert("overhead_ratio".to_string(), Json::Num(ratio));
    overhead.insert("events_published".to_string(), Json::Num(published as f64));
    overhead.insert("byte_identical".to_string(), Json::Bool(true));
    root.insert("overhead".to_string(), Json::Obj(overhead));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root")
        .join("BENCH_bus.json");
    std::fs::write(&out, Json::Obj(root).to_string_pretty())?;
    println!("\nresults written to {}", out.display());
    Ok(())
}
