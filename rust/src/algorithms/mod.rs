//! The algorithm execution engine (§6.7, Figure 10).
//!
//! Algorithms declare required input tokens and produced output tokens;
//! the executor computes a workflow order so every algorithm runs after
//! its inputs exist. Tokens can be data ("placements") or implicit
//! markers ("data_loaded") — exactly the paper's token mechanism.
//!
//! Data flows through a type-erased [`Blackboard`] keyed by token name;
//! an algorithm is a boxed closure over it. The front end (Figure 8)
//! expresses every phase — machine discovery, mapping, data generation,
//! loading, running — as algorithms on this engine.

use std::any::Any;
use std::collections::{BTreeMap, BTreeSet};

/// Type-erased token store.
///
/// Tokens may carry an *input fingerprint* (`set_fp`/`put_with_fp`): a
/// digest of the content the token was derived from, used by
/// [`Executor::execute_cached`] to decide stage cleanliness. The
/// fingerprint table is independent of the value table — `put`/`take`
/// never touch it — because stages routinely `take` a token, transform
/// it, and re-`put` it within one algorithm; the executor re-stamps the
/// fingerprints of every declared output after the stage runs, so a
/// stale entry can only be observed by code that bypasses the executor.
#[derive(Default)]
pub struct Blackboard {
    items: BTreeMap<String, Box<dyn Any>>,
    fps: BTreeMap<String, u64>,
}

impl Blackboard {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put<T: Any>(&mut self, token: &str, value: T) {
        self.items.insert(token.to_string(), Box::new(value));
    }

    /// `put` plus an input fingerprint for the token.
    pub fn put_with_fp<T: Any>(&mut self, token: &str, value: T, fp: u64) {
        self.put(token, value);
        self.set_fp(token, fp);
    }

    /// Stamp a token's fingerprint without touching its value.
    pub fn set_fp(&mut self, token: &str, fp: u64) {
        self.fps.insert(token.to_string(), fp);
    }

    /// A token's fingerprint, if one was stamped.
    pub fn fp_of(&self, token: &str) -> Option<u64> {
        self.fps.get(token).copied()
    }

    /// Insert a marker token (implicit output, e.g. "data_loaded").
    pub fn mark(&mut self, token: &str) {
        self.put(token, ());
    }

    pub fn has(&self, token: &str) -> bool {
        self.items.contains_key(token)
    }

    pub fn get<T: Any>(&self, token: &str) -> anyhow::Result<&T> {
        self.items
            .get(token)
            .ok_or_else(|| anyhow::anyhow!("token '{token}' not produced"))?
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow::anyhow!("token '{token}' has unexpected type"))
    }

    pub fn get_mut<T: Any>(&mut self, token: &str) -> anyhow::Result<&mut T> {
        self.items
            .get_mut(token)
            .ok_or_else(|| anyhow::anyhow!("token '{token}' not produced"))?
            .downcast_mut::<T>()
            .ok_or_else(|| anyhow::anyhow!("token '{token}' has unexpected type"))
    }

    pub fn take<T: Any>(&mut self, token: &str) -> anyhow::Result<T> {
        let boxed = self
            .items
            .remove(token)
            .ok_or_else(|| anyhow::anyhow!("token '{token}' not produced"))?;
        boxed
            .downcast::<T>()
            .map(|b| *b)
            .map_err(|_| anyhow::anyhow!("token '{token}' has unexpected type"))
    }

    pub fn tokens(&self) -> impl Iterator<Item = &str> {
        self.items.keys().map(|s| s.as_str())
    }
}

type AlgorithmFn = Box<dyn FnMut(&mut Blackboard) -> anyhow::Result<()>>;

/// A sharded algorithm body: called with the executor's worker-pool
/// width; internally splits, fans out, and joins.
type ShardedFn = Box<dyn FnMut(&mut Blackboard, usize) -> anyhow::Result<()>>;

/// How an algorithm executes: a plain closure, or a declared shardable
/// inner loop the executor fans out over its worker pool.
enum Body {
    Plain(AlgorithmFn),
    Sharded(ShardedFn),
}

/// One algorithm: a named closure with declared inputs/outputs.
pub struct Algorithm {
    pub name: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    /// The tokens whose *fingerprints* key this stage's cache entry
    /// (see [`Executor::execute_cached`]). `None` means "all declared
    /// inputs". Narrowing this below `inputs` is a soundness claim by
    /// the author: the excluded inputs cannot change the output while
    /// the included fingerprints are stable (e.g. the mapping pipeline's
    /// tag allocator excludes `placements` because pinned placements
    /// never move while the tag-request digest is unchanged).
    fp_inputs: Option<Vec<String>>,
    body: Body,
}

impl Algorithm {
    pub fn new(
        name: &str,
        inputs: &[&str],
        outputs: &[&str],
        run: impl FnMut(&mut Blackboard) -> anyhow::Result<()> + 'static,
    ) -> Self {
        Self {
            name: name.to_string(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            fp_inputs: None,
            body: Body::Plain(Box::new(run)),
        }
    }

    /// Override which tokens' fingerprints key this stage's cache entry.
    pub fn with_fp_inputs(mut self, tokens: &[&str]) -> Self {
        self.fp_inputs = Some(tokens.iter().map(|s| s.to_string()).collect());
        self
    }

    /// The tokens whose fingerprints key this stage (DESIGN.md §7).
    pub fn fp_tokens(&self) -> &[String] {
        self.fp_inputs.as_deref().unwrap_or(&self.inputs)
    }

    /// An algorithm with a declared shardable inner loop, in three
    /// phases the executor drives:
    ///
    /// 1. `split` (serial, on the blackboard) produces a shared context
    ///    and a list of independent work items;
    /// 2. `process` runs once per item on the executor's worker pool —
    ///    it sees only the context and its item, never the blackboard;
    /// 3. `merge` (serial) receives the outputs **in item order** and
    ///    writes the declared output tokens.
    ///
    /// Because the join preserves item order, a sharded algorithm's
    /// result is identical at any pool width.
    pub fn sharded<C, I, O, S, P, M>(
        name: &str,
        inputs: &[&str],
        outputs: &[&str],
        split: S,
        process: P,
        merge: M,
    ) -> Self
    where
        C: Sync + 'static,
        I: Sync + 'static,
        O: Send + 'static,
        S: FnMut(&mut Blackboard) -> anyhow::Result<(C, Vec<I>)> + 'static,
        P: Fn(&C, &I) -> anyhow::Result<O> + Sync + 'static,
        M: FnMut(&mut Blackboard, C, Vec<O>) -> anyhow::Result<()> + 'static,
    {
        let mut split = split;
        let mut merge = merge;
        let body = move |board: &mut Blackboard, threads: usize| -> anyhow::Result<()> {
            let (ctx, items) = split(board)?;
            let outs =
                crate::util::par::try_par_map(threads, &items, |_, item| process(&ctx, item))?;
            merge(board, ctx, outs)
        };
        Self {
            name: name.to_string(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            fp_inputs: None,
            body: Body::Sharded(Box::new(body)),
        }
    }

    /// Whether this algorithm declares a shardable inner loop.
    pub fn is_sharded(&self) -> bool {
        matches!(self.body, Body::Sharded(_))
    }
}

/// The workflow executor of Figure 10: orders algorithms by token
/// dependencies and runs them, fanning sharded algorithms out over a
/// worker pool of the configured width.
pub struct Executor {
    algorithms: Vec<Algorithm>,
    threads: usize,
}

/// The order the executor chose (kept for provenance/debugging).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workflow(pub Vec<String>);

/// Per-stage record of one [`Executor::execute_cached`] pass — the
/// §6.3.5 provenance of the pipeline itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageStat {
    pub name: String,
    /// True when the stage was skipped because its input fingerprints
    /// were unchanged and its outputs were still on the blackboard.
    pub cached: bool,
    /// Wall-clock of the stage body (0 for cache hits).
    pub elapsed_us: u64,
}

/// Fingerprint-keyed stage memo (DESIGN.md §7). Each executed stage
/// records the combined fingerprint of the tokens it declared it reads;
/// on the next pass over a *persistent* blackboard, a stage whose
/// fingerprint is unchanged and whose outputs are still present is
/// skipped outright. Tokens without a stamped fingerprint are treated as
/// always-changed (a fresh nonce per lookup), so forgetting to stamp an
/// input degrades to correct-but-uncached behaviour.
#[derive(Debug, Default)]
pub struct StageCache {
    /// stage name -> input fingerprint at its last execution.
    fps: BTreeMap<String, u64>,
    nonce: u64,
    /// Stats of the most recent `execute_cached` pass.
    pub last_run: Vec<StageStat>,
}

impl StageCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Forget every memoised stage (the next pass re-runs everything).
    pub fn clear(&mut self) {
        self.fps.clear();
        self.last_run.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.fps.is_empty()
    }

    fn next_nonce(&mut self) -> u64 {
        self.nonce = self.nonce.wrapping_add(1);
        self.nonce ^ 0x9E37_79B9_7F4A_7C15
    }
}

/// The derived fingerprint of a stage output: a pure function of the
/// stage's input fingerprint and the output token name, so downstream
/// cache keys flow through the DAG without hashing any actual output.
fn derived_fp(in_fp: u64, output: &str) -> u64 {
    let mut h = crate::util::FNV_OFFSET;
    crate::util::fnv1a_64_extend(&mut h, &in_fp.to_le_bytes());
    crate::util::fnv1a_64_extend(&mut h, output.as_bytes());
    h
}

impl Executor {
    pub fn new(algorithms: Vec<Algorithm>) -> Self {
        Self { algorithms, threads: 1 }
    }

    /// Set the worker-pool width sharded algorithms fan out to
    /// (`1` = serial, `0` = one worker per hardware thread).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Compute an execution order: repeatedly run any algorithm whose
    /// inputs are all available (initial tokens + prior outputs). Errors
    /// if tokens required to reach `goals` can never be produced.
    pub fn plan(&self, initial: &BTreeSet<String>, goals: &[&str]) -> anyhow::Result<Workflow> {
        let mut available = initial.clone();
        let mut remaining: Vec<usize> = (0..self.algorithms.len()).collect();
        let mut order = Vec::new();
        loop {
            let ready = remaining.iter().position(|i| {
                self.algorithms[*i]
                    .inputs
                    .iter()
                    .all(|t| available.contains(t))
            });
            match ready {
                Some(pos) => {
                    let idx = remaining.remove(pos);
                    for o in &self.algorithms[idx].outputs {
                        available.insert(o.clone());
                    }
                    order.push(self.algorithms[idx].name.clone());
                }
                None => break,
            }
        }
        for goal in goals {
            if !available.contains(*goal) {
                let missing: Vec<&str> = remaining
                    .iter()
                    .flat_map(|i| self.algorithms[*i].inputs.iter())
                    .filter(|t| !available.contains(*t))
                    .map(|s| s.as_str())
                    .collect();
                anyhow::bail!(
                    "goal token '{goal}' unreachable; unsatisfied inputs: {missing:?}"
                );
            }
        }
        Ok(Workflow(order))
    }

    /// Plan then run every algorithm in order against `board` until all
    /// `goals` exist. Algorithms not needed for the goals still run if
    /// their inputs become available (matching the paper's engine, which
    /// executes the provided algorithm list, not a minimal slice).
    pub fn execute(
        self,
        board: &mut Blackboard,
        goals: &[&str],
    ) -> anyhow::Result<Workflow> {
        let mut cache = StageCache::new();
        self.execute_cached(board, goals, &mut cache)
    }

    /// [`Self::execute`] with fingerprint-keyed stage skipping: a stage
    /// whose `fp_tokens` digests match its entry in `cache` — and whose
    /// declared outputs are still on `board` — does not run at all; the
    /// prior outputs on the persistent blackboard stand in for it. Every
    /// pass records per-stage hit/miss and wall-clock into
    /// `cache.last_run` for provenance.
    pub fn execute_cached(
        mut self,
        board: &mut Blackboard,
        goals: &[&str],
        cache: &mut StageCache,
    ) -> anyhow::Result<Workflow> {
        let initial: BTreeSet<String> = board.tokens().map(|s| s.to_string()).collect();
        let plan = self.plan(&initial, goals)?;
        let threads = self.threads;
        let mut by_name: BTreeMap<String, Algorithm> = self
            .algorithms
            .drain(..)
            .map(|a| (a.name.clone(), a))
            .collect();
        cache.last_run.clear();
        for name in &plan.0 {
            let alg = by_name.get_mut(name).unwrap();
            // Combined fingerprint of the declared cache-key tokens.
            let mut in_fp = crate::util::FNV_OFFSET;
            crate::util::fnv1a_64_extend(&mut in_fp, name.as_bytes());
            for token in alg.fp_tokens() {
                let fp = match board.fp_of(token) {
                    Some(fp) => fp,
                    // Unstamped input: treat as always-changed.
                    None => cache.next_nonce(),
                };
                crate::util::fnv1a_64_extend(&mut in_fp, token.as_bytes());
                crate::util::fnv1a_64_extend(&mut in_fp, &fp.to_le_bytes());
            }
            let clean = cache.fps.get(name) == Some(&in_fp)
                && alg.outputs.iter().all(|o| board.has(o));
            if clean {
                for o in &alg.outputs {
                    board.set_fp(o, derived_fp(in_fp, o));
                }
                cache.last_run.push(StageStat {
                    name: name.clone(),
                    cached: true,
                    elapsed_us: 0,
                });
                continue;
            }
            let t0 = std::time::Instant::now();
            match &mut alg.body {
                Body::Plain(run) => run(board),
                Body::Sharded(run) => run(board, threads),
            }
            .map_err(|e| anyhow::anyhow!("algorithm '{name}' failed: {e}"))?;
            // Verify the algorithm delivered its declared outputs.
            for o in &alg.outputs {
                anyhow::ensure!(
                    board.has(o),
                    "algorithm '{name}' did not produce declared output '{o}'"
                );
                board.set_fp(o, derived_fp(in_fp, o));
            }
            cache.fps.insert(name.clone(), in_fp);
            cache.last_run.push(StageStat {
                name: name.clone(),
                cached: false,
                elapsed_us: t0.elapsed().as_micros() as u64,
            });
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn marker_alg(name: &str, inputs: &[&str], outputs: &[&str]) -> Algorithm {
        let outs: Vec<String> = outputs.iter().map(|s| s.to_string()).collect();
        Algorithm::new(name, inputs, outputs, move |b| {
            for o in &outs {
                b.mark(o);
            }
            Ok(())
        })
    }

    #[test]
    fn orders_by_dependencies() {
        // placement -> routing -> tables, declared in reverse.
        let ex = Executor::new(vec![
            marker_alg("tables", &["routes", "keys"], &["tables"]),
            marker_alg("keys", &["graph"], &["keys"]),
            marker_alg("router", &["placements"], &["routes"]),
            marker_alg("placer", &["graph", "machine"], &["placements"]),
        ]);
        let mut initial = BTreeSet::new();
        initial.insert("graph".to_string());
        initial.insert("machine".to_string());
        let plan = ex.plan(&initial, &["tables"]).unwrap();
        let pos = |n: &str| plan.0.iter().position(|x| x == n).unwrap();
        assert!(pos("placer") < pos("router"));
        assert!(pos("router") < pos("tables"));
        assert!(pos("keys") < pos("tables"));
    }

    #[test]
    fn unreachable_goal_errors() {
        let ex = Executor::new(vec![marker_alg("a", &["missing"], &["out"])]);
        let err = ex.plan(&BTreeSet::new(), &["out"]).unwrap_err();
        assert!(err.to_string().contains("unreachable"));
    }

    #[test]
    fn execute_runs_and_checks_outputs() {
        let mut board = Blackboard::new();
        board.put("x", 21u64);
        let ex = Executor::new(vec![
            Algorithm::new("double", &["x"], &["y"], |b| {
                let x: u64 = *b.get("x")?;
                b.put("y", x * 2);
                Ok(())
            }),
            Algorithm::new("stringify", &["y"], &["s"], |b| {
                let y: u64 = *b.get("y")?;
                b.put("s", format!("{y}"));
                Ok(())
            }),
        ]);
        ex.execute(&mut board, &["s"]).unwrap();
        assert_eq!(board.get::<String>("s").unwrap(), "42");
    }

    #[test]
    fn lying_algorithm_detected() {
        let mut board = Blackboard::new();
        let ex = Executor::new(vec![Algorithm::new("liar", &[], &["gold"], |_| Ok(()))]);
        let err = ex.execute(&mut board, &["gold"]).unwrap_err();
        assert!(err.to_string().contains("did not produce"));
    }

    #[test]
    fn multi_output_algorithm() {
        // §6.7: "algorithms are not constrained to produce only one
        // output ... placements and routing tables optimised together".
        let mut board = Blackboard::new();
        board.mark("graph");
        let ex = Executor::new(vec![marker_alg(
            "place_and_route",
            &["graph"],
            &["placements", "routes"],
        )]);
        ex.execute(&mut board, &["placements", "routes"]).unwrap();
        assert!(board.has("placements") && board.has("routes"));
    }

    #[test]
    fn token_type_mismatch_is_error() {
        let mut b = Blackboard::new();
        b.put("n", 1u32);
        assert!(b.get::<String>("n").is_err());
        assert!(b.get::<u32>("n").is_ok());
    }

    fn square_sum_alg() -> Algorithm {
        Algorithm::sharded(
            "square_sum",
            &["numbers"],
            &["total"],
            |b: &mut Blackboard| {
                let ns: &Vec<u64> = b.get("numbers")?;
                Ok((2u64, ns.clone()))
            },
            |scale: &u64, n: &u64| Ok(n * n * scale),
            |b: &mut Blackboard, _scale, squares: Vec<u64>| {
                b.put("total", squares.iter().sum::<u64>());
                Ok(())
            },
        )
    }

    #[test]
    fn sharded_algorithm_fans_out_and_joins() {
        let serial = {
            let mut board = Blackboard::new();
            board.put("numbers", (0u64..100).collect::<Vec<u64>>());
            Executor::new(vec![square_sum_alg()])
                .with_threads(1)
                .execute(&mut board, &["total"])
                .unwrap();
            *board.get::<u64>("total").unwrap()
        };
        for threads in [2usize, 8] {
            let mut board = Blackboard::new();
            board.put("numbers", (0u64..100).collect::<Vec<u64>>());
            let ex = Executor::new(vec![square_sum_alg()]).with_threads(threads);
            assert!(ex.algorithms[0].is_sharded());
            ex.execute(&mut board, &["total"]).unwrap();
            assert_eq!(*board.get::<u64>("total").unwrap(), serial, "threads={threads}");
        }
        assert_eq!(serial, 2 * (0u64..100).map(|n| n * n).sum::<u64>());
    }

    #[test]
    fn sharded_algorithm_propagates_item_errors() {
        let alg = Algorithm::sharded(
            "fails",
            &[],
            &["out"],
            |_: &mut Blackboard| Ok(((), vec![1u32, 2, 3])),
            |_: &(), n: &u32| {
                anyhow::ensure!(*n != 2, "item {n} broke");
                Ok(*n)
            },
            |b: &mut Blackboard, _, _outs: Vec<u32>| {
                b.put("out", ());
                Ok(())
            },
        );
        let mut board = Blackboard::new();
        let err = Executor::new(vec![alg])
            .with_threads(4)
            .execute(&mut board, &["out"])
            .unwrap_err();
        assert!(err.to_string().contains("item 2 broke"), "{err}");
    }

    /// A pipeline of two counting stages for the cache tests.
    fn counting_algs(runs: std::rc::Rc<std::cell::RefCell<Vec<&'static str>>>) -> Vec<Algorithm> {
        let r1 = runs.clone();
        let r2 = runs;
        vec![
            Algorithm::new("double", &["x"], &["y"], move |b| {
                r1.borrow_mut().push("double");
                let x: u64 = *b.get("x")?;
                b.put("y", x * 2);
                Ok(())
            }),
            Algorithm::new("stringify", &["y"], &["s"], move |b| {
                r2.borrow_mut().push("stringify");
                let y: u64 = *b.get("y")?;
                b.put("s", format!("{y}"));
                Ok(())
            }),
        ]
    }

    #[test]
    fn cached_execution_skips_clean_stages() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let runs = Rc::new(RefCell::new(Vec::new()));
        let mut board = Blackboard::new();
        let mut cache = StageCache::new();
        board.put_with_fp("x", 21u64, 100);
        Executor::new(counting_algs(runs.clone()))
            .execute_cached(&mut board, &["s"], &mut cache)
            .unwrap();
        assert_eq!(*runs.borrow(), vec!["double", "stringify"]);
        assert!(cache.last_run.iter().all(|s| !s.cached));

        // Same fingerprints: both stages are clean and skipped.
        board.put_with_fp("x", 21u64, 100);
        Executor::new(counting_algs(runs.clone()))
            .execute_cached(&mut board, &["s"], &mut cache)
            .unwrap();
        assert_eq!(runs.borrow().len(), 2, "no stage should have re-run");
        assert!(cache.last_run.iter().all(|s| s.cached));
        assert_eq!(board.get::<String>("s").unwrap(), "42");

        // Changed input fingerprint: the whole chain re-runs (the
        // derived fingerprint of y changes, dirtying stringify too).
        board.put_with_fp("x", 30u64, 101);
        Executor::new(counting_algs(runs.clone()))
            .execute_cached(&mut board, &["s"], &mut cache)
            .unwrap();
        assert_eq!(runs.borrow().len(), 4);
        assert_eq!(board.get::<String>("s").unwrap(), "60");
    }

    #[test]
    fn unstamped_inputs_always_rerun() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let runs = Rc::new(RefCell::new(Vec::new()));
        let mut board = Blackboard::new();
        let mut cache = StageCache::new();
        board.put("x", 5u64); // no fingerprint stamped
        for _ in 0..2 {
            Executor::new(counting_algs(runs.clone()))
                .execute_cached(&mut board, &["s"], &mut cache)
                .unwrap();
        }
        assert_eq!(runs.borrow().len(), 4, "unstamped token must defeat the cache");
    }

    #[test]
    fn fp_inputs_narrow_the_cache_key() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let runs = Rc::new(RefCell::new(0usize));
        let make = |runs: Rc<RefCell<usize>>| {
            Algorithm::new("narrow", &["a", "b"], &["out"], move |board| {
                *runs.borrow_mut() += 1;
                board.mark("out");
                Ok(())
            })
            .with_fp_inputs(&["a"])
        };
        let mut board = Blackboard::new();
        let mut cache = StageCache::new();
        board.put_with_fp("a", 1u64, 7);
        board.put_with_fp("b", 1u64, 7);
        Executor::new(vec![make(runs.clone())])
            .execute_cached(&mut board, &["out"], &mut cache)
            .unwrap();
        // b changes, but only a's fingerprint keys the stage.
        board.put_with_fp("b", 2u64, 8);
        Executor::new(vec![make(runs.clone())])
            .execute_cached(&mut board, &["out"], &mut cache)
            .unwrap();
        assert_eq!(*runs.borrow(), 1, "change to excluded input must not dirty");
        board.put_with_fp("a", 2u64, 9);
        Executor::new(vec![make(runs.clone())])
            .execute_cached(&mut board, &["out"], &mut cache)
            .unwrap();
        assert_eq!(*runs.borrow(), 2);
    }
}
