//! The [`Machine`] representation and virtual-machine builders (§5.1).

use std::collections::BTreeMap;
use std::iter::Peekable;

use super::chip::Chip;
use super::geometry::{spinn5_chip_offsets, triad_ethernet_positions, Direction};

/// Chip coordinates (x, y).
pub type ChipCoord = (u32, u32);

/// A fully-qualified core location (chip x, chip y, processor id).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CoreLocation {
    pub x: u32,
    pub y: u32,
    pub p: u8,
}

impl CoreLocation {
    pub fn new(x: u32, y: u32, p: u8) -> Self {
        Self { x, y, p }
    }

    pub fn chip(&self) -> ChipCoord {
        (self.x, self.y)
    }
}

impl std::fmt::Display for CoreLocation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{},{},{}", self.x, self.y, self.p)
    }
}

/// A SpiNNaker machine: chips on a (possibly torus-wrapped) 2D grid.
///
/// Storage is a flat slot array indexed `x * height + y` — struct of
/// arrays rather than a map, so a 1M-chip machine is one allocation with
/// no per-chip node overhead (DESIGN.md §12). The slot order is exactly
/// the `(x, y)` lexicographic order the historical `BTreeMap` iterated
/// in, and off-grid virtual device chips (§5.1 — their coordinates
/// "don't have to align with the rest of the machine") live in a small
/// side map merged back into iteration at the right positions, so every
/// consumer still sees the deterministic order mapping reproducibility
/// (§6.5) depends on.
#[derive(Debug, Clone)]
pub struct Machine {
    pub width: u32,
    pub height: u32,
    /// Whether links wrap around the edges (true for triad-tiled
    /// multi-board toroids, false for standalone boards).
    pub wrap: bool,
    /// In-grid chips, slot `x * height + y`; `None` = no chip (dead, or
    /// outside a board footprint).
    grid: Vec<Option<Chip>>,
    /// Chips whose coordinates fall outside the declared grid (virtual
    /// device chips parked off-board).
    off_grid: BTreeMap<ChipCoord, Chip>,
    /// Chip count, maintained on add/remove (the grid is not scanned).
    n_chips: usize,
    /// Cached [`Machine::real_extent`], maintained on add/remove.
    extent: (u32, u32),
    /// Off-grid adjacencies for virtual (device) chips, §5.1: virtual
    /// chip coordinates "don't have to align with the rest of the
    /// machine", so their links are recorded explicitly rather than
    /// derived from geometry. Key: (chip, link direction) -> other chip.
    virtual_links: BTreeMap<(ChipCoord, Direction), ChipCoord>,
}

/// Merge two `(x, y)`-sorted chip streams (the grid slots and the
/// off-grid side map) into one globally sorted stream.
struct MergeByCoord<A: Iterator, B: Iterator> {
    a: Peekable<A>,
    b: Peekable<B>,
}

impl<'m, A, B> Iterator for MergeByCoord<A, B>
where
    A: Iterator<Item = &'m Chip>,
    B: Iterator<Item = &'m Chip>,
{
    type Item = &'m Chip;

    fn next(&mut self) -> Option<&'m Chip> {
        match (self.a.peek(), self.b.peek()) {
            (Some(x), Some(y)) => {
                if (x.x, x.y) <= (y.x, y.y) {
                    self.a.next()
                } else {
                    self.b.next()
                }
            }
            (Some(_), None) => self.a.next(),
            (None, _) => self.b.next(),
        }
    }
}

impl Machine {
    pub fn new(width: u32, height: u32, wrap: bool) -> Self {
        Self {
            width,
            height,
            wrap,
            grid: vec![None; width as usize * height as usize],
            off_grid: BTreeMap::new(),
            n_chips: 0,
            extent: (width.max(1), height.max(1)),
            virtual_links: BTreeMap::new(),
        }
    }

    #[inline]
    fn slot(&self, c: ChipCoord) -> Option<usize> {
        if c.0 < self.width && c.1 < self.height {
            Some(c.0 as usize * self.height as usize + c.1 as usize)
        } else {
            None
        }
    }

    /// Register an explicit (non-geometric) link, e.g. to a virtual chip.
    pub fn add_virtual_link(&mut self, from: ChipCoord, d: Direction, to: ChipCoord) {
        self.virtual_links.insert((from, d), to);
        self.virtual_links.insert((to, d.opposite()), from);
    }

    pub fn add_chip(&mut self, chip: Chip) {
        let c = (chip.x, chip.y);
        if !chip.is_virtual {
            self.extent.0 = self.extent.0.max(chip.x + 1);
            self.extent.1 = self.extent.1.max(chip.y + 1);
        }
        let replaced = match self.slot(c) {
            Some(i) => self.grid[i].replace(chip).is_some(),
            None => self.off_grid.insert(c, chip).is_some(),
        };
        if !replaced {
            self.n_chips += 1;
        }
    }

    pub fn chip(&self, c: ChipCoord) -> Option<&Chip> {
        match self.slot(c) {
            Some(i) => self.grid[i].as_ref(),
            None => self.off_grid.get(&c),
        }
    }

    pub fn chip_mut(&mut self, c: ChipCoord) -> Option<&mut Chip> {
        match self.slot(c) {
            Some(i) => self.grid[i].as_mut(),
            None => self.off_grid.get_mut(&c),
        }
    }

    /// All chips in `(x, y)` lexicographic order (off-grid device chips
    /// merged in at their coordinate positions).
    pub fn chips(&self) -> impl Iterator<Item = &Chip> {
        MergeByCoord {
            a: self.grid.iter().filter_map(|c| c.as_ref()).peekable(),
            b: self.off_grid.values().peekable(),
        }
    }

    pub fn chip_coords(&self) -> impl Iterator<Item = ChipCoord> + '_ {
        self.chips().map(|c| (c.x, c.y))
    }

    pub fn n_chips(&self) -> usize {
        self.n_chips
    }

    pub fn n_cores(&self) -> usize {
        self.chips().map(|c| c.n_processors()).sum()
    }

    pub fn n_application_cores(&self) -> usize {
        self.chips().map(|c| c.n_application_cores()).sum()
    }

    pub fn ethernet_chips(&self) -> impl Iterator<Item = &Chip> {
        self.chips().filter(|c| c.is_ethernet())
    }

    /// The chip one hop from `from` in direction `d`, with torus wrap if
    /// enabled — ignoring link health (pure geometry).
    pub fn neighbour_coord(&self, from: ChipCoord, d: Direction) -> Option<ChipCoord> {
        let (dx, dy) = d.delta();
        let nx = from.0 as i64 + dx as i64;
        let ny = from.1 as i64 + dy as i64;
        let (nx, ny) = if self.wrap {
            (
                nx.rem_euclid(self.width as i64) as u32,
                ny.rem_euclid(self.height as i64) as u32,
            )
        } else {
            if nx < 0 || ny < 0 || nx >= self.width as i64 || ny >= self.height as i64 {
                return None;
            }
            (nx as u32, ny as u32)
        };
        Some((nx, ny))
    }

    /// The chip reachable over a *working* link in direction `d`: both
    /// endpoints must exist and both ends of the link must be up.
    /// Explicit virtual links (devices) take precedence over geometry.
    pub fn link_target(&self, from: ChipCoord, d: Direction) -> Option<ChipCoord> {
        if let Some(to) = self.virtual_links.get(&(from, d)) {
            return self.chip(*to).map(|_| *to);
        }
        let src = self.chip(from)?;
        if !src.has_link(d) {
            return None;
        }
        let to = self.neighbour_coord(from, d)?;
        let dst = self.chip(to)?;
        if dst.is_virtual {
            // Geometric adjacency to a virtual chip is a coincidence of
            // coordinates, not a wire.
            return None;
        }
        if !dst.has_link(d.opposite()) {
            return None;
        }
        Some(to)
    }

    /// Shortest-path (dx, dy) vector from `a` to `b` respecting wrap.
    pub fn shortest_vector(&self, a: ChipCoord, b: ChipCoord) -> (i32, i32) {
        let mut dx = b.0 as i64 - a.0 as i64;
        let mut dy = b.1 as i64 - a.1 as i64;
        if self.wrap {
            let w = self.width as i64;
            let h = self.height as i64;
            if dx > w / 2 {
                dx -= w;
            } else if dx < -w / 2 {
                dx += w;
            }
            if dy > h / 2 {
                dy -= h;
            } else if dy < -h / 2 {
                dy += h;
            }
        }
        (dx as i32, dy as i32)
    }

    /// Total working SDRAM for applications, over all chips.
    pub fn total_user_sdram(&self) -> u64 {
        self.chips().map(|c| c.sdram.user_size() as u64).sum()
    }

    /// The Ethernet chip responsible for `c` (SCAMP relays host traffic
    /// to non-Ethernet chips over the P2P fabric via this chip, §3).
    pub fn nearest_ethernet(&self, c: ChipCoord) -> Option<ChipCoord> {
        self.chip(c).map(|ch| ch.nearest_ethernet)
    }

    /// Dense bounding-box dimensions of the *real* (non-virtual) chips:
    /// the smallest `(w, h)` such that every real chip has `x < w` and
    /// `y < h`, never smaller than the declared grid. The simulator
    /// sizes its flat chip arena (index `y * w + x`) from this, so
    /// virtual device chips parked at off-grid coordinates (§5.1) cost
    /// nothing. Cached at construction time and maintained on
    /// [`Machine::add_chip`]/[`Machine::remove_chip`] — construction
    /// paths call this per chip, so it must not rescan the machine.
    pub fn real_extent(&self) -> (u32, u32) {
        self.extent
    }

    fn recompute_extent(&mut self) {
        let mut w = self.width.max(1);
        let mut h = self.height.max(1);
        for c in self.chips().filter(|c| !c.is_virtual) {
            w = w.max(c.x + 1);
            h = h.max(c.y + 1);
        }
        self.extent = (w, h);
    }

    /// Remove a chip from the machine entirely (runtime chip death or a
    /// degraded re-discovery view): neighbours lose the link toward it
    /// and any virtual link touching it is dropped. The builder-time
    /// [`MachineBuilder::dead_chip`] delegates here. O(1) in machine
    /// size: only the six geometric neighbours are touched.
    pub fn remove_chip(&mut self, c: ChipCoord) {
        let removed = match self.slot(c) {
            Some(i) => self.grid[i].take(),
            None => self.off_grid.remove(&c),
        };
        let Some(removed) = removed else { return };
        self.n_chips -= 1;
        // The six neighbours hold the only geometric links toward `c`:
        // the chip at neighbour_coord(c, d) reaches c via d.opposite().
        for d in super::geometry::ALL_DIRECTIONS {
            if let Some(n) = self.neighbour_coord(c, d) {
                if let Some(chip) = self.chip_mut(n) {
                    chip.remove_link(d.opposite());
                }
            }
        }
        self.virtual_links
            .retain(|(from, _), to| *from != c && *to != c);
        // Only a real chip parked outside the declared grid can have
        // stretched the cached extent; in-grid chips are bounded by the
        // (width, height) floor, so the cache cannot shrink below it.
        if !removed.is_virtual && (c.0 >= self.width || c.1 >= self.height) {
            self.recompute_extent();
        }
    }

    /// Remove a link in both directions (runtime link death). Geometry
    /// is unaffected; only link health changes. Explicit virtual links
    /// (device wires) die the same way — `link_target` consults the
    /// virtual-link table before geometry, so they must be dropped here
    /// or the wire would survive its own death.
    pub fn remove_link(&mut self, c: ChipCoord, d: Direction) {
        if let Some(to) = self.virtual_links.remove(&(c, d)) {
            self.virtual_links.remove(&(to, d.opposite()));
        }
        let other = self.neighbour_coord(c, d);
        if let Some(chip) = self.chip_mut(c) {
            chip.remove_link(d);
        }
        if let Some(o) = other {
            if let Some(chip) = self.chip_mut(o) {
                chip.remove_link(d.opposite());
            }
        }
    }

    /// Manhattan-ish hop distance on the hexagonal fabric: with diagonal
    /// NE/SW moves, distance((dx,dy)) = max(|dx|,|dy|) when signs match,
    /// |dx|+|dy| when they differ.
    pub fn hop_distance(&self, a: ChipCoord, b: ChipCoord) -> u32 {
        let (dx, dy) = self.shortest_vector(a, b);
        if (dx >= 0) == (dy >= 0) {
            dx.abs().max(dy.abs()) as u32
        } else {
            (dx.abs() + dy.abs()) as u32
        }
    }
}

/// Builders for virtual machines (and the geometry the simulator boots).
pub struct MachineBuilder {
    machine: Machine,
}

impl MachineBuilder {
    /// A single SpiNN-3 board: 2x2 grid of 4 chips, Ethernet at (0,0).
    pub fn spinn3() -> Self {
        let mut m = Machine::new(2, 2, false);
        for (x, y) in [(0, 0), (1, 0), (0, 1), (1, 1)] {
            let mut chip = Chip::new(x, y, 18);
            chip.nearest_ethernet = (0, 0);
            m.add_chip(chip);
        }
        m.chip_mut((0, 0)).unwrap().ethernet_ip = Some("192.168.240.253".into());
        Self { machine: m }.prune_edge_links()
    }

    /// A single SpiNN-5 board: 48 chips in the hexagonal footprint,
    /// Ethernet at (0,0). Not wrapped.
    pub fn spinn5() -> Self {
        let mut m = Machine::new(8, 8, false);
        for (x, y) in spinn5_chip_offsets() {
            let mut chip = Chip::new(x as u32, y as u32, 18);
            chip.nearest_ethernet = (0, 0);
            m.add_chip(chip);
        }
        m.chip_mut((0, 0)).unwrap().ethernet_ip = Some("192.168.240.1".into());
        Self { machine: m }.prune_edge_links()
    }

    /// A triad-tiled toroidal machine of `triads_x x triads_y` triads
    /// (3 boards, 144 chips, 12x12 per triad) — the wiring of Figure 3.
    pub fn triads(triads_x: u32, triads_y: u32) -> Self {
        assert!(triads_x > 0 && triads_y > 0);
        let (w, h) = (triads_x * 12, triads_y * 12);
        let mut m = Machine::new(w, h, true);
        for x in 0..w {
            for y in 0..h {
                m.add_chip(Chip::new(x, y, 18));
            }
        }
        let eths = triad_ethernet_positions(triads_x, triads_y);
        // Assign each chip to the nearest Ethernet chip (its board).
        for x in 0..w {
            for y in 0..h {
                let best = *eths
                    .iter()
                    .min_by_key(|e| {
                        let dx = (x as i64 - e.0 as i64).rem_euclid(w as i64).min(
                            (e.0 as i64 - x as i64).rem_euclid(w as i64),
                        );
                        let dy = (y as i64 - e.1 as i64).rem_euclid(h as i64).min(
                            (e.1 as i64 - y as i64).rem_euclid(h as i64),
                        );
                        dx + dy
                    })
                    .unwrap();
                m.chip_mut((x, y)).unwrap().nearest_ethernet = best;
            }
        }
        for (i, e) in eths.iter().enumerate() {
            m.chip_mut(*e).unwrap().ethernet_ip = Some(format!("10.11.{}.{}", i / 256, i % 256));
        }
        Self { machine: m }
    }

    /// `n_boards` SpiNN-5 boards: 1 board is a standalone spinn5; larger
    /// counts round up to whole triads (as physical machines do).
    pub fn boards(n_boards: u32) -> Self {
        if n_boards <= 1 {
            return Self::spinn5();
        }
        let triads = n_boards.div_ceil(3);
        // Lay triads out in as square a grid as possible.
        let tx = (triads as f64).sqrt().ceil() as u32;
        let ty = triads.div_ceil(tx);
        Self::triads(tx, ty)
    }

    /// A wafer-scale toroid of at least `n_chips` chips: the smallest
    /// square triad-tiled torus (side a multiple of 12) with that many
    /// chips. This is the SpiNNaker2-scale construction path (DESIGN.md
    /// §12): chips stream straight into the flat slot array, and the
    /// per-chip nearest-Ethernet assignment is served from a 12x12
    /// periodic lookup table — the Ethernet lattice repeats every triad,
    /// so the O(chips x boards) scan [`MachineBuilder::triads`] performs
    /// is unnecessary. Construction is O(n) with no intermediate maps:
    /// ~1M chips build in well under a second.
    pub fn wafer(n_chips: u32) -> Self {
        let side = ((n_chips.max(1) as f64).sqrt().ceil() as u32).div_ceil(12).max(1) * 12;
        let (w, h) = (side, side);
        let mut m = Machine::new(w, h, true);
        // Nearest-Ethernet offsets, one per position within a triad tile:
        // the best (dx, dy) to add (mod w/h) to reach the chip's board
        // Ethernet. The candidate lattice is the 3 per-tile Ethernet
        // offsets across the 3x3 surrounding tiles; anything further is
        // at least 13 hops away while the in-tile candidate is <= 22 and
        // the true optimum <= 8, so the neighbourhood is exhaustive.
        const TILE_ETHS: [(i64, i64); 3] = [(0, 0), (4, 8), (8, 4)];
        let mut nearest = [[(0i64, 0i64); 12]; 12];
        for lx in 0..12i64 {
            for ly in 0..12i64 {
                let mut best = (i64::MAX, (0i64, 0i64));
                for tdx in -1..=1i64 {
                    for tdy in -1..=1i64 {
                        for (ex, ey) in TILE_ETHS {
                            let ddx = tdx * 12 + ex - lx;
                            let ddy = tdy * 12 + ey - ly;
                            let key = (ddx.abs() + ddy.abs(), (ddx, ddy));
                            if key < best {
                                best = (key.0, key.1);
                            }
                        }
                    }
                }
                nearest[lx as usize][ly as usize] = best.1;
            }
        }
        let mut eth_index = 0usize;
        for x in 0..w {
            for y in 0..h {
                let mut chip = Chip::new(x, y, 18);
                let (ddx, ddy) = nearest[x as usize % 12][y as usize % 12];
                chip.nearest_ethernet = (
                    (x as i64 + ddx).rem_euclid(w as i64) as u32,
                    (y as i64 + ddy).rem_euclid(h as i64) as u32,
                );
                if (ddx, ddy) == (0, 0) {
                    chip.ethernet_ip = Some(format!(
                        "10.{}.{}.{}",
                        eth_index / 65536,
                        (eth_index / 256) % 256,
                        eth_index % 256
                    ));
                    eth_index += 1;
                }
                m.add_chip(chip);
            }
        }
        Self { machine: m }
    }

    /// A full rectangular torus (every chip present) — convenient for
    /// unit tests that need exact dimensions.
    pub fn grid(width: u32, height: u32, wrap: bool) -> Self {
        let mut m = Machine::new(width, height, wrap);
        for x in 0..width {
            for y in 0..height {
                let mut c = Chip::new(x, y, 18);
                c.nearest_ethernet = (0, 0);
                m.add_chip(c);
            }
        }
        m.chip_mut((0, 0)).unwrap().ethernet_ip = Some("127.0.0.1".into());
        Self { machine: m }.prune_edge_links()
    }

    /// Remove links that point off the machine (non-wrapped boards).
    fn prune_edge_links(mut self) -> Self {
        if self.machine.wrap {
            return self;
        }
        let coords: Vec<ChipCoord> = self.machine.chip_coords().collect();
        for c in coords {
            for d in super::geometry::ALL_DIRECTIONS {
                let target = self.machine.neighbour_coord(c, d);
                let missing = match target {
                    None => true,
                    Some(t) => self.machine.chip(t).is_none(),
                };
                if missing {
                    self.machine.chip_mut(c).unwrap().remove_link(d);
                }
            }
        }
        self
    }

    /// Blacklist a whole chip (§2 fault tolerance).
    pub fn dead_chip(mut self, c: ChipCoord) -> Self {
        self.machine.remove_chip(c);
        self
    }

    /// Blacklist one core of a chip.
    pub fn dead_core(mut self, c: ChipCoord, p: u8) -> Self {
        if let Some(chip) = self.machine.chip_mut(c) {
            chip.remove_processor(p);
        }
        self
    }

    /// Blacklist a link (both directions).
    pub fn dead_link(mut self, c: ChipCoord, d: Direction) -> Self {
        self.machine.remove_link(c, d);
        self
    }

    /// Add a virtual chip standing in for an external device (§5.1),
    /// connected to real chip `attached_to` via its `link` direction.
    /// The wire is recorded as an explicit virtual link, so `coord` need
    /// not be geometrically adjacent (or even on the grid).
    pub fn virtual_chip(mut self, coord: ChipCoord, attached_to: ChipCoord, link: Direction) -> Self {
        let mut chip = Chip::new(coord.0, coord.1, 1);
        chip.is_virtual = true;
        chip.nearest_ethernet = self
            .machine
            .chip(attached_to)
            .map(|c| c.nearest_ethernet)
            .unwrap_or((0, 0));
        chip.set_only_link(link.opposite());
        self.machine.add_chip(chip);
        self.machine.add_virtual_link(attached_to, link, coord);
        self
    }

    pub fn build(self) -> Machine {
        self.machine
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spinn3_is_4_chips() {
        let m = MachineBuilder::spinn3().build();
        assert_eq!(m.n_chips(), 4);
        assert_eq!(m.ethernet_chips().count(), 1);
        assert_eq!(m.n_cores(), 72);
    }

    #[test]
    fn spinn5_is_48_chips() {
        let m = MachineBuilder::spinn5().build();
        assert_eq!(m.n_chips(), 48);
        assert!(m.chip((0, 0)).unwrap().is_ethernet());
        // (4,0) is on the board, (7,0) isn't.
        assert!(m.chip((4, 0)).is_some());
        assert!(m.chip((7, 0)).is_none());
        assert_eq!(m.n_application_cores(), 48 * 17);
    }

    #[test]
    fn spinn5_edge_links_pruned() {
        let m = MachineBuilder::spinn5().build();
        // (0,0) is the bottom-left corner: West/South/SouthWest point off-board.
        let c = m.chip((0, 0)).unwrap();
        assert!(!c.has_link(Direction::West));
        assert!(!c.has_link(Direction::South));
        assert!(!c.has_link(Direction::SouthWest));
        assert!(c.has_link(Direction::East));
        assert!(c.has_link(Direction::North));
        assert!(c.has_link(Direction::NorthEast));
    }

    #[test]
    fn one_triad_is_144_chip_torus() {
        let m = MachineBuilder::triads(1, 1).build();
        assert_eq!(m.n_chips(), 144);
        assert!(m.wrap);
        assert_eq!(m.ethernet_chips().count(), 3);
        // Torus wrap: neighbour of (11, 5) going East is (0, 5).
        assert_eq!(m.neighbour_coord((11, 5), Direction::East), Some((0, 5)));
    }

    #[test]
    fn boards_rounds_to_triads() {
        assert_eq!(MachineBuilder::boards(1).build().n_chips(), 48);
        assert_eq!(MachineBuilder::boards(3).build().n_chips(), 144);
        assert_eq!(MachineBuilder::boards(6).build().n_chips(), 288);
    }

    #[test]
    fn shortest_vector_wraps() {
        let m = MachineBuilder::triads(1, 1).build(); // 12x12 torus
        assert_eq!(m.shortest_vector((0, 0), (11, 0)), (-1, 0));
        assert_eq!(m.shortest_vector((0, 0), (5, 0)), (5, 0));
        assert_eq!(m.shortest_vector((1, 1), (0, 11)), (-1, -2));
    }

    #[test]
    fn shortest_vector_no_wrap() {
        let m = MachineBuilder::spinn5().build();
        assert_eq!(m.shortest_vector((0, 0), (7, 7)), (7, 7));
    }

    #[test]
    fn hop_distance_hexagonal() {
        let m = MachineBuilder::grid(16, 16, false).build();
        // Same-sign diagonal uses NE moves: max(|dx|,|dy|).
        assert_eq!(m.hop_distance((0, 0), (3, 5)), 5);
        // Opposite signs can't use a diagonal: |dx|+|dy|.
        assert_eq!(m.hop_distance((3, 0), (0, 5)), 8);
    }

    #[test]
    fn dead_chip_removes_neighbour_links() {
        let m = MachineBuilder::grid(4, 4, false).dead_chip((1, 1)).build();
        assert!(m.chip((1, 1)).is_none());
        assert!(!m.chip((0, 1)).unwrap().has_link(Direction::East));
        assert!(!m.chip((1, 0)).unwrap().has_link(Direction::North));
        assert!(!m.chip((0, 0)).unwrap().has_link(Direction::NorthEast));
        assert_eq!(m.link_target((0, 1), Direction::East), None);
    }

    #[test]
    fn dead_core_removed() {
        let m = MachineBuilder::spinn3().dead_core((0, 0), 17).build();
        assert_eq!(m.chip((0, 0)).unwrap().n_processors(), 17);
    }

    #[test]
    fn remove_link_kills_virtual_wires_too() {
        // `link_target` consults virtual links before geometry, so a
        // device wire must actually die when its link is removed.
        let mut m = MachineBuilder::spinn5()
            .virtual_chip((100, 100), (0, 0), Direction::SouthWest)
            .build();
        assert_eq!(m.link_target((0, 0), Direction::SouthWest), Some((100, 100)));
        m.remove_link((0, 0), Direction::SouthWest);
        assert_eq!(m.link_target((0, 0), Direction::SouthWest), None);
        assert_eq!(m.link_target((100, 100), Direction::NorthEast), None);
    }

    #[test]
    fn dead_link_is_bidirectional() {
        let m = MachineBuilder::grid(4, 4, false)
            .dead_link((0, 0), Direction::East)
            .build();
        assert_eq!(m.link_target((0, 0), Direction::East), None);
        assert_eq!(m.link_target((1, 0), Direction::West), None);
        // Geometry unaffected.
        assert_eq!(m.neighbour_coord((0, 0), Direction::East), Some((1, 0)));
    }

    #[test]
    fn real_extent_ignores_virtual_chips() {
        let m = MachineBuilder::spinn5()
            .virtual_chip((100, 100), (0, 0), Direction::SouthWest)
            .build();
        assert_eq!(m.real_extent(), (8, 8), "device chip must not inflate the arena");
        assert_eq!(MachineBuilder::spinn3().build().real_extent(), (2, 2));
    }

    #[test]
    fn virtual_chip_attaches() {
        let m = MachineBuilder::spinn5()
            .virtual_chip((100, 100), (0, 0), Direction::SouthWest)
            .build();
        let v = m.chip((100, 100)).unwrap();
        assert!(v.is_virtual);
        assert_eq!(m.n_chips(), 49);
    }

    #[test]
    fn triad_chips_have_boards_assigned() {
        let m = MachineBuilder::triads(1, 1).build();
        for chip in m.chips() {
            let e = chip.nearest_ethernet;
            assert!(m.chip(e).unwrap().is_ethernet(), "chip {:?}", (chip.x, chip.y));
        }
    }

    #[test]
    fn iteration_order_is_lexicographic_with_off_grid_merged() {
        // Off-grid virtual chips must interleave at their coordinate
        // positions, not trail the grid: (0, 999) sorts between (0, 7)
        // and (1, 0) on an 8-wide board.
        let m = MachineBuilder::spinn5()
            .virtual_chip((0, 999), (0, 0), Direction::SouthWest)
            .virtual_chip((100, 100), (7, 7), Direction::NorthEast)
            .build();
        let coords: Vec<ChipCoord> = m.chip_coords().collect();
        assert_eq!(coords.len(), 50);
        assert!(coords.windows(2).all(|w| w[0] < w[1]), "sorted: {coords:?}");
        let i999 = coords.iter().position(|c| *c == (0, 999)).unwrap();
        assert!(coords[i999 - 1].0 == 0 && coords[i999 + 1] == (1, 0));
        assert_eq!(*coords.last().unwrap(), (100, 100));
    }

    #[test]
    fn extent_cache_tracks_removals() {
        let mut m = MachineBuilder::spinn5().build();
        // An off-grid *real* chip stretches the extent...
        let far = Chip::new(20, 3, 18);
        m.add_chip(far);
        assert_eq!(m.real_extent(), (21, 8));
        // ...and removing it shrinks the cache back to the grid floor.
        m.remove_chip((20, 3));
        assert_eq!(m.real_extent(), (8, 8));
        // In-grid removals never move the extent.
        m.remove_chip((4, 4));
        assert_eq!(m.real_extent(), (8, 8));
    }

    #[test]
    fn wafer_builds_triad_toroids() {
        let m = MachineBuilder::wafer(1000).build();
        // 1000 chips -> 32 side -> rounded up to 36: a 3x3-triad torus.
        assert_eq!((m.width, m.height), (36, 36));
        assert_eq!(m.n_chips(), 36 * 36);
        assert!(m.wrap);
        // One board Ethernet per 48 chips, at the triad lattice points.
        assert_eq!(m.ethernet_chips().count(), (36 / 12) * (36 / 12) * 3);
        assert!(m.chip((0, 0)).unwrap().is_ethernet());
        assert!(m.chip((4, 8)).unwrap().is_ethernet());
        assert!(m.chip((20, 16)).unwrap().is_ethernet());
        // Every chip's board assignment is a real Ethernet chip.
        for chip in m.chips() {
            let e = chip.nearest_ethernet;
            assert!(m.chip(e).unwrap().is_ethernet(), "chip {:?} -> {e:?}", (chip.x, chip.y));
        }
        assert_eq!(m.real_extent(), (36, 36));
    }

    #[test]
    fn wafer_matches_triads_on_structure() {
        // Same side -> same chip set, wrap, and Ethernet lattice as the
        // scan-based triad builder (nearest-Ethernet may tie-break
        // differently; the lattice itself must agree).
        let w = MachineBuilder::wafer(144).build();
        let t = MachineBuilder::triads(1, 1).build();
        assert_eq!((w.width, w.height), (t.width, t.height));
        assert_eq!(w.n_chips(), t.n_chips());
        let we: Vec<ChipCoord> = w.ethernet_chips().map(|c| (c.x, c.y)).collect();
        let te: Vec<ChipCoord> = t.ethernet_chips().map(|c| (c.x, c.y)).collect();
        assert_eq!(we, te);
    }
}
