//! The SpiNNaker multicast router TCAM (§2, Figure 4).
//!
//! An ordered list of up to [`super::ROUTER_ENTRIES`] `{key, mask, route}`
//! entries. An incoming packet key matches entry *i* iff
//! `key & mask_i == key_i & mask_i`; the **first** match wins. The route
//! word has 6 link bits (bits 0–5, [`Direction`] id order) and 18
//! processor bits (bits 6–23). With no match, the packet default-routes
//! straight through (out the opposite link); a no-match packet injected
//! by a local core is dropped.



use std::collections::HashMap;

use super::geometry::{Direction, ALL_DIRECTIONS};
use super::ROUTER_ENTRIES;

/// Iterate the set bits of a word, lowest first. Shared by the route
/// accessors so link/processor iteration is one `trailing_zeros` per
/// member instead of a scan over every possible position.
struct Bits(u32);

impl Iterator for Bits {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.0 == 0 {
            return None;
        }
        let b = self.0.trailing_zeros();
        self.0 &= self.0 - 1;
        Some(b)
    }
}

/// A multicast route: which links and local processors a packet is
/// forwarded to. Wraps the 24-bit route word.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Route(pub u32);

impl Route {
    pub const EMPTY: Route = Route(0);

    pub fn with_link(mut self, d: Direction) -> Route {
        self.0 |= 1 << d.id();
        self
    }

    pub fn with_processor(mut self, p: u8) -> Route {
        // The route word has exactly 18 processor bits (6..=23), the
        // same range `processors()` iterates.
        debug_assert!(p < 18, "processor id out of range");
        self.0 |= 1 << (6 + p as u32);
        self
    }

    pub fn add_link(&mut self, d: Direction) {
        self.0 |= 1 << d.id();
    }

    pub fn add_processor(&mut self, p: u8) {
        debug_assert!(p < 18, "processor id out of range");
        self.0 |= 1 << (6 + p as u32);
    }

    pub fn has_link(self, d: Direction) -> bool {
        self.0 & (1 << d.id()) != 0
    }

    pub fn has_processor(self, p: u8) -> bool {
        self.0 & (1 << (6 + p as u32)) != 0
    }

    pub fn links(self) -> impl Iterator<Item = Direction> {
        Bits(self.0 & 0x3f).map(|b| ALL_DIRECTIONS[b as usize])
    }

    pub fn processors(self) -> impl Iterator<Item = u8> {
        Bits((self.0 >> 6) & 0x3_ffff).map(|b| b as u8)
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Merge two routes (multicast union).
    pub fn union(self, other: Route) -> Route {
        Route(self.0 | other.0)
    }

    /// A route that only continues out of one link with no local
    /// delivery — the only kind of entry that default routing could
    /// replace (used by the compressor's default-route elision).
    pub fn single_link(self) -> Option<Direction> {
        if self.0 & !0x3f != 0 {
            return None;
        }
        let mut it = self.links();
        match (it.next(), it.next()) {
            (Some(d), None) => Some(d),
            _ => None,
        }
    }
}

/// One TCAM entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RoutingEntry {
    pub key: u32,
    pub mask: u32,
    pub route: Route,
}

impl RoutingEntry {
    pub fn new(key: u32, mask: u32, route: Route) -> Self {
        Self { key, mask, route }
    }

    #[inline]
    pub fn matches(&self, key: u32) -> bool {
        key & self.mask == self.key & self.mask
    }

    /// True iff every key matched by `other` is also matched by `self`
    /// (self's mask is a subset of other's constraint). Used by the
    /// ordered-covering compressor's aliasing check.
    pub fn covers(&self, other: &RoutingEntry) -> bool {
        // self covers other iff self.mask bits ⊆ other.mask bits and the
        // two agree on self's masked bits.
        (self.mask & !other.mask) == 0
            && (self.key & self.mask) == (other.key & self.mask)
    }

    /// Whether the match sets of the two entries intersect.
    pub fn intersects(&self, other: &RoutingEntry) -> bool {
        let common = self.mask & other.mask;
        (self.key & common) == (other.key & common)
    }
}

/// An ordered multicast routing table (first match wins).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RoutingTable {
    entries: Vec<RoutingEntry>,
}

impl RoutingTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_entries(entries: Vec<RoutingEntry>) -> Self {
        Self { entries }
    }

    /// Append an entry. Unlike hardware we do not hard-fail at 1024 here —
    /// capacity is validated by the loader so the compressor can be
    /// exercised on oversubscribed tables (experiment E10).
    pub fn push(&mut self, e: RoutingEntry) {
        self.entries.push(e);
    }

    pub fn entries(&self) -> &[RoutingEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True iff the table fits the hardware TCAM.
    pub fn fits(&self) -> bool {
        self.entries.len() <= ROUTER_ENTRIES
    }

    /// First-match lookup (Figure 4 semantics).
    #[inline]
    pub fn lookup(&self, key: u32) -> Option<Route> {
        self.entries.iter().find(|e| e.matches(key)).map(|e| e.route)
    }

    /// Full routing decision for a packet arriving from `from`:
    /// a matched route, or the default straight-through route, or a drop
    /// (locally-injected packet with no matching entry).
    pub fn route_packet(&self, key: u32, from: PacketSource) -> RoutingDecision {
        RoutingDecision::from_lookup(self.lookup(key), from)
    }
}

/// A memoising front for [`RoutingTable`] lookups — the simulator's
/// per-chip route cache (experiment E11). A chip sees a small bounded
/// set of distinct keys (the partitions whose multicast trees touch
/// it), so the first-match linear scan over up to 1024 TCAM entries
/// amortises to a single hash probe. Only the *lookup* is cached — the
/// default-route/drop outcome still depends on where the packet entered
/// and is derived per packet, so one cache serves every [`PacketSource`].
///
/// The owner must [`RouteCache::clear`] whenever the table changes; the
/// simulator routes every table load through `SimChip::install_table`,
/// which does exactly that.
#[derive(Debug, Clone, Default)]
pub struct RouteCache {
    map: HashMap<u32, Option<Route>>,
}

impl RouteCache {
    /// Bound on distinct cached keys. Past it the cache resets — a
    /// safety valve against adversarial key streams; real workloads
    /// stay orders of magnitude below (keys per chip ≈ table entries).
    pub const MAX_ENTRIES: usize = 8192;

    pub fn new() -> Self {
        Self::default()
    }

    /// Invalidate every memoised lookup (table load/clear).
    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Route `key` through `table`, memoising the TCAM scan. Returns
    /// the decision plus whether it was served from the cache.
    #[inline]
    pub fn route(
        &mut self,
        table: &RoutingTable,
        key: u32,
        from: PacketSource,
    ) -> (RoutingDecision, bool) {
        if let Some(&cached) = self.map.get(&key) {
            return (RoutingDecision::from_lookup(cached, from), true);
        }
        let looked = table.lookup(key);
        if self.map.len() >= Self::MAX_ENTRIES {
            self.map.clear();
        }
        self.map.insert(key, looked);
        (RoutingDecision::from_lookup(looked, from), false)
    }
}

/// Where a packet entered this router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PacketSource {
    /// Arrived over an inter-chip link: the value is the side of *this*
    /// chip the packet entered on (a packet travelling East enters on
    /// the West link), so default routing continues out of `.opposite()`.
    Link(Direction),
    /// Injected by a local core.
    Local(u8),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingDecision {
    Routed(Route),
    /// No entry matched; continues out of the given link.
    DefaultRouted(Direction),
    /// No entry matched a locally-injected packet.
    Dropped,
}

impl RoutingDecision {
    /// Decision for a TCAM lookup result plus the packet's entry point —
    /// the Figure-4 semantics shared by [`RoutingTable::route_packet`]
    /// and the memoised [`RouteCache`] path.
    #[inline]
    pub fn from_lookup(route: Option<Route>, from: PacketSource) -> RoutingDecision {
        match route {
            Some(r) => RoutingDecision::Routed(r),
            None => match from {
                PacketSource::Link(d) => RoutingDecision::DefaultRouted(d.opposite()),
                PacketSource::Local(_) => RoutingDecision::Dropped,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(key: u32, mask: u32, route: Route) -> RoutingEntry {
        RoutingEntry::new(key, mask, route)
    }

    #[test]
    fn first_match_wins() {
        let mut t = RoutingTable::new();
        t.push(e(0x10, 0xfff0, Route::EMPTY.with_processor(1)));
        t.push(e(0x10, 0xff00, Route::EMPTY.with_processor(2)));
        // 0x10 matches both; entry order decides
        assert_eq!(t.lookup(0x10), Some(Route::EMPTY.with_processor(1)));
        // 0x20 only matches the wider second entry
        assert_eq!(t.lookup(0x20), Some(Route::EMPTY.with_processor(2)));
    }

    #[test]
    fn masked_matching() {
        let entry = e(0b1010_0000, 0b1111_0000, Route::EMPTY.with_link(Direction::East));
        assert!(entry.matches(0b1010_0000));
        assert!(entry.matches(0b1010_1111)); // low bits ignored
        assert!(!entry.matches(0b1011_0000));
    }

    #[test]
    fn default_route_is_straight_through() {
        let t = RoutingTable::new();
        // Packet travelling East entered via our West side; it leaves East.
        match t.route_packet(0x1234, PacketSource::Link(Direction::West)) {
            RoutingDecision::DefaultRouted(d) => assert_eq!(d, Direction::East),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn local_no_match_drops() {
        let t = RoutingTable::new();
        assert_eq!(
            t.route_packet(0x1234, PacketSource::Local(3)),
            RoutingDecision::Dropped
        );
    }

    #[test]
    fn route_word_layout() {
        let r = Route::EMPTY.with_link(Direction::East).with_processor(0).with_processor(17);
        assert_eq!(r.0, 1 | (1 << 6) | (1 << 23));
        assert!(r.has_link(Direction::East));
        assert!(!r.has_link(Direction::West));
        assert_eq!(r.processors().collect::<Vec<_>>(), vec![0, 17]);
    }

    #[test]
    fn single_link_detection() {
        assert_eq!(
            Route::EMPTY.with_link(Direction::North).single_link(),
            Some(Direction::North)
        );
        assert_eq!(
            Route::EMPTY
                .with_link(Direction::North)
                .with_link(Direction::South)
                .single_link(),
            None
        );
        assert_eq!(
            Route::EMPTY
                .with_link(Direction::North)
                .with_processor(2)
                .single_link(),
            None
        );
        assert_eq!(Route::EMPTY.single_link(), None);
    }

    #[test]
    fn covers_and_intersects() {
        let wide = e(0x100, 0xff00, Route::EMPTY);
        let narrow = e(0x110, 0xfff0, Route::EMPTY);
        assert!(wide.covers(&narrow));
        assert!(!narrow.covers(&wide));
        assert!(wide.intersects(&narrow));
        let disjoint = e(0x200, 0xff00, Route::EMPTY);
        assert!(!wide.intersects(&disjoint));
    }

    #[test]
    fn cache_agrees_with_table_for_every_source() {
        let table = RoutingTable::from_entries(vec![
            e(0x100, 0xff00, Route::EMPTY.with_processor(3)),
            e(0x1000, 0xf000, Route::EMPTY.with_link(Direction::North)),
        ]);
        let mut cache = RouteCache::new();
        let sources = [
            PacketSource::Local(1),
            PacketSource::Link(Direction::West),
            PacketSource::Link(Direction::SouthWest),
        ];
        for key in [0x100u32, 0x1fe, 0x1234, 0xdead_0000, 0x1001] {
            for from in sources {
                let (first, _) = cache.route(&table, key, from);
                assert_eq!(first, table.route_packet(key, from), "key {key:#x}");
                // Second time round must hit and agree.
                let (again, hit) = cache.route(&table, key, from);
                assert!(hit);
                assert_eq!(again, first);
            }
        }
        assert_eq!(cache.len(), 5, "one entry per distinct key");
    }

    #[test]
    fn cache_clear_forgets_stale_routes() {
        let a = RoutingTable::from_entries(vec![e(7, !0, Route::EMPTY.with_processor(1))]);
        let b = RoutingTable::from_entries(vec![e(7, !0, Route::EMPTY.with_processor(2))]);
        let mut cache = RouteCache::new();
        let from = PacketSource::Local(0);
        assert_eq!(cache.route(&a, 7, from).0, RoutingDecision::Routed(Route::EMPTY.with_processor(1)));
        // Without a clear the memo would mask the new table.
        cache.clear();
        assert!(cache.is_empty());
        let (decision, hit) = cache.route(&b, 7, from);
        assert!(!hit);
        assert_eq!(decision, RoutingDecision::Routed(Route::EMPTY.with_processor(2)));
    }

    #[test]
    fn cache_resets_at_capacity_instead_of_growing() {
        let table = RoutingTable::new();
        let mut cache = RouteCache::new();
        for key in 0..(RouteCache::MAX_ENTRIES as u32 + 10) {
            cache.route(&table, key, PacketSource::Local(0));
        }
        assert!(cache.len() <= RouteCache::MAX_ENTRIES);
        assert!(!cache.is_empty());
    }

    #[test]
    fn fits_tracks_capacity() {
        let mut t = RoutingTable::new();
        for i in 0..1024 {
            t.push(e(i, 0xffff_ffff, Route::EMPTY.with_processor(1)));
        }
        assert!(t.fits());
        t.push(e(2000, 0xffff_ffff, Route::EMPTY));
        assert!(!t.fits());
    }
}
