//! The SpiNNaker machine model (§2 and Figure 5 of the paper).
//!
//! A [`Machine`] is a 2D (torus-wrapped for multi-board systems) grid of
//! [`Chip`]s, each with up to 18 ARM cores, 128 MiB of shared SDRAM, a
//! 1024-entry multicast [`router`], and six inter-chip links. Boards are
//! the 48-chip SpiNN-5 (or 4-chip SpiNN-3) production layouts; larger
//! machines tile SpiNN-5 boards in *triads* exactly as the physical
//! wiring (Figure 3) does.
//!
//! Mirroring the paper's Python class hierarchy, the same structures
//! describe both a *discovered* physical machine (here: discovered from
//! the [`crate::simulator`]) and a *virtual machine* built for mapping
//! without hardware, including fault injection (dead chips / cores /
//! links — the "blacklist" of §2).

mod chip;
mod geometry;
mod machine_impl;
pub mod router;

pub use chip::{Chip, Processor, Sdram};
pub use geometry::{spinn5_chip_offsets, Direction, ALL_DIRECTIONS};
pub use machine_impl::{ChipCoord, CoreLocation, Machine, MachineBuilder};

/// Bytes of SDRAM on a production chip (128 MiB), minus nothing: the
/// usable amount after SCAMP is configured per-chip on the [`Chip`].
pub const SDRAM_PER_CHIP: u32 = 128 * 1024 * 1024;

/// Bytes of DTCM per core.
pub const DTCM_PER_CORE: u32 = 64 * 1024;

/// Bytes of ITCM per core.
pub const ITCM_PER_CORE: u32 = 32 * 1024;

/// Multicast routing-table capacity per router (§2, Figure 4).
pub const ROUTER_ENTRIES: usize = 1024;

/// Cores per chip on a fully working production chip.
pub const MAX_CORES_PER_CHIP: usize = 18;

/// IP tags per Ethernet chip (§3).
pub const IPTAGS_PER_BOARD: usize = 8;
