//! Per-chip structures: processors, SDRAM bookkeeping and the chip record
//! itself (the `Chip`/`Processor`/`SDRAM`/`Router` classes of Figure 5).
//!
//! At SpiNNaker2 scale (100k–1M chips, DESIGN.md §12) the chip record is
//! the unit the whole machine model multiplies by, so it is kept flat:
//! the working-core and working-link sets are bitmasks (`u32`/`u8`), and
//! [`Processor`] records are derived on demand rather than stored. Every
//! production core is identical silicon (200 MHz, 64 KiB DTCM, 32 KiB
//! ITCM, core 0 runs the monitor), so a present/absent bit reconstructs
//! the full record losslessly. One `Chip` is ~64 bytes with no heap
//! allocations (unless it is an Ethernet chip carrying an IP string),
//! down from ~500 bytes across three allocations in the pre-SoA layout.

use super::geometry::{Direction, ALL_DIRECTIONS};
use super::{DTCM_PER_CORE, ITCM_PER_CORE, ROUTER_ENTRIES, SDRAM_PER_CHIP};

/// One ARM968 core. Core 0 conventionally runs the SCAMP monitor after
/// boot; application cores are 1..n. Derived on demand from the chip's
/// working-core bitmask — all production cores share this shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Processor {
    pub id: u8,
    pub is_monitor: bool,
    /// Clock in MHz — 200 on production silicon; exposed because mapping
    /// uses it to budget CPU cycles per timestep.
    pub clock_mhz: u32,
    pub dtcm_bytes: u32,
    pub itcm_bytes: u32,
}

impl Processor {
    pub fn application(id: u8) -> Self {
        Self {
            id,
            is_monitor: false,
            clock_mhz: 200,
            dtcm_bytes: DTCM_PER_CORE,
            itcm_bytes: ITCM_PER_CORE,
        }
    }

    pub fn monitor(id: u8) -> Self {
        Self { is_monitor: true, ..Self::application(id) }
    }

    /// The record for core `id` under the core-0-is-monitor convention.
    fn for_id(id: u8) -> Self {
        if id == 0 {
            Self::monitor(id)
        } else {
            Self::application(id)
        }
    }

    /// CPU cycles available per simulation timestep of `timestep_us`.
    pub fn cycles_per_timestep(&self, timestep_us: u32) -> u64 {
        self.clock_mhz as u64 * timestep_us as u64
    }
}

/// Shared node-local SDRAM bookkeeping.
#[derive(Debug, Clone)]
pub struct Sdram {
    pub size: u32,
    /// Bytes reserved by system software (SCAMP, reinjector buffers...).
    pub system_reserved: u32,
}

impl Default for Sdram {
    fn default() -> Self {
        // SCAMP reserves a small system heap at the top of SDRAM.
        Self { size: SDRAM_PER_CHIP, system_reserved: 1024 * 1024 }
    }
}

impl Sdram {
    pub fn user_size(&self) -> u32 {
        self.size - self.system_reserved
    }
}

/// Iterate the set bits of a word, lowest first.
struct Bits(u32);

impl Iterator for Bits {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.0 == 0 {
            return None;
        }
        let b = self.0.trailing_zeros();
        self.0 &= self.0 - 1;
        Some(b)
    }
}

/// One SpiNNaker chip as seen by the mapping layer.
#[derive(Debug, Clone)]
pub struct Chip {
    pub x: u32,
    pub y: u32,
    /// Working cores, bit `p` set ⇒ core `p` present (bit 0 = monitor).
    core_mask: u32,
    /// Working links, bit `d.id()` set ⇒ link `d` present.
    link_mask: u8,
    pub sdram: Sdram,
    /// Routing entries available to applications (SCAMP can consume some).
    pub n_router_entries: usize,
    /// IP address when this is an Ethernet chip.
    pub ethernet_ip: Option<String>,
    /// Coordinates of the Ethernet chip of this chip's board.
    pub nearest_ethernet: (u32, u32),
    /// Virtual chips (§5.1) stand in for external devices: they exist in
    /// the machine representation so placement/routing can target them,
    /// but nothing is loaded onto them.
    pub is_virtual: bool,
}

impl Chip {
    pub fn new(x: u32, y: u32, n_cores: usize) -> Self {
        debug_assert!(n_cores <= 32, "core mask is 32 bits wide");
        let core_mask = if n_cores >= 32 { u32::MAX } else { (1u32 << n_cores) - 1 };
        Self {
            x,
            y,
            core_mask,
            link_mask: 0x3f,
            sdram: Sdram::default(),
            n_router_entries: ROUTER_ENTRIES,
            ethernet_ip: None,
            nearest_ethernet: (x, y),
            is_virtual: false,
        }
    }

    pub fn is_ethernet(&self) -> bool {
        self.ethernet_ip.is_some()
    }

    /// Working cores, ascending id, as derived [`Processor`] records.
    pub fn processors(&self) -> impl Iterator<Item = Processor> {
        Bits(self.core_mask).map(|b| Processor::for_id(b as u8))
    }

    /// Application (non-monitor) cores, ascending id.
    pub fn application_processors(&self) -> impl Iterator<Item = Processor> {
        Bits(self.core_mask & !1).map(|b| Processor::application(b as u8))
    }

    pub fn n_processors(&self) -> usize {
        self.core_mask.count_ones() as usize
    }

    pub fn n_application_cores(&self) -> usize {
        (self.core_mask & !1).count_ones() as usize
    }

    pub fn processor(&self, id: u8) -> Option<Processor> {
        if id < 32 && self.core_mask & (1 << id) != 0 {
            Some(Processor::for_id(id))
        } else {
            None
        }
    }

    /// Mark core `id` dead (§2 blacklist / runtime fault).
    pub fn remove_processor(&mut self, id: u8) {
        if id < 32 {
            self.core_mask &= !(1 << id);
        }
    }

    /// The raw working-core bitmask (bit `p` = core `p` present) — the
    /// simulator boots its per-chip core store straight off this.
    pub fn core_mask(&self) -> u32 {
        self.core_mask
    }

    pub fn has_link(&self, d: Direction) -> bool {
        self.link_mask & (1 << d.id()) != 0
    }

    pub fn remove_link(&mut self, d: Direction) {
        self.link_mask &= !(1 << d.id());
    }

    /// Reduce the link set to exactly `d` (virtual device chips have a
    /// single wire back to their attachment point).
    pub fn set_only_link(&mut self, d: Direction) {
        self.link_mask = 1 << d.id();
    }

    /// Links that are present and working, in [`Direction`] id order.
    pub fn working_links(&self) -> impl Iterator<Item = Direction> {
        Bits(self.link_mask as u32).map(|b| ALL_DIRECTIONS[b as usize])
    }

    pub fn n_links(&self) -> usize {
        self.link_mask.count_ones() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_defaults() {
        let c = Chip::new(1, 2, 18);
        assert_eq!(c.n_processors(), 18);
        assert_eq!(c.n_application_cores(), 17); // core 0 is the monitor
        assert!(c.processor(0).unwrap().is_monitor);
        assert!(!c.processor(1).unwrap().is_monitor);
        assert_eq!(c.n_links(), 6);
        assert!(!c.is_ethernet());
        assert_eq!(c.n_router_entries, 1024);
    }

    #[test]
    fn sdram_user_size_excludes_system() {
        let s = Sdram::default();
        assert_eq!(s.user_size(), 127 * 1024 * 1024);
    }

    #[test]
    fn cycles_per_timestep_at_200mhz() {
        let p = Processor::application(1);
        assert_eq!(p.cycles_per_timestep(1000), 200_000);
    }

    #[test]
    fn remove_link() {
        let mut c = Chip::new(0, 0, 18);
        c.remove_link(Direction::North);
        assert!(!c.has_link(Direction::North));
        assert_eq!(c.n_links(), 5);
    }

    #[test]
    fn processors_derive_from_mask_in_id_order() {
        let mut c = Chip::new(0, 0, 18);
        c.remove_processor(3);
        assert!(c.processor(3).is_none());
        assert_eq!(c.n_processors(), 17);
        assert_eq!(c.n_application_cores(), 16);
        let ids: Vec<u8> = c.processors().map(|p| p.id).collect();
        assert_eq!(ids.len(), 17);
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "ascending id order");
        assert!(!ids.contains(&3));
        let app_ids: Vec<u8> = c.application_processors().map(|p| p.id).collect();
        assert!(!app_ids.contains(&0) && !app_ids.contains(&3));
    }

    #[test]
    fn set_only_link_keeps_one_wire() {
        let mut c = Chip::new(5, 5, 1);
        c.set_only_link(Direction::SouthWest);
        assert_eq!(c.working_links().collect::<Vec<_>>(), vec![Direction::SouthWest]);
        assert!(!c.has_link(Direction::East));
    }

    #[test]
    fn chip_record_is_flat() {
        // The per-chip byte budget DESIGN.md §12 documents: the record
        // itself must stay within ~64 bytes so a 1M-chip machine fits in
        // a few hundred MB.
        assert!(std::mem::size_of::<Chip>() <= 80, "{}", std::mem::size_of::<Chip>());
    }
}
