//! Per-chip structures: processors, SDRAM bookkeeping and the chip record
//! itself (the `Chip`/`Processor`/`SDRAM`/`Router` classes of Figure 5).



use super::geometry::Direction;
use super::{DTCM_PER_CORE, ITCM_PER_CORE, ROUTER_ENTRIES, SDRAM_PER_CHIP};

/// One ARM968 core. Core 0 conventionally runs the SCAMP monitor after
/// boot; application cores are 1..n.
#[derive(Debug, Clone)]
pub struct Processor {
    pub id: u8,
    pub is_monitor: bool,
    /// Clock in MHz — 200 on production silicon; exposed because mapping
    /// uses it to budget CPU cycles per timestep.
    pub clock_mhz: u32,
    pub dtcm_bytes: u32,
    pub itcm_bytes: u32,
}

impl Processor {
    pub fn application(id: u8) -> Self {
        Self {
            id,
            is_monitor: false,
            clock_mhz: 200,
            dtcm_bytes: DTCM_PER_CORE,
            itcm_bytes: ITCM_PER_CORE,
        }
    }

    pub fn monitor(id: u8) -> Self {
        Self { is_monitor: true, ..Self::application(id) }
    }

    /// CPU cycles available per simulation timestep of `timestep_us`.
    pub fn cycles_per_timestep(&self, timestep_us: u32) -> u64 {
        self.clock_mhz as u64 * timestep_us as u64
    }
}

/// Shared node-local SDRAM bookkeeping.
#[derive(Debug, Clone)]
pub struct Sdram {
    pub size: u32,
    /// Bytes reserved by system software (SCAMP, reinjector buffers...).
    pub system_reserved: u32,
}

impl Default for Sdram {
    fn default() -> Self {
        // SCAMP reserves a small system heap at the top of SDRAM.
        Self { size: SDRAM_PER_CHIP, system_reserved: 1024 * 1024 }
    }
}

impl Sdram {
    pub fn user_size(&self) -> u32 {
        self.size - self.system_reserved
    }
}

/// One SpiNNaker chip as seen by the mapping layer.
#[derive(Debug, Clone)]
pub struct Chip {
    pub x: u32,
    pub y: u32,
    pub processors: Vec<Processor>,
    pub sdram: Sdram,
    /// Links that are present and working, by direction.
    pub working_links: Vec<Direction>,
    /// Routing entries available to applications (SCAMP can consume some).
    pub n_router_entries: usize,
    /// IP address when this is an Ethernet chip.
    pub ethernet_ip: Option<String>,
    /// Coordinates of the Ethernet chip of this chip's board.
    pub nearest_ethernet: (u32, u32),
    /// Virtual chips (§5.1) stand in for external devices: they exist in
    /// the machine representation so placement/routing can target them,
    /// but nothing is loaded onto them.
    pub is_virtual: bool,
}

impl Chip {
    pub fn new(x: u32, y: u32, n_cores: usize) -> Self {
        let mut processors = Vec::with_capacity(n_cores);
        for p in 0..n_cores as u8 {
            if p == 0 {
                processors.push(Processor::monitor(p));
            } else {
                processors.push(Processor::application(p));
            }
        }
        Self {
            x,
            y,
            processors,
            sdram: Sdram::default(),
            working_links: super::geometry::ALL_DIRECTIONS.to_vec(),
            n_router_entries: ROUTER_ENTRIES,
            ethernet_ip: None,
            nearest_ethernet: (x, y),
            is_virtual: false,
        }
    }

    pub fn is_ethernet(&self) -> bool {
        self.ethernet_ip.is_some()
    }

    /// Application (non-monitor) cores.
    pub fn application_processors(&self) -> impl Iterator<Item = &Processor> {
        self.processors.iter().filter(|p| !p.is_monitor)
    }

    pub fn n_application_cores(&self) -> usize {
        self.application_processors().count()
    }

    pub fn has_link(&self, d: Direction) -> bool {
        self.working_links.contains(&d)
    }

    pub fn remove_link(&mut self, d: Direction) {
        self.working_links.retain(|l| *l != d);
    }

    pub fn processor(&self, id: u8) -> Option<&Processor> {
        self.processors.iter().find(|p| p.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_defaults() {
        let c = Chip::new(1, 2, 18);
        assert_eq!(c.processors.len(), 18);
        assert_eq!(c.n_application_cores(), 17); // core 0 is the monitor
        assert!(c.processors[0].is_monitor);
        assert_eq!(c.working_links.len(), 6);
        assert!(!c.is_ethernet());
        assert_eq!(c.n_router_entries, 1024);
    }

    #[test]
    fn sdram_user_size_excludes_system() {
        let s = Sdram::default();
        assert_eq!(s.user_size(), 127 * 1024 * 1024);
    }

    #[test]
    fn cycles_per_timestep_at_200mhz() {
        let p = Processor::application(1);
        assert_eq!(p.cycles_per_timestep(1000), 200_000);
    }

    #[test]
    fn remove_link() {
        let mut c = Chip::new(0, 0, 18);
        c.remove_link(Direction::North);
        assert!(!c.has_link(Direction::North));
        assert_eq!(c.working_links.len(), 5);
    }
}
