//! Board geometry: link directions, the SpiNN-5 48-chip board shape and
//! the triad tiling used to assemble multi-board toroids (Figure 3).



/// The six inter-chip link directions, in SpiNNaker link-id order
/// (E=0, NE=1, N=2, W=3, SW=4, S=5) — the order used in routing-table
/// route words.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Direction {
    East = 0,
    NorthEast = 1,
    North = 2,
    West = 3,
    SouthWest = 4,
    South = 5,
}

pub const ALL_DIRECTIONS: [Direction; 6] = [
    Direction::East,
    Direction::NorthEast,
    Direction::North,
    Direction::West,
    Direction::SouthWest,
    Direction::South,
];

impl Direction {
    /// SpiNNaker link id (bit position in a route word).
    #[inline]
    pub fn id(self) -> u8 {
        self as u8
    }

    pub fn from_id(id: u8) -> Option<Direction> {
        ALL_DIRECTIONS.get(id as usize).copied()
    }

    /// (dx, dy) on the hexagonally-connected grid. Note NE/SW are the
    /// diagonals (+1,+1)/(-1,-1); there is no NW/SE link.
    #[inline]
    pub fn delta(self) -> (i32, i32) {
        match self {
            Direction::East => (1, 0),
            Direction::NorthEast => (1, 1),
            Direction::North => (0, 1),
            Direction::West => (-1, 0),
            Direction::SouthWest => (-1, -1),
            Direction::South => (0, -1),
        }
    }

    /// The link a packet continues out of when default-routed (§2: "the
    /// opposite link to the one on which it was received").
    #[inline]
    pub fn opposite(self) -> Direction {
        match self {
            Direction::East => Direction::West,
            Direction::NorthEast => Direction::SouthWest,
            Direction::North => Direction::South,
            Direction::West => Direction::East,
            Direction::SouthWest => Direction::NorthEast,
            Direction::South => Direction::North,
        }
    }

    pub fn from_delta(dx: i32, dy: i32) -> Option<Direction> {
        match (dx, dy) {
            (1, 0) => Some(Direction::East),
            (1, 1) => Some(Direction::NorthEast),
            (0, 1) => Some(Direction::North),
            (-1, 0) => Some(Direction::West),
            (-1, -1) => Some(Direction::SouthWest),
            (0, -1) => Some(Direction::South),
            _ => None,
        }
    }
}

/// The 48 chip coordinates of a SpiNN-5 board, relative to its Ethernet
/// chip at (0, 0). The board is a parallelogram-ish hexagon: rows 0..=7,
/// with each row spanning a window of x coordinates.
pub fn spinn5_chip_offsets() -> Vec<(u8, u8)> {
    // Row y: x from X_START[y] to X_END[y] inclusive — the standard
    // SpiNN-5 board footprint (48 chips).
    const X_RANGE: [(u8, u8); 8] = [
        (0, 4), // y = 0
        (0, 5),
        (0, 6),
        (0, 7),
        (1, 7),
        (2, 7),
        (3, 7),
        (4, 7), // y = 7
    ];
    let mut out = Vec::with_capacity(48);
    for (y, &(x0, x1)) in X_RANGE.iter().enumerate() {
        for x in x0..=x1 {
            out.push((x, y as u8));
        }
    }
    debug_assert_eq!(out.len(), 48);
    out
}

/// Ethernet-chip positions for an `n_boards_x x n_boards_y` triad-tiled
/// machine. Boards come in groups of three with Ethernet chips at
/// (0,0), (4,8), (8,4) within each 12x12 triad — the physical wiring of
/// large SpiNNaker machines (Figure 3; Heathcote 2016 §2).
pub fn triad_ethernet_positions(triads_x: u32, triads_y: u32) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    for tx in 0..triads_x {
        for ty in 0..triads_y {
            let (bx, by) = (tx * 12, ty * 12);
            out.push((bx, by));
            out.push((bx + 4, by + 8));
            out.push((bx + 8, by + 4));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spinn5_has_48_chips() {
        assert_eq!(spinn5_chip_offsets().len(), 48);
    }

    #[test]
    fn spinn5_contains_origin_and_is_unique() {
        let offs = spinn5_chip_offsets();
        assert!(offs.contains(&(0, 0)));
        let set: std::collections::HashSet<_> = offs.iter().collect();
        assert_eq!(set.len(), 48);
    }

    #[test]
    fn opposite_is_involution() {
        for d in ALL_DIRECTIONS {
            assert_eq!(d.opposite().opposite(), d);
            let (dx, dy) = d.delta();
            let (ox, oy) = d.opposite().delta();
            assert_eq!((dx + ox, dy + oy), (0, 0));
        }
    }

    #[test]
    fn delta_round_trips() {
        for d in ALL_DIRECTIONS {
            let (dx, dy) = d.delta();
            assert_eq!(Direction::from_delta(dx, dy), Some(d));
        }
        assert_eq!(Direction::from_delta(2, 0), None);
        assert_eq!(Direction::from_delta(-1, 1), None);
    }

    #[test]
    fn link_ids_match_route_word_order() {
        assert_eq!(Direction::East.id(), 0);
        assert_eq!(Direction::South.id(), 5);
        for (i, d) in ALL_DIRECTIONS.iter().enumerate() {
            assert_eq!(d.id() as usize, i);
            assert_eq!(Direction::from_id(d.id()), Some(*d));
        }
    }

    #[test]
    fn one_triad_has_three_ethernets() {
        assert_eq!(triad_ethernet_positions(1, 1).len(), 3);
        assert_eq!(triad_ethernet_positions(2, 1).len(), 6);
    }
}
