//! # SpiNNTools — the execution engine for the SpiNNaker platform
//!
//! A production-quality reproduction of *SpiNNTools: The Execution Engine
//! for the SpiNNaker Platform* (Rowley et al., 2018) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate):** the complete toolchain — graph data structures
//!   ([`graph`]), the mapping stack ([`mapping`]: splitting, placement,
//!   NER routing, key/tag allocation, routing-table generation and
//!   ordered-covering compression), the Figure-10 algorithm execution
//!   engine ([`algorithms`]), loading/run control/extraction including
//!   the per-board bulk data plane of §6.8 ([`front`]), and — because
//!   no physical SpiNNaker hardware is available — a discrete-event
//!   simulator of the machine itself ([`simulator`]) with the real
//!   board geometry, router TCAM semantics, SCAMP monitor protocol and
//!   wire bandwidth models ([`machine`], [`transport`]).
//! - **L2 (build-time JAX, `python/compile/model.py`):** the per-core
//!   compute graphs (LIF population step, Conway tile step, Poisson
//!   thinning), AOT-lowered once to HLO text in `artifacts/`.
//! - **L1 (build-time Pallas, `python/compile/kernels/`):** the compute
//!   hot-spots, validated against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate, behind the off-by-default `pjrt` cargo feature) and
//! executes them from the simulated cores in [`apps`] — Python is never
//! on the run path. Without the feature the crate still builds and the
//! whole mapping/simulation stack works; only HLO-backed vertices need it.
//!
//! ## Quickstart
//!
//! ```no_run
//! use spinntools::front::{SpiNNTools, ToolsConfig};
//! use spinntools::apps::conway::{ConwayCellVertex, STATE_PARTITION};
//!
//! let mut tools = SpiNNTools::new(ToolsConfig::virtual_spinn5(1)).unwrap();
//! let a = tools.add_machine_vertex(ConwayCellVertex::arc(0, 0, true)).unwrap();
//! let b = tools.add_machine_vertex(ConwayCellVertex::arc(0, 1, false)).unwrap();
//! tools.add_machine_edge(a, b, STATE_PARTITION).unwrap();
//! tools.run_ms(100).unwrap();
//! ```
//!
//! See `examples/` for the paper's two use cases (Conway's Game of Life,
//! §7.1; the Potjans–Diesmann cortical microcircuit, §7.2) and DESIGN.md
//! for the experiment index.

pub mod algorithms;
pub mod apps;
pub mod front;
pub mod graph;
pub mod machine;
pub mod mapping;
pub mod runtime;
pub mod simulator;
pub mod transport;
pub mod util;
