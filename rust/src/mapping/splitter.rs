//! Graph partitioning: application graph → machine graph (§6.3.2,
//! Figure 6 c→d).
//!
//! Each application vertex is split into machine vertices over contiguous
//! atom slices. The slice width is the largest count that (a) respects
//! the vertex's `max_atoms_per_core` and (b) produces per-core resources
//! that fit a core's DTCM/ITCM/cycle budget. Machine edges are then added
//! so that "the flow of data between the vertices is still correct":
//! one machine edge per (pre machine vertex, post machine vertex) pair of
//! each application edge, in the same outgoing partition.

use std::collections::BTreeMap;

use crate::graph::{
    AppEdgeId, AppVertexId, ApplicationGraph, EdgeId, MachineGraph, Slice, VertexId,
};
use crate::machine::{Machine, DTCM_PER_CORE, ITCM_PER_CORE};

/// The application↔machine graph correspondence kept for data generation
/// (synaptic-matrix construction needs pre/post slices) and result
/// extraction (reassembling per-atom recordings).
#[derive(Debug, Default)]
pub struct GraphMapping {
    pub machine_vertices_of: BTreeMap<AppVertexId, Vec<(VertexId, Slice)>>,
    pub app_vertex_of: BTreeMap<VertexId, (AppVertexId, Slice)>,
    pub app_edge_of: BTreeMap<EdgeId, AppEdgeId>,
}

impl GraphMapping {
    /// The machine vertex holding `atom` of `app_vertex`.
    pub fn vertex_for_atom(&self, app_vertex: AppVertexId, atom: u32) -> Option<(VertexId, Slice)> {
        self.machine_vertices_of
            .get(&app_vertex)?
            .iter()
            .find(|(_, s)| s.contains(atom))
            .copied()
    }
}

/// Split `app` into a machine graph for `machine`'s core budgets.
pub fn split_graph(
    app: &ApplicationGraph,
    machine: &Machine,
) -> anyhow::Result<(MachineGraph, GraphMapping)> {
    let cycles_cap = machine
        .chips()
        .flat_map(|c| c.application_processors())
        .map(|p| p.cycles_per_timestep(1000))
        .min()
        .unwrap_or(200_000);

    let mut mg = MachineGraph::new();
    let mut mapping = GraphMapping::default();

    // Split every application vertex into slices.
    for (app_id, vertex) in app.vertices() {
        let n_atoms = vertex.n_atoms();
        anyhow::ensure!(n_atoms > 0, "vertex {} has no atoms", vertex.label());
        let mut produced = Vec::new();
        let mut lo = 0u32;
        while lo < n_atoms {
            let width = best_slice_width(vertex.as_ref(), lo, n_atoms, cycles_cap)?;
            let slice = Slice::new(lo, (lo + width).min(n_atoms));
            let mv = vertex.create_machine_vertex(slice);
            let mv_id = mg.add_vertex(mv);
            produced.push((mv_id, slice));
            mapping.app_vertex_of.insert(mv_id, (app_id, slice));
            lo = slice.hi;
        }
        mapping.machine_vertices_of.insert(app_id, produced);
    }

    // Expand application edges to machine edges (all pre-slices to all
    // post-slices; the receiving binary demultiplexes by key, §5.2).
    for (app_edge_id, edge) in app.edges() {
        let partition = app.partition_of_edge(app_edge_id);
        let pres = mapping.machine_vertices_of[&edge.pre].clone();
        let posts = mapping.machine_vertices_of[&edge.post].clone();
        for (pre_mv, _) in &pres {
            for (post_mv, _) in &posts {
                let eid = mg.add_edge(*pre_mv, *post_mv, partition);
                mapping.app_edge_of.insert(eid, app_edge_id);
            }
        }
    }

    Ok((mg, mapping))
}

/// The widest slice starting at `lo` whose resources fit one core.
fn best_slice_width(
    vertex: &dyn crate::graph::ApplicationVertexImpl,
    lo: u32,
    n_atoms: u32,
    cycles_cap: u64,
) -> anyhow::Result<u32> {
    let mut width = vertex.max_atoms_per_core().min(n_atoms - lo).max(1);
    loop {
        let slice = Slice::new(lo, lo + width);
        let res = vertex.resources_for(slice);
        if res.fits_core(DTCM_PER_CORE, ITCM_PER_CORE, cycles_cap) {
            return Ok(width);
        }
        if width == 1 {
            anyhow::bail!(
                "vertex {} atom {lo} does not fit a core even alone \
                 (dtcm={} itcm={} cycles={})",
                vertex.label(),
                res.dtcm_bytes,
                res.itcm_bytes,
                res.cpu_cycles_per_step
            );
        }
        // Binary back-off: resource models are monotone in practice.
        width /= 2;
    }
}

/// Estimate how many chips a graph needs — used by machine discovery to
/// size an allocation before a machine exists (§6.3.1).
pub fn chips_required(app: &ApplicationGraph, machine_template: &Machine) -> anyhow::Result<u32> {
    let (mg, _) = split_graph(app, machine_template)?;
    let cores_per_chip = machine_template
        .chips()
        .map(|c| c.n_application_cores())
        .min()
        .unwrap_or(16)
        .max(1);
    // Cores bound...
    let by_cores = mg.n_vertices().div_ceil(cores_per_chip);
    // ...and SDRAM bound (§6.3.1's "10 vertices x 20 MB won't fit one chip").
    let sdram_per_chip = machine_template
        .chips()
        .map(|c| c.sdram.user_size() as u64)
        .min()
        .unwrap_or(1) as u64;
    let total_sdram: u64 = mg
        .vertices()
        .map(|(_, v)| v.resources().sdram_bytes)
        .sum();
    let by_sdram = total_sdram.div_ceil(sdram_per_chip.max(1)) as usize;
    Ok(by_cores.max(by_sdram) as u32)
}

#[cfg(test)]
mod tests {
    use std::any::Any;
    use std::sync::Arc;

    use super::*;
    use crate::graph::{
        ApplicationVertexImpl, DataGenContext, DataRegion, MachineVertexImpl,
        ResourceRequirements,
    };
    use crate::machine::MachineBuilder;

    #[derive(Debug)]
    struct SliceRecorder {
        atoms: u32,
        max_per_core: u32,
        dtcm_per_atom: u32,
    }

    #[derive(Debug)]
    struct SliceMv {
        slice: Slice,
        dtcm: u32,
    }

    impl MachineVertexImpl for SliceMv {
        fn label(&self) -> String {
            format!("mv{}", self.slice)
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements {
                dtcm_bytes: self.dtcm,
                ..Default::default()
            }
        }
        fn binary_name(&self) -> String {
            "t.aplx".into()
        }
        fn generate_data(&self, _: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn n_keys_for_partition(&self, _: &str) -> u32 {
            self.slice.n_atoms()
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    impl ApplicationVertexImpl for SliceRecorder {
        fn label(&self) -> String {
            "app".into()
        }
        fn n_atoms(&self) -> u32 {
            self.atoms
        }
        fn max_atoms_per_core(&self) -> u32 {
            self.max_per_core
        }
        fn resources_for(&self, slice: Slice) -> ResourceRequirements {
            ResourceRequirements {
                dtcm_bytes: self.dtcm_per_atom * slice.n_atoms(),
                ..Default::default()
            }
        }
        fn create_machine_vertex(&self, slice: Slice) -> Arc<dyn MachineVertexImpl> {
            Arc::new(SliceMv { slice, dtcm: self.dtcm_per_atom * slice.n_atoms() })
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    fn app_vertex(atoms: u32, max_per_core: u32, dtcm_per_atom: u32) -> Arc<dyn ApplicationVertexImpl> {
        Arc::new(SliceRecorder { atoms, max_per_core, dtcm_per_atom })
    }

    #[test]
    fn splits_by_max_atoms_per_core() {
        // Figure 6(c)->(d): 4 atoms, 2 per core -> 2 machine vertices.
        let mut app = ApplicationGraph::new();
        let a = app.add_vertex(app_vertex(4, 2, 1));
        let b = app.add_vertex(app_vertex(4, 4, 1));
        app.add_edge(a, b, "p", None);
        let machine = MachineBuilder::spinn3().build();
        let (mg, mapping) = split_graph(&app, &machine).unwrap();
        assert_eq!(mapping.machine_vertices_of[&a].len(), 2);
        assert_eq!(mapping.machine_vertices_of[&b].len(), 1);
        assert_eq!(mg.n_vertices(), 3);
        // Both of a's slices connect to b's single vertex.
        assert_eq!(mg.n_edges(), 2);
    }

    #[test]
    fn splits_by_dtcm_budget() {
        // 100 atoms, no per-core cap, but 1 KiB DTCM each: 64 fit in 64 KiB.
        let mut app = ApplicationGraph::new();
        let a = app.add_vertex(app_vertex(100, u32::MAX, 1024));
        let _ = a;
        let machine = MachineBuilder::spinn3().build();
        let (mg, mapping) = split_graph(&app, &machine).unwrap();
        let slices: Vec<Slice> = mapping.machine_vertices_of[&AppVertexId(0)]
            .iter()
            .map(|(_, s)| *s)
            .collect();
        assert!(slices.iter().all(|s| s.n_atoms() <= 64));
        let total: u32 = slices.iter().map(|s| s.n_atoms()).sum();
        assert_eq!(total, 100);
        assert!(mg.n_vertices() >= 2);
    }

    #[test]
    fn slices_are_contiguous_and_cover() {
        let mut app = ApplicationGraph::new();
        app.add_vertex(app_vertex(37, 5, 1));
        let machine = MachineBuilder::spinn3().build();
        let (_, mapping) = split_graph(&app, &machine).unwrap();
        let slices = &mapping.machine_vertices_of[&AppVertexId(0)];
        let mut expect_lo = 0;
        for (_, s) in slices {
            assert_eq!(s.lo, expect_lo);
            expect_lo = s.hi;
        }
        assert_eq!(expect_lo, 37);
    }

    #[test]
    fn vertex_for_atom_finds_slice() {
        let mut app = ApplicationGraph::new();
        let a = app.add_vertex(app_vertex(10, 4, 1));
        let machine = MachineBuilder::spinn3().build();
        let (_, mapping) = split_graph(&app, &machine).unwrap();
        let (_, s) = mapping.vertex_for_atom(a, 5).unwrap();
        assert!(s.contains(5));
        assert!(mapping.vertex_for_atom(a, 100).is_none());
    }

    #[test]
    fn oversized_atom_fails() {
        let mut app = ApplicationGraph::new();
        app.add_vertex(app_vertex(1, 1, 128 * 1024)); // 128 KiB in 64 KiB DTCM
        let machine = MachineBuilder::spinn3().build();
        assert!(split_graph(&app, &machine).is_err());
    }

    #[test]
    fn edges_expand_all_pairs() {
        let mut app = ApplicationGraph::new();
        let a = app.add_vertex(app_vertex(4, 2, 1)); // 2 mvs
        let b = app.add_vertex(app_vertex(6, 2, 1)); // 3 mvs
        app.add_edge(a, b, "x", None);
        let machine = MachineBuilder::spinn3().build();
        let (mg, mapping) = split_graph(&app, &machine).unwrap();
        assert_eq!(mg.n_edges(), 6);
        // every machine edge traces back to the app edge
        assert!(mapping.app_edge_of.values().all(|e| e.0 == 0));
    }

    #[test]
    fn chips_required_accounts_cores_and_sdram() {
        let machine = MachineBuilder::spinn5().build();
        let mut app = ApplicationGraph::new();
        app.add_vertex(app_vertex(17 * 3, 1, 1)); // 51 cores -> 3 chips
        assert_eq!(chips_required(&app, &machine).unwrap(), 3);
    }
}
