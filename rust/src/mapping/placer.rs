//! Placement: machine vertices → cores (§6.3.2).
//!
//! Radial first-fit: chips are visited in BFS order from the boot chip
//! (0,0) over working links, and each vertex takes the next free
//! application core whose chip still has SDRAM for it — keeping
//! communicating vertices dense around the root the way the production
//! placer does. Constrained vertices (fixed core or chip, and virtual
//! vertices bound to their device's virtual chip) are placed first.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::graph::{MachineGraph, VertexId};
use crate::machine::{ChipCoord, CoreLocation, Machine, ALL_DIRECTIONS};

/// The placement map (vertex ↔ core, both directions).
#[derive(Debug, Default, Clone)]
pub struct Placements {
    by_vertex: BTreeMap<VertexId, CoreLocation>,
    by_core: BTreeMap<CoreLocation, VertexId>,
}

impl Placements {
    pub fn insert(&mut self, v: VertexId, loc: CoreLocation) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.by_core.contains_key(&loc),
            "core {loc} already hosts a vertex"
        );
        anyhow::ensure!(
            !self.by_vertex.contains_key(&v),
            "vertex {v:?} placed twice"
        );
        self.by_vertex.insert(v, loc);
        self.by_core.insert(loc, v);
        Ok(())
    }

    pub fn of(&self, v: VertexId) -> Option<CoreLocation> {
        self.by_vertex.get(&v).copied()
    }

    pub fn at(&self, loc: CoreLocation) -> Option<VertexId> {
        self.by_core.get(&loc).copied()
    }

    pub fn iter(&self) -> impl Iterator<Item = (VertexId, CoreLocation)> + '_ {
        self.by_vertex.iter().map(|(v, l)| (*v, *l))
    }

    pub fn len(&self) -> usize {
        self.by_vertex.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_vertex.is_empty()
    }

    /// Vertices on one chip, in core order.
    pub fn on_chip(&self, chip: ChipCoord) -> Vec<(VertexId, CoreLocation)> {
        self.by_core
            .range(
                CoreLocation::new(chip.0, chip.1, 0)
                    ..=CoreLocation::new(chip.0, chip.1, u8::MAX),
            )
            .map(|(l, v)| (*v, *l))
            .collect()
    }

    /// All chips that host at least one vertex.
    pub fn used_chips(&self) -> BTreeSet<ChipCoord> {
        self.by_core.keys().map(|l| l.chip()).collect()
    }

    /// The vertex -> core map (borrowed; used by DataGenContext).
    pub fn as_map(&self) -> &BTreeMap<VertexId, CoreLocation> {
        &self.by_vertex
    }

    /// Cores already occupied on one chip.
    pub fn cores_used_on(&self, chip: ChipCoord) -> BTreeSet<u8> {
        self.on_chip(chip).into_iter().map(|(_, l)| l.p).collect()
    }
}

/// BFS order of chips from the boot chip over working links — the
/// "radial" chip ordering. Unreachable chips (isolated by faults) are
/// appended last so they can still host unconnected work.
pub fn radial_chip_order(machine: &Machine) -> Vec<ChipCoord> {
    let root = (0, 0);
    let mut order = Vec::with_capacity(machine.n_chips());
    let mut seen = BTreeSet::new();
    let mut queue = VecDeque::new();
    if machine.chip(root).is_some() {
        queue.push_back(root);
        seen.insert(root);
    }
    while let Some(c) = queue.pop_front() {
        order.push(c);
        for d in ALL_DIRECTIONS {
            if let Some(n) = machine.link_target(c, d) {
                if machine.chip(n).map(|ch| !ch.is_virtual).unwrap_or(false)
                    && seen.insert(n)
                {
                    queue.push_back(n);
                }
            }
        }
    }
    for c in machine.chip_coords() {
        if !seen.contains(&c) && !machine.chip(c).map(|ch| ch.is_virtual).unwrap_or(true) {
            order.push(c);
        }
    }
    order
}

/// Per-chip resource ledger used during placement.
struct ChipLedger {
    free_cores: Vec<u8>,
    sdram_free: u64,
}

/// Place every vertex of `graph` on `machine`.
pub fn place(machine: &Machine, graph: &MachineGraph) -> anyhow::Result<Placements> {
    place_avoiding(machine, graph, &BTreeSet::new())
}

/// [`place`] with a first-class set of *forbidden* chips: chips that are
/// physically present in `machine` but must not host any vertex — how a
/// degraded-machine re-map (chips that died at runtime, §2's blacklist
/// grown mid-run) is expressed without rebuilding the machine object.
pub fn place_avoiding(
    machine: &Machine,
    graph: &MachineGraph,
    forbidden: &BTreeSet<ChipCoord>,
) -> anyhow::Result<Placements> {
    let mut placements = Placements::default();
    let mut ledgers: BTreeMap<ChipCoord, ChipLedger> = machine
        .chips()
        .filter(|c| !c.is_virtual && !forbidden.contains(&(c.x, c.y)))
        .map(|c| {
            (
                (c.x, c.y),
                ChipLedger {
                    free_cores: c.application_processors().map(|p| p.id).collect(),
                    sdram_free: c.sdram.user_size() as u64,
                },
            )
        })
        .collect();

    // Pass 1: constrained vertices (fixed cores beat chip constraints).
    let mut unplaced: Vec<VertexId> = Vec::new();
    let mut chip_constrained: Vec<(VertexId, ChipCoord)> = Vec::new();
    for (vid, vertex) in graph.vertices() {
        if let Some(vl) = vertex.virtual_link() {
            // Virtual vertices sit on the virtual chip the front end added
            // for their device; nothing is loaded there (§7.2).
            let vchip = find_virtual_chip(machine, vl.attached_to, vl.direction)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no virtual chip for device vertex {} (attached {:?})",
                        vertex.label(),
                        vl.attached_to
                    )
                })?;
            placements.insert(vid, CoreLocation::new(vchip.0, vchip.1, 0))?;
        } else if let Some(loc) = vertex.placement_constraint() {
            let ledger = ledgers
                .get_mut(&loc.chip())
                .ok_or_else(|| anyhow::anyhow!("constraint on missing chip {:?}", loc.chip()))?;
            let pos = ledger
                .free_cores
                .iter()
                .position(|p| *p == loc.p)
                .ok_or_else(|| anyhow::anyhow!("constrained core {loc} unavailable"))?;
            ledger.free_cores.remove(pos);
            charge_sdram(ledger, graph, vid, loc.chip())?;
            placements.insert(vid, loc)?;
        } else if let Some(chip) = vertex.chip_constraint() {
            chip_constrained.push((vid, chip));
        } else {
            unplaced.push(vid);
        }
    }

    for (vid, chip) in chip_constrained {
        let ledger = ledgers
            .get_mut(&chip)
            .ok_or_else(|| anyhow::anyhow!("chip constraint on missing chip {chip:?}"))?;
        let p = ledger
            .free_cores
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no free core on constrained chip {chip:?}"))?;
        ledger.free_cores.retain(|c| *c != p);
        charge_sdram(ledger, graph, vid, chip)?;
        placements.insert(vid, CoreLocation::new(chip.0, chip.1, p))?;
    }

    // Pass 2: everything else, radial first-fit (forbidden chips carry
    // no ledger and are skipped from the visit order entirely).
    let mut order = radial_chip_order(machine);
    order.retain(|c| ledgers.contains_key(c));
    let mut chip_cursor = 0usize;
    for vid in unplaced {
        let sdram = graph.vertex(vid).resources().sdram_bytes;
        let mut tried = 0usize;
        loop {
            if tried >= order.len() {
                anyhow::bail!(
                    "machine full: cannot place vertex {} ({} cores, {} chips)",
                    graph.vertex(vid).label(),
                    graph.n_vertices(),
                    machine.n_chips()
                );
            }
            let chip = order[(chip_cursor + tried) % order.len()];
            let ledger = ledgers.get_mut(&chip).unwrap();
            if !ledger.free_cores.is_empty() && ledger.sdram_free >= sdram {
                let p = ledger.free_cores.remove(0);
                ledger.sdram_free -= sdram;
                placements.insert(vid, CoreLocation::new(chip.0, chip.1, p))?;
                // Stay on this chip while it has room (dense packing).
                chip_cursor = (chip_cursor + tried) % order.len();
                break;
            }
            tried += 1;
        }
    }

    Ok(placements)
}

/// Machines at or above this many chips take the hierarchical path in
/// [`crate::mapping::map_graph`]; below it the flat placer is cheaper
/// (no sharding setup) and the two produce byte-identical output anyway.
pub const HIERARCHICAL_PLACEMENT_THRESHOLD: usize = 4096;

/// Hierarchical placement for big machines (DESIGN.md §12).
///
/// Two levels. The *coarse pass* bin-packs vertices onto boards by
/// replaying the radial first-fit against flat per-chip capacity
/// counters — a struct-of-arrays ledger (free-core count, SDRAM
/// remaining) indexed by radial order position, touched with integer
/// ops only, no per-chip map lookups. It decides, for every vertex, the
/// chip and the *slot* (how many plain vertices landed on that chip
/// before it), and groups the decisions by the chip's board (its
/// `nearest_ethernet` group). The *refinement pass* then resolves slots
/// to concrete core ids per board — slot `k` on a chip is the
/// `k+1`-lowest set bit of the chip's post-constraint free-core mask,
/// exactly the `free_cores.remove(0)` of the flat placer — sharded
/// across the [`crate::util::par`] pool, one unit per board.
///
/// Because the coarse pass replays the flat algorithm's decisions
/// exactly and the refinement is a pure per-board function of them, the
/// result is byte-identical to [`place_avoiding`] on the same inputs at
/// *every* scale (the A/B digest tests in `tests/scale.rs` pin this at
/// overlap scales), and thread-invariant: `par_map` preserves item
/// order and the workers share only immutable state.
pub fn place_hierarchical(
    machine: &Machine,
    graph: &MachineGraph,
    forbidden: &BTreeSet<ChipCoord>,
    threads: usize,
) -> anyhow::Result<Placements> {
    let mut placements = Placements::default();

    // Radial visit order over placeable chips, and the SoA ledgers.
    let mut order = radial_chip_order(machine);
    order.retain(|c| {
        machine.chip(*c).map(|ch| !ch.is_virtual).unwrap_or(false) && !forbidden.contains(c)
    });
    let n = order.len();
    let mut mask: Vec<u32> = Vec::with_capacity(n); // free app cores
    let mut sdram_free: Vec<u64> = Vec::with_capacity(n);
    let mut board_key: Vec<ChipCoord> = Vec::with_capacity(n);
    for c in &order {
        let chip = machine.chip(*c).unwrap();
        mask.push(chip.core_mask() & !1); // core 0 is the monitor
        sdram_free.push(chip.sdram.user_size() as u64);
        board_key.push(chip.nearest_ethernet);
    }
    // Coordinate -> order position. In-grid coords resolve through a
    // flat vector (4 bytes/chip); only off-grid chips pay a map.
    let grid_len = machine.width as usize * machine.height as usize;
    let mut pos_grid: Vec<u32> = vec![u32::MAX; grid_len];
    let mut pos_off: BTreeMap<ChipCoord, usize> = BTreeMap::new();
    for (i, c) in order.iter().enumerate() {
        if c.0 < machine.width && c.1 < machine.height {
            pos_grid[c.0 as usize * machine.height as usize + c.1 as usize] = i as u32;
        } else {
            pos_off.insert(*c, i);
        }
    }
    let pos = |c: ChipCoord| -> Option<usize> {
        if c.0 < machine.width && c.1 < machine.height {
            let p = pos_grid[c.0 as usize * machine.height as usize + c.1 as usize];
            (p != u32::MAX).then_some(p as usize)
        } else {
            pos_off.get(&c).copied()
        }
    };

    // Pass 1: constrained vertices, same order and same errors as the
    // flat placer (these are assumed rare; they mutate the masks the
    // refinement pass reads, so they must settle first).
    let mut plain: Vec<VertexId> = Vec::new();
    let mut chip_constrained: Vec<(VertexId, ChipCoord)> = Vec::new();
    for (vid, vertex) in graph.vertices() {
        if let Some(vl) = vertex.virtual_link() {
            let vchip = find_virtual_chip(machine, vl.attached_to, vl.direction)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no virtual chip for device vertex {} (attached {:?})",
                        vertex.label(),
                        vl.attached_to
                    )
                })?;
            placements.insert(vid, CoreLocation::new(vchip.0, vchip.1, 0))?;
        } else if let Some(loc) = vertex.placement_constraint() {
            let i = pos(loc.chip())
                .ok_or_else(|| anyhow::anyhow!("constraint on missing chip {:?}", loc.chip()))?;
            anyhow::ensure!(
                loc.p != 0 && loc.p < 32 && mask[i] & (1 << loc.p) != 0,
                "constrained core {loc} unavailable"
            );
            mask[i] &= !(1 << loc.p);
            let need = vertex.resources().sdram_bytes;
            anyhow::ensure!(
                sdram_free[i] >= need,
                "chip {:?} out of SDRAM for constrained vertex",
                loc.chip()
            );
            sdram_free[i] -= need;
            placements.insert(vid, loc)?;
        } else if let Some(chip) = vertex.chip_constraint() {
            chip_constrained.push((vid, chip));
        } else {
            plain.push(vid);
        }
    }
    for (vid, chip) in chip_constrained {
        let i = pos(chip)
            .ok_or_else(|| anyhow::anyhow!("chip constraint on missing chip {chip:?}"))?;
        anyhow::ensure!(mask[i] != 0, "no free core on constrained chip {chip:?}");
        let p = mask[i].trailing_zeros() as u8;
        mask[i] &= mask[i] - 1;
        let need = graph.vertex(vid).resources().sdram_bytes;
        anyhow::ensure!(
            sdram_free[i] >= need,
            "chip {chip:?} out of SDRAM for constrained vertex"
        );
        sdram_free[i] -= need;
        placements.insert(vid, CoreLocation::new(chip.0, chip.1, p))?;
    }

    // Coarse pass: radial first-fit replay at chip granularity. Only
    // counters move — which core a slot becomes is the refinement's job.
    let free_count: Vec<u16> = mask.iter().map(|m| m.count_ones() as u16).collect();
    let mut taken: Vec<u16> = vec![0; n];
    let mut board_ids: BTreeMap<ChipCoord, u32> = BTreeMap::new();
    let mut board_of: Vec<u32> = Vec::with_capacity(n);
    for bk in &board_key {
        let next = board_ids.len() as u32;
        board_of.push(*board_ids.entry(*bk).or_insert(next));
    }
    let mut per_board: Vec<Vec<(VertexId, u32, u16)>> = vec![Vec::new(); board_ids.len()];
    let mut chip_cursor = 0usize;
    for vid in plain {
        let need = graph.vertex(vid).resources().sdram_bytes;
        let mut tried = 0usize;
        loop {
            if tried >= order.len() {
                anyhow::bail!(
                    "machine full: cannot place vertex {} ({} cores, {} chips)",
                    graph.vertex(vid).label(),
                    graph.n_vertices(),
                    machine.n_chips()
                );
            }
            let i = (chip_cursor + tried) % order.len();
            if taken[i] < free_count[i] && sdram_free[i] >= need {
                per_board[board_of[i] as usize].push((vid, i as u32, taken[i]));
                taken[i] += 1;
                sdram_free[i] -= need;
                chip_cursor = i;
                break;
            }
            tried += 1;
        }
    }

    // Refinement: per board, resolve slots to core ids off the shared
    // post-constraint masks. Pure, order-preserving, thread-invariant.
    let resolved = crate::util::par::par_map(threads, &per_board, |_, items| {
        items
            .iter()
            .map(|&(vid, i, slot)| {
                let mut m = mask[i as usize];
                for _ in 0..slot {
                    m &= m - 1; // drop the slots consumed before this one
                }
                let c = order[i as usize];
                (vid, CoreLocation::new(c.0, c.1, m.trailing_zeros() as u8))
            })
            .collect::<Vec<_>>()
    });
    for pairs in resolved {
        for (vid, loc) in pairs {
            placements.insert(vid, loc)?;
        }
    }
    Ok(placements)
}

/// Incremental placement (DESIGN.md §7): every vertex present in
/// `prior` keeps its exact core (the *pin*) while that core still
/// exists, vertices no longer in the graph simply vanish, and only new
/// vertices are placed — into the capacity the pins, the `reserved`
/// cores (the bulk data plane's system cores) and the `forbidden` chips
/// (chips that died at runtime) leave over, with the same
/// constrained-first + radial first-fit policy as [`place`].
///
/// A pin whose core is gone — its chip removed from the machine or
/// listed in `forbidden`, its processor blacklisted by re-discovery, or
/// its core newly reserved — does not error: the vertex is *displaced*
/// and re-placed like a new vertex. This is the self-healing move: on a
/// degraded machine the survivors stay put and only the victims travel.
///
/// Errors when placement is infeasible (a new constrained vertex
/// collides with a pin, a displaced vertex's constraint names a dead
/// resource, or no capacity remains) — the caller falls back to a full
/// from-scratch re-map. New *virtual* vertices are also an error: they
/// need a machine rebuild to gain their virtual chip.
pub fn place_incremental(
    machine: &Machine,
    graph: &MachineGraph,
    prior: &Placements,
    reserved: &std::collections::BTreeSet<CoreLocation>,
    forbidden: &BTreeSet<ChipCoord>,
) -> anyhow::Result<Placements> {
    let mut placements = Placements::default();
    let mut ledgers: BTreeMap<ChipCoord, ChipLedger> = machine
        .chips()
        .filter(|c| !c.is_virtual && !forbidden.contains(&(c.x, c.y)))
        .map(|c| {
            (
                (c.x, c.y),
                ChipLedger {
                    free_cores: c
                        .application_processors()
                        .map(|p| p.id)
                        .filter(|p| !reserved.contains(&CoreLocation::new(c.x, c.y, *p)))
                        .collect(),
                    sdram_free: c.sdram.user_size() as u64,
                },
            )
        })
        .collect();

    // Pass 1: pins. Charge their cores and SDRAM so new vertices see
    // only the genuinely remaining capacity; pins whose core no longer
    // exists fall through to the new-vertex passes (displacement).
    let mut new_plain: Vec<VertexId> = Vec::new();
    let mut new_chip_constrained: Vec<(VertexId, ChipCoord)> = Vec::new();
    let mut new_core_constrained: Vec<(VertexId, CoreLocation)> = Vec::new();
    for (vid, vertex) in graph.vertices() {
        if let Some(loc) = prior.of(vid) {
            if vertex.virtual_link().is_some() {
                // Virtual (device) vertices sit on virtual chips, which
                // cannot die at runtime: the pin always holds.
                placements.insert(vid, loc)?;
                continue;
            }
            let sdram = vertex.resources().sdram_bytes;
            let held = match ledgers.get_mut(&loc.chip()) {
                Some(ledger) => {
                    match ledger.free_cores.iter().position(|p| *p == loc.p) {
                        Some(pos) if ledger.sdram_free >= sdram => {
                            ledger.free_cores.remove(pos);
                            ledger.sdram_free -= sdram;
                            true
                        }
                        _ => false,
                    }
                }
                None => false,
            };
            if held {
                placements.insert(vid, loc)?;
                continue;
            }
            // Displaced: the pinned core is dead/forbidden/reserved.
        } else if vertex.virtual_link().is_some() {
            anyhow::bail!(
                "new device vertex {} needs a virtual chip (full re-map required)",
                vertex.label()
            );
        }
        if let Some(loc) = vertex.placement_constraint() {
            new_core_constrained.push((vid, loc));
        } else if let Some(chip) = vertex.chip_constraint() {
            new_chip_constrained.push((vid, chip));
        } else {
            new_plain.push(vid);
        }
    }

    // Pass 2: new constrained vertices (same order as the full placer).
    for (vid, loc) in new_core_constrained {
        let ledger = ledgers
            .get_mut(&loc.chip())
            .ok_or_else(|| anyhow::anyhow!("constraint on missing chip {:?}", loc.chip()))?;
        let pos = ledger
            .free_cores
            .iter()
            .position(|p| *p == loc.p)
            .ok_or_else(|| anyhow::anyhow!("constrained core {loc} unavailable"))?;
        ledger.free_cores.remove(pos);
        charge_sdram(ledger, graph, vid, loc.chip())?;
        placements.insert(vid, loc)?;
    }
    for (vid, chip) in new_chip_constrained {
        let ledger = ledgers
            .get_mut(&chip)
            .ok_or_else(|| anyhow::anyhow!("chip constraint on missing chip {chip:?}"))?;
        let p = ledger
            .free_cores
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no free core on constrained chip {chip:?}"))?;
        ledger.free_cores.retain(|c| *c != p);
        charge_sdram(ledger, graph, vid, chip)?;
        placements.insert(vid, CoreLocation::new(chip.0, chip.1, p))?;
    }

    // Pass 3: new + displaced plain vertices, radial first-fit over the
    // remainder (forbidden chips carry no ledger and are not visited).
    let mut order = radial_chip_order(machine);
    order.retain(|c| ledgers.contains_key(c));
    let mut chip_cursor = 0usize;
    for vid in new_plain {
        let sdram = graph.vertex(vid).resources().sdram_bytes;
        let mut tried = 0usize;
        loop {
            anyhow::ensure!(
                tried < order.len(),
                "machine full: cannot place new vertex {} incrementally",
                graph.vertex(vid).label()
            );
            let chip = order[(chip_cursor + tried) % order.len()];
            let ledger = ledgers.get_mut(&chip).unwrap();
            if !ledger.free_cores.is_empty() && ledger.sdram_free >= sdram {
                let p = ledger.free_cores.remove(0);
                ledger.sdram_free -= sdram;
                placements.insert(vid, CoreLocation::new(chip.0, chip.1, p))?;
                chip_cursor = (chip_cursor + tried) % order.len();
                break;
            }
            tried += 1;
        }
    }

    Ok(placements)
}

fn charge_sdram(
    ledger: &mut ChipLedger,
    graph: &MachineGraph,
    vid: VertexId,
    chip: ChipCoord,
) -> anyhow::Result<()> {
    let sdram = graph.vertex(vid).resources().sdram_bytes;
    anyhow::ensure!(
        ledger.sdram_free >= sdram,
        "chip {chip:?} out of SDRAM for constrained vertex"
    );
    ledger.sdram_free -= sdram;
    Ok(())
}

fn find_virtual_chip(
    machine: &Machine,
    attached_to: ChipCoord,
    direction: crate::machine::Direction,
) -> Option<ChipCoord> {
    // The wire to the device is recorded as an explicit virtual link on
    // the machine (§5.1: coordinates need not align with the grid).
    let target = machine.link_target(attached_to, direction)?;
    machine.chip(target).filter(|c| c.is_virtual).map(|c| (c.x, c.y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::machine::{Direction, MachineBuilder};

    #[test]
    fn radial_order_starts_at_root_and_covers() {
        let m = MachineBuilder::spinn5().build();
        let order = radial_chip_order(&m);
        assert_eq!(order[0], (0, 0));
        assert_eq!(order.len(), 48);
        // Early chips are near the root.
        assert!(m.hop_distance((0, 0), order[1]) == 1);
    }

    #[test]
    fn places_one_vertex_per_core() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        for i in 0..20 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let p = place(&m, &g).unwrap();
        assert_eq!(p.len(), 20);
        let cores: BTreeSet<_> = p.iter().map(|(_, l)| l).collect();
        assert_eq!(cores.len(), 20, "two vertices share a core");
        // 17 app cores per chip: 20 vertices need 2 chips.
        assert_eq!(p.used_chips().len(), 2);
    }

    #[test]
    fn respects_sdram_budget() {
        // §6.3.1's example: vertices needing 20MB each; 127MB user SDRAM
        // fits 6 per chip even though 17 cores are free.
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        for i in 0..10 {
            g.add_vertex(TestVertex::with_sdram(&format!("v{i}"), 20 * 1024 * 1024));
        }
        let p = place(&m, &g).unwrap();
        for chip in p.used_chips() {
            let total: u64 = p
                .on_chip(chip)
                .iter()
                .map(|(v, _)| g.vertex(*v).resources().sdram_bytes)
                .sum();
            assert!(total <= 127 * 1024 * 1024);
        }
        assert!(p.used_chips().len() >= 2);
    }

    #[test]
    fn machine_full_errors() {
        let m = MachineBuilder::spinn3().build(); // 4 chips x 17 cores = 68
        let mut g = MachineGraph::new();
        for i in 0..69 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        assert!(place(&m, &g).is_err());
    }

    #[test]
    fn core_constraint_honoured() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let loc = CoreLocation::new(1, 1, 5);
        let v = g.add_vertex(TestVertex::constrained("c", loc));
        g.add_vertex(TestVertex::arc("free"));
        let p = place(&m, &g).unwrap();
        assert_eq!(p.of(v), Some(loc));
    }

    #[test]
    fn conflicting_core_constraints_error() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let loc = CoreLocation::new(0, 0, 1);
        g.add_vertex(TestVertex::constrained("a", loc));
        g.add_vertex(TestVertex::constrained("b", loc));
        assert!(place(&m, &g).is_err());
    }

    #[test]
    fn monitor_core_never_used() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        for i in 0..68 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let p = place(&m, &g).unwrap();
        assert!(p.iter().all(|(_, l)| l.p != 0), "monitor core was allocated");
    }

    #[test]
    fn dead_chip_skipped() {
        let m = MachineBuilder::spinn3().dead_chip((1, 1)).build();
        let mut g = MachineGraph::new();
        for i in 0..51 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let p = place(&m, &g).unwrap();
        assert!(!p.used_chips().contains(&(1, 1)));
    }

    #[test]
    fn incremental_pins_survivors_and_places_new() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let ids: Vec<_> = (0..20)
            .map(|i| g.add_vertex(TestVertex::arc(&format!("v{i}"))))
            .collect();
        let prior = place(&m, &g).unwrap();
        // Remove one vertex, add two.
        g.remove_vertex(ids[3]).unwrap();
        let n1 = g.add_vertex(TestVertex::arc("n1"));
        let n2 = g.add_vertex(TestVertex::arc("n2"));
        let inc = place_incremental(&m, &g, &prior, &Default::default(), &Default::default()).unwrap();
        for (i, id) in ids.iter().enumerate() {
            if i == 3 {
                assert_eq!(inc.of(*id), None, "removed vertex must be unplaced");
            } else {
                assert_eq!(inc.of(*id), prior.of(*id), "survivor moved");
            }
        }
        let l1 = inc.of(n1).unwrap();
        let l2 = inc.of(n2).unwrap();
        assert_ne!(l1, l2, "new vertices need distinct cores");
        assert_eq!(inc.len(), 21);
    }

    #[test]
    fn incremental_respects_reserved_cores() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let prior = place(&m, &g).unwrap();
        // Reserve every remaining core on the machine except one.
        let mut reserved = BTreeSet::new();
        let mut left = None;
        for chip in m.chips().filter(|c| !c.is_virtual) {
            for p in chip.application_processors().map(|p| p.id) {
                let loc = CoreLocation::new(chip.x, chip.y, p);
                if prior.of(a) == Some(loc) {
                    continue;
                }
                if left.is_none() {
                    left = Some(loc);
                } else {
                    reserved.insert(loc);
                }
            }
        }
        let b = g.add_vertex(TestVertex::arc("b"));
        let inc = place_incremental(&m, &g, &prior, &reserved, &Default::default()).unwrap();
        assert_eq!(inc.of(b), left, "only the unreserved core may host b");
        // One more vertex no longer fits.
        g.add_vertex(TestVertex::arc("c"));
        assert!(place_incremental(&m, &g, &prior, &reserved, &Default::default()).is_err());
    }

    #[test]
    fn incremental_conflicting_constraint_errors() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let loc = CoreLocation::new(0, 0, 1);
        g.add_vertex(TestVertex::constrained("a", loc));
        let prior = place(&m, &g).unwrap();
        // A new vertex demanding the pinned core must fail (full re-map).
        g.add_vertex(TestVertex::constrained("b", loc));
        assert!(place_incremental(&m, &g, &prior, &Default::default(), &Default::default()).is_err());
    }

    #[test]
    fn incremental_matches_full_for_pure_appends() {
        // With no SDRAM pressure, appending vertices incrementally lands
        // them exactly where a full re-place of the final graph would.
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        for i in 0..10 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let prior = place(&m, &g).unwrap();
        for i in 10..25 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let full = place(&m, &g).unwrap();
        let inc = place_incremental(&m, &g, &prior, &Default::default(), &Default::default()).unwrap();
        for v in g.vertex_ids() {
            assert_eq!(inc.of(v), full.of(v), "{v:?}");
        }
    }

    #[test]
    fn forbidden_chips_never_host() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        for i in 0..30 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let mut forbidden = BTreeSet::new();
        forbidden.insert((0u32, 0u32));
        forbidden.insert((1u32, 1u32));
        let p = place_avoiding(&m, &g, &forbidden).unwrap();
        assert_eq!(p.len(), 30);
        for (_, loc) in p.iter() {
            assert!(!forbidden.contains(&loc.chip()), "placed on forbidden {loc}");
        }
        // Capacity shrinks accordingly: 2 chips x 17 cores = 34 < 35.
        let mut big = MachineGraph::new();
        for i in 0..35 {
            big.add_vertex(TestVertex::arc(&format!("b{i}")));
        }
        assert!(place_avoiding(&m, &big, &forbidden).is_err());
    }

    #[test]
    fn incremental_displaces_pins_on_forbidden_chips() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let ids: Vec<_> = (0..20)
            .map(|i| g.add_vertex(TestVertex::arc(&format!("v{i}"))))
            .collect();
        let prior = place(&m, &g).unwrap();
        // Forbid the chip hosting v0: its residents move, others stay.
        let dead = prior.of(ids[0]).unwrap().chip();
        let mut forbidden = BTreeSet::new();
        forbidden.insert(dead);
        let inc =
            place_incremental(&m, &g, &prior, &Default::default(), &forbidden).unwrap();
        assert_eq!(inc.len(), 20, "every vertex must survive the chip death");
        let mut moved = 0;
        for id in &ids {
            let was = prior.of(*id).unwrap();
            let now = inc.of(*id).unwrap();
            assert_ne!(now.chip(), dead, "vertex left on forbidden chip");
            if was.chip() == dead {
                moved += 1;
                assert_ne!(was, now);
            } else {
                assert_eq!(was, now, "survivor moved");
            }
        }
        assert!(moved > 0, "the dead chip hosted someone");
    }

    #[test]
    fn incremental_displaces_pin_on_removed_core() {
        // A machine whose re-discovery blacklisted one core: the pin on
        // it is displaced, everything else holds.
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let ids: Vec<_> = (0..5)
            .map(|i| g.add_vertex(TestVertex::arc(&format!("v{i}"))))
            .collect();
        let prior = place(&m, &g).unwrap();
        let victim_loc = prior.of(ids[2]).unwrap();
        let degraded = MachineBuilder::spinn3()
            .dead_core(victim_loc.chip(), victim_loc.p)
            .build();
        let inc =
            place_incremental(&degraded, &g, &prior, &Default::default(), &Default::default())
                .unwrap();
        for (i, id) in ids.iter().enumerate() {
            if i == 2 {
                assert_ne!(inc.of(*id), Some(victim_loc), "victim must move");
                assert!(inc.of(*id).is_some());
            } else {
                assert_eq!(inc.of(*id), prior.of(*id), "survivor moved");
            }
        }
    }

    #[test]
    fn hierarchical_matches_flat_placer() {
        // Mixed workload: a pinned core, SDRAM-heavy stragglers that
        // force chip skips, and plain filler. The two-level placer must
        // reproduce the flat map exactly at every thread count.
        let m = MachineBuilder::spinn5().build();
        let mut g = MachineGraph::new();
        g.add_vertex(TestVertex::constrained("pin", CoreLocation::new(1, 1, 5)));
        for i in 0..300 {
            let sdram = if i % 7 == 0 { 30 * 1024 * 1024 } else { 1024 };
            g.add_vertex(TestVertex::with_sdram(&format!("v{i}"), sdram));
        }
        let flat = place(&m, &g).unwrap();
        for threads in [1, 2, 8] {
            let h = place_hierarchical(&m, &g, &BTreeSet::new(), threads).unwrap();
            assert_eq!(h.len(), flat.len());
            for (v, l) in flat.iter() {
                assert_eq!(h.of(v), Some(l), "{v:?} with {threads} threads");
            }
        }
    }

    #[test]
    fn hierarchical_matches_flat_on_degraded_machine() {
        let m = MachineBuilder::spinn3()
            .dead_chip((1, 1))
            .dead_core((0, 1), 4)
            .build();
        let mut g = MachineGraph::new();
        for i in 0..40 {
            g.add_vertex(TestVertex::arc(&format!("v{i}")));
        }
        let mut forbidden = BTreeSet::new();
        forbidden.insert((0u32, 0u32));
        let flat = place_avoiding(&m, &g, &forbidden).unwrap();
        let h = place_hierarchical(&m, &g, &forbidden, 4).unwrap();
        for (v, l) in flat.iter() {
            assert_eq!(h.of(v), Some(l), "{v:?}");
        }
        assert_eq!(h.len(), flat.len());
        // And both reject the same overfull graph.
        for i in 0..20 {
            g.add_vertex(TestVertex::arc(&format!("x{i}")));
        }
        assert!(place_avoiding(&m, &g, &forbidden).is_err());
        assert!(place_hierarchical(&m, &g, &forbidden, 4).is_err());
    }

    #[test]
    fn radial_order_survives_partition() {
        // Kill the links around (0,0) except East: BFS must still reach all.
        let m = MachineBuilder::spinn3()
            .dead_link((0, 0), Direction::North)
            .dead_link((0, 0), Direction::NorthEast)
            .build();
        let order = radial_chip_order(&m);
        assert_eq!(order.len(), 4);
    }
}
