//! Routing-table generation: routing trees + key ranges → per-chip TCAM
//! tables (§6.3.2), with optional default-route elision.
//!
//! Each tree node becomes one entry `{key: partition base, mask:
//! partition mask, route: out_links ∪ local_cores}` on its chip. A node
//! that merely passes the packet straight through (single inbound link,
//! single outbound link exactly opposite, no local delivery) can be
//! elided entirely: the router's default routing reproduces it (§2) —
//! the cheapest form of table compression, applied at generation time.
//!
//! Generation is sharded **per chip**: each chip's table depends only on
//! the trees that touch that chip, so chips are independent work items.
//! Entries within a chip are emitted in forest order — the same order
//! the historical tree-major loop produced — so the result is identical
//! at any thread count.

use std::borrow::Borrow;
use std::collections::BTreeMap;

use crate::graph::{KeyRange, MachineGraph, VertexId};
use crate::machine::router::{Route, RoutingEntry, RoutingTable};
use crate::machine::{ChipCoord, Machine};

use super::router::{RoutingForest, RoutingTree};
use super::MappingConfig;

/// One table-generation work item: a chip plus the forest-order indices
/// of the trees that have a node on it.
pub type ChipWork = (ChipCoord, Vec<usize>);

/// The serial planning half of table generation: resolve each tree's key
/// range (forest order) and group tree indices per non-virtual chip.
pub fn plan_chips<'f>(
    machine: &Machine,
    forest: &'f RoutingForest,
    keys: &BTreeMap<(VertexId, String), KeyRange>,
) -> anyhow::Result<(Vec<&'f RoutingTree>, Vec<KeyRange>, Vec<ChipWork>)> {
    let mut trees = Vec::with_capacity(forest.trees.len());
    let mut ranges = Vec::with_capacity(forest.trees.len());
    let mut per_chip: BTreeMap<ChipCoord, Vec<usize>> = BTreeMap::new();
    for (i, ((vertex, partition), tree)) in forest.trees.iter().enumerate() {
        let range = keys
            .get(&(*vertex, partition.clone()))
            .ok_or_else(|| anyhow::anyhow!("no keys for ({vertex:?}, {partition})"))?;
        for chip in tree.nodes.keys() {
            // Skip virtual chips: nothing is loaded on them (§7.2); the
            // device itself consumes the packets.
            if machine.chip(*chip).map(|c| c.is_virtual).unwrap_or(false) {
                continue;
            }
            per_chip.entry(*chip).or_default().push(i);
        }
        trees.push(tree);
        ranges.push(*range);
    }
    Ok((trees, ranges, per_chip.into_iter().collect()))
}

/// Generate one chip's table from the trees that touch it, in forest
/// order. Generic over tree ownership so both the borrowed direct path
/// and the engine's owned-context path share it.
pub fn chip_table<T: Borrow<RoutingTree>>(
    trees: &[T],
    ranges: &[KeyRange],
    chip: ChipCoord,
    tree_idxs: &[usize],
    use_default_routes: bool,
) -> RoutingTable {
    let mut table = RoutingTable::new();
    for &i in tree_idxs {
        let node = &trees[i].borrow().nodes[&chip];
        let range = &ranges[i];
        let mut route = Route::EMPTY;
        for d in &node.out_links {
            route.add_link(*d);
        }
        for p in &node.local_cores {
            route.add_processor(*p);
        }
        if route.is_empty() {
            // Leaf with no delivery — shouldn't occur, but harmless.
            continue;
        }
        if use_default_routes {
            if let (Some(in_link), Some(out)) = (node.in_link, route.single_link()) {
                if in_link == out {
                    // Packet continues straight: default routing
                    // handles it with no table entry.
                    continue;
                }
            }
        }
        table.push(RoutingEntry::new(range.base, range.mask, route));
    }
    table
}

/// Build the per-chip routing tables for a routed, keyed graph, sharded
/// per chip over `config.options.threads` workers. Chips whose every
/// node was elided produce no table at all (not an empty one).
pub fn build_tables(
    machine: &Machine,
    _graph: &MachineGraph,
    forest: &RoutingForest,
    keys: &BTreeMap<(VertexId, String), KeyRange>,
    config: &MappingConfig,
) -> anyhow::Result<BTreeMap<ChipCoord, RoutingTable>> {
    let (trees, ranges, work) = plan_chips(machine, forest, keys)?;
    let built = crate::util::par::par_map(
        config.options.threads,
        &work,
        |_, (chip, idxs)| chip_table(&trees, &ranges, *chip, idxs, config.use_default_routes),
    );
    Ok(work
        .iter()
        .zip(built)
        .filter(|(_, table)| !table.is_empty())
        .map(|((chip, _), table)| (*chip, table))
        .collect())
}

/// Demand-driven table materialization (DESIGN.md §12).
///
/// [`build_tables`] materializes every chip's table eagerly, which is
/// the right shape for the pipeline (the whole map is loaded anyway)
/// but the wrong one at SpiNNaker2 scale, where a 1M-chip machine may
/// carry traffic on a few thousand chips: the loader wants tables one
/// chip at a time, paying only for chips a route actually crosses.
///
/// A `TablePlan` is the cheap, traffic-proportional planning half
/// (resolve key ranges, group trees per touched chip — no entries are
/// built), borrowed from the forest. Individual tables are then built
/// on demand with [`TablePlan::table_for`], and compressed only when
/// oversubscribed via [`TablePlan::loadable_table_for`] — so mapping
/// cost tracks traffic, not machine size. Materializing every planned
/// chip reproduces [`build_tables`] byte-for-byte (pinned by tests).
pub struct TablePlan<'f> {
    trees: Vec<&'f RoutingTree>,
    ranges: Vec<KeyRange>,
    /// Touched chips with their forest-order tree indices, chip-sorted.
    work: Vec<ChipWork>,
    use_default_routes: bool,
}

impl<'f> TablePlan<'f> {
    pub fn new(
        machine: &Machine,
        forest: &'f RoutingForest,
        keys: &BTreeMap<(VertexId, String), KeyRange>,
        config: &MappingConfig,
    ) -> anyhow::Result<TablePlan<'f>> {
        let (trees, ranges, work) = plan_chips(machine, forest, keys)?;
        Ok(TablePlan { trees, ranges, work, use_default_routes: config.use_default_routes })
    }

    /// Chips at least one routing tree touches, ascending — the only
    /// chips [`Self::table_for`] can return a table for.
    pub fn chips(&self) -> impl Iterator<Item = ChipCoord> + '_ {
        self.work.iter().map(|(c, _)| *c)
    }

    /// Number of touched chips (the plan's size, not the machine's).
    pub fn n_chips(&self) -> usize {
        self.work.len()
    }

    /// Materialize one chip's table. `None` when no tree touches the
    /// chip or every node on it was elided by default routing — the
    /// same chips [`build_tables`] omits from its map.
    pub fn table_for(&self, chip: ChipCoord) -> Option<RoutingTable> {
        let i = self.work.binary_search_by_key(&chip, |(c, _)| *c).ok()?;
        let table =
            chip_table(&self.trees, &self.ranges, chip, &self.work[i].1, self.use_default_routes);
        (!table.is_empty()).then_some(table)
    }

    /// [`Self::table_for`], compressed only when the raw table
    /// oversubscribes the TCAM (the lazy analogue of
    /// [`super::compress::compress_tables_in_place`]). Errors if the
    /// table still does not fit after compression.
    pub fn loadable_table_for(&self, chip: ChipCoord) -> anyhow::Result<Option<RoutingTable>> {
        let Some(table) = self.table_for(chip) else {
            return Ok(None);
        };
        if table.fits() {
            return Ok(Some(table));
        }
        let compressed = super::compress::compress(&table);
        anyhow::ensure!(
            compressed.fits(),
            "routing table on chip {chip:?} needs {} entries (TCAM holds {})",
            compressed.len(),
            crate::machine::ROUTER_ENTRIES
        );
        Ok(Some(compressed))
    }
}

/// Verify that the generated tables route every key of every partition
/// from its source to exactly its destination set — the E2/E10 oracle
/// used by tests and the compression benchmark.
pub fn check_tables(
    machine: &Machine,
    tables: &BTreeMap<ChipCoord, RoutingTable>,
    source: ChipCoord,
    key: u32,
    expected: &[(ChipCoord, u8)],
) -> anyhow::Result<()> {
    use crate::machine::router::{PacketSource, RoutingDecision};
    let mut delivered = Vec::new();
    // (chip, how the packet entered)
    let mut stack = vec![(source, PacketSource::Local(1))];
    let mut hops = 0usize;
    while let Some((chip, entered)) = stack.pop() {
        hops += 1;
        anyhow::ensure!(
            hops < 100_000,
            "routing loop detected for key {key:#x} from {source:?}"
        );
        let empty = RoutingTable::new();
        let table = tables.get(&chip).unwrap_or(&empty);
        match table.route_packet(key, entered) {
            RoutingDecision::Routed(route) => {
                for p in route.processors() {
                    delivered.push((chip, p));
                }
                for d in route.links() {
                    let next = machine
                        .link_target(chip, d)
                        .ok_or_else(|| anyhow::anyhow!("route over dead link at {chip:?}"))?;
                    if machine.chip(next).map(|c| c.is_virtual).unwrap_or(false) {
                        delivered.push((next, 0)); // device consumed it
                    } else {
                        // Travelling in direction d, the packet arrives on
                        // the next chip's opposite-side link.
                        stack.push((next, PacketSource::Link(d.opposite())));
                    }
                }
            }
            RoutingDecision::DefaultRouted(d) => {
                let next = machine
                    .link_target(chip, d)
                    .ok_or_else(|| anyhow::anyhow!("default route over dead link at {chip:?}"))?;
                if machine.chip(next).map(|c| c.is_virtual).unwrap_or(false) {
                    delivered.push((next, 0));
                } else {
                    stack.push((next, PacketSource::Link(d.opposite())));
                }
            }
            RoutingDecision::Dropped => {
                anyhow::bail!("key {key:#x} dropped at source chip {chip:?}")
            }
        }
    }
    let mut got = delivered;
    got.sort();
    got.dedup();
    let mut want: Vec<(ChipCoord, u8)> = expected.to_vec();
    want.sort();
    want.dedup();
    anyhow::ensure!(
        got == want,
        "key {key:#x}: delivered {got:?}, expected {want:?}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::router::build_tree;
    use crate::machine::MachineBuilder;
    use std::collections::BTreeSet;

    fn dests(chips: &[(ChipCoord, u8)]) -> BTreeMap<ChipCoord, BTreeSet<u8>> {
        let mut m: BTreeMap<ChipCoord, BTreeSet<u8>> = BTreeMap::new();
        for (c, p) in chips {
            m.entry(*c).or_default().insert(*p);
        }
        m
    }

    /// Build tables for a single synthetic tree without a graph.
    fn tables_for_tree(
        machine: &Machine,
        source: ChipCoord,
        targets: &[(ChipCoord, u8)],
        key: KeyRange,
        use_default: bool,
    ) -> BTreeMap<ChipCoord, RoutingTable> {
        let tree = build_tree(machine, source, &dests(targets)).unwrap();
        let mut tables: BTreeMap<ChipCoord, RoutingTable> = BTreeMap::new();
        let config = MappingConfig {
            use_default_routes: use_default,
            ..Default::default()
        };
        // Reuse the production code path through a fake forest.
        let mut forest = RoutingForest::default();
        forest.trees.insert((VertexId(0), "p".into()), tree);
        let mut keys = BTreeMap::new();
        keys.insert((VertexId(0), "p".to_string()), key);
        // Minimal graph so signatures line up.
        let graph = MachineGraph::new();
        let built = build_tables(machine, &graph, &forest, &keys, &config).unwrap();
        for (c, t) in built {
            tables.insert(c, t);
        }
        tables
    }

    #[test]
    fn straight_line_with_default_routing_needs_two_entries() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let key = KeyRange::new(0x100, 0xffff_ff00);
        let tables = tables_for_tree(&m, (0, 0), &[((4, 0), 3)], key, true);
        // Only source (inject East) and target (deliver core 3) have
        // entries; (1,0)..(3,0) default-route.
        let total: usize = tables.values().map(|t| t.len()).sum();
        assert_eq!(total, 2, "intermediate chips should default-route");
        check_tables(&m, &tables, (0, 0), key.base, &[((4, 0), 3)]).unwrap();
        check_tables(&m, &tables, (0, 0), key.key_for_atom(200), &[((4, 0), 3)]).unwrap();
    }

    #[test]
    fn without_default_routing_every_hop_has_entry() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let key = KeyRange::new(0x100, 0xffff_ff00);
        let tables = tables_for_tree(&m, (0, 0), &[((4, 0), 3)], key, false);
        let total: usize = tables.values().map(|t| t.len()).sum();
        assert_eq!(total, 5);
        check_tables(&m, &tables, (0, 0), key.base, &[((4, 0), 3)]).unwrap();
    }

    #[test]
    fn branching_multicast_delivers_everywhere() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let key = KeyRange::new(0x200, 0xffff_ff00);
        let targets = [((4, 0), 1), ((0, 4), 2), ((3, 3), 3), ((0, 0), 4)];
        let tables = tables_for_tree(&m, (0, 0), &targets, key, true);
        check_tables(&m, &tables, (0, 0), key.base, &targets).unwrap();
    }

    #[test]
    fn lazy_plan_matches_eager_tables() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let key = KeyRange::new(0x200, 0xffff_ff00);
        let targets = [((4, 0), 1), ((0, 4), 2), ((3, 3), 3)];
        let tree = build_tree(&m, (0, 0), &dests(&targets)).unwrap();
        let mut forest = RoutingForest::default();
        forest.trees.insert((VertexId(0), "p".into()), tree);
        let mut keys = BTreeMap::new();
        keys.insert((VertexId(0), "p".to_string()), key);
        let config = MappingConfig::default();
        let graph = MachineGraph::new();
        let eager = build_tables(&m, &graph, &forest, &keys, &config).unwrap();
        let plan = TablePlan::new(&m, &forest, &keys, &config).unwrap();
        // Demand-materializing every planned chip reproduces the eager
        // map exactly, including which chips get no table at all.
        let mut lazy = BTreeMap::new();
        for chip in plan.chips() {
            if let Some(t) = plan.table_for(chip) {
                lazy.insert(chip, t);
            }
        }
        assert_eq!(lazy, eager);
        // A chip no route crosses costs nothing and yields nothing.
        assert!(plan.table_for((7, 7)).is_none());
        assert!(
            plan.n_chips() < 64,
            "plan size must track traffic, not machine size ({})",
            plan.n_chips()
        );
        // Small tables pass through loadable_table_for uncompressed.
        let c0 = plan.loadable_table_for((0, 0)).unwrap().unwrap();
        assert_eq!(&c0, &eager[&(0, 0)]);
    }

    #[test]
    fn turns_cannot_be_default_routed() {
        // Path that turns a corner must have an entry at the turn.
        let m = MachineBuilder::grid(8, 8, false)
            .dead_link((1, 0), crate::machine::Direction::East)
            .build();
        let key = KeyRange::new(0x300, 0xffff_ffff);
        let tables = tables_for_tree(&m, (0, 0), &[((4, 0), 1)], key, true);
        check_tables(&m, &tables, (0, 0), key.base, &[((4, 0), 1)]).unwrap();
        // The detour has at least one turn -> more than 2 entries.
        let total: usize = tables.values().map(|t| t.len()).sum();
        assert!(total > 2, "turns require explicit entries, got {total}");
    }
}
