//! IP tag and reverse IP tag allocation (§3, §6.3.2).
//!
//! Each board's Ethernet chip holds up to 8 tags. A vertex's tag request
//! is served by the Ethernet chip of the board it was placed on;
//! requests with identical (host, port, strip) can share a tag.

use std::collections::BTreeMap;

use crate::graph::{AllocatedIpTag, AllocatedReverseIpTag, MachineGraph, VertexId};
use crate::machine::{ChipCoord, Machine, IPTAGS_PER_BOARD};

use super::placer::Placements;

type TagMaps = (
    BTreeMap<(VertexId, String), AllocatedIpTag>,
    BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
);

/// Allocate all requested tags.
pub fn allocate_tags(
    machine: &Machine,
    graph: &MachineGraph,
    placements: &Placements,
) -> anyhow::Result<TagMaps> {
    let mut iptags = BTreeMap::new();
    let mut reverse = BTreeMap::new();
    // Per-board: next free tag id, plus shared-tag index.
    let mut next_tag: BTreeMap<ChipCoord, u8> = BTreeMap::new();
    let mut shared: BTreeMap<(ChipCoord, String, u16, bool), u8> = BTreeMap::new();

    for (vid, vertex) in graph.vertices() {
        let res = vertex.resources();
        if res.iptags.is_empty() && res.reverse_iptags.is_empty() {
            continue;
        }
        let placement = placements
            .of(vid)
            .ok_or_else(|| anyhow::anyhow!("vertex {} unplaced", vertex.label()))?;
        let board = machine
            .nearest_ethernet(placement.chip())
            .ok_or_else(|| anyhow::anyhow!("no ethernet for chip {:?}", placement.chip()))?;

        for req in &res.iptags {
            let share_key = (board, req.host.clone(), req.port, req.strip_sdp);
            let tag = match shared.get(&share_key) {
                Some(t) => *t,
                None => {
                    let t = alloc_tag(&mut next_tag, board)?;
                    shared.insert(share_key, t);
                    t
                }
            };
            iptags.insert(
                (vid, req.label.clone()),
                AllocatedIpTag {
                    board,
                    tag,
                    host: req.host.clone(),
                    port: req.port,
                    strip_sdp: req.strip_sdp,
                },
            );
        }
        for req in &res.reverse_iptags {
            // Reverse tags cannot be shared: each maps a UDP port to one core.
            let tag = alloc_tag(&mut next_tag, board)?;
            reverse.insert(
                (vid, req.label.clone()),
                AllocatedReverseIpTag {
                    board,
                    tag,
                    port: req.port,
                    destination: placement,
                },
            );
        }
    }
    Ok((iptags, reverse))
}

fn alloc_tag(next_tag: &mut BTreeMap<ChipCoord, u8>, board: ChipCoord) -> anyhow::Result<u8> {
    let t = next_tag.entry(board).or_insert(1);
    anyhow::ensure!(
        (*t as usize) <= IPTAGS_PER_BOARD,
        "board {board:?} out of IP tags ({IPTAGS_PER_BOARD} available)"
    );
    let out = *t;
    *t += 1;
    Ok(out)
}

/// Per-board allocation of *system-level* IP tags — tags for cores the
/// tools install outside the user graph (the bulk data plane's gatherer
/// and data-in reply channels). Unlike [`allocate_tags`], which owns the
/// whole tag space during mapping, this allocator starts from the tags
/// already committed on each board (seeded with [`mark_used`]) and hands
/// out the remaining ids, so system tags never collide with graph tags.
///
/// [`mark_used`]: SystemTagAllocator::mark_used
#[derive(Debug, Clone, Default)]
pub struct SystemTagAllocator {
    used: BTreeMap<ChipCoord, std::collections::BTreeSet<u8>>,
}

impl SystemTagAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `tag` on `board` is already taken (typically read
    /// back from the machine's installed tag tables).
    pub fn mark_used(&mut self, board: ChipCoord, tag: u8) {
        self.used.entry(board).or_default().insert(tag);
    }

    /// Claim the lowest free tag id on `board`.
    pub fn alloc(&mut self, board: ChipCoord) -> anyhow::Result<u8> {
        let used = self.used.entry(board).or_default();
        for t in 1..=IPTAGS_PER_BOARD as u8 {
            if !used.contains(&t) {
                used.insert(t);
                return Ok(t);
            }
        }
        anyhow::bail!("board {board:?} out of IP tags ({IPTAGS_PER_BOARD} available)")
    }
}

#[cfg(test)]
mod tests {
    use std::any::Any;
    use std::sync::Arc;

    use super::*;
    use crate::graph::{
        DataGenContext, DataRegion, IpTagRequest, MachineVertexImpl, ResourceRequirements,
        ReverseIpTagRequest,
    };
    use crate::machine::MachineBuilder;
    use crate::mapping::placer;

    #[derive(Debug)]
    struct Tagged {
        tags: Vec<IpTagRequest>,
        rtags: Vec<ReverseIpTagRequest>,
    }

    impl Tagged {
        fn arc(tags: Vec<IpTagRequest>, rtags: Vec<ReverseIpTagRequest>) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { tags, rtags })
        }
    }

    impl MachineVertexImpl for Tagged {
        fn label(&self) -> String {
            "tagged".into()
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements {
                iptags: self.tags.clone(),
                reverse_iptags: self.rtags.clone(),
                ..Default::default()
            }
        }
        fn binary_name(&self) -> String {
            "t.aplx".into()
        }
        fn generate_data(&self, _: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    fn tag_req(label: &str, host: &str, port: u16) -> IpTagRequest {
        IpTagRequest { host: host.into(), port, strip_sdp: false, label: label.into() }
    }

    #[test]
    fn allocates_on_board_ethernet() {
        let m = MachineBuilder::spinn5().build();
        let mut g = MachineGraph::new();
        let v = g.add_vertex(Tagged::arc(vec![tag_req("out", "host", 17893)], vec![]));
        let p = placer::place(&m, &g).unwrap();
        let (tags, _) = allocate_tags(&m, &g, &p).unwrap();
        let t = &tags[&(v, "out".to_string())];
        assert_eq!(t.board, (0, 0));
        assert_eq!(t.tag, 1);
    }

    #[test]
    fn identical_requests_share_a_tag() {
        let m = MachineBuilder::spinn5().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Tagged::arc(vec![tag_req("x", "h", 1)], vec![]));
        let b = g.add_vertex(Tagged::arc(vec![tag_req("y", "h", 1)], vec![]));
        let p = placer::place(&m, &g).unwrap();
        let (tags, _) = allocate_tags(&m, &g, &p).unwrap();
        assert_eq!(tags[&(a, "x".to_string())].tag, tags[&(b, "y".to_string())].tag);
    }

    #[test]
    fn distinct_requests_get_distinct_tags() {
        let m = MachineBuilder::spinn5().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Tagged::arc(vec![tag_req("x", "h", 1)], vec![]));
        let b = g.add_vertex(Tagged::arc(vec![tag_req("y", "h", 2)], vec![]));
        let p = placer::place(&m, &g).unwrap();
        let (tags, _) = allocate_tags(&m, &g, &p).unwrap();
        assert_ne!(tags[&(a, "x".to_string())].tag, tags[&(b, "y".to_string())].tag);
    }

    #[test]
    fn board_exhaustion_errors() {
        let m = MachineBuilder::spinn5().build();
        let mut g = MachineGraph::new();
        for i in 0..9 {
            g.add_vertex(Tagged::arc(vec![tag_req("t", "h", 5000 + i)], vec![]));
        }
        let p = placer::place(&m, &g).unwrap();
        assert!(allocate_tags(&m, &g, &p).is_err());
    }

    #[test]
    fn system_tags_avoid_marked_ids() {
        let mut alloc = SystemTagAllocator::new();
        alloc.mark_used((0, 0), 1);
        alloc.mark_used((0, 0), 3);
        assert_eq!(alloc.alloc((0, 0)).unwrap(), 2);
        assert_eq!(alloc.alloc((0, 0)).unwrap(), 4);
        // An untouched board starts from 1.
        assert_eq!(alloc.alloc((4, 8)).unwrap(), 1);
    }

    #[test]
    fn system_tags_exhaust_per_board() {
        let mut alloc = SystemTagAllocator::new();
        for _ in 0..IPTAGS_PER_BOARD {
            alloc.alloc((0, 0)).unwrap();
        }
        assert!(alloc.alloc((0, 0)).is_err());
        assert!(alloc.alloc((4, 8)).is_ok(), "other boards unaffected");
    }

    #[test]
    fn reverse_tag_targets_placement() {
        let m = MachineBuilder::spinn5().build();
        let mut g = MachineGraph::new();
        let v = g.add_vertex(Tagged::arc(
            vec![],
            vec![ReverseIpTagRequest { port: 12345, label: "in".into() }],
        ));
        let p = placer::place(&m, &g).unwrap();
        let (_, rtags) = allocate_tags(&m, &g, &p).unwrap();
        let rt = &rtags[&(v, "in".to_string())];
        assert_eq!(rt.destination, p.of(v).unwrap());
        assert_eq!(rt.port, 12345);
    }
}
