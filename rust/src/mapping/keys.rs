//! Multicast key allocation (§6.3.2: "a set of routing keys detailing
//! the range of keys that must be sent by each vertex ... over each
//! outgoing edge partition").
//!
//! Each partition gets a contiguous power-of-two block of the 32-bit key
//! space: base key + atom index, with the mask covering the block. Blocks
//! are allocated sequentially in deterministic partition order, aligned
//! to their size, so every pair of allocations is disjoint — the property
//! the routing tables (and the order-exploiting compressor) rely on.

use std::collections::BTreeMap;

use crate::graph::{KeyRange, MachineGraph, VertexId};

/// Allocate key ranges for every outgoing edge partition of `graph`.
pub fn allocate_keys(
    graph: &MachineGraph,
) -> anyhow::Result<BTreeMap<(VertexId, String), KeyRange>> {
    let (keys, _, _) = allocate_keys_incremental(graph, &BTreeMap::new(), 0)?;
    Ok(keys)
}

/// Incremental key allocation (DESIGN.md §7): partitions already in
/// `prior` whose block-size demand is unchanged keep their exact range;
/// removed partitions' ranges are retired; new (or resized) partitions
/// take fresh blocks strictly above `cursor`, the session's high-water
/// mark. Freed ranges are **never reused** within a session: a retired
/// key may still be matched by an aggressive compression cover on an
/// untouched chip, so reuse could hijack packets — monotone allocation
/// makes that impossible by construction.
///
/// With an empty `prior` and `cursor == 0` this is exactly the
/// from-scratch allocator (the wrapper above), so first runs are
/// byte-identical to the historical behaviour.
///
/// Returns `(keys, rekeyed partitions, new high-water cursor)`.
#[allow(clippy::type_complexity)]
pub fn allocate_keys_incremental(
    graph: &MachineGraph,
    prior: &BTreeMap<(VertexId, String), KeyRange>,
    cursor: u64,
) -> anyhow::Result<(
    BTreeMap<(VertexId, String), KeyRange>,
    Vec<(VertexId, String)>,
    u64,
)> {
    allocate_keys_incremental_bounded(graph, prior, cursor, 1u64 << 32)
}

/// [`allocate_keys_incremental`] with an explicit upper bound on the key
/// space: allocations must fit strictly below `limit`. This is how the
/// multi-tenant [`crate::front::MachineService`] namespaces keys — each
/// tenant's session allocates inside a disjoint `[base, limit)` window
/// (the base arrives as the session's starting cursor), so two tenants'
/// multicast traffic can never share a key even though they share one
/// physical router fabric.
#[allow(clippy::type_complexity)]
pub fn allocate_keys_incremental_bounded(
    graph: &MachineGraph,
    prior: &BTreeMap<(VertexId, String), KeyRange>,
    cursor: u64,
    limit: u64,
) -> anyhow::Result<(
    BTreeMap<(VertexId, String), KeyRange>,
    Vec<(VertexId, String)>,
    u64,
)> {
    let mut out = BTreeMap::new();
    let mut rekeyed = Vec::new();
    let mut cursor = cursor;
    for partition in graph.partitions() {
        let key = (partition.pre, partition.id.clone());
        let n_keys = graph
            .vertex(partition.pre)
            .n_keys_for_partition(&partition.id)
            .max(1);
        let block = (n_keys as u64).next_power_of_two();
        if let Some(kr) = prior.get(&key) {
            if kr.n_keys() == block {
                out.insert(key, *kr);
                continue;
            }
        }
        // Align the cursor to the block size.
        cursor = cursor.div_ceil(block) * block;
        anyhow::ensure!(
            cursor + block <= limit,
            "multicast key space exhausted at partition ({:?}, {})",
            partition.pre,
            partition.id
        );
        let mask = !(block as u32 - 1);
        out.insert(key.clone(), KeyRange::new(cursor as u32, mask));
        rekeyed.push(key);
        cursor += block;
    }
    Ok((out, rekeyed, cursor))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::graph::{DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements};
    use std::any::Any;
    use std::sync::Arc;

    #[derive(Debug)]
    struct ManyKeys(u32);

    impl MachineVertexImpl for ManyKeys {
        fn label(&self) -> String {
            format!("many{}", self.0)
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements::default()
        }
        fn binary_name(&self) -> String {
            "t.aplx".into()
        }
        fn generate_data(&self, _: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn n_keys_for_partition(&self, _: &str) -> u32 {
            self.0
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    #[test]
    fn ranges_are_disjoint_and_sized() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Arc::new(ManyKeys(100)));
        let b = g.add_vertex(Arc::new(ManyKeys(3)));
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, b, "x");
        g.add_edge(b, c, "y");
        g.add_edge(c, a, "z");
        let keys = allocate_keys(&g).unwrap();
        assert_eq!(keys.len(), 3);
        let ka = keys[&(a, "x".to_string())];
        let kb = keys[&(b, "y".to_string())];
        let kc = keys[&(c, "z".to_string())];
        assert_eq!(ka.n_keys(), 128); // 100 rounded up
        assert_eq!(kb.n_keys(), 4);
        assert_eq!(kc.n_keys(), 1);
        // Disjoint: no key of one range matches another range.
        for k in [ka, kb, kc] {
            for other in [ka, kb, kc] {
                if k != other {
                    assert!(!other.contains(k.base));
                    assert!(!other.contains(k.key_for_atom((k.n_keys() - 1) as u32)));
                }
            }
        }
    }

    #[test]
    fn alignment_preserves_base_mask_identity() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Arc::new(ManyKeys(1)));
        let b = g.add_vertex(Arc::new(ManyKeys(256)));
        g.add_edge(a, b, "small");
        g.add_edge(b, a, "big");
        let keys = allocate_keys(&g).unwrap();
        for kr in keys.values() {
            assert_eq!(kr.base & !kr.mask, 0, "base must sit on mask boundary");
        }
    }

    #[test]
    fn two_partitions_same_vertex() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p1");
        g.add_edge(a, b, "p2");
        let keys = allocate_keys(&g).unwrap();
        let k1 = keys[&(a, "p1".to_string())];
        let k2 = keys[&(a, "p2".to_string())];
        assert_ne!(k1.base, k2.base, "each message type needs its own keys");
    }

    #[test]
    fn incremental_keeps_old_ranges_and_never_reuses_freed_space() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Arc::new(ManyKeys(100)));
        let b = g.add_vertex(Arc::new(ManyKeys(3)));
        let c = g.add_vertex(TestVertex::arc("c"));
        let e_ab = g.add_edge(a, b, "x");
        g.add_edge(b, c, "y");
        let (prior, rekeyed, cursor) =
            allocate_keys_incremental(&g, &BTreeMap::new(), 0).unwrap();
        assert_eq!(rekeyed.len(), 2, "first run allocates everything");
        // Drop a's partition, add a new one from c.
        g.remove_edge(e_ab).unwrap();
        g.add_edge(c, a, "z");
        let (keys, rekeyed, cursor2) =
            allocate_keys_incremental(&g, &prior, cursor).unwrap();
        // Survivor keeps its exact range.
        assert_eq!(keys[&(b, "y".to_string())], prior[&(b, "y".to_string())]);
        // Removed partition is gone.
        assert!(!keys.contains_key(&(a, "x".to_string())));
        // New partition sits strictly above the old high-water mark —
        // never inside the freed 128-key block of (a, "x").
        assert_eq!(rekeyed, vec![(c, "z".to_string())]);
        let kz = keys[&(c, "z".to_string())];
        assert!(kz.base as u64 >= cursor, "freed key space reused");
        assert!(cursor2 > cursor);
        // All surviving ranges stay pairwise disjoint.
        for (k1, r1) in &keys {
            for (k2, r2) in &keys {
                if k1 != k2 {
                    assert!(!r2.contains(r1.base), "{k1:?} overlaps {k2:?}");
                }
            }
        }
    }

    #[test]
    fn bounded_window_is_respected() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Arc::new(ManyKeys(100)));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "x");
        let base = 0x0100_0000u64;
        let limit = 0x0200_0000u64;
        let (keys, _, cursor) =
            allocate_keys_incremental_bounded(&g, &BTreeMap::new(), base, limit).unwrap();
        let kr = keys[&(a, "x".to_string())];
        assert!(kr.base as u64 >= base, "allocation below the window base");
        assert!(cursor <= limit);
        // A window too small for the block errors instead of spilling
        // past the tenant boundary.
        assert!(allocate_keys_incremental_bounded(&g, &BTreeMap::new(), base, base + 64).is_err());
    }

    #[test]
    fn deterministic() {
        let build = || {
            let mut g = MachineGraph::new();
            let a = g.add_vertex(Arc::new(ManyKeys(10)));
            let b = g.add_vertex(Arc::new(ManyKeys(20)));
            g.add_edge(a, b, "x");
            g.add_edge(b, a, "y");
            allocate_keys(&g).unwrap()
        };
        assert_eq!(build(), build());
    }
}
