//! Multicast key allocation (§6.3.2: "a set of routing keys detailing
//! the range of keys that must be sent by each vertex ... over each
//! outgoing edge partition").
//!
//! Each partition gets a contiguous power-of-two block of the 32-bit key
//! space: base key + atom index, with the mask covering the block. Blocks
//! are allocated sequentially in deterministic partition order, aligned
//! to their size, so every pair of allocations is disjoint — the property
//! the routing tables (and the order-exploiting compressor) rely on.

use std::collections::BTreeMap;

use crate::graph::{KeyRange, MachineGraph, VertexId};

/// Allocate key ranges for every outgoing edge partition of `graph`.
pub fn allocate_keys(
    graph: &MachineGraph,
) -> anyhow::Result<BTreeMap<(VertexId, String), KeyRange>> {
    let mut out = BTreeMap::new();
    let mut cursor: u64 = 0;
    for partition in graph.partitions() {
        let n_keys = graph
            .vertex(partition.pre)
            .n_keys_for_partition(&partition.id)
            .max(1);
        let block = (n_keys as u64).next_power_of_two();
        // Align the cursor to the block size.
        cursor = cursor.div_ceil(block) * block;
        anyhow::ensure!(
            cursor + block <= (1u64 << 32),
            "multicast key space exhausted at partition ({:?}, {})",
            partition.pre,
            partition.id
        );
        let mask = !(block as u32 - 1);
        out.insert(
            (partition.pre, partition.id.clone()),
            KeyRange::new(cursor as u32, mask),
        );
        cursor += block;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::graph::{DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements};
    use std::any::Any;
    use std::sync::Arc;

    #[derive(Debug)]
    struct ManyKeys(u32);

    impl MachineVertexImpl for ManyKeys {
        fn label(&self) -> String {
            format!("many{}", self.0)
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements::default()
        }
        fn binary_name(&self) -> String {
            "t.aplx".into()
        }
        fn generate_data(&self, _: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn n_keys_for_partition(&self, _: &str) -> u32 {
            self.0
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    #[test]
    fn ranges_are_disjoint_and_sized() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Arc::new(ManyKeys(100)));
        let b = g.add_vertex(Arc::new(ManyKeys(3)));
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, b, "x");
        g.add_edge(b, c, "y");
        g.add_edge(c, a, "z");
        let keys = allocate_keys(&g).unwrap();
        assert_eq!(keys.len(), 3);
        let ka = keys[&(a, "x".to_string())];
        let kb = keys[&(b, "y".to_string())];
        let kc = keys[&(c, "z".to_string())];
        assert_eq!(ka.n_keys(), 128); // 100 rounded up
        assert_eq!(kb.n_keys(), 4);
        assert_eq!(kc.n_keys(), 1);
        // Disjoint: no key of one range matches another range.
        for k in [ka, kb, kc] {
            for other in [ka, kb, kc] {
                if k != other {
                    assert!(!other.contains(k.base));
                    assert!(!other.contains(k.key_for_atom((k.n_keys() - 1) as u32)));
                }
            }
        }
    }

    #[test]
    fn alignment_preserves_base_mask_identity() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(Arc::new(ManyKeys(1)));
        let b = g.add_vertex(Arc::new(ManyKeys(256)));
        g.add_edge(a, b, "small");
        g.add_edge(b, a, "big");
        let keys = allocate_keys(&g).unwrap();
        for kr in keys.values() {
            assert_eq!(kr.base & !kr.mask, 0, "base must sit on mask boundary");
        }
    }

    #[test]
    fn two_partitions_same_vertex() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p1");
        g.add_edge(a, b, "p2");
        let keys = allocate_keys(&g).unwrap();
        let k1 = keys[&(a, "p1".to_string())];
        let k2 = keys[&(a, "p2".to_string())];
        assert_ne!(k1.base, k2.base, "each message type needs its own keys");
    }

    #[test]
    fn deterministic() {
        let build = || {
            let mut g = MachineGraph::new();
            let a = g.add_vertex(Arc::new(ManyKeys(10)));
            let b = g.add_vertex(Arc::new(ManyKeys(20)));
            g.add_edge(a, b, "x");
            g.add_edge(b, a, "y");
            allocate_keys(&g).unwrap()
        };
        assert_eq!(build(), build());
    }
}
