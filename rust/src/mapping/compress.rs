//! Order-exploiting routing-table minimization (§6.7, after Mundy,
//! Heathcote & Garside 2016: "On-chip order-exploiting routing table
//! minimization for a multicast supercomputer network").
//!
//! Two phases:
//!
//! 1. **Buddy merging** (always safe): two entries with the same route,
//!    the same mask, and keys differing in exactly one masked bit are
//!    replaced by one entry with that bit wildcarded. The merged match
//!    set is *exactly* the union of the two, so no foreign key is
//!    captured. Iterated to a fixpoint.
//! 2. **Aggressive covering** (validated): within each route group the
//!    remaining entries are greedily merged into wider covers that may
//!    capture keys outside the originals. The result is ordered
//!    most-specific-first and then *checked*: every key the original
//!    table matched (sampled exhaustively for small ranges, at the
//!    corners for large ones) must still produce the same route. If the
//!    check fails the buddy-phase table is returned instead.
//!
//! Keys the original table did not match may hit a merged cover — the
//! "order-exploiting" trade: on SpiNNaker such keys are never sent (key
//! allocation covers exactly the partitions that exist), so capturing
//! them is free. This is the same assumption the paper's tools make.

use std::collections::BTreeMap;

use crate::machine::router::{Route, RoutingEntry, RoutingTable};
use crate::machine::ChipCoord;

/// Group a table's entries by route word.
fn route_groups(table: &RoutingTable) -> BTreeMap<u32, Vec<RoutingEntry>> {
    let mut groups: BTreeMap<u32, Vec<RoutingEntry>> = BTreeMap::new();
    for e in table.entries() {
        groups.entry(e.route.0).or_default().push(*e);
    }
    groups
}

/// Phase 1 over every route group: exact buddy merging.
fn buddy_table(groups: &BTreeMap<u32, Vec<RoutingEntry>>) -> RoutingTable {
    let mut buddy: Vec<RoutingEntry> = Vec::new();
    for (route, entries) in groups {
        buddy.extend(buddy_merge(entries.clone(), Route(*route)));
    }
    sort_specific_first(&mut buddy);
    RoutingTable::from_entries(buddy)
}

/// Compress a table. Semantics are preserved for all keys the input
/// table matches (see module docs for the unmatched-key caveat).
pub fn compress(table: &RoutingTable) -> RoutingTable {
    let groups = route_groups(table);

    // Phase 1: exact buddy merging per group.
    let buddy_table = buddy_table(&groups);

    // Phase 2: aggressive covering, accepted only if validation passes.
    let mut aggressive: Vec<RoutingEntry> = Vec::new();
    for (route, entries) in &groups {
        aggressive.extend(cover_merge(
            buddy_merge(entries.clone(), Route(*route)),
            Route(*route),
        ));
    }
    sort_specific_first(&mut aggressive);
    let aggressive_table = RoutingTable::from_entries(aggressive);

    if aggressive_table.len() < buddy_table.len()
        && semantics_preserved(table, &aggressive_table)
    {
        aggressive_table
    } else if semantics_preserved(table, &buddy_table) {
        buddy_table
    } else {
        // Buddy merging is provably safe for disjoint-across-route
        // tables; if the input had conflicting overlaps, refuse to touch it.
        table.clone()
    }
}

/// Compress a table preserving the semantics of **every** 32-bit key,
/// matched or not: only the exact buddy phase runs. A buddy-merged
/// entry's match set is precisely the union of the two originals, so a
/// key the input table dropped is still dropped — unlike [`compress`],
/// whose aggressive covers may capture never-allocated keys (the
/// order-exploiting trade). The price is a weaker compression ratio.
pub fn compress_exact(table: &RoutingTable) -> RoutingTable {
    let buddy = buddy_table(&route_groups(table));
    if semantics_preserved(table, &buddy) {
        buddy
    } else {
        // Conflicting cross-route overlaps in the input: refuse.
        table.clone()
    }
}

/// Compress every oversubscribed table in `tables` in place, sharding
/// across up to `threads` workers (chips are independent). Tables that
/// already fit are left untouched, matching the serial pipeline.
pub fn compress_tables_in_place(
    tables: &mut BTreeMap<ChipCoord, RoutingTable>,
    threads: usize,
) {
    let victims: Vec<ChipCoord> = tables
        .iter()
        .filter(|(_, t)| !t.fits())
        .map(|(c, _)| *c)
        .collect();
    let inputs: Vec<&RoutingTable> = victims.iter().map(|c| &tables[c]).collect();
    let compressed = crate::util::par::par_map(threads, &inputs, |_, t| compress(t));
    drop(inputs);
    for (chip, table) in victims.into_iter().zip(compressed) {
        tables.insert(chip, table);
    }
}

/// Order entries most-specific-first (descending mask popcount), ties by
/// key then mask, for determinism. First-match-wins then lets specific
/// original entries shadow wide merged covers from other groups.
fn sort_specific_first(entries: &mut [RoutingEntry]) {
    entries.sort_by(|a, b| {
        b.mask
            .count_ones()
            .cmp(&a.mask.count_ones())
            .then(a.key.cmp(&b.key))
            .then(a.mask.cmp(&b.mask))
    });
}

/// Phase-1 worker: merge buddies to fixpoint.
fn buddy_merge(mut entries: Vec<RoutingEntry>, route: Route) -> Vec<RoutingEntry> {
    entries.sort_by_key(|e| (e.key, e.mask));
    entries.dedup_by_key(|e| (e.key, e.mask));
    loop {
        let mut merged_any = false;
        'outer: for i in 0..entries.len() {
            for j in (i + 1)..entries.len() {
                let (a, b) = (entries[i], entries[j]);
                if a.mask != b.mask {
                    continue;
                }
                let diff = (a.key & a.mask) ^ (b.key & b.mask);
                if diff.count_ones() == 1 {
                    let mask = a.mask & !diff;
                    let key = a.key & mask;
                    entries.remove(j);
                    entries.remove(i);
                    entries.push(RoutingEntry::new(key, mask, route));
                    entries.sort_by_key(|e| (e.key, e.mask));
                    entries.dedup_by_key(|e| (e.key, e.mask));
                    merged_any = true;
                    break 'outer;
                }
            }
        }
        if !merged_any {
            return entries;
        }
    }
}

/// Phase-2 worker: greedily merge entries into the smallest covers.
fn cover_merge(mut entries: Vec<RoutingEntry>, route: Route) -> Vec<RoutingEntry> {
    loop {
        if entries.len() <= 1 {
            return entries;
        }
        let mut best: Option<(usize, usize, u32, u32)> = None;
        for i in 0..entries.len() {
            for j in (i + 1)..entries.len() {
                let (key, mask) = union_cover(&entries[i], &entries[j]);
                let width = (!mask) as u64 + 1;
                if best.map(|(_, _, _, bm)| width < (!bm) as u64 + 1).unwrap_or(true) {
                    best = Some((i, j, key, mask));
                }
            }
        }
        let (i, j, key, mask) = best.unwrap();
        entries.remove(j);
        entries.remove(i);
        entries.push(RoutingEntry::new(key & mask, mask, route));
        entries.sort_by_key(|e| (e.key, e.mask));
        entries.dedup_by_key(|e| (e.key, e.mask));
    }
}

/// The smallest bottom-aligned (key, mask) cover containing both entries.
fn union_cover(a: &RoutingEntry, b: &RoutingEntry) -> (u32, u32) {
    let mut mask = a.mask & b.mask & !(a.key ^ b.key);
    // Make the wildcard region contiguous from the bottom, matching the
    // bottom-aligned ranges the key allocator emits.
    let width = 32 - (!mask).leading_zeros();
    mask = if width >= 32 { 0 } else { !((1u32 << width) - 1) };
    ((a.key & mask), mask)
}

/// Check: every key `original` matches must keep its route in `candidate`.
/// Ranges up to 4096 keys are checked exhaustively; larger ones at their
/// corners and a stride of samples.
fn semantics_preserved(original: &RoutingTable, candidate: &RoutingTable) -> bool {
    for e in original.entries() {
        let lo = e.key & e.mask;
        let hi = lo | !e.mask;
        let n = (hi - lo) as u64 + 1;
        let check = |key: u32| original.lookup(key) == candidate.lookup(key);
        if n <= 4096 {
            for key in lo..=hi {
                if !check(key) {
                    return false;
                }
            }
        } else {
            let stride = (n / 257).max(1) as u32;
            let mut key = lo;
            loop {
                if !check(key) {
                    return false;
                }
                match key.checked_add(stride) {
                    Some(k) if k <= hi => key = k,
                    _ => break,
                }
            }
            if !check(hi) {
                return false;
            }
        }
    }
    true
}

/// Statistics for the compression benchmark (experiment E10).
#[derive(Debug, Clone, Copy)]
pub struct CompressionStats {
    pub before: usize,
    pub after: usize,
}

impl CompressionStats {
    pub fn ratio(&self) -> f64 {
        if self.before == 0 {
            1.0
        } else {
            self.after as f64 / self.before as f64
        }
    }
}

/// Compress and report sizes.
pub fn compress_with_stats(table: &RoutingTable) -> (RoutingTable, CompressionStats) {
    let out = compress(table);
    let stats = CompressionStats { before: table.len(), after: out.len() };
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::Direction;
    use crate::util::{prop, SplitMix64};

    fn e(key: u32, mask: u32, route: Route) -> RoutingEntry {
        RoutingEntry::new(key, mask, route)
    }

    fn east() -> Route {
        Route::EMPTY.with_link(Direction::East)
    }

    fn north() -> Route {
        Route::EMPTY.with_link(Direction::North)
    }

    #[test]
    fn buddy_blocks_merge_exactly() {
        let t = RoutingTable::from_entries(vec![
            e(0x000, 0xffff_ff00, east()),
            e(0x100, 0xffff_ff00, east()),
        ]);
        let c = compress(&t);
        assert_eq!(c.len(), 1);
        assert_eq!(c.entries()[0].mask, 0xffff_fe00);
        for key in [0x000u32, 0x0ff, 0x100, 0x1ff] {
            assert_eq!(c.lookup(key), Some(east()));
        }
    }

    #[test]
    fn different_routes_do_not_merge() {
        let t = RoutingTable::from_entries(vec![
            e(0x000, 0xffff_ff00, east()),
            e(0x100, 0xffff_ff00, north()),
        ]);
        let c = compress(&t);
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup(0x050), Some(east()));
        assert_eq!(c.lookup(0x150), Some(north()));
    }

    #[test]
    fn matched_keys_preserved_under_aggressive_merge() {
        // Non-adjacent blocks around a foreign block: whatever phase 2
        // decides, every matched key keeps its route.
        let t = RoutingTable::from_entries(vec![
            e(0x000, 0xffff_ff00, east()),
            e(0x200, 0xffff_ff00, east()),
            e(0x100, 0xffff_ff00, north()),
        ]);
        let c = compress(&t);
        assert!(c.len() <= 3);
        for key in 0x000..0x300u32 {
            let want = if (0x100..0x200).contains(&key) {
                north()
            } else {
                east()
            };
            assert_eq!(c.lookup(key), Some(want), "key {key:#x}");
        }
    }

    #[test]
    fn thousand_entry_table_fits_after_compression() {
        // E10 shape: 2048 single-key entries, all the same route, in an
        // aligned block -> collapses to one entry.
        let entries: Vec<RoutingEntry> = (0..2048)
            .map(|k| e(k, 0xffff_ffff, east()))
            .collect();
        let t = RoutingTable::from_entries(entries);
        assert!(!t.fits());
        let (c, stats) = compress_with_stats(&t);
        assert!(c.fits());
        assert_eq!(c.len(), 1);
        assert_eq!(stats.before, 2048);
        assert!(stats.ratio() < 0.01);
    }

    #[test]
    fn mixed_routes_interleaved_blocks() {
        // Alternating single keys of two routes: buddies can't merge
        // across routes; compression must stay correct.
        let mut entries = Vec::new();
        for k in 0..64u32 {
            let r = if k % 2 == 0 { east() } else { north() };
            entries.push(e(k, 0xffff_ffff, r));
        }
        let t = RoutingTable::from_entries(entries);
        let c = compress(&t);
        for k in 0..64u32 {
            let want = if k % 2 == 0 { east() } else { north() };
            assert_eq!(c.lookup(k), Some(want), "key {k}");
        }
    }

    #[test]
    fn property_matched_keys_unchanged() {
        prop::check(40, 0xc0ffee, |rng: &mut SplitMix64| {
            let n_groups = 1 + rng.below(4);
            let mut entries = Vec::new();
            for g in 0..n_groups {
                let route = Route(1 << g);
                for _ in 0..1 + rng.below(12) {
                    let block_bits = rng.below(6) as u32;
                    let block = 1u32 << block_bits;
                    let base = (rng.below(64) as u32) * block;
                    entries.push(e(base, !(block - 1), route));
                }
            }
            // Drop overlaps across groups (the allocator never produces
            // them; overlap makes "the matched route" order-dependent).
            let mut clean: Vec<RoutingEntry> = Vec::new();
            'outer: for cand in entries {
                for kept in &clean {
                    if kept.intersects(&cand) && kept.route != cand.route {
                        continue 'outer;
                    }
                }
                clean.push(cand);
            }
            let t = RoutingTable::from_entries(clean.clone());
            let c = compress(&t);
            assert!(c.len() <= t.len(), "compression must not grow tables");
            for orig in &clean {
                let lo = orig.key & orig.mask;
                let hi = lo | !orig.mask;
                for key in lo..=hi {
                    assert_eq!(
                        t.lookup(key),
                        c.lookup(key),
                        "key {key:#x} changed route"
                    );
                }
            }
        });
    }

    #[test]
    fn empty_table_compresses_to_empty() {
        let t = RoutingTable::new();
        assert_eq!(compress(&t).len(), 0);
        assert_eq!(compress_exact(&t).len(), 0);
    }

    #[test]
    fn exact_compression_keeps_dead_keys_dead() {
        // Two non-adjacent blocks: aggressive covering would swallow the
        // gap; the exact compressor must not.
        let t = RoutingTable::from_entries(vec![
            e(0x000, 0xffff_ff00, east()),
            e(0x200, 0xffff_ff00, east()),
        ]);
        let c = compress_exact(&t);
        for key in 0x100..0x200u32 {
            assert_eq!(c.lookup(key), None, "dead key {key:#x} came alive");
        }
        for key in (0x000..0x100u32).chain(0x200..0x300) {
            assert_eq!(c.lookup(key), Some(east()));
        }
    }

    #[test]
    fn exact_compression_merges_buddies() {
        let t = RoutingTable::from_entries(vec![
            e(0x000, 0xffff_ff00, east()),
            e(0x100, 0xffff_ff00, east()),
        ]);
        assert_eq!(compress_exact(&t).len(), 1);
    }

    #[test]
    fn sharded_whole_map_compression_matches_serial() {
        use std::collections::BTreeMap;
        // Two just-oversubscribed single-route tables (cheap to buddy-
        // merge) plus one that already fits and must be left untouched.
        let build = || -> BTreeMap<crate::machine::ChipCoord, RoutingTable> {
            let mut m = BTreeMap::new();
            for i in 0..2u32 {
                let entries: Vec<RoutingEntry> =
                    (0..1040u32).map(|k| e(k + i, !0, east())).collect();
                m.insert((i, 0u32), RoutingTable::from_entries(entries));
            }
            m.insert(
                (9, 9),
                RoutingTable::from_entries(vec![e(0, !0, north()), e(1, !0, north())]),
            );
            m
        };
        let mut serial = build();
        compress_tables_in_place(&mut serial, 1);
        let mut sharded = build();
        compress_tables_in_place(&mut sharded, 4);
        assert_eq!(serial.len(), sharded.len());
        for (chip, t) in &serial {
            assert_eq!(t.entries(), sharded[chip].entries(), "chip {chip:?}");
        }
        // The fitting table was not compressed.
        assert_eq!(sharded[&(9, 9)].len(), 2);
    }

    #[test]
    fn stats_ratio() {
        let t = RoutingTable::from_entries(vec![
            e(0, 0xffff_ffff, east()),
            e(1, 0xffff_ffff, east()),
        ]);
        let (_, stats) = compress_with_stats(&t);
        assert_eq!(stats.before, 2);
        assert_eq!(stats.after, 1);
        assert_eq!(stats.ratio(), 0.5);
    }
}
