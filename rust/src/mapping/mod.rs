//! The mapping phase (§6.3.2): graph → machine.
//!
//! Sub-steps, each its own algorithm run by the Figure-10 execution
//! engine (see [`crate::algorithms`] and [`crate::front`]):
//!
//! 1. [`splitter`] — application graph → machine graph ("graph
//!    partitioning", kept separate from the rest per §6.3.2);
//! 2. [`placer`] — machine vertices → cores (radial first-fit with
//!    resource accounting and constraint handling);
//! 3. [`router`] — edges → multicast routing trees (NER: longest
//!    dimension first, with BFS fallback around faults; Heathcote 2016);
//! 4. [`keys`] — outgoing edge partitions → multicast key ranges;
//! 5. [`tables`] — routing trees + keys → per-chip TCAM tables, with
//!    optional default-route elision;
//! 6. [`compress`] — order-exploiting table minimization (Mundy et
//!    al. 2016);
//! 7. [`tags`] — IP tag / reverse IP tag allocation on Ethernet chips;
//! 8. [`database`] — the mapping database external live apps read (§6.9).

pub mod compress;
pub mod database;
pub mod keys;
pub mod placer;
pub mod router;
pub mod splitter;
pub mod tables;
pub mod tags;

use std::collections::BTreeMap;

use crate::graph::{AllocatedIpTag, AllocatedReverseIpTag, KeyRange, MachineGraph, VertexId};
use crate::machine::{ChipCoord, CoreLocation, Machine};

pub use placer::Placements;
pub use router::{RoutingForest, RoutingTree, TreeNode};
pub use splitter::GraphMapping;

/// Everything mapping produces (the §6.3.2 outputs: placements, routing
/// tables, routing keys, IP tags).
pub struct Mapping {
    pub placements: Placements,
    pub forest: RoutingForest,
    pub keys: BTreeMap<(VertexId, String), KeyRange>,
    pub tables: BTreeMap<ChipCoord, crate::machine::router::RoutingTable>,
    pub iptags: BTreeMap<(VertexId, String), AllocatedIpTag>,
    pub reverse_iptags: BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
}

/// Options controlling the mapping pipeline.
#[derive(Debug, Clone)]
pub struct MappingConfig {
    /// Elide entries default routing would reproduce (§2's
    /// straight-through rule) at table-generation time.
    pub use_default_routes: bool,
    /// Run the ordered-covering compressor on oversubscribed tables.
    pub compress_tables: bool,
    /// Fail if a compressed table still exceeds the 1024-entry TCAM.
    pub enforce_table_capacity: bool,
}

impl Default for MappingConfig {
    fn default() -> Self {
        Self {
            use_default_routes: true,
            compress_tables: true,
            enforce_table_capacity: true,
        }
    }
}

/// Run the full machine-graph mapping pipeline. (Application graphs are
/// split first by [`splitter::split_graph`]; the front end wires both
/// through the algorithm engine.)
pub fn map_graph(
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
) -> anyhow::Result<Mapping> {
    let placements = placer::place(machine, graph)?;
    let forest = router::route(machine, graph, &placements)?;
    let keys = keys::allocate_keys(graph)?;
    let mut tables = tables::build_tables(machine, graph, &forest, &keys, config)?;
    if config.compress_tables {
        for table in tables.values_mut() {
            if !table.fits() {
                *table = compress::compress(table);
            }
        }
    }
    if config.enforce_table_capacity {
        for (chip, table) in &tables {
            if !table.fits() {
                anyhow::bail!(
                    "routing table on chip {chip:?} needs {} entries (TCAM holds {})",
                    table.len(),
                    crate::machine::ROUTER_ENTRIES
                );
            }
        }
    }
    let (iptags, reverse_iptags) = tags::allocate_tags(machine, graph, &placements)?;
    Ok(Mapping { placements, forest, keys, tables, iptags, reverse_iptags })
}

impl Mapping {
    pub fn placement(&self, v: VertexId) -> Option<CoreLocation> {
        self.placements.of(v)
    }
}

/// Run the same pipeline through the Figure-10 algorithm execution
/// engine: each step is an [`crate::algorithms::Algorithm`] with token
/// inputs/outputs, and the executor derives the workflow order. Returns
/// the mapping plus the executed workflow (for provenance).
pub fn map_graph_via_engine(
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
) -> anyhow::Result<(Mapping, crate::algorithms::Workflow)> {
    use crate::algorithms::{Algorithm, Blackboard, Executor};

    let mut board = Blackboard::new();
    board.put("machine", machine.clone());
    board.put("machine_graph", graph.clone());
    board.put("mapping_config", config.clone());

    let algorithms = vec![
        Algorithm::new(
            "radial_placer",
            &["machine", "machine_graph"],
            &["placements"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p = placer::place(m, g)?;
                b.put("placements", p);
                Ok(())
            },
        ),
        Algorithm::new(
            "ner_router",
            &["machine", "machine_graph", "placements"],
            &["routing_trees"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p: &Placements = b.get("placements")?;
                let f = router::route(m, g, p)?;
                b.put("routing_trees", f);
                Ok(())
            },
        ),
        Algorithm::new(
            "key_allocator",
            &["machine_graph"],
            &["routing_keys"],
            |b| {
                let g: &MachineGraph = b.get("machine_graph")?;
                let k = keys::allocate_keys(g)?;
                b.put("routing_keys", k);
                Ok(())
            },
        ),
        Algorithm::new(
            "table_generator",
            &["machine", "machine_graph", "routing_trees", "routing_keys", "mapping_config"],
            &["routing_tables"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let f: &RoutingForest = b.get("routing_trees")?;
                let k: &BTreeMap<(VertexId, String), KeyRange> = b.get("routing_keys")?;
                let c: &MappingConfig = b.get("mapping_config")?;
                let t = tables::build_tables(m, g, f, k, c)?;
                b.put("routing_tables", t);
                Ok(())
            },
        ),
        Algorithm::new(
            "table_compressor",
            &["routing_tables", "mapping_config"],
            &["compressed_tables"],
            |b| {
                let c: &MappingConfig = b.get("mapping_config")?;
                let compress = c.compress_tables;
                let enforce = c.enforce_table_capacity;
                let mut t: BTreeMap<ChipCoord, crate::machine::router::RoutingTable> =
                    b.take("routing_tables")?;
                if compress {
                    for table in t.values_mut() {
                        if !table.fits() {
                            *table = compress::compress(table);
                        }
                    }
                }
                if enforce {
                    for (chip, table) in &t {
                        anyhow::ensure!(
                            table.fits(),
                            "routing table on chip {chip:?} exceeds TCAM after compression"
                        );
                    }
                }
                b.put("compressed_tables", t);
                Ok(())
            },
        ),
        Algorithm::new(
            "tag_allocator",
            &["machine", "machine_graph", "placements"],
            &["ip_tags"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p: &Placements = b.get("placements")?;
                let tags = tags::allocate_tags(m, g, p)?;
                b.put("ip_tags", tags);
                Ok(())
            },
        ),
    ];

    let workflow = Executor::new(algorithms).execute(
        &mut board,
        &["placements", "compressed_tables", "routing_keys", "ip_tags"],
    )?;

    let placements: Placements = board.take("placements")?;
    let forest: RoutingForest = board.take("routing_trees")?;
    let keys: BTreeMap<(VertexId, String), KeyRange> = board.take("routing_keys")?;
    let tables: BTreeMap<ChipCoord, crate::machine::router::RoutingTable> =
        board.take("compressed_tables")?;
    let (iptags, reverse_iptags): (
        BTreeMap<(VertexId, String), AllocatedIpTag>,
        BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
    ) = board.take("ip_tags")?;

    Ok((
        Mapping { placements, forest, keys, tables, iptags, reverse_iptags },
        workflow,
    ))
}

#[cfg(test)]
mod engine_tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::machine::MachineBuilder;

    #[test]
    fn engine_pipeline_matches_direct() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        let direct = map_graph(&m, &g, &MappingConfig::default()).unwrap();
        let (engine, workflow) =
            map_graph_via_engine(&m, &g, &MappingConfig::default()).unwrap();
        assert_eq!(direct.placements.of(a), engine.placements.of(a));
        assert_eq!(direct.keys, engine.keys);
        assert_eq!(
            direct.tables.keys().collect::<Vec<_>>(),
            engine.tables.keys().collect::<Vec<_>>()
        );
        // The engine ordered the placer before the router.
        let pos = |n: &str| workflow.0.iter().position(|x| x == n).unwrap();
        assert!(pos("radial_placer") < pos("ner_router"));
        assert!(pos("table_generator") < pos("table_compressor"));
    }
}
