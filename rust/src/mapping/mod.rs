//! The mapping phase (§6.3.2): graph → machine.
//!
//! Sub-steps, each its own algorithm run by the Figure-10 execution
//! engine (see [`crate::algorithms`] and [`crate::front`]):
//!
//! 1. [`splitter`] — application graph → machine graph ("graph
//!    partitioning", kept separate from the rest per §6.3.2);
//! 2. [`placer`] — machine vertices → cores (radial first-fit with
//!    resource accounting and constraint handling);
//! 3. [`router`] — edges → multicast routing trees (NER: longest
//!    dimension first, with BFS fallback around faults; Heathcote 2016);
//! 4. [`keys`] — outgoing edge partitions → multicast key ranges;
//! 5. [`tables`] — routing trees + keys → per-chip TCAM tables, with
//!    optional default-route elision;
//! 6. [`compress`] — order-exploiting table minimization (Mundy et
//!    al. 2016);
//! 7. [`tags`] — IP tag / reverse IP tag allocation on Ethernet chips;
//! 8. [`database`] — the mapping database external live apps read (§6.9).
//!
//! Steps 2–7 are delta-aware: run against a persistent
//! [`PipelineState`], [`map_graph_incremental`] re-executes only the
//! stages (and within them only the partitions/chips) a graph change
//! invalidated (DESIGN.md §7).

// The engine stages pass wide context tuples through the sharded
// split/process/merge hooks; naming each would obscure, not clarify.
#![allow(clippy::type_complexity)]

pub mod compress;
pub mod database;
pub mod keys;
pub mod placer;
pub mod router;
pub mod splitter;
pub mod tables;
pub mod tags;

use std::collections::BTreeMap;

use crate::graph::{AllocatedIpTag, AllocatedReverseIpTag, KeyRange, MachineGraph, VertexId};
use crate::machine::{ChipCoord, CoreLocation, Machine};

pub use placer::Placements;
pub use router::{RoutingForest, RoutingTree, TreeNode};
pub use splitter::GraphMapping;

/// Everything mapping produces (the §6.3.2 outputs: placements, routing
/// tables, routing keys, IP tags).
pub struct Mapping {
    pub placements: Placements,
    pub forest: RoutingForest,
    pub keys: BTreeMap<(VertexId, String), KeyRange>,
    pub tables: BTreeMap<ChipCoord, crate::machine::router::RoutingTable>,
    pub iptags: BTreeMap<(VertexId, String), AllocatedIpTag>,
    pub reverse_iptags: BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
}

/// Host-side execution options for the mapping pipeline: §1 warns that
/// mapping time "will dwarf the computational execution time" if it does
/// not scale with the machine, so the shardable stages (NER routing,
/// table generation, ordered-covering compression) run on a scoped
/// worker pool this wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappingOptions {
    /// Worker threads for the shardable mapping stages. `1` = serial
    /// (the default); `0` = one worker per available hardware thread.
    /// Output is byte-identical at any setting.
    pub threads: usize,
}

impl Default for MappingOptions {
    fn default() -> Self {
        Self { threads: 1 }
    }
}

impl MappingOptions {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads }
    }

    /// The actual pool width (resolves `0` to the hardware parallelism).
    pub fn effective_threads(&self) -> usize {
        crate::util::par::effective_threads(self.threads)
    }
}

/// Options controlling the mapping pipeline.
#[derive(Debug, Clone)]
pub struct MappingConfig {
    /// Elide entries default routing would reproduce (§2's
    /// straight-through rule) at table-generation time.
    pub use_default_routes: bool,
    /// Run the ordered-covering compressor on oversubscribed tables.
    pub compress_tables: bool,
    /// Fail if a compressed table still exceeds the 1024-entry TCAM.
    pub enforce_table_capacity: bool,
    /// `[base, limit)` window of the 32-bit multicast key space this
    /// session may allocate from. The default is the whole space, which
    /// makes single-session behaviour byte-identical to the historical
    /// allocator; the multi-tenant service gives each tenant a disjoint
    /// window so no two sessions can ever mint the same key.
    pub key_space: (u64, u64),
    /// Host-side execution options (worker-pool width).
    pub options: MappingOptions,
}

impl Default for MappingConfig {
    fn default() -> Self {
        Self {
            use_default_routes: true,
            compress_tables: true,
            enforce_table_capacity: true,
            key_space: (0, 1u64 << 32),
            options: MappingOptions::default(),
        }
    }
}

/// Run the full machine-graph mapping pipeline. (Application graphs are
/// split first by [`splitter::split_graph`]; the front end wires both
/// through the algorithm engine.)
pub fn map_graph(
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
) -> anyhow::Result<Mapping> {
    let threads = config.options.threads;
    // Big machines take the two-level placer (byte-identical output,
    // flat ledgers + board-sharded refinement — DESIGN.md §12); small
    // ones keep the flat path, which needs no sharding setup.
    let placements = if machine.n_chips() >= placer::HIERARCHICAL_PLACEMENT_THRESHOLD {
        placer::place_hierarchical(machine, graph, &std::collections::BTreeSet::new(), threads)?
    } else {
        placer::place(machine, graph)?
    };
    let forest = router::route_sharded(machine, graph, &placements, threads)?;
    let keys = keys::allocate_keys(graph)?;
    let mut tables = tables::build_tables(machine, graph, &forest, &keys, config)?;
    if config.compress_tables {
        compress::compress_tables_in_place(&mut tables, threads);
    }
    if config.enforce_table_capacity {
        for (chip, table) in &tables {
            if !table.fits() {
                anyhow::bail!(
                    "routing table on chip {chip:?} needs {} entries (TCAM holds {})",
                    table.len(),
                    crate::machine::ROUTER_ENTRIES
                );
            }
        }
    }
    let (iptags, reverse_iptags) = tags::allocate_tags(machine, graph, &placements)?;
    Ok(Mapping { placements, forest, keys, tables, iptags, reverse_iptags })
}

impl Mapping {
    pub fn placement(&self, v: VertexId) -> Option<CoreLocation> {
        self.placements.of(v)
    }
}

/// Persistent pipeline state for incremental re-mapping (DESIGN.md §7):
/// the [`Blackboard`](crate::algorithms::Blackboard) carrying every
/// stage's last outputs plus the fingerprint-keyed
/// [`StageCache`](crate::algorithms::StageCache). The front end keeps
/// one of these across runs; [`crate::front::SpiNNTools::reset`]
/// clears it so a reset run is provably from-scratch.
///
/// If [`map_graph_incremental`] returns an error the board may be left
/// partially mutated — the caller must `clear()` before mapping again.
#[derive(Default)]
pub struct PipelineState {
    board: crate::algorithms::Blackboard,
    cache: crate::algorithms::StageCache,
}

impl PipelineState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Forget every cached stage and token: the next map is full.
    pub fn clear(&mut self) {
        *self = Self::default();
    }

    /// True when no mapping has been memoised (fresh or just cleared).
    pub fn is_fresh(&self) -> bool {
        self.cache.is_empty()
    }

    /// Per-stage hit/miss/wall-clock of the most recent map.
    pub fn stage_stats(&self) -> &[crate::algorithms::StageStat] {
        &self.cache.last_run
    }

    /// Seed a fresh pipeline with a prior map's placements and key
    /// allocations — the restore half of a run snapshot. The next
    /// [`map_graph_incremental`] pass treats the seeded tokens exactly
    /// like its own previous outputs: every seeded vertex stays pinned
    /// to its core and surviving partitions keep their exact key
    /// ranges, with new allocations above `key_cursor`. Tokens are
    /// deliberately left unstamped, so every stage re-runs once (no
    /// stale cache hit against a board the stages never saw) and the
    /// cache warms from there.
    pub fn seed(
        &mut self,
        placements: Placements,
        keys: BTreeMap<(VertexId, String), KeyRange>,
        key_cursor: u64,
    ) {
        self.board.put("placements", placements);
        self.board.put("routing_keys", keys);
        self.board.put("key_cursor", key_cursor);
    }

    /// The key allocator's high-water mark after the most recent map
    /// (`None` before any map) — captured into run snapshots so a
    /// resumed run's allocator never re-issues a range the suspended
    /// run already handed out.
    pub fn key_cursor(&self) -> Option<u64> {
        self.board.get::<u64>("key_cursor").ok().copied()
    }
}

/// Everything one [`map_graph_incremental`] pass produces.
pub struct MapOutcome {
    pub mapping: Mapping,
    pub workflow: crate::algorithms::Workflow,
    /// Per-stage hit/miss/elapsed provenance for this pass.
    pub stages: Vec<crate::algorithms::StageStat>,
    /// Chips whose routing table differs from the prior map and must be
    /// (re)installed — on a fresh map, every chip that has a table.
    pub install_chips: std::collections::BTreeSet<ChipCoord>,
}

/// Content digest of a machine (geometry, faults, core/SDRAM capacity):
/// the cache key guarding every machine-dependent pipeline stage.
pub fn machine_fingerprint(machine: &Machine) -> u64 {
    let mut h = crate::util::FNV_OFFSET;
    let mut put = |bytes: &[u8]| crate::util::fnv1a_64_extend(&mut h, bytes);
    put(&machine.width.to_le_bytes());
    put(&machine.height.to_le_bytes());
    for chip in machine.chips() {
        put(&chip.x.to_le_bytes());
        put(&chip.y.to_le_bytes());
        put(&[chip.is_virtual as u8]);
        put(&chip.sdram.user_size().to_le_bytes());
        for p in chip.application_processors() {
            put(&[p.id]);
        }
        for d in crate::machine::ALL_DIRECTIONS {
            match machine.link_target((chip.x, chip.y), d) {
                Some(t) => {
                    put(&[1, d.id()]);
                    put(&t.0.to_le_bytes());
                    put(&t.1.to_le_bytes());
                }
                None => put(&[0, d.id()]),
            }
        }
    }
    h
}

fn config_fingerprint(config: &MappingConfig) -> u64 {
    let mut h = crate::util::fnv1a_64(&[
        config.use_default_routes as u8,
        config.compress_tables as u8,
        config.enforce_table_capacity as u8,
    ]);
    crate::util::fnv1a_64_extend(&mut h, &config.key_space.0.to_le_bytes());
    crate::util::fnv1a_64_extend(&mut h, &config.key_space.1.to_le_bytes());
    h
}

/// Digest of the graph's IP-tag / reverse-IP-tag demands — the cache
/// key of the tag allocator. Placements are deliberately *not* part of
/// it: while this digest is stable, every tag-bearing vertex is pinned
/// (incremental placement never moves a surviving vertex), so its
/// nearest-Ethernet assignment cannot change.
fn tag_requests_fingerprint(graph: &MachineGraph) -> u64 {
    let mut h = crate::util::FNV_OFFSET;
    let mut put = |bytes: &[u8]| crate::util::fnv1a_64_extend(&mut h, bytes);
    for (vid, vertex) in graph.vertices() {
        let r = vertex.resources();
        if r.iptags.is_empty() && r.reverse_iptags.is_empty() {
            continue;
        }
        put(&vid.0.to_le_bytes());
        for t in &r.iptags {
            put(t.host.as_bytes());
            put(&t.port.to_le_bytes());
            put(&[t.strip_sdp as u8]);
            put(t.label.as_bytes());
        }
        for t in &r.reverse_iptags {
            put(&t.port.to_le_bytes());
            put(t.label.as_bytes());
        }
    }
    h
}

/// Does this prior tree still serve this route item exactly (same
/// source chip, same delivered (chip, core) set)? If so the tree can be
/// reused verbatim: `build_tree` is deterministic in (machine, source,
/// dests), and the machine is fingerprint-guarded.
fn tree_matches(tree: &router::RoutingTree, item: &router::RouteItem) -> bool {
    if tree.source != item.source {
        return false;
    }
    let want: Vec<(ChipCoord, u8)> = item
        .dests
        .iter()
        .flat_map(|(c, ps)| ps.iter().map(move |p| (*c, *p)))
        .collect();
    tree.destinations() == want
}

/// Run the Figure-10 pipeline against the persistent `state`,
/// incrementally where the fingerprints allow (DESIGN.md §7):
///
/// - stages whose input fingerprints are unchanged are **skipped**
///   outright (the prior outputs on the blackboard stand in);
/// - the placer pins every vertex of the prior placements to its core
///   and only places new vertices (`reserved` protects the bulk data
///   plane's system cores); pins whose core no longer exists — the
///   machine degraded at runtime, or the chip is in `forbidden` — are
///   *displaced* and re-placed like new vertices;
/// - the router rebuilds only trees whose endpoints changed **or whose
///   path crosses a dead link/chip** ([`router::tree_valid`]), the key
///   allocator re-keys only new/resized partitions (monotone key
///   space — freed ranges are never reused), and tables are regenerated
///   and re-compressed only on chips those trees/keys touch, with
///   [`compress::compress_exact`] on incrementally-dirty tables so a
///   retired key can never be captured by a fresh cover.
///
/// A *machine* change is therefore an ordinary delta, not a reset: the
/// self-healing run supervisor feeds the degraded re-discovered machine
/// (plus the newly-dead chips as `forbidden`) straight back in, and only
/// the work the faults invalidated re-runs. A *config* change still
/// clears the whole state — config is not delta-tracked.
///
/// On a fresh `state` this is exactly the historical full pipeline.
/// The sharded inner loops still fan out over
/// `config.options.threads`; output remains thread-count-invariant.
pub fn map_graph_incremental(
    state: &mut PipelineState,
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
    reserved: &std::collections::BTreeSet<CoreLocation>,
    forbidden: &std::collections::BTreeSet<ChipCoord>,
) -> anyhow::Result<MapOutcome> {
    use crate::algorithms::{Algorithm, Blackboard, Executor};
    use crate::machine::router::RoutingTable;
    use std::collections::BTreeSet;

    // A different mapping config invalidates everything the board holds
    // (config is not delta-tracked): start over rather than reason about
    // partial invalidation. Machine changes, by contrast, flow through
    // the stages as deltas — see the docs above.
    let machine_fp = machine_fingerprint(machine);
    let config_fp = config_fingerprint(config);
    if state
        .board
        .fp_of("mapping_config")
        .is_some_and(|fp| fp != config_fp)
    {
        state.clear();
    }

    let forbidden_fp = {
        let mut h = crate::util::FNV_OFFSET;
        for c in forbidden {
            crate::util::fnv1a_64_extend(&mut h, &c.0.to_le_bytes());
            crate::util::fnv1a_64_extend(&mut h, &c.1.to_le_bytes());
        }
        h
    };

    let board = &mut state.board;
    board.put_with_fp("machine", machine.clone(), machine_fp);
    board.put("machine_graph", graph.clone());
    board.put_with_fp("mapping_config", config.clone(), config_fp);
    // Fingerprint markers: the graph rides the board as one (unstamped)
    // data token, while invalidation is keyed on these content digests —
    // so e.g. adding an edge dirties routing without dirtying placement.
    board.put_with_fp("graph_vertices", (), graph.vertices_fingerprint());
    board.put_with_fp("graph_partitions", (), graph.partitions_fingerprint());
    board.put_with_fp("tag_requests", (), tag_requests_fingerprint(graph));
    board.put_with_fp("forbidden_chips", forbidden.clone(), forbidden_fp);

    let reserved_cores = reserved.clone();
    let forbidden_placer = forbidden.clone();
    let (key_base, key_limit) = config.key_space;
    let algorithms = vec![
        // Placement: pin-and-extend when a prior placement exists (pins
        // on dead/forbidden resources displace, DESIGN.md §8).
        Algorithm::new(
            "radial_placer",
            &["machine", "machine_graph", "graph_vertices", "forbidden_chips"],
            &["placements"],
            move |b| {
                let prior: Option<Placements> = if b.has("placements") {
                    Some(b.take("placements")?)
                } else {
                    None
                };
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p = match &prior {
                    Some(prev) => placer::place_incremental(
                        m,
                        g,
                        prev,
                        &reserved_cores,
                        &forbidden_placer,
                    )?,
                    None => placer::place_avoiding(m, g, &forbidden_placer)?,
                };
                b.put("placements", p);
                Ok(())
            },
        )
        .with_fp_inputs(&["machine", "graph_vertices", "forbidden_chips"]),
        // Routing, sharded per *dirty* partition: prior trees whose
        // endpoints are unchanged — and which are still *sound* on the
        // (possibly degraded) machine with the forbidden chips
        // quarantined — are reused verbatim; the chips of every
        // dropped/rebuilt tree (old and new shape) are collected for
        // the table generator.
        Algorithm::sharded(
            "ner_router",
            &[
                "machine", "machine_graph", "graph_partitions", "placements",
                "forbidden_chips",
            ],
            &["routing_trees", "route_dirty_chips"],
            |b: &mut Blackboard| {
                let items = {
                    let g: &MachineGraph = b.get("machine_graph")?;
                    let p: &Placements = b.get("placements")?;
                    router::route_items(g, p)?
                };
                let forbidden: BTreeSet<ChipCoord> =
                    b.get::<BTreeSet<ChipCoord>>("forbidden_chips")?.clone();
                let prior: RoutingForest = if b.has("routing_trees") {
                    b.take("routing_trees")?
                } else {
                    RoutingForest::default()
                };
                let m: Machine = b.take("machine")?;
                let mut prior_trees = prior.trees;
                let mut kept: BTreeMap<(VertexId, String), router::RoutingTree> =
                    BTreeMap::new();
                let mut dirty: BTreeSet<ChipCoord> = BTreeSet::new();
                let mut work: Vec<router::RouteItem> = Vec::new();
                for item in items {
                    match prior_trees.remove(&item.key) {
                        Some(tree)
                            if tree_matches(&tree, &item)
                                && router::tree_valid(&tree, &m, &forbidden) =>
                        {
                            kept.insert(item.key.clone(), tree);
                        }
                        Some(old) => {
                            dirty.extend(RoutingForest::tree_chips(&old, &m));
                            work.push(item);
                        }
                        None => work.push(item),
                    }
                }
                // Trees whose partition no longer exists: retire them,
                // dirtying every chip they touched.
                for (_, old) in prior_trees {
                    dirty.extend(RoutingForest::tree_chips(&old, &m));
                }
                Ok(((m, forbidden, kept, dirty), work))
            },
            |ctx: &(
                Machine,
                BTreeSet<ChipCoord>,
                BTreeMap<(VertexId, String), router::RoutingTree>,
                BTreeSet<ChipCoord>,
            ),
             item: &router::RouteItem| {
                let (m, forbidden, _, _) = ctx;
                Ok((
                    item.key.clone(),
                    router::build_tree_avoiding(m, item.source, &item.dests, forbidden)?,
                ))
            },
            |b: &mut Blackboard,
             ctx,
             built: Vec<((VertexId, String), router::RoutingTree)>| {
                let (m, _, kept, mut dirty) = ctx;
                let mut forest = RoutingForest { trees: kept };
                for (key, tree) in built {
                    dirty.extend(RoutingForest::tree_chips(&tree, &m));
                    forest.trees.insert(key, tree);
                }
                b.put("machine", m);
                b.put("routing_trees", forest);
                b.put("route_dirty_chips", dirty);
                Ok(())
            },
        )
        .with_fp_inputs(&["machine", "graph_partitions", "placements", "forbidden_chips"]),
        // Key allocation: monotone incremental (see
        // [`keys::allocate_keys_incremental`]), confined to the
        // session's `key_space` window. The cursor is clamped up to the
        // window base so a seeded/fresh session starts allocating inside
        // its own namespace; the window limit bounds exhaustion.
        Algorithm::new(
            "key_allocator",
            &["machine_graph", "graph_partitions"],
            &["routing_keys", "rekeyed_partitions", "key_cursor"],
            move |b| {
                let prior: BTreeMap<(VertexId, String), KeyRange> =
                    if b.has("routing_keys") { b.take("routing_keys")? } else { BTreeMap::new() };
                let cursor: u64 = if b.has("key_cursor") { b.take("key_cursor")? } else { 0 };
                let g: &MachineGraph = b.get("machine_graph")?;
                let (keys, rekeyed, cursor) = keys::allocate_keys_incremental_bounded(
                    g,
                    &prior,
                    cursor.max(key_base),
                    key_limit,
                )?;
                b.put("routing_keys", keys);
                b.put("rekeyed_partitions", rekeyed);
                b.put("key_cursor", cursor);
                Ok(())
            },
        )
        .with_fp_inputs(&["graph_partitions"]),
        // Table generation, sharded per *dirty* chip: the union of the
        // router's dirty chips, the chips of partitions whose key range
        // changed since this stage last ran (diffed against the stage's
        // own key snapshot — exact even when the key allocator was a
        // cache hit), and chips whose table must vanish. Clean chips
        // keep their prior (uncompressed) table verbatim.
        Algorithm::sharded(
            "table_generator",
            &[
                "machine", "machine_graph", "routing_trees", "routing_keys",
                "mapping_config", "route_dirty_chips",
            ],
            &["routing_tables", "tables_dirty_chips", "tables_keys_snapshot"],
            |b: &mut Blackboard| {
                let f: RoutingForest = b.take("routing_trees")?;
                let had_prior = b.has("routing_tables");
                let prior_tables: BTreeMap<ChipCoord, RoutingTable> =
                    if had_prior { b.take("routing_tables")? } else { BTreeMap::new() };
                let snapshot: BTreeMap<(VertexId, String), KeyRange> =
                    if b.has("tables_keys_snapshot") {
                        b.take("tables_keys_snapshot")?
                    } else {
                        BTreeMap::new()
                    };
                let (ranges, work_all, use_default, dirty, new_snapshot) = {
                    let m: &Machine = b.get("machine")?;
                    let k: &BTreeMap<(VertexId, String), KeyRange> = b.get("routing_keys")?;
                    let c: &MappingConfig = b.get("mapping_config")?;
                    let (trees_ref, ranges, work_all) = tables::plan_chips(m, &f, k)?;
                    drop(trees_ref);
                    let dirty: BTreeSet<ChipCoord> = if had_prior {
                        let mut d = b.get::<BTreeSet<ChipCoord>>("route_dirty_chips")?.clone();
                        for (key, kr) in k.iter() {
                            if snapshot.get(key) != Some(kr) {
                                if let Some(tree) = f.trees.get(key) {
                                    d.extend(RoutingForest::tree_chips(tree, m));
                                }
                            }
                        }
                        let planned: BTreeSet<ChipCoord> =
                            work_all.iter().map(|(c, _)| *c).collect();
                        d.extend(prior_tables.keys().filter(|c| !planned.contains(c)));
                        d
                    } else {
                        work_all.iter().map(|(c, _)| *c).collect()
                    };
                    (ranges, work_all, c.use_default_routes, dirty, k.clone())
                };
                let work: Vec<tables::ChipWork> = work_all
                    .into_iter()
                    .filter(|(c, _)| dirty.contains(c))
                    .collect();
                // Forest order matches plan_chips' range/index order.
                let (tree_keys, trees): (Vec<(VertexId, String)>, Vec<router::RoutingTree>) =
                    f.trees.into_iter().unzip();
                Ok((
                    (tree_keys, trees, ranges, use_default, prior_tables, dirty, new_snapshot),
                    work,
                ))
            },
            |ctx: &(
                Vec<(VertexId, String)>,
                Vec<router::RoutingTree>,
                Vec<KeyRange>,
                bool,
                BTreeMap<ChipCoord, RoutingTable>,
                BTreeSet<ChipCoord>,
                BTreeMap<(VertexId, String), KeyRange>,
            ),
             item: &tables::ChipWork| {
                let (_, trees, ranges, use_default, _, _, _) = ctx;
                Ok((item.0, tables::chip_table(trees, ranges, item.0, &item.1, *use_default)))
            },
            |b: &mut Blackboard, ctx, chip_tables: Vec<(ChipCoord, RoutingTable)>| {
                let (tree_keys, trees, _, _, prior_tables, dirty, new_snapshot) = ctx;
                b.put("routing_trees", RoutingForest {
                    trees: tree_keys.into_iter().zip(trees).collect(),
                });
                let mut tables = prior_tables;
                let mut regen: BTreeMap<ChipCoord, RoutingTable> =
                    chip_tables.into_iter().collect();
                let mut changed: BTreeSet<ChipCoord> = BTreeSet::new();
                for chip in &dirty {
                    let old = tables.remove(chip);
                    let new = regen.remove(chip).filter(|t| !t.is_empty());
                    match (old, new) {
                        (Some(o), Some(n)) => {
                            if o != n {
                                changed.insert(*chip);
                            }
                            tables.insert(*chip, n);
                        }
                        (Some(_), None) => {
                            changed.insert(*chip); // table vanished
                        }
                        (None, Some(n)) => {
                            changed.insert(*chip);
                            tables.insert(*chip, n);
                        }
                        (None, None) => {}
                    }
                }
                b.put("routing_tables", tables);
                b.put("tables_dirty_chips", changed);
                b.put("tables_keys_snapshot", new_snapshot);
                Ok(())
            },
        )
        .with_fp_inputs(&["machine", "routing_trees", "routing_keys", "mapping_config"]),
        // Compression, sharded per changed chip. Fresh maps use the
        // aggressive order-exploiting compressor (historical behaviour);
        // incrementally-dirty tables use `compress_exact`, whose covers
        // can never capture a key outside the originals — required
        // because retired keys may still be sent nowhere near this chip
        // in a later session epoch.
        Algorithm::sharded(
            "table_compressor",
            &["routing_tables", "mapping_config", "tables_dirty_chips"],
            &["compressed_tables", "install_chips"],
            |b: &mut Blackboard| {
                let (run_compressor, enforce) = {
                    let c: &MappingConfig = b.get("mapping_config")?;
                    (c.compress_tables, c.enforce_table_capacity)
                };
                let had_prior = b.has("compressed_tables");
                let prior: BTreeMap<ChipCoord, RoutingTable> =
                    if had_prior { b.take("compressed_tables")? } else { BTreeMap::new() };
                let dirty: BTreeSet<ChipCoord> = if had_prior {
                    b.get::<BTreeSet<ChipCoord>>("tables_dirty_chips")?.clone()
                } else {
                    b.get::<BTreeMap<ChipCoord, RoutingTable>>("routing_tables")?
                        .keys()
                        .copied()
                        .collect()
                };
                let uncompressed: &BTreeMap<ChipCoord, RoutingTable> =
                    b.get("routing_tables")?;
                let work: Vec<(ChipCoord, RoutingTable, bool)> = dirty
                    .iter()
                    .filter_map(|c| uncompressed.get(c).map(|t| (*c, t.clone(), had_prior)))
                    .collect();
                Ok(((prior, dirty, enforce, run_compressor), work))
            },
            |ctx: &(BTreeMap<ChipCoord, RoutingTable>, BTreeSet<ChipCoord>, bool, bool),
             item: &(ChipCoord, RoutingTable, bool)| {
                let (_, _, _, run_compressor) = ctx;
                let (chip, table, exact) = item;
                let out = if *run_compressor && !table.fits() {
                    if *exact { compress::compress_exact(table) } else { compress::compress(table) }
                } else {
                    table.clone()
                };
                Ok((*chip, out))
            },
            |b: &mut Blackboard, ctx, compressed: Vec<(ChipCoord, RoutingTable)>| {
                let (prior, dirty, enforce, _) = ctx;
                let mut out = prior;
                for chip in &dirty {
                    out.remove(chip);
                }
                for (chip, table) in compressed {
                    if enforce {
                        anyhow::ensure!(
                            table.fits(),
                            "routing table on chip {chip:?} exceeds TCAM after compression"
                        );
                    }
                    out.insert(chip, table);
                }
                b.put("compressed_tables", out);
                b.put("install_chips", dirty);
                Ok(())
            },
        )
        .with_fp_inputs(&["routing_tables", "mapping_config"]),
        // Tag allocation: cheap, so a miss re-runs it in full. Keyed on
        // the tag-request digest (not placements — see
        // `tag_requests_fingerprint` for the soundness argument; the
        // machine and forbidden-chip digests cover every way a pinned
        // tag-bearing vertex can be displaced).
        Algorithm::new(
            "tag_allocator",
            &["machine", "machine_graph", "placements", "forbidden_chips"],
            &["ip_tags"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p: &Placements = b.get("placements")?;
                let tags = tags::allocate_tags(m, g, p)?;
                b.put("ip_tags", tags);
                Ok(())
            },
        )
        .with_fp_inputs(&["machine", "tag_requests", "forbidden_chips"]),
    ];

    let workflow = Executor::new(algorithms)
        .with_threads(config.options.threads)
        .execute_cached(
            board,
            &["placements", "compressed_tables", "routing_keys", "ip_tags"],
            &mut state.cache,
        )?;

    // Clone the outputs off the board: the board itself stays intact as
    // the prior state of the next incremental pass.
    let placements = board.get::<Placements>("placements")?.clone();
    let forest = board.get::<RoutingForest>("routing_trees")?.clone();
    let keys = board
        .get::<BTreeMap<(VertexId, String), KeyRange>>("routing_keys")?
        .clone();
    let tables = board
        .get::<BTreeMap<ChipCoord, crate::machine::router::RoutingTable>>("compressed_tables")?
        .clone();
    let (iptags, reverse_iptags) = board
        .get::<(
            BTreeMap<(VertexId, String), AllocatedIpTag>,
            BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
        )>("ip_tags")?
        .clone();
    // A cached compressor means no table changed at all this pass; the
    // persisted install set describes an *earlier* pass, not this one.
    let compressor_ran = state
        .cache
        .last_run
        .iter()
        .any(|s| s.name == "table_compressor" && !s.cached);
    let install_chips = if compressor_ran {
        board
            .get::<std::collections::BTreeSet<ChipCoord>>("install_chips")?
            .clone()
    } else {
        std::collections::BTreeSet::new()
    };

    Ok(MapOutcome {
        mapping: Mapping { placements, forest, keys, tables, iptags, reverse_iptags },
        workflow,
        stages: state.cache.last_run.clone(),
        install_chips,
    })
}

/// Run the pipeline through the Figure-10 engine from a fresh
/// [`PipelineState`]: the historical one-shot entry point. Returns the
/// mapping plus the executed workflow (for provenance).
pub fn map_graph_via_engine(
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
) -> anyhow::Result<(Mapping, crate::algorithms::Workflow)> {
    let mut state = PipelineState::new();
    let out = map_graph_incremental(
        &mut state,
        machine,
        graph,
        config,
        &std::collections::BTreeSet::new(),
        &std::collections::BTreeSet::new(),
    )?;
    Ok((out.mapping, out.workflow))
}

#[cfg(test)]
mod engine_tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::machine::MachineBuilder;

    #[test]
    fn engine_pipeline_matches_direct() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        let direct = map_graph(&m, &g, &MappingConfig::default()).unwrap();
        let (engine, workflow) =
            map_graph_via_engine(&m, &g, &MappingConfig::default()).unwrap();
        assert_eq!(direct.placements.of(a), engine.placements.of(a));
        assert_eq!(direct.keys, engine.keys);
        assert_eq!(
            direct.tables.keys().collect::<Vec<_>>(),
            engine.tables.keys().collect::<Vec<_>>()
        );
        // The engine ordered the placer before the router.
        let pos = |n: &str| workflow.0.iter().position(|x| x == n).unwrap();
        assert!(pos("radial_placer") < pos("ner_router"));
        assert!(pos("table_generator") < pos("table_compressor"));
    }

    #[test]
    fn incremental_noop_pass_hits_every_stage() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        let mut state = PipelineState::new();
        let cfg = MappingConfig::default();
        let first =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        assert!(first.stages.iter().all(|s| !s.cached), "first map is full");
        assert!(!first.install_chips.is_empty());
        let again =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        assert!(again.stages.iter().all(|s| s.cached), "{:?}", again.stages);
        assert!(again.install_chips.is_empty(), "no table changed");
        assert_eq!(first.mapping.keys, again.mapping.keys);
        assert_eq!(
            first.mapping.placements.of(a),
            again.mapping.placements.of(a)
        );
    }

    #[test]
    fn incremental_delta_pass_is_partial_and_routes_correctly() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        let mut state = PipelineState::new();
        let cfg = MappingConfig::default();
        let first =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        // Grow the graph: a new vertex and a new partition.
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, c, "q");
        let third =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        let cached = third.stages.iter().filter(|s| s.cached).count();
        assert!(cached >= 1, "a small delta must reuse stages: {:?}", third.stages);
        // Pins held, old keys survived, new partition exists.
        assert_eq!(third.mapping.placements.of(a), first.mapping.placements.of(a));
        assert_eq!(third.mapping.placements.of(b), first.mapping.placements.of(b));
        assert_eq!(
            third.mapping.keys[&(a, "p".to_string())],
            first.mapping.keys[&(a, "p".to_string())]
        );
        assert!(third.mapping.forest.trees.contains_key(&(a, "q".to_string())));
        // The merged tables still route every partition to exactly its
        // targets (the E2 oracle).
        for p in g.partitions() {
            let src = third.mapping.placement(p.pre).unwrap();
            let key = third.mapping.keys[&(p.pre, p.id.clone())];
            let expected: Vec<_> = g
                .partition_targets(p)
                .into_iter()
                .map(|t| {
                    let l = third.mapping.placement(t).unwrap();
                    (l.chip(), l.p)
                })
                .collect();
            tables::check_tables(&m, &third.mapping.tables, src.chip(), key.base, &expected)
                .unwrap();
        }
    }

    #[test]
    fn degraded_machine_remap_displaces_victims_and_keeps_cache() {
        // The heal shape (DESIGN.md §8): after a chip dies mid-run, the
        // degraded machine + forbidden set flow back through the warm
        // pipeline — survivors stay pinned, victims displace, the key
        // allocator is a cache hit, and the merged tables still satisfy
        // the routing oracle.
        let m = MachineBuilder::grid(4, 4, false).build();
        let mut g = MachineGraph::new();
        // Enough vertices to occupy several chips (17 app cores each).
        let ids: Vec<_> = (0..40)
            .map(|i| g.add_vertex(TestVertex::arc(&format!("v{i}"))))
            .collect();
        for w in ids.windows(2) {
            g.add_edge(w[0], w[1], "p");
        }
        let mut state = PipelineState::new();
        let cfg = MappingConfig::default();
        let first =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        // Chip death: pick the chip hosting v20.
        let dead = first.mapping.placement(ids[20]).unwrap().chip();
        let mut degraded = m.clone();
        degraded.remove_chip(dead);
        let mut forbidden = std::collections::BTreeSet::new();
        forbidden.insert(dead);
        let healed =
            map_graph_incremental(&mut state, &degraded, &g, &cfg, &Default::default(), &forbidden)
                .unwrap();
        // Keys: pure graph function — must be served from the cache.
        let key_stage = healed
            .stages
            .iter()
            .find(|s| s.name == "key_allocator")
            .unwrap();
        assert!(key_stage.cached, "key allocator must not re-run: {:?}", healed.stages);
        assert_eq!(healed.mapping.keys, first.mapping.keys);
        // Survivors pinned, victims displaced off the dead chip.
        let mut moved = 0;
        for id in &ids {
            let was = first.mapping.placement(*id).unwrap();
            let now = healed.mapping.placement(*id).unwrap();
            assert_ne!(now.chip(), dead);
            if was.chip() == dead {
                moved += 1;
            } else {
                assert_eq!(was, now, "survivor moved during heal");
            }
        }
        assert!(moved > 0);
        // No tree mentions the dead chip, and the oracle holds.
        for tree in healed.mapping.forest.trees.values() {
            assert!(!tree.nodes.contains_key(&dead));
        }
        for p in g.partitions() {
            let src = healed.mapping.placement(p.pre).unwrap();
            let key = healed.mapping.keys[&(p.pre, p.id.clone())];
            let expected: Vec<_> = g
                .partition_targets(p)
                .into_iter()
                .map(|t| {
                    let l = healed.mapping.placement(t).unwrap();
                    (l.chip(), l.p)
                })
                .collect();
            tables::check_tables(&degraded, &healed.mapping.tables, src.chip(), key.base, &expected)
                .unwrap();
        }
    }

    #[test]
    fn incremental_remove_retires_trees_and_keys() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, b, "p");
        g.add_edge(c, b, "r");
        let mut state = PipelineState::new();
        let cfg = MappingConfig::default();
        let first =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        g.remove_vertex(a).unwrap();
        let second =
            map_graph_incremental(&mut state, &m, &g, &cfg, &Default::default(), &Default::default()).unwrap();
        assert_eq!(second.mapping.placements.of(a), None);
        assert!(!second.mapping.keys.contains_key(&(a, "p".to_string())));
        assert!(!second.mapping.forest.trees.contains_key(&(a, "p".to_string())));
        // The surviving partition kept its key and its tree.
        assert_eq!(
            second.mapping.keys[&(c, "r".to_string())],
            first.mapping.keys[&(c, "r".to_string())]
        );
        assert_eq!(second.mapping.placements.of(c), first.mapping.placements.of(c));
        for p in g.partitions() {
            let src = second.mapping.placement(p.pre).unwrap();
            let key = second.mapping.keys[&(p.pre, p.id.clone())];
            let expected: Vec<_> = g
                .partition_targets(p)
                .into_iter()
                .map(|t| {
                    let l = second.mapping.placement(t).unwrap();
                    (l.chip(), l.p)
                })
                .collect();
            tables::check_tables(&m, &second.mapping.tables, src.chip(), key.base, &expected)
                .unwrap();
        }
    }
}
