//! The mapping phase (§6.3.2): graph → machine.
//!
//! Sub-steps, each its own algorithm run by the Figure-10 execution
//! engine (see [`crate::algorithms`] and [`crate::front`]):
//!
//! 1. [`splitter`] — application graph → machine graph ("graph
//!    partitioning", kept separate from the rest per §6.3.2);
//! 2. [`placer`] — machine vertices → cores (radial first-fit with
//!    resource accounting and constraint handling);
//! 3. [`router`] — edges → multicast routing trees (NER: longest
//!    dimension first, with BFS fallback around faults; Heathcote 2016);
//! 4. [`keys`] — outgoing edge partitions → multicast key ranges;
//! 5. [`tables`] — routing trees + keys → per-chip TCAM tables, with
//!    optional default-route elision;
//! 6. [`compress`] — order-exploiting table minimization (Mundy et
//!    al. 2016);
//! 7. [`tags`] — IP tag / reverse IP tag allocation on Ethernet chips;
//! 8. [`database`] — the mapping database external live apps read (§6.9).

pub mod compress;
pub mod database;
pub mod keys;
pub mod placer;
pub mod router;
pub mod splitter;
pub mod tables;
pub mod tags;

use std::collections::BTreeMap;

use crate::graph::{AllocatedIpTag, AllocatedReverseIpTag, KeyRange, MachineGraph, VertexId};
use crate::machine::{ChipCoord, CoreLocation, Machine};

pub use placer::Placements;
pub use router::{RoutingForest, RoutingTree, TreeNode};
pub use splitter::GraphMapping;

/// Everything mapping produces (the §6.3.2 outputs: placements, routing
/// tables, routing keys, IP tags).
pub struct Mapping {
    pub placements: Placements,
    pub forest: RoutingForest,
    pub keys: BTreeMap<(VertexId, String), KeyRange>,
    pub tables: BTreeMap<ChipCoord, crate::machine::router::RoutingTable>,
    pub iptags: BTreeMap<(VertexId, String), AllocatedIpTag>,
    pub reverse_iptags: BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
}

/// Host-side execution options for the mapping pipeline: §1 warns that
/// mapping time "will dwarf the computational execution time" if it does
/// not scale with the machine, so the shardable stages (NER routing,
/// table generation, ordered-covering compression) run on a scoped
/// worker pool this wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappingOptions {
    /// Worker threads for the shardable mapping stages. `1` = serial
    /// (the default); `0` = one worker per available hardware thread.
    /// Output is byte-identical at any setting.
    pub threads: usize,
}

impl Default for MappingOptions {
    fn default() -> Self {
        Self { threads: 1 }
    }
}

impl MappingOptions {
    pub fn with_threads(threads: usize) -> Self {
        Self { threads }
    }

    /// The actual pool width (resolves `0` to the hardware parallelism).
    pub fn effective_threads(&self) -> usize {
        crate::util::par::effective_threads(self.threads)
    }
}

/// Options controlling the mapping pipeline.
#[derive(Debug, Clone)]
pub struct MappingConfig {
    /// Elide entries default routing would reproduce (§2's
    /// straight-through rule) at table-generation time.
    pub use_default_routes: bool,
    /// Run the ordered-covering compressor on oversubscribed tables.
    pub compress_tables: bool,
    /// Fail if a compressed table still exceeds the 1024-entry TCAM.
    pub enforce_table_capacity: bool,
    /// Host-side execution options (worker-pool width).
    pub options: MappingOptions,
}

impl Default for MappingConfig {
    fn default() -> Self {
        Self {
            use_default_routes: true,
            compress_tables: true,
            enforce_table_capacity: true,
            options: MappingOptions::default(),
        }
    }
}

/// Run the full machine-graph mapping pipeline. (Application graphs are
/// split first by [`splitter::split_graph`]; the front end wires both
/// through the algorithm engine.)
pub fn map_graph(
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
) -> anyhow::Result<Mapping> {
    let threads = config.options.threads;
    let placements = placer::place(machine, graph)?;
    let forest = router::route_sharded(machine, graph, &placements, threads)?;
    let keys = keys::allocate_keys(graph)?;
    let mut tables = tables::build_tables(machine, graph, &forest, &keys, config)?;
    if config.compress_tables {
        compress::compress_tables_in_place(&mut tables, threads);
    }
    if config.enforce_table_capacity {
        for (chip, table) in &tables {
            if !table.fits() {
                anyhow::bail!(
                    "routing table on chip {chip:?} needs {} entries (TCAM holds {})",
                    table.len(),
                    crate::machine::ROUTER_ENTRIES
                );
            }
        }
    }
    let (iptags, reverse_iptags) = tags::allocate_tags(machine, graph, &placements)?;
    Ok(Mapping { placements, forest, keys, tables, iptags, reverse_iptags })
}

impl Mapping {
    pub fn placement(&self, v: VertexId) -> Option<CoreLocation> {
        self.placements.of(v)
    }
}

/// Run the same pipeline through the Figure-10 algorithm execution
/// engine: each step is an [`crate::algorithms::Algorithm`] with token
/// inputs/outputs, and the executor derives the workflow order. The
/// router, table generator and compressor declare shardable inner loops
/// the executor fans out over `config.options.threads` workers; their
/// order-preserving joins keep the result byte-identical to the serial
/// [`map_graph`] path. Returns the mapping plus the executed workflow
/// (for provenance).
pub fn map_graph_via_engine(
    machine: &Machine,
    graph: &MachineGraph,
    config: &MappingConfig,
) -> anyhow::Result<(Mapping, crate::algorithms::Workflow)> {
    use crate::algorithms::{Algorithm, Blackboard, Executor};
    use crate::machine::router::RoutingTable;

    let mut board = Blackboard::new();
    board.put("machine", machine.clone());
    board.put("machine_graph", graph.clone());
    board.put("mapping_config", config.clone());

    let algorithms = vec![
        Algorithm::new(
            "radial_placer",
            &["machine", "machine_graph"],
            &["placements"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p = placer::place(m, g)?;
                b.put("placements", p);
                Ok(())
            },
        ),
        // Sharded: one work item per outgoing edge partition; each tree
        // is grown independently against a shared machine context. The
        // machine token rides through the context (no clone) and the
        // merge returns it to the blackboard for the later algorithms.
        Algorithm::sharded(
            "ner_router",
            &["machine", "machine_graph", "placements"],
            &["routing_trees"],
            |b: &mut Blackboard| {
                let items = {
                    let g: &MachineGraph = b.get("machine_graph")?;
                    let p: &Placements = b.get("placements")?;
                    router::route_items(g, p)?
                };
                let m: Machine = b.take("machine")?;
                Ok((m, items))
            },
            |m: &Machine, item: &router::RouteItem| {
                Ok((item.key.clone(), router::build_tree(m, item.source, &item.dests)?))
            },
            |b: &mut Blackboard, m, keyed_trees: Vec<((VertexId, String), router::RoutingTree)>| {
                b.put("machine", m);
                let mut forest = RoutingForest::default();
                for (key, tree) in keyed_trees {
                    forest.trees.insert(key, tree);
                }
                b.put("routing_trees", forest);
                Ok(())
            },
        ),
        Algorithm::new(
            "key_allocator",
            &["machine_graph"],
            &["routing_keys"],
            |b| {
                let g: &MachineGraph = b.get("machine_graph")?;
                let k = keys::allocate_keys(g)?;
                b.put("routing_keys", k);
                Ok(())
            },
        ),
        // Sharded: one work item per chip. The forest is *moved* into
        // the context (split into parallel key/tree vectors, no clone)
        // so workers never touch the blackboard; the merge reassembles
        // it and returns the routing_trees token.
        Algorithm::sharded(
            "table_generator",
            &["machine", "machine_graph", "routing_trees", "routing_keys", "mapping_config"],
            &["routing_tables"],
            |b: &mut Blackboard| {
                let f: RoutingForest = b.take("routing_trees")?;
                let (ranges, work, use_default) = {
                    let m: &Machine = b.get("machine")?;
                    let k: &BTreeMap<(VertexId, String), KeyRange> = b.get("routing_keys")?;
                    let c: &MappingConfig = b.get("mapping_config")?;
                    let (trees_ref, ranges, work) = tables::plan_chips(m, &f, k)?;
                    drop(trees_ref);
                    (ranges, work, c.use_default_routes)
                };
                // Forest order matches plan_chips' range/index order.
                let (tree_keys, trees): (Vec<(VertexId, String)>, Vec<router::RoutingTree>) =
                    f.trees.into_iter().unzip();
                Ok(((tree_keys, trees, ranges, use_default), work))
            },
            |ctx: &(Vec<(VertexId, String)>, Vec<router::RoutingTree>, Vec<KeyRange>, bool),
             item: &tables::ChipWork| {
                let (_, trees, ranges, use_default) = ctx;
                Ok((item.0, tables::chip_table(trees, ranges, item.0, &item.1, *use_default)))
            },
            |b: &mut Blackboard, ctx, chip_tables: Vec<(ChipCoord, RoutingTable)>| {
                let (tree_keys, trees, _, _) = ctx;
                b.put("routing_trees", RoutingForest {
                    trees: tree_keys.into_iter().zip(trees).collect(),
                });
                let t: BTreeMap<ChipCoord, RoutingTable> = chip_tables
                    .into_iter()
                    .filter(|(_, table)| !table.is_empty())
                    .collect();
                b.put("routing_tables", t);
                Ok(())
            },
        ),
        // Sharded: one work item per oversubscribed table; fitting
        // tables ride along in the context untouched.
        Algorithm::sharded(
            "table_compressor",
            &["routing_tables", "mapping_config"],
            &["compressed_tables"],
            |b: &mut Blackboard| {
                let c: &MappingConfig = b.get("mapping_config")?;
                let run_compressor = c.compress_tables;
                let enforce = c.enforce_table_capacity;
                let mut t: BTreeMap<ChipCoord, RoutingTable> = b.take("routing_tables")?;
                let mut victims = Vec::new();
                if run_compressor {
                    let chips: Vec<ChipCoord> =
                        t.iter().filter(|(_, tb)| !tb.fits()).map(|(c, _)| *c).collect();
                    for chip in chips {
                        let table = t.remove(&chip).unwrap();
                        victims.push((chip, table));
                    }
                }
                Ok(((t, enforce), victims))
            },
            |_ctx: &(BTreeMap<ChipCoord, RoutingTable>, bool),
             item: &(ChipCoord, RoutingTable)| {
                Ok((item.0, compress::compress(&item.1)))
            },
            |b: &mut Blackboard, ctx, compressed: Vec<(ChipCoord, RoutingTable)>| {
                let (mut t, enforce) = ctx;
                for (chip, table) in compressed {
                    t.insert(chip, table);
                }
                if enforce {
                    for (chip, table) in &t {
                        anyhow::ensure!(
                            table.fits(),
                            "routing table on chip {chip:?} exceeds TCAM after compression"
                        );
                    }
                }
                b.put("compressed_tables", t);
                Ok(())
            },
        ),
        Algorithm::new(
            "tag_allocator",
            &["machine", "machine_graph", "placements"],
            &["ip_tags"],
            |b| {
                let m: &Machine = b.get("machine")?;
                let g: &MachineGraph = b.get("machine_graph")?;
                let p: &Placements = b.get("placements")?;
                let tags = tags::allocate_tags(m, g, p)?;
                b.put("ip_tags", tags);
                Ok(())
            },
        ),
    ];

    let workflow = Executor::new(algorithms)
        .with_threads(config.options.threads)
        .execute(
            &mut board,
            &["placements", "compressed_tables", "routing_keys", "ip_tags"],
        )?;

    let placements: Placements = board.take("placements")?;
    let forest: RoutingForest = board.take("routing_trees")?;
    let keys: BTreeMap<(VertexId, String), KeyRange> = board.take("routing_keys")?;
    let tables: BTreeMap<ChipCoord, crate::machine::router::RoutingTable> =
        board.take("compressed_tables")?;
    let (iptags, reverse_iptags): (
        BTreeMap<(VertexId, String), AllocatedIpTag>,
        BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
    ) = board.take("ip_tags")?;

    Ok((
        Mapping { placements, forest, keys, tables, iptags, reverse_iptags },
        workflow,
    ))
}

#[cfg(test)]
mod engine_tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::machine::MachineBuilder;

    #[test]
    fn engine_pipeline_matches_direct() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        let direct = map_graph(&m, &g, &MappingConfig::default()).unwrap();
        let (engine, workflow) =
            map_graph_via_engine(&m, &g, &MappingConfig::default()).unwrap();
        assert_eq!(direct.placements.of(a), engine.placements.of(a));
        assert_eq!(direct.keys, engine.keys);
        assert_eq!(
            direct.tables.keys().collect::<Vec<_>>(),
            engine.tables.keys().collect::<Vec<_>>()
        );
        // The engine ordered the placer before the router.
        let pos = |n: &str| workflow.0.iter().position(|x| x == n).unwrap();
        assert!(pos("radial_placer") < pos("ner_router"));
        assert!(pos("table_generator") < pos("table_compressor"));
    }
}
