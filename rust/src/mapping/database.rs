//! The mapping database (§6.3.2, Figure 8): a queryable record of the
//! mapping that external live applications read to decode/encode live
//! event streams (§6.9), plus the notification handshake around it.
//!
//! Serialized as deterministic JSON via [`crate::util::json`] (the paper
//! uses sqlite; JSON keeps this build dependency-free while preserving
//! the interface contract: vertex → placement, partition → key range).

use std::collections::BTreeMap;
use std::path::Path;

use crate::graph::{KeyRange, MachineGraph, VertexId};
use crate::machine::CoreLocation;
use crate::util::json::Json;

use super::placer::Placements;

/// The queryable mapping database.
#[derive(Debug, Default, Clone)]
pub struct MappingDatabase {
    /// vertex label -> placement.
    pub placements: BTreeMap<String, CoreLocation>,
    /// (vertex label, partition) -> key range.
    pub keys: BTreeMap<(String, String), KeyRange>,
}

impl MappingDatabase {
    pub fn build(
        graph: &MachineGraph,
        placements: &Placements,
        keys: &BTreeMap<(VertexId, String), KeyRange>,
    ) -> Self {
        let mut db = MappingDatabase::default();
        for (vid, vertex) in graph.vertices() {
            if let Some(loc) = placements.of(vid) {
                db.placements.insert(vertex.label(), loc);
            }
        }
        for ((vid, partition), range) in keys {
            db.keys
                .insert((graph.vertex(*vid).label(), partition.clone()), *range);
        }
        db
    }

    /// Key range an external app must listen for / send to (§6.9: "read
    /// the mapping database to determine the multicast keys").
    pub fn key_of(&self, vertex_label: &str, partition: &str) -> Option<KeyRange> {
        self.keys
            .get(&(vertex_label.to_string(), partition.to_string()))
            .copied()
    }

    pub fn placement_of(&self, vertex_label: &str) -> Option<CoreLocation> {
        self.placements.get(vertex_label).copied()
    }

    /// Reverse lookup: which (vertex, partition) does a received key
    /// belong to? Used by live receivers to attribute events.
    pub fn source_of_key(&self, key: u32) -> Option<(&str, &str, u32)> {
        for ((v, p), range) in &self.keys {
            if range.contains(key) {
                return Some((v, p, range.atom_for_key(key)));
            }
        }
        None
    }

    pub fn to_json(&self) -> Json {
        let mut placements = BTreeMap::new();
        for (label, loc) in &self.placements {
            placements.insert(
                label.clone(),
                Json::Arr(vec![loc.x.into(), loc.y.into(), (loc.p as u32).into()]),
            );
        }
        let mut keys = BTreeMap::new();
        for ((label, partition), range) in &self.keys {
            let mut entry = BTreeMap::new();
            entry.insert("base".to_string(), Json::from(range.base));
            entry.insert("mask".to_string(), Json::from(range.mask));
            keys.insert(format!("{label}\u{1f}{partition}"), Json::Obj(entry));
        }
        let mut root = BTreeMap::new();
        root.insert("placements".to_string(), Json::Obj(placements));
        root.insert("keys".to_string(), Json::Obj(keys));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut db = MappingDatabase::default();
        let placements = j
            .get("placements")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("missing placements"))?;
        for (label, arr) in placements {
            let a = arr.as_arr().ok_or_else(|| anyhow::anyhow!("bad placement"))?;
            db.placements.insert(
                label.clone(),
                CoreLocation::new(
                    a[0].as_usize().unwrap_or(0) as u32,
                    a[1].as_usize().unwrap_or(0) as u32,
                    a[2].as_usize().unwrap_or(0) as u8,
                ),
            );
        }
        let keys = j
            .get("keys")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("missing keys"))?;
        for (k, v) in keys {
            let (label, partition) = k
                .split_once('\u{1f}')
                .ok_or_else(|| anyhow::anyhow!("bad key id {k}"))?;
            let base = v.get("base").and_then(Json::as_f64).unwrap_or(0.0) as u32;
            let mask = v.get("mask").and_then(Json::as_f64).unwrap_or(0.0) as u32;
            db.keys
                .insert((label.to_string(), partition.to_string()), KeyRange::new(base, mask));
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// The database-ready / setup-done handshake of Figure 8: applications
/// "register to be notified when the database is ready for reading, and
/// can then notify the tools when they have completed any setup".
#[derive(Default)]
pub struct NotificationProtocol {
    listeners: Vec<Box<dyn FnMut(&MappingDatabase) + Send>>,
}

impl std::fmt::Debug for NotificationProtocol {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NotificationProtocol({} listeners)", self.listeners.len())
    }
}

impl NotificationProtocol {
    pub fn register(&mut self, listener: Box<dyn FnMut(&MappingDatabase) + Send>) {
        self.listeners.push(listener);
    }

    /// Called by the tools when the database is written; every listener
    /// runs its setup, and the call returns when all are ready.
    pub fn database_ready(&mut self, db: &MappingDatabase) {
        for l in &mut self.listeners {
            l(db);
        }
    }

    pub fn n_listeners(&self) -> usize {
        self.listeners.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::mapping::{keys, placer};
    use crate::machine::MachineBuilder;

    fn sample_db() -> MappingDatabase {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("alpha"));
        let b = g.add_vertex(TestVertex::arc("beta"));
        g.add_edge(a, b, "events");
        let p = placer::place(&m, &g).unwrap();
        let k = keys::allocate_keys(&g).unwrap();
        MappingDatabase::build(&g, &p, &k)
    }

    #[test]
    fn lookups_work() {
        let db = sample_db();
        assert!(db.placement_of("alpha").is_some());
        assert!(db.placement_of("nonexistent").is_none());
        let kr = db.key_of("alpha", "events").unwrap();
        let (v, p, atom) = db.source_of_key(kr.base).unwrap();
        assert_eq!(v, "alpha");
        assert_eq!(p, "events");
        assert_eq!(atom, 0);
    }

    #[test]
    fn json_round_trip() {
        let db = sample_db();
        let j = db.to_json();
        let back = MappingDatabase::from_json(&j).unwrap();
        assert_eq!(back.placements, db.placements);
        assert_eq!(back.keys, db.keys);
    }

    #[test]
    fn file_round_trip() {
        let db = sample_db();
        let dir = std::env::temp_dir().join("spinntools_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mapping.json");
        db.save(&path).unwrap();
        let back = MappingDatabase::load(&path).unwrap();
        assert_eq!(back.keys, db.keys);
    }

    #[test]
    fn notification_handshake() {
        let db = sample_db();
        let mut proto = NotificationProtocol::default();
        let hits = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let h = hits.clone();
        proto.register(Box::new(move |db| {
            assert!(db.placement_of("alpha").is_some());
            h.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        }));
        proto.database_ready(&db);
        assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
