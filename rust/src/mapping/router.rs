//! Multicast routing: one routing tree per outgoing edge partition
//! (§6.3.2; algorithmic background in Heathcote 2016).
//!
//! NER (Nearest-neighbour, longest-dimension-first) routing: targets are
//! connected to the growing tree nearest-first; each connection walks
//! greedily from the nearest tree node towards the target, taking the
//! hexagonal diagonal (NE/SW) while both axes agree and the longest
//! remaining dimension otherwise, falling back to BFS over working links
//! when faults block the ideal step. Every chip in a tree has exactly
//! one inbound link — the invariant that makes multicast duplication
//! impossible and enables default-route elision.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::graph::{MachineGraph, VertexId};
use crate::machine::{ChipCoord, Direction, Machine, ALL_DIRECTIONS};

use super::placer::Placements;

/// One chip's role in a routing tree.
#[derive(Debug, Clone, Default)]
pub struct TreeNode {
    /// Links this chip forwards the packet out of.
    pub out_links: BTreeSet<Direction>,
    /// Local cores the packet is delivered to on this chip.
    pub local_cores: BTreeSet<u8>,
    /// The link the packet arrives on (None at the source chip).
    pub in_link: Option<Direction>,
}

/// The multicast tree for one (source vertex, partition).
#[derive(Debug, Clone)]
pub struct RoutingTree {
    pub source: ChipCoord,
    pub nodes: BTreeMap<ChipCoord, TreeNode>,
}

impl RoutingTree {
    fn new(source: ChipCoord) -> Self {
        let mut nodes = BTreeMap::new();
        nodes.insert(source, TreeNode::default());
        Self { source, nodes }
    }

    /// Total number of inter-chip hops in the tree.
    pub fn n_links(&self) -> usize {
        self.nodes.values().map(|n| n.out_links.len()).sum()
    }

    /// Every (chip, core) the tree delivers to.
    pub fn destinations(&self) -> Vec<(ChipCoord, u8)> {
        let mut out = Vec::new();
        for (chip, node) in &self.nodes {
            for p in &node.local_cores {
                out.push((*chip, *p));
            }
        }
        out
    }
}

/// All routing trees of a mapped graph.
#[derive(Debug, Default, Clone)]
pub struct RoutingForest {
    pub trees: BTreeMap<(VertexId, String), RoutingTree>,
}

impl RoutingForest {
    /// The real (non-virtual) chips a tree occupies — path chips
    /// included, since every chip on the path holds a node (possibly
    /// elided at table-generation time, but still invalidated by it).
    pub fn tree_chips(tree: &RoutingTree, machine: &Machine) -> Vec<ChipCoord> {
        tree.nodes
            .keys()
            .filter(|c| machine.chip(**c).map(|ch| !ch.is_virtual).unwrap_or(false))
            .copied()
            .collect()
    }
}

/// One routing work item: an outgoing edge partition with its placements
/// resolved. Items are independent of one another — the unit of sharding
/// for the parallel router.
#[derive(Debug, Clone)]
pub struct RouteItem {
    /// The forest key: (source vertex, partition id).
    pub key: (VertexId, String),
    /// The chip the source vertex is placed on.
    pub source: ChipCoord,
    /// Destination cores, grouped per chip.
    pub dests: BTreeMap<ChipCoord, BTreeSet<u8>>,
}

/// Resolve every outgoing edge partition of `graph` to a [`RouteItem`]
/// (the cheap, serial half of routing).
pub fn route_items(
    graph: &MachineGraph,
    placements: &Placements,
) -> anyhow::Result<Vec<RouteItem>> {
    let mut items = Vec::with_capacity(graph.n_partitions());
    for partition in graph.partitions() {
        let src_loc = placements.of(partition.pre).ok_or_else(|| {
            anyhow::anyhow!("partition source {:?} unplaced", partition.pre)
        })?;
        let mut dests: BTreeMap<ChipCoord, BTreeSet<u8>> = BTreeMap::new();
        for target in graph.partition_targets(partition) {
            let loc = placements
                .of(target)
                .ok_or_else(|| anyhow::anyhow!("target {target:?} unplaced"))?;
            dests.entry(loc.chip()).or_default().insert(loc.p);
        }
        items.push(RouteItem {
            key: (partition.pre, partition.id.clone()),
            source: src_loc.chip(),
            dests,
        });
    }
    Ok(items)
}

/// Route every outgoing edge partition of `graph` (serial).
pub fn route(
    machine: &Machine,
    graph: &MachineGraph,
    placements: &Placements,
) -> anyhow::Result<RoutingForest> {
    route_sharded(machine, graph, placements, 1)
}

/// Route every outgoing edge partition of `graph`, building trees on up
/// to `threads` workers. Each partition's tree depends only on the
/// machine and that partition's placements, and the forest is merged in
/// partition order — output is byte-identical to the serial path at any
/// thread count.
pub fn route_sharded(
    machine: &Machine,
    graph: &MachineGraph,
    placements: &Placements,
    threads: usize,
) -> anyhow::Result<RoutingForest> {
    let items = route_items(graph, placements)?;
    let trees = crate::util::par::try_par_map(threads, &items, |_, item| {
        build_tree(machine, item.source, &item.dests)
    })?;
    let mut forest = RoutingForest::default();
    for (item, tree) in items.into_iter().zip(trees) {
        forest.trees.insert(item.key, tree);
    }
    Ok(forest)
}

/// Grow one NER tree from `source` to every chip in `dest_cores`.
pub fn build_tree(
    machine: &Machine,
    source: ChipCoord,
    dest_cores: &BTreeMap<ChipCoord, BTreeSet<u8>>,
) -> anyhow::Result<RoutingTree> {
    build_tree_avoiding(machine, source, dest_cores, &BTreeSet::new())
}

/// [`build_tree`] with a first-class set of *forbidden* chips: chips
/// still present in `machine` that the tree must neither touch nor
/// traverse — how routes are rebuilt around chips that died at runtime
/// without rebuilding the machine object. Targets (and the source) on a
/// forbidden chip are an error: the placer must displace them first.
pub fn build_tree_avoiding(
    machine: &Machine,
    source: ChipCoord,
    dest_cores: &BTreeMap<ChipCoord, BTreeSet<u8>>,
    forbidden: &BTreeSet<ChipCoord>,
) -> anyhow::Result<RoutingTree> {
    anyhow::ensure!(
        !forbidden.contains(&source),
        "route source {source:?} is on a forbidden (dead) chip"
    );
    let mut tree = RoutingTree::new(source);

    // Nearest targets first: they form the trunk later targets graft onto.
    let mut targets: Vec<ChipCoord> = dest_cores.keys().copied().collect();
    targets.sort_by_key(|t| (machine.hop_distance(source, *t), *t));

    for t in targets {
        anyhow::ensure!(
            !forbidden.contains(&t),
            "route target {t:?} is on a forbidden (dead) chip"
        );
        if !tree.nodes.contains_key(&t) {
            // Grow a path from the nearest tree chip.
            let start = *tree
                .nodes
                .keys()
                .min_by_key(|c| (machine.hop_distance(**c, t), **c))
                .unwrap();
            let path = find_path_avoiding(machine, start, t, forbidden)?;
            graft(&mut tree, start, &path, machine);
        }
        let node = tree.nodes.get_mut(&t).unwrap();
        for p in &dest_cores[&t] {
            node.local_cores.insert(*p);
        }
    }
    Ok(tree)
}

/// Is this (previously built) tree still sound on `machine` with
/// `forbidden` chips quarantined? Sound means: every chip the tree
/// touches still exists and is not forbidden, and every out-link still
/// lands on the tree node it was built toward. Trees that fail are
/// rebuilt by the incremental router; trees that pass are reused
/// verbatim.
pub fn tree_valid(
    tree: &RoutingTree,
    machine: &Machine,
    forbidden: &BTreeSet<ChipCoord>,
) -> bool {
    for (chip, node) in &tree.nodes {
        if forbidden.contains(chip) || machine.chip(*chip).is_none() {
            return false;
        }
        for d in &node.out_links {
            match machine.link_target(*chip, *d) {
                Some(next) if tree.nodes.contains_key(&next) && !forbidden.contains(&next) => {}
                _ => return false,
            }
        }
    }
    true
}

/// Attach `path` (a list of directions from `start`) to the tree; only
/// the suffix beyond the last chip already in the tree adds new links,
/// preserving the single-inbound-link invariant.
fn graft(tree: &mut RoutingTree, start: ChipCoord, path: &[Direction], machine: &Machine) {
    // Compute the chip sequence along the path.
    let mut chips = vec![start];
    let mut cur = start;
    for d in path {
        cur = machine.link_target(cur, *d).expect("path uses working links");
        chips.push(cur);
    }
    // Find the last path position already in the tree.
    let mut graft_at = 0;
    for (i, c) in chips.iter().enumerate() {
        if tree.nodes.contains_key(c) {
            graft_at = i;
        }
    }
    for i in graft_at..path.len() {
        let from = chips[i];
        let to = chips[i + 1];
        let d = path[i];
        tree.nodes.entry(from).or_default().out_links.insert(d);
        let node = tree.nodes.entry(to).or_default();
        if node.in_link.is_none() && to != tree.source {
            node.in_link = Some(d);
        }
    }
}

/// Greedy longest-dimension-first walk from `from` to `to`; falls back
/// to BFS across working links when the ideal next hop is unavailable.
pub fn find_path(
    machine: &Machine,
    from: ChipCoord,
    to: ChipCoord,
) -> anyhow::Result<Vec<Direction>> {
    find_path_avoiding(machine, from, to, &BTreeSet::new())
}

/// [`find_path`] that additionally refuses to step onto `forbidden`
/// chips (runtime-dead chips still present in the machine object).
pub fn find_path_avoiding(
    machine: &Machine,
    from: ChipCoord,
    to: ChipCoord,
    forbidden: &BTreeSet<ChipCoord>,
) -> anyhow::Result<Vec<Direction>> {
    let mut path = Vec::new();
    let mut cur = from;
    let mut fuel = (machine.width + machine.height) as usize + 4;
    while cur != to {
        if fuel == 0 {
            // Geometry said we should have arrived; fall back to BFS.
            return bfs_path(machine, from, to, forbidden);
        }
        fuel -= 1;
        let (dx, dy) = machine.shortest_vector(cur, to);
        let ideal = ideal_moves(dx, dy);
        let mut stepped = false;
        for d in ideal {
            if let Some(next) = machine.link_target(cur, d) {
                // Never step onto an unrelated virtual chip or a
                // quarantined (runtime-dead) chip.
                let ok = (next == to
                    || machine.chip(next).map(|c| !c.is_virtual).unwrap_or(false))
                    && !forbidden.contains(&next);
                if ok {
                    path.push(d);
                    cur = next;
                    stepped = true;
                    break;
                }
            }
        }
        if !stepped {
            // Faults block every productive direction: BFS the rest.
            let rest = bfs_path(machine, cur, to, forbidden)?;
            path.extend(rest);
            return Ok(path);
        }
    }
    Ok(path)
}

/// Productive directions for the remaining vector, best first:
/// diagonal while both axes agree, else longest dimension first.
fn ideal_moves(dx: i32, dy: i32) -> Vec<Direction> {
    let mut out = Vec::with_capacity(3);
    if dx > 0 && dy > 0 {
        out.push(Direction::NorthEast);
    }
    if dx < 0 && dy < 0 {
        out.push(Direction::SouthWest);
    }
    let x_move = if dx > 0 {
        Some(Direction::East)
    } else if dx < 0 {
        Some(Direction::West)
    } else {
        None
    };
    let y_move = if dy > 0 {
        Some(Direction::North)
    } else if dy < 0 {
        Some(Direction::South)
    } else {
        None
    };
    if dx.abs() >= dy.abs() {
        out.extend(x_move);
        out.extend(y_move);
    } else {
        out.extend(y_move);
        out.extend(x_move);
    }
    out
}

/// Shortest path over working links (fault tolerant, used as fallback).
fn bfs_path(
    machine: &Machine,
    from: ChipCoord,
    to: ChipCoord,
    forbidden: &BTreeSet<ChipCoord>,
) -> anyhow::Result<Vec<Direction>> {
    let mut prev: BTreeMap<ChipCoord, (ChipCoord, Direction)> = BTreeMap::new();
    let mut queue = VecDeque::new();
    queue.push_back(from);
    let mut seen = BTreeSet::new();
    seen.insert(from);
    while let Some(c) = queue.pop_front() {
        if c == to {
            let mut dirs = Vec::new();
            let mut cur = to;
            while cur != from {
                let (p, d) = prev[&cur];
                dirs.push(d);
                cur = p;
            }
            dirs.reverse();
            return Ok(dirs);
        }
        for d in ALL_DIRECTIONS {
            if let Some(n) = machine.link_target(c, d) {
                let ok = (n == to
                    || machine.chip(n).map(|ch| !ch.is_virtual).unwrap_or(false))
                    && !forbidden.contains(&n);
                if ok && seen.insert(n) {
                    prev.insert(n, (c, d));
                    queue.push_back(n);
                }
            }
        }
    }
    anyhow::bail!("no route from {from:?} to {to:?} (machine partitioned?)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;
    use crate::util::prop;
    use crate::util::SplitMix64;

    fn dests(chips: &[(ChipCoord, u8)]) -> BTreeMap<ChipCoord, BTreeSet<u8>> {
        let mut m: BTreeMap<ChipCoord, BTreeSet<u8>> = BTreeMap::new();
        for (c, p) in chips {
            m.entry(*c).or_default().insert(*p);
        }
        m
    }

    /// Follow the tree from the source, collecting deliveries; checks the
    /// tree is consistent (every out_link lands on a tree node) and that
    /// no chip is visited twice (no duplicate delivery).
    fn walk(machine: &Machine, tree: &RoutingTree) -> Vec<(ChipCoord, u8)> {
        let mut visited = BTreeSet::new();
        let mut out = Vec::new();
        let mut stack = vec![tree.source];
        while let Some(c) = stack.pop() {
            assert!(visited.insert(c), "chip {c:?} reached twice: duplicate packets");
            let node = &tree.nodes[&c];
            for p in &node.local_cores {
                out.push((c, *p));
            }
            for d in &node.out_links {
                let n = machine.link_target(c, *d).expect("tree uses working links");
                stack.push(n);
            }
        }
        out.sort();
        out
    }

    #[test]
    fn single_target_straight_line() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let tree = build_tree(&m, (0, 0), &dests(&[((4, 0), 3)])).unwrap();
        assert_eq!(tree.n_links(), 4);
        assert_eq!(walk(&m, &tree), vec![((4, 0), 3)]);
    }

    #[test]
    fn diagonal_uses_ne_links() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let tree = build_tree(&m, (0, 0), &dests(&[((3, 3), 1)])).unwrap();
        // Pure diagonal: 3 NE hops.
        assert_eq!(tree.n_links(), 3);
    }

    #[test]
    fn multicast_shares_trunk() {
        let m = MachineBuilder::grid(12, 12, false).build();
        // Two targets behind one another: path to the far one reuses trunk.
        let tree = build_tree(&m, (0, 0), &dests(&[((4, 0), 1), ((8, 0), 2)])).unwrap();
        assert_eq!(tree.n_links(), 8, "trunk must be shared, not duplicated");
        assert_eq!(walk(&m, &tree).len(), 2);
    }

    #[test]
    fn self_delivery_on_source_chip() {
        let m = MachineBuilder::grid(4, 4, false).build();
        let tree = build_tree(&m, (1, 1), &dests(&[((1, 1), 5), ((2, 1), 6)])).unwrap();
        let d = walk(&m, &tree);
        assert!(d.contains(&((1, 1), 5)));
        assert!(d.contains(&((2, 1), 6)));
    }

    #[test]
    fn routes_around_dead_link() {
        let m = MachineBuilder::grid(8, 8, false)
            .dead_link((1, 0), Direction::East)
            .build();
        let tree = build_tree(&m, (0, 0), &dests(&[((4, 0), 1)])).unwrap();
        assert_eq!(walk(&m, &tree), vec![((4, 0), 1)]);
        assert!(tree.n_links() > 4, "must detour");
    }

    #[test]
    fn routes_around_dead_chip() {
        let m = MachineBuilder::grid(8, 8, false).dead_chip((2, 0)).build();
        let tree = build_tree(&m, (0, 0), &dests(&[((4, 0), 1)])).unwrap();
        assert_eq!(walk(&m, &tree), vec![((4, 0), 1)]);
    }

    #[test]
    fn routes_around_forbidden_chip_without_machine_rebuild() {
        // The chip is still in the machine (it died at runtime); the
        // tree must detour exactly as if it were blacklisted at boot.
        let m = MachineBuilder::grid(8, 8, false).build();
        let mut forbidden = BTreeSet::new();
        forbidden.insert((2u32, 0u32));
        let tree =
            build_tree_avoiding(&m, (0, 0), &dests(&[((4, 0), 1)]), &forbidden).unwrap();
        assert_eq!(walk(&m, &tree), vec![((4, 0), 1)]);
        assert!(!tree.nodes.contains_key(&(2, 0)), "tree crossed the dead chip");
        // Equivalent boot-time-dead machine takes the same detour length.
        let boot = MachineBuilder::grid(8, 8, false).dead_chip((2, 0)).build();
        let boot_tree = build_tree(&boot, (0, 0), &dests(&[((4, 0), 1)])).unwrap();
        assert_eq!(tree.n_links(), boot_tree.n_links());
        // A target on the forbidden chip is the placer's bug, not ours.
        assert!(build_tree_avoiding(&m, (0, 0), &dests(&[((2, 0), 1)]), &forbidden).is_err());
    }

    #[test]
    fn tree_validity_tracks_machine_and_forbidden_state() {
        let m = MachineBuilder::grid(8, 8, false).build();
        let tree = build_tree(&m, (0, 0), &dests(&[((4, 0), 1), ((2, 2), 3)])).unwrap();
        assert!(tree_valid(&tree, &m, &BTreeSet::new()));
        // A link the tree uses dies: invalid.
        let mut cut = m.clone();
        cut.remove_link((1, 0), Direction::East);
        assert!(!tree_valid(&tree, &cut, &BTreeSet::new()));
        // A chip the tree crosses dies: invalid.
        let mut dead = m.clone();
        dead.remove_chip((3, 0));
        assert!(!tree_valid(&tree, &dead, &BTreeSet::new()));
        // Same chip quarantined via `forbidden` on the intact machine.
        let mut forbidden = BTreeSet::new();
        forbidden.insert((3u32, 0u32));
        assert!(!tree_valid(&tree, &m, &forbidden));
        // An unrelated fault leaves the tree valid.
        let mut far = m.clone();
        far.remove_chip((7, 7));
        assert!(tree_valid(&tree, &far, &BTreeSet::new()));
    }

    #[test]
    fn torus_wraps_short_way() {
        let m = MachineBuilder::triads(1, 1).build(); // 12x12 torus
        let tree = build_tree(&m, (0, 0), &dests(&[((11, 0), 1)])).unwrap();
        assert_eq!(tree.n_links(), 1, "torus should wrap West one hop");
    }

    #[test]
    fn unreachable_target_errors() {
        // Isolate (3,3) completely.
        let mut b = MachineBuilder::grid(8, 8, false);
        for d in ALL_DIRECTIONS {
            b = b.dead_link((3, 3), d);
        }
        let m = b.build();
        assert!(build_tree(&m, (0, 0), &dests(&[((3, 3), 1)])).is_err());
    }

    #[test]
    fn single_in_link_invariant() {
        let m = MachineBuilder::grid(12, 12, false).build();
        let mut rng = SplitMix64::new(99);
        let targets: Vec<(ChipCoord, u8)> = (0..20)
            .map(|_| (((rng.below(12) as u32, rng.below(12) as u32)), rng.below(16) as u8 + 1))
            .collect();
        let tree = build_tree(&m, (5, 5), &dests(&targets)).unwrap();
        walk(&m, &tree); // asserts no chip reached twice
    }

    #[test]
    fn property_all_destinations_reached() {
        // E2-style invariant: every requested (chip, core) is delivered,
        // exactly once, over random machines with random faults.
        prop::check(25, 0xbeef, |rng| {
            let mut b = MachineBuilder::grid(10, 10, rng.below(2) == 0);
            // Random dead links (avoid partitioning by limiting count).
            for _ in 0..rng.below(6) {
                let c = (rng.below(10) as u32, rng.below(10) as u32);
                let d = ALL_DIRECTIONS[rng.below(6)];
                b = b.dead_link(c, d);
            }
            let m = b.build();
            let source = (rng.below(10) as u32, rng.below(10) as u32);
            let mut want: Vec<(ChipCoord, u8)> = (0..1 + rng.below(15))
                .map(|_| {
                    (
                        (rng.below(10) as u32, rng.below(10) as u32),
                        1 + rng.below(16) as u8,
                    )
                })
                .collect();
            want.sort();
            want.dedup();
            let tree = match build_tree(&m, source, &dests(&want)) {
                Ok(t) => t,
                Err(_) => return, // random faults partitioned the machine
            };
            let mut got = Vec::new();
            let mut visited = BTreeSet::new();
            let mut stack = vec![source];
            while let Some(c) = stack.pop() {
                assert!(visited.insert(c), "duplicate visit {c:?}");
                let node = &tree.nodes[&c];
                got.extend(node.local_cores.iter().map(|p| (c, *p)));
                for d in &node.out_links {
                    stack.push(m.link_target(c, *d).expect("working link"));
                }
            }
            got.sort();
            assert_eq!(got, want, "delivered set mismatch");
        });
    }
}
