//! Little-endian byte serialization for SDRAM data regions.
//!
//! The Python tools write data regions that the on-machine C code reads
//! back (§6.3.3); here the rust data generator writes regions that the
//! simulated core apps decode. Little-endian word-aligned layout, exactly
//! as the ARM side would see it.

/// Writer for one data region.
#[derive(Debug, Default, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn i32(&mut self, v: i32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32s(&mut self, vs: &[f32]) -> &mut Self {
        for v in vs {
            self.f32(*v);
        }
        self
    }

    pub fn u32s(&mut self, vs: &[u32]) -> &mut Self {
        for v in vs {
            self.u32(*v);
        }
        self
    }

    pub fn bytes(&mut self, vs: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(vs);
        self
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Reader over one data region.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            anyhow::bail!(
                "region underrun: need {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into()?))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }

    pub fn i32(&mut self) -> anyhow::Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into()?))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into()?))
    }

    pub fn f32s(&mut self, n: usize) -> anyhow::Result<Vec<f32>> {
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn u32s(&mut self, n: usize) -> anyhow::Result<Vec<u32>> {
        (0..n).map(|_| self.u32()).collect()
    }

    /// Consume exactly `n` bytes (the payload of a length-prefixed blob,
    /// as the snapshot codec writes them) or error on underrun.
    pub fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        self.take(n)
    }

    /// Consume and return every remaining byte (the tail payload of a
    /// frame) in one slice — cheaper than a byte-at-a-time loop on the
    /// UDP/SDP decode paths.
    pub fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let mut w = ByteWriter::new();
        w.u32(0xdead_beef).f32(1.5).u8(7).u16(300).u64(1 << 40).i32(-5);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn vector_round_trip() {
        let mut w = ByteWriter::new();
        w.f32s(&[1.0, 2.0, 3.0]).u32s(&[9, 8]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.f32s(3).unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(r.u32s(2).unwrap(), vec![9, 8]);
    }

    #[test]
    fn rest_consumes_the_tail() {
        let buf = [1u8, 2, 3, 4, 5];
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u16().unwrap(), u16::from_le_bytes([1, 2]));
        assert_eq!(r.rest(), &[3, 4, 5]);
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.rest(), &[] as &[u8]);
    }

    #[test]
    fn underrun_errors() {
        let buf = [1u8, 2];
        let mut r = ByteReader::new(&buf);
        assert!(r.u32().is_err());
    }

    #[test]
    fn counted_bytes_round_trip() {
        let mut w = ByteWriter::new();
        w.u32(3).bytes(&[7, 8, 9]).u8(0xFF);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        let n = r.u32().unwrap() as usize;
        assert_eq!(r.bytes(n).unwrap(), &[7, 8, 9]);
        assert_eq!(r.u8().unwrap(), 0xFF);
        assert!(r.bytes(1).is_err());
    }
}
