//! Allocation accounting: a [`GlobalAlloc`] wrapper that counts bytes.
//!
//! The scale bench (`benches/scale.rs`, experiment E18) needs a peak
//! memory proxy that is portable and deterministic-ish across CI hosts,
//! where RSS is neither. [`AllocCounter`] wraps the system allocator
//! and keeps two relaxed atomic counters: bytes currently live and the
//! high-water mark. Install it as the binary's `#[global_allocator]`:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: spinntools::util::mem::AllocCounter = spinntools::util::mem::AllocCounter::new();
//! ```
//!
//! Counting is exact for allocation *requests* (layout sizes), not OS
//! pages — a proxy, but one that moves 1:1 with the data structures
//! under audit. Relaxed ordering means a reading thread may observe a
//! peak a few allocations stale; the benches read after joining their
//! workers, where the counters are quiescent.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Byte-counting wrapper over the system allocator.
pub struct AllocCounter {
    live: AtomicU64,
    peak: AtomicU64,
}

impl AllocCounter {
    #[allow(clippy::new_without_default)]
    pub const fn new() -> AllocCounter {
        AllocCounter { live: AtomicU64::new(0), peak: AtomicU64::new(0) }
    }

    /// Bytes currently allocated (sum of live layout sizes).
    pub fn live_bytes(&self) -> u64 {
        self.live.load(Relaxed)
    }

    /// High-water mark of [`Self::live_bytes`] since construction (or
    /// the last [`Self::reset_peak`]).
    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Relaxed)
    }

    /// Restart peak tracking from the current live count, so a bench
    /// can attribute a high-water mark to one phase.
    pub fn reset_peak(&self) {
        self.peak.store(self.live.load(Relaxed), Relaxed);
    }

    fn count_alloc(&self, bytes: u64) {
        let live = self.live.fetch_add(bytes, Relaxed) + bytes;
        self.peak.fetch_max(live, Relaxed);
    }

    fn count_dealloc(&self, bytes: u64) {
        self.live.fetch_sub(bytes, Relaxed);
    }
}

// SAFETY: delegates every allocation verbatim to `System`; the counters
// are side bookkeeping and never influence pointers or layouts.
unsafe impl GlobalAlloc for AllocCounter {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            self.count_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        self.count_dealloc(layout.size() as u64);
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            self.count_alloc(layout.size() as u64);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            // Count the delta as free-then-alloc of the same block.
            self.count_dealloc(layout.size() as u64);
            self.count_alloc(new_size as u64);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_track_alloc_and_dealloc() {
        // Drive the GlobalAlloc impl directly (installing a global
        // allocator inside a test binary would count the whole world).
        let c = AllocCounter::new();
        let layout = Layout::from_size_align(4096, 8).unwrap();
        unsafe {
            let p = c.alloc(layout);
            assert!(!p.is_null());
            assert_eq!(c.live_bytes(), 4096);
            assert_eq!(c.peak_bytes(), 4096);
            let p2 = c.realloc(p, layout, 8192);
            assert!(!p2.is_null());
            assert_eq!(c.live_bytes(), 8192);
            assert!(c.peak_bytes() >= 8192);
            c.dealloc(p2, Layout::from_size_align(8192, 8).unwrap());
        }
        assert_eq!(c.live_bytes(), 0);
        assert!(c.peak_bytes() >= 8192, "peak survives the free");
        c.reset_peak();
        assert_eq!(c.peak_bytes(), 0);
    }
}
