//! A minimal scoped worker pool (the vendor bundle has no rayon): an
//! order-preserving parallel map over independent work items, used by
//! the sharded mapping stages (§6.3.2 scaling) and the Figure-10
//! engine's fan-out/join support.
//!
//! Work is pulled from a shared atomic cursor so uneven items balance
//! across workers, but results are re-assembled **in item order** — the
//! caller sees exactly the sequence a serial map would produce, which is
//! what lets the mapping pipeline promise byte-identical output at any
//! thread count.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Normalise a thread-count knob: `0` means one worker per available
/// hardware thread; anything else is taken literally.
pub fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// Map `f` over `items` on up to `threads` scoped workers, preserving
/// item order in the result. `threads <= 1` (after [`effective_threads`]
/// normalisation) runs serially on the caller's thread with no pool.
///
/// On error, the error of the **lowest-indexed** failing item is
/// returned, so failures are as deterministic as the successes: the
/// cursor hands indices out in increasing order and the cancel flag is
/// only consulted *before claiming new work* — an index already claimed
/// is always evaluated, so by the time any failure is recorded the
/// lowest failing index has been claimed and will record its own error.
/// Cancellation just stops workers from starting further (discarded)
/// items after the first failure.
pub fn try_par_map<T, R, F>(threads: usize, items: &[T], f: F) -> anyhow::Result<Vec<R>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> anyhow::Result<R> + Sync,
{
    let threads = effective_threads(threads).min(items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let results: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(items.len()));
    let errors: Mutex<Vec<(usize, anyhow::Error)>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                while !failed.load(Ordering::Relaxed) {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    match f(i, &items[i]) {
                        Ok(r) => local.push((i, r)),
                        Err(e) => {
                            errors.lock().unwrap().push((i, e));
                            failed.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                }
                results.lock().unwrap().append(&mut local);
            });
        }
    });

    let mut errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        errors.sort_by_key(|(i, _)| *i);
        return Err(errors.remove(0).1);
    }
    let mut collected = results.into_inner().unwrap();
    collected.sort_by_key(|(i, _)| *i);
    anyhow::ensure!(
        collected.len() == items.len(),
        "worker pool lost results ({} of {})",
        collected.len(),
        items.len()
    );
    Ok(collected.into_iter().map(|(_, r)| r).collect())
}

/// Infallible variant of [`try_par_map`].
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    try_par_map(threads, items, |i, t| Ok(f(i, t))).expect("infallible map failed")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_any_thread_count() {
        let items: Vec<u64> = (0..257).collect();
        let serial: Vec<u64> = items.iter().map(|x| x * 3).collect();
        for threads in [1, 2, 3, 8, 64] {
            let got = par_map(threads, &items, |_, x| x * 3);
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_input() {
        let got: Vec<u32> = par_map(4, &[] as &[u32], |_, x| *x);
        assert!(got.is_empty());
    }

    #[test]
    fn index_matches_item() {
        let items: Vec<usize> = (0..100).collect();
        let got = par_map(4, &items, |i, x| i == *x);
        assert!(got.into_iter().all(|ok| ok));
    }

    #[test]
    fn first_error_wins_deterministically() {
        let items: Vec<u32> = (0..200).collect();
        for threads in [1, 2, 8] {
            let err = try_par_map(threads, &items, |_, x| {
                if *x >= 50 {
                    anyhow::bail!("item {x} failed")
                }
                Ok(*x)
            })
            .unwrap_err();
            // Workers may also fail on later items, but the reported
            // error must be the lowest-indexed failure.
            assert_eq!(err.to_string(), "item 50 failed", "threads={threads}");
        }
    }

    #[test]
    fn effective_zero_means_hardware() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}
