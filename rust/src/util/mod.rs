//! Small shared utilities: deterministic PRNG, byte codecs, JSON, an
//! FNV digest, and a lightweight property-testing helper (the vendor
//! bundle carries no rand/serde_json/proptest).

pub mod bytes;
pub mod json;
pub mod mem;
pub mod par;
pub mod prop;

/// FNV-1a offset basis: the seed for an incremental [`fnv1a_64_extend`]
/// digest.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend an FNV-1a digest with more bytes (incremental form — the
/// fabric probe digests many fields into one running hash).
pub fn fnv1a_64_extend(h: &mut u64, bytes: &[u8]) {
    for b in bytes {
        *h ^= *b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

/// FNV-1a over a byte slice: the cheap, dependency-free content digest
/// the benches and equivalence suites use to prove two data paths moved
/// byte-identical payloads.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    fnv1a_64_extend(&mut h, bytes);
    h
}

/// SplitMix64 PRNG — deterministic, dependency-free randomness for the
/// Poisson sources, synthetic workload generators and the simulator's
/// tie-breaking. (The real SpiNNaker binaries keep their RNG state in
/// DTCM the same way.)
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// The raw generator state. `SplitMix64::new(rng.state())` resumes
    /// the exact stream — `new` stores the seed verbatim — which is how
    /// run snapshots serialize a core's RNG without replaying draws.
    pub fn state(&self) -> u64 {
        self.state
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Gaussian via Box–Muller (one draw per call; simple, adequate here).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Poisson-distributed count with the given mean (Knuth for small
    /// lambda, normal approximation above 64).
    pub fn next_poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = lambda + lambda.sqrt() * self.next_gaussian();
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn poisson_mean_near_lambda() {
        let mut r = SplitMix64::new(7);
        let n = 20_000;
        let total: u64 = (0..n).map(|_| r.next_poisson(3.0)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_zero_lambda_is_zero() {
        let mut r = SplitMix64::new(9);
        assert_eq!(r.next_poisson(0.0), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn fnv_distinguishes_and_repeats() {
        assert_eq!(fnv1a_64(b"abc"), fnv1a_64(b"abc"));
        assert_ne!(fnv1a_64(b"abc"), fnv1a_64(b"abd"));
        assert_ne!(fnv1a_64(b""), fnv1a_64(b"\0"));
    }
}
