//! A small property-testing helper (proptest is not in the vendor
//! bundle): run a closure over many seeded-random cases and report the
//! first failing seed so failures are reproducible.

use super::SplitMix64;

/// Run `f` for `cases` deterministic random cases. On panic, re-raises
/// with the offending case index + seed in the message.
pub fn check<F: Fn(&mut SplitMix64)>(cases: u32, base_seed: u64, f: F) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check(50, 1, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn reports_seed_on_failure() {
        check(50, 2, |rng| {
            assert!(rng.below(10) < 5, "too big");
        });
    }
}
