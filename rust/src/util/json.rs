//! Minimal JSON reader/writer (the vendor bundle has no serde_json).
//!
//! Parses the AOT `artifacts/manifest.json` and serializes the mapping
//! database (§6.3.2). Supports the full JSON value grammar except
//! non-finite numbers; strings handle the standard escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { s: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            anyhow::bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (keys sorted — BTreeMap — so output is deterministic).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line serialization (JSONL records, one value per line).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.s[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.s
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.s[start]);
                    let chunk = self
                        .s
                        .get(start..start + len)
                        .ok_or_else(|| anyhow::anyhow!("bad utf8"))?;
                    out.push_str(std::str::from_utf8(chunk)?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => anyhow::bail!("expected , or ] found {other:?}"),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => anyhow::bail!("expected , or }} found {other:?}"),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "lif_step_n256": {
            "file": "lif_step_n256.hlo.txt",
            "inputs": [{"shape": [256], "dtype": "float32"},
                       {"shape": [], "dtype": "float32"}],
            "n_outputs": 5
          }
        }"#;
        let j = Json::parse(text).unwrap();
        let entry = j.get("lif_step_n256").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("lif_step_n256.hlo.txt"));
        assert_eq!(entry.get("n_outputs").unwrap().as_usize(), Some(5));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().idx(0).unwrap().as_usize(), Some(256));
        assert_eq!(inputs[1].get("shape").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn round_trip() {
        let text = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": null, "d": true}"#;
        let j = Json::parse(text).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn compact_is_one_line_and_round_trips() {
        let text = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": null, "d": true}"#;
        let j = Json::parse(text).unwrap();
        let s = j.to_string_compact();
        assert!(!s.contains('\n') && !s.contains("  "));
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = j.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"caf\\u00e9 ☕\"").unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
    }
}
