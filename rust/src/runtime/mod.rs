//! The PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Python never runs here — `make artifacts` lowered the L2 JAX models
//! (which call the L1 Pallas kernels) to HLO *text*; this module parses
//! that text, compiles each module once on the PJRT CPU client, and
//! serves executions to the simulated cores in [`crate::apps`].
//!
//! See /opt/xla-example/README.md for why text (not serialized proto) is
//! the interchange format.
//!
//! The PJRT backing is gated behind the off-by-default `pjrt` cargo
//! feature: without it the crate builds on machines that lack the XLA
//! toolchain, and [`Runtime::open`] returns a descriptive error instead.
//! Everything that does not execute HLO — the whole mapping stack, the
//! simulator, the pure-Rust apps — is unaffected.

use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;

#[cfg(feature = "pjrt")]
use crate::util::json::Json;

/// A host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
    ScalarF32(f32),
}

/// The default artifact directory: `$SPINNTOOLS_ARTIFACTS` or
/// `<repo>/artifacts` relative to the crate.
fn artifacts_default_dir() -> PathBuf {
    std::env::var("SPINNTOOLS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// One compiled artifact.
#[cfg(feature = "pjrt")]
struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    input_shapes: Vec<Vec<usize>>,
    n_outputs: usize,
}

/// The artifact runtime: one compiled executable per model variant.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: BTreeMap<String, (String, Vec<Vec<usize>>, usize)>,
    models: std::cell::RefCell<BTreeMap<String, LoadedModel>>,
    /// Execution counter (perf accounting).
    pub execs: std::cell::Cell<u64>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open an artifact directory (reads `manifest.json`; compiles each
    /// model lazily on first use so binaries that exercise one model
    /// don't pay for all).
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {manifest_path:?}: {e}; run `make artifacts` first"
            )
        })?;
        let json = Json::parse(&text)?;
        let mut manifest = BTreeMap::new();
        for (name, entry) in json.as_obj().ok_or_else(|| anyhow::anyhow!("bad manifest"))? {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("manifest entry {name} missing file"))?
                .to_string();
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("manifest entry {name} missing inputs"))?
                .iter()
                .map(|i| {
                    i.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                        .unwrap_or_default()
                })
                .collect();
            let n_outputs = entry
                .get("n_outputs")
                .and_then(Json::as_usize)
                .unwrap_or(1);
            manifest.insert(name.clone(), (file, inputs, n_outputs));
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            models: std::cell::RefCell::new(BTreeMap::new()),
            execs: std::cell::Cell::new(0),
        })
    }

    /// See [`artifacts_default_dir`].
    pub fn default_dir() -> PathBuf {
        artifacts_default_dir()
    }

    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(&Self::default_dir())
    }

    pub fn model_names(&self) -> Vec<String> {
        self.manifest.keys().cloned().collect()
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.manifest.contains_key(name)
    }

    /// Input shapes declared by the manifest for one model.
    pub fn input_shapes(&self, name: &str) -> anyhow::Result<Vec<Vec<usize>>> {
        Ok(self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no model {name}"))?
            .1
            .clone())
    }

    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        if self.models.borrow().contains_key(name) {
            return Ok(());
        }
        let (file, shapes, n_outputs) = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no model '{name}' in manifest"))?
            .clone();
        let path = self.dir.join(&file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        self.models.borrow_mut().insert(
            name.to_string(),
            LoadedModel { exe, input_shapes: shapes, n_outputs },
        );
        Ok(())
    }

    /// Execute a model. Inputs must match the manifest shapes; outputs
    /// come back flattened, one `HostTensor::F32`/`I32` per output.
    pub fn exec(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let models = self.models.borrow();
        let model = models.get(name).unwrap();
        anyhow::ensure!(
            inputs.len() == model.input_shapes.len(),
            "model {name}: {} inputs given, {} expected",
            inputs.len(),
            model.input_shapes.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (input, shape)) in inputs.iter().zip(&model.input_shapes).enumerate() {
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            let lit = match input {
                HostTensor::F32(v) => {
                    let n: usize = shape.iter().product();
                    anyhow::ensure!(
                        v.len() == n,
                        "model {name} input {i}: {} elems, shape {shape:?} wants {n}",
                        v.len()
                    );
                    xla::Literal::vec1(v)
                        .reshape(&dims)
                        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
                }
                HostTensor::I32(v) => {
                    let n: usize = shape.iter().product();
                    anyhow::ensure!(v.len() == n, "model {name} input {i}: bad length");
                    xla::Literal::vec1(v)
                        .reshape(&dims)
                        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
                }
                HostTensor::ScalarF32(v) => {
                    anyhow::ensure!(shape.is_empty(), "input {i} is not scalar");
                    xla::Literal::scalar(*v)
                }
            };
            literals.push(lit);
        }
        self.execs.set(self.execs.get() + 1);
        let result = model
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == model.n_outputs,
            "model {name}: {} outputs, manifest says {}",
            parts.len(),
            model.n_outputs
        );
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let ty = p
                .element_type()
                .map_err(|e| anyhow::anyhow!("element_type: {e:?}"))?;
            match ty {
                xla::ElementType::F32 => out.push(HostTensor::F32(
                    p.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
                )),
                xla::ElementType::S32 => out.push(HostTensor::I32(
                    p.to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?,
                )),
                other => anyhow::bail!("unsupported output type {other:?}"),
            }
        }
        Ok(out)
    }
}

/// The artifact runtime, built **without** the `pjrt` feature: a stub
/// with the same API whose constructors fail with a clear error. No
/// instance can ever exist, so the accessor methods are unreachable —
/// they only keep callers compiling.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    /// Execution counter (perf accounting).
    pub execs: std::cell::Cell<u64>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    fn unavailable(what: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "{what}: spinntools was built without the `pjrt` feature, so the \
             PJRT/XLA runtime that executes the AOT HLO artifacts is \
             unavailable. Rebuild with `cargo build --features pjrt` (needs \
             the XLA toolchain; see Cargo.toml) to run HLO-backed workloads."
        )
    }

    /// Always fails: the PJRT backing is not compiled in.
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let _ = dir;
        Err(Self::unavailable("Runtime::open"))
    }

    /// See [`artifacts_default_dir`].
    pub fn default_dir() -> PathBuf {
        artifacts_default_dir()
    }

    /// Always fails: the PJRT backing is not compiled in.
    pub fn open_default() -> anyhow::Result<Self> {
        Err(Self::unavailable("Runtime::open_default"))
    }

    pub fn model_names(&self) -> Vec<String> {
        Vec::new()
    }

    pub fn has_model(&self, _name: &str) -> bool {
        false
    }

    pub fn input_shapes(&self, _name: &str) -> anyhow::Result<Vec<Vec<usize>>> {
        Err(Self::unavailable("Runtime::input_shapes"))
    }

    pub fn exec(&self, _name: &str, _inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        Err(Self::unavailable("Runtime::exec"))
    }
}

impl HostTensor {
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => anyhow::bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> anyhow::Result<Vec<i32>> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => anyhow::bail!("tensor is not i32"),
        }
    }
}

#[cfg(test)]
mod stub_tests {
    use super::*;

    #[test]
    fn default_dir_is_stable() {
        // Shared by both backings: the artifact directory is derived
        // from the env var or the crate root.
        let d = Runtime::default_dir();
        assert!(d.to_string_lossy().contains("artifacts") || std::env::var("SPINNTOOLS_ARTIFACTS").is_ok());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_open_reports_missing_feature() {
        let err = Runtime::open_default().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        let err = Runtime::open(Path::new("/nonexistent")).unwrap_err().to_string();
        assert!(err.contains("without the `pjrt` feature"), "{err}");
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn runtime() -> Runtime {
        Runtime::open_default().expect("artifacts missing — run `make artifacts`")
    }

    #[test]
    fn manifest_lists_models() {
        let rt = runtime();
        assert!(rt.has_model("lif_step_n256"));
        assert!(rt.has_model("conway_step_32x32"));
        assert!(rt.has_model("poisson_step_n256"));
    }

    #[test]
    fn lif_step_executes_and_decays() {
        let rt = runtime();
        let n = 64;
        let params = vec![
            (-1.0f32 / 10.0).exp(), // alpha_mem
            (-1.0f32 / 0.5).exp(),
            (-1.0f32 / 0.5).exp(),
            -65.0,
            -65.0,
            -50.0,
            2.0,
            0.0,
        ];
        let v = vec![-55.0f32; n];
        let z = vec![0.0f32; n];
        let out = rt
            .exec(
                "lif_step_n64",
                &[
                    HostTensor::F32(v),
                    HostTensor::F32(z.clone()),
                    HostTensor::F32(z.clone()),
                    HostTensor::F32(z.clone()),
                    HostTensor::F32(z.clone()),
                    HostTensor::F32(z.clone()),
                    HostTensor::F32(params),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 5);
        let v1 = out[0].as_f32().unwrap();
        // decays toward -65 from -55
        assert!(v1.iter().all(|x| *x < -55.0 && *x > -65.0), "v1[0]={}", v1[0]);
        let spiked = out[4].as_f32().unwrap();
        assert!(spiked.iter().all(|s| *s == 0.0));
    }

    #[test]
    fn lif_step_spikes_with_input() {
        let rt = runtime();
        let n = 64;
        let params = vec![0.9f32, 0.1, 0.1, -65.0, -65.0, -50.0, 2.0, 0.0];
        let out = rt
            .exec(
                "lif_step_n64",
                &[
                    HostTensor::F32(vec![-65.0; n]),
                    HostTensor::F32(vec![0.0; n]),
                    HostTensor::F32(vec![0.0; n]),
                    HostTensor::F32(vec![0.0; n]),
                    HostTensor::F32(vec![1000.0; n]), // massive excitation
                    HostTensor::F32(vec![0.0; n]),
                    HostTensor::F32(params),
                ],
            )
            .unwrap();
        let spiked = out[4].as_f32().unwrap();
        assert!(spiked.iter().all(|s| *s == 1.0));
        let v1 = out[0].as_f32().unwrap();
        assert!(v1.iter().all(|v| *v == -65.0), "reset to v_reset");
    }

    #[test]
    fn conway_blinker_via_hlo() {
        let rt = runtime();
        let mut board = vec![0i32; 16 * 16];
        board[2 * 16 + 1] = 1;
        board[2 * 16 + 2] = 1;
        board[2 * 16 + 3] = 1;
        let out = rt
            .exec("conway_step_16x16", &[HostTensor::I32(board)])
            .unwrap();
        let b1 = out[0].as_i32().unwrap();
        assert_eq!(b1[1 * 16 + 2], 1);
        assert_eq!(b1[2 * 16 + 2], 1);
        assert_eq!(b1[3 * 16 + 2], 1);
        assert_eq!(b1.iter().sum::<i32>(), 3);
    }

    #[test]
    fn poisson_thinning_via_hlo() {
        let rt = runtime();
        let unif: Vec<f32> = (0..256).map(|i| i as f32 / 256.0).collect();
        let out = rt
            .exec(
                "poisson_step_n256",
                &[HostTensor::F32(unif), HostTensor::ScalarF32(0.25)],
            )
            .unwrap();
        let spikes = out[0].as_f32().unwrap();
        let count: f32 = spikes.iter().sum();
        assert_eq!(count, 64.0); // exactly the uniforms below 0.25
    }

    #[test]
    fn unknown_model_errors() {
        let rt = runtime();
        assert!(rt.exec("nonexistent", &[]).is_err());
    }

    #[test]
    fn wrong_arity_errors() {
        let rt = runtime();
        assert!(rt.exec("lif_step_n64", &[]).is_err());
    }

    #[test]
    fn wrong_shape_errors() {
        let rt = runtime();
        let bad = vec![HostTensor::F32(vec![0.0; 3]); 7];
        assert!(rt.exec("lif_step_n64", &bad).is_err());
    }
}
