//! The machine graph: vertices that each fit one core, machine edges,
//! and outgoing edge partitions (Figure 6 a/b).
//!
//! Mutations (including [`MachineGraph::remove_vertex`]) are recorded in
//! a [`ChangeJournal`] so the front end can re-map incrementally (§6.5's
//! "graph changed" branch, DESIGN.md §7). Removal uses tombstones:
//! vertex and edge ids are positional, so removed slots stay allocated
//! and every id handed out remains stable for the graph's lifetime.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::journal::{ChangeJournal, GraphDelta};
use super::vertex::MachineVertexImpl;

/// Handle to a machine vertex within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VertexId(pub u32);

/// Handle to a machine edge within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EdgeId(pub u32);

/// Communication from `pre` to `post` (§5.2: "an edge represents some
/// communication that will take place from a source ... to a target").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachineEdge {
    pub pre: VertexId,
    pub post: VertexId,
}

/// All edges leaving one vertex under one message type / key-space
/// (Figure 6 b). Each partition gets its own multicast key range.
#[derive(Debug, Clone)]
pub struct OutgoingEdgePartition {
    pub pre: VertexId,
    pub id: String,
    pub edges: Vec<EdgeId>,
}

/// The default partition id used when callers don't need multiple
/// message types from one vertex.
pub const DEFAULT_PARTITION: &str = "default";

/// A machine graph (vertices + edges + partitions). Deterministic
/// iteration everywhere: mapping results must be reproducible.
#[derive(Default, Clone)]
pub struct MachineGraph {
    vertices: Vec<Arc<dyn MachineVertexImpl>>,
    /// Tombstones: `false` marks a removed vertex slot (ids stay stable).
    vertex_live: Vec<bool>,
    edges: Vec<MachineEdge>,
    edge_live: Vec<bool>,
    /// (pre, partition id) -> partition, insertion-ordered by BTreeMap.
    /// Holds only live edges; a partition whose last edge is removed is
    /// dropped entirely.
    partitions: BTreeMap<(VertexId, String), OutgoingEdgePartition>,
    /// edge -> partition id (reverse index; kept for removed edges too).
    edge_partition: Vec<String>,
    /// Per-vertex "data/resources changed" epochs (see
    /// [`MachineGraph::touch_vertex`]); folded into the fingerprints.
    touch_epochs: BTreeMap<VertexId, u64>,
    journal: ChangeJournal,
}

impl MachineGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_vertex(&mut self, v: Arc<dyn MachineVertexImpl>) -> VertexId {
        let id = VertexId(self.vertices.len() as u32);
        self.vertices.push(v);
        self.vertex_live.push(true);
        self.journal.record(GraphDelta::VertexAdded(id.0));
        id
    }

    /// Add an edge in the given outgoing edge partition of `pre`.
    pub fn add_edge(&mut self, pre: VertexId, post: VertexId, partition: &str) -> EdgeId {
        assert!(self.is_live(pre), "bad pre vertex");
        assert!(self.is_live(post), "bad post vertex");
        let eid = EdgeId(self.edges.len() as u32);
        self.edges.push(MachineEdge { pre, post });
        self.edge_live.push(true);
        self.edge_partition.push(partition.to_string());
        self.partitions
            .entry((pre, partition.to_string()))
            .or_insert_with(|| OutgoingEdgePartition {
                pre,
                id: partition.to_string(),
                edges: Vec::new(),
            })
            .edges
            .push(eid);
        self.journal.record(GraphDelta::EdgeAdded(eid.0));
        eid
    }

    /// Remove a vertex and every edge incident to it. The slot is
    /// tombstoned: the id is never reused, existing ids stay valid.
    pub fn remove_vertex(&mut self, v: VertexId) -> anyhow::Result<()> {
        anyhow::ensure!(self.is_live(v), "vertex {v:?} is not live");
        let incident: Vec<EdgeId> = self
            .edges()
            .filter(|(_, e)| e.pre == v || e.post == v)
            .map(|(id, _)| id)
            .collect();
        for eid in incident {
            self.remove_edge_inner(eid);
        }
        self.vertex_live[v.0 as usize] = false;
        self.touch_epochs.remove(&v);
        self.journal.record(GraphDelta::VertexRemoved(v.0));
        Ok(())
    }

    /// Remove a single edge (tombstoned, like vertices).
    pub fn remove_edge(&mut self, e: EdgeId) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.edge_live.get(e.0 as usize).copied().unwrap_or(false),
            "edge {e:?} is not live"
        );
        self.remove_edge_inner(e);
        Ok(())
    }

    fn remove_edge_inner(&mut self, eid: EdgeId) {
        self.edge_live[eid.0 as usize] = false;
        let pre = self.edges[eid.0 as usize].pre;
        let pkey = (pre, self.edge_partition[eid.0 as usize].clone());
        if let Some(p) = self.partitions.get_mut(&pkey) {
            p.edges.retain(|e| *e != eid);
            if p.edges.is_empty() {
                self.partitions.remove(&pkey);
            }
        }
        self.journal.record(GraphDelta::EdgeRemoved(eid.0));
    }

    /// Declare that a vertex's resources or generated data changed in a
    /// way the graph structure does not show. Bumps the vertex's touch
    /// epoch (folded into [`Self::vertices_fingerprint`]) and journals a
    /// [`GraphDelta::VertexTouched`]; on the next run the placer stage
    /// re-runs (re-validating the pin against current resources) and
    /// data generation re-diffs the vertex's regions.
    pub fn touch_vertex(&mut self, v: VertexId) -> anyhow::Result<()> {
        anyhow::ensure!(self.is_live(v), "vertex {v:?} is not live");
        *self.touch_epochs.entry(v).or_insert(0) += 1;
        self.journal.record(GraphDelta::VertexTouched(v.0));
        Ok(())
    }

    /// Whether `id` names a live (non-removed) vertex.
    pub fn is_live(&self, id: VertexId) -> bool {
        self.vertex_live.get(id.0 as usize).copied().unwrap_or(false)
    }

    /// The change journal (revision counter + typed delta log).
    pub fn journal(&self) -> &ChangeJournal {
        &self.journal
    }

    /// The current graph revision (`journal().revision()`).
    pub fn revision(&self) -> u64 {
        self.journal.revision()
    }

    /// Drop the journal's delta log (revision stays monotone).
    pub fn clear_journal(&mut self) {
        self.journal.clear();
    }

    pub fn vertex(&self, id: VertexId) -> &Arc<dyn MachineVertexImpl> {
        &self.vertices[id.0 as usize]
    }

    pub fn n_vertices(&self) -> usize {
        self.vertex_live.iter().filter(|l| **l).count()
    }

    pub fn n_edges(&self) -> usize {
        self.edge_live.iter().filter(|l| **l).count()
    }

    pub fn vertex_ids(&self) -> impl Iterator<Item = VertexId> + '_ {
        self.vertex_live
            .iter()
            .enumerate()
            .filter(|(_, l)| **l)
            .map(|(i, _)| VertexId(i as u32))
    }

    pub fn vertices(&self) -> impl Iterator<Item = (VertexId, &Arc<dyn MachineVertexImpl>)> {
        self.vertices
            .iter()
            .enumerate()
            .filter(|(i, _)| self.vertex_live[*i])
            .map(|(i, v)| (VertexId(i as u32), v))
    }

    pub fn edge(&self, id: EdgeId) -> MachineEdge {
        self.edges[id.0 as usize]
    }

    pub fn edges(&self) -> impl Iterator<Item = (EdgeId, MachineEdge)> + '_ {
        self.edges
            .iter()
            .enumerate()
            .filter(|(i, _)| self.edge_live[*i])
            .map(|(i, e)| (EdgeId(i as u32), *e))
    }

    pub fn partition_of_edge(&self, id: EdgeId) -> String {
        self.edge_partition[id.0 as usize].clone()
    }

    pub fn partitions(&self) -> impl Iterator<Item = &OutgoingEdgePartition> {
        self.partitions.values()
    }

    pub fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    /// Partitions leaving one vertex (§5.2: "there can be more than one
    /// outgoing edge partition for each source vertex").
    pub fn partitions_of(&self, v: VertexId) -> impl Iterator<Item = &OutgoingEdgePartition> {
        self.partitions
            .range((v, String::new())..=(v, "\u{10ffff}".to_string()))
            .map(|(_, p)| p)
    }

    pub fn partition(&self, pre: VertexId, id: &str) -> Option<&OutgoingEdgePartition> {
        self.partitions.get(&(pre, id.to_string()))
    }

    /// The target vertices of one partition (deduplicated, ordered).
    pub fn partition_targets(&self, p: &OutgoingEdgePartition) -> Vec<VertexId> {
        let mut targets: Vec<VertexId> =
            p.edges.iter().map(|e| self.edge(*e).post).collect();
        targets.sort();
        targets.dedup();
        targets
    }

    /// Edges arriving at `v`.
    pub fn incoming_edges(&self, v: VertexId) -> Vec<EdgeId> {
        self.edges()
            .filter(|(_, e)| e.post == v)
            .map(|(id, _)| id)
            .collect()
    }

    /// Edges leaving `v` (all partitions).
    pub fn outgoing_edges(&self, v: VertexId) -> Vec<EdgeId> {
        self.edges()
            .filter(|(_, e)| e.pre == v)
            .map(|(id, _)| id)
            .collect()
    }

    // -- content fingerprints (DESIGN.md §7) --------------------------------

    /// FNV-1a digest over the live *vertex* content: ids, labels,
    /// binaries, resource footprints, constraints and touch epochs —
    /// everything placement depends on, and nothing it does not (edges
    /// are deliberately excluded, so adding an edge does not invalidate
    /// a cached placement stage).
    pub fn vertices_fingerprint(&self) -> u64 {
        let mut h = crate::util::FNV_OFFSET;
        let mut put = |bytes: &[u8]| crate::util::fnv1a_64_extend(&mut h, bytes);
        for (vid, vertex) in self.vertices() {
            put(&vid.0.to_le_bytes());
            put(vertex.label().as_bytes());
            put(vertex.binary_name().as_bytes());
            let r = vertex.resources();
            put(&r.dtcm_bytes.to_le_bytes());
            put(&r.itcm_bytes.to_le_bytes());
            put(&r.sdram_bytes.to_le_bytes());
            put(&r.cpu_cycles_per_step.to_le_bytes());
            if let Some(loc) = vertex.placement_constraint() {
                put(&[1, loc.p]);
                put(&loc.x.to_le_bytes());
                put(&loc.y.to_le_bytes());
            }
            if let Some(chip) = vertex.chip_constraint() {
                put(&[2]);
                put(&chip.0.to_le_bytes());
                put(&chip.1.to_le_bytes());
            }
            if let Some(vl) = vertex.virtual_link() {
                put(&[3, vl.direction.id()]);
                put(&vl.attached_to.0.to_le_bytes());
                put(&vl.attached_to.1.to_le_bytes());
            }
            put(&self.touch_epochs.get(&vid).copied().unwrap_or(0).to_le_bytes());
        }
        h
    }

    /// FNV-1a digest over the live *topology*: every outgoing edge
    /// partition with its key demand and deduplicated target set — what
    /// routing and key allocation depend on.
    pub fn partitions_fingerprint(&self) -> u64 {
        let mut h = crate::util::FNV_OFFSET;
        let mut put = |bytes: &[u8]| crate::util::fnv1a_64_extend(&mut h, bytes);
        for partition in self.partitions() {
            put(&partition.pre.0.to_le_bytes());
            put(partition.id.as_bytes());
            let n_keys = self
                .vertex(partition.pre)
                .n_keys_for_partition(&partition.id);
            put(&n_keys.to_le_bytes());
            for target in self.partition_targets(partition) {
                put(&target.0.to_le_bytes());
            }
        }
        h
    }

    /// FNV-1a digest over the whole canonical graph content (vertices,
    /// topology, and the exact live edge multiset).
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::FNV_OFFSET;
        crate::util::fnv1a_64_extend(&mut h, &self.vertices_fingerprint().to_le_bytes());
        crate::util::fnv1a_64_extend(&mut h, &self.partitions_fingerprint().to_le_bytes());
        for (eid, e) in self.edges() {
            crate::util::fnv1a_64_extend(&mut h, &eid.0.to_le_bytes());
            crate::util::fnv1a_64_extend(&mut h, &e.pre.0.to_le_bytes());
            crate::util::fnv1a_64_extend(&mut h, &e.post.0.to_le_bytes());
        }
        h
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    //! A minimal vertex for graph/mapping unit tests.
    use std::any::Any;
    use std::sync::Arc;

    use crate::graph::resources::ResourceRequirements;
    use crate::graph::vertex::{DataGenContext, DataRegion, MachineVertexImpl};
    use crate::machine::CoreLocation;

    #[derive(Debug)]
    pub struct TestVertex {
        pub name: String,
        pub sdram: u64,
        pub constraint: Option<CoreLocation>,
    }

    impl TestVertex {
        pub fn arc(name: &str) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), sdram: 1024, constraint: None })
        }

        pub fn with_sdram(name: &str, sdram: u64) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), sdram, constraint: None })
        }

        pub fn constrained(name: &str, loc: CoreLocation) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), sdram: 1024, constraint: Some(loc) })
        }
    }

    impl MachineVertexImpl for TestVertex {
        fn label(&self) -> String {
            self.name.clone()
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements::with_sdram(self.sdram)
        }
        fn binary_name(&self) -> String {
            "test.aplx".into()
        }
        fn generate_data(&self, _ctx: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn placement_constraint(&self) -> Option<CoreLocation> {
            self.constraint
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::TestVertex;
    use super::*;

    #[test]
    fn add_vertices_and_edges() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let e = g.add_edge(a, b, DEFAULT_PARTITION);
        assert_eq!(g.n_vertices(), 2);
        assert_eq!(g.n_edges(), 1);
        assert_eq!(g.edge(e).pre, a);
        assert_eq!(g.edge(e).post, b);
        assert_eq!(g.partition_of_edge(e), DEFAULT_PARTITION);
    }

    #[test]
    fn partitions_group_edges_by_type() {
        // Figure 6(b): one vertex, two message types to two target sets.
        let mut g = MachineGraph::new();
        let src = g.add_vertex(TestVertex::arc("src"));
        let t1 = g.add_vertex(TestVertex::arc("t1"));
        let t2 = g.add_vertex(TestVertex::arc("t2"));
        let t3 = g.add_vertex(TestVertex::arc("t3"));
        g.add_edge(src, t1, "solid");
        g.add_edge(src, t2, "solid");
        g.add_edge(src, t2, "dashed");
        g.add_edge(src, t3, "dashed");
        assert_eq!(g.n_partitions(), 2);
        assert_eq!(g.partitions_of(src).count(), 2);
        let solid = g.partition(src, "solid").unwrap();
        assert_eq!(g.partition_targets(solid), vec![t1, t2]);
        let dashed = g.partition(src, "dashed").unwrap();
        assert_eq!(g.partition_targets(dashed), vec![t2, t3]);
    }

    #[test]
    fn incoming_outgoing() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, b, DEFAULT_PARTITION);
        g.add_edge(c, b, DEFAULT_PARTITION);
        assert_eq!(g.incoming_edges(b).len(), 2);
        assert_eq!(g.outgoing_edges(a).len(), 1);
        assert_eq!(g.incoming_edges(a).len(), 0);
    }

    #[test]
    fn partition_targets_dedup() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        g.add_edge(a, b, "p");
        let p = g.partition(a, "p").unwrap();
        assert_eq!(p.edges.len(), 2);
        assert_eq!(g.partition_targets(p), vec![b]);
    }

    #[test]
    #[should_panic]
    fn edge_to_unknown_vertex_panics() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        g.add_edge(a, VertexId(99), DEFAULT_PARTITION);
    }

    #[test]
    fn remove_vertex_tombstones_and_journals() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, b, "p");
        g.add_edge(b, c, "p");
        g.add_edge(c, a, "q");
        let rev = g.revision();
        g.remove_vertex(b).unwrap();
        assert!(!g.is_live(b));
        assert!(g.is_live(a) && g.is_live(c));
        assert_eq!(g.n_vertices(), 2);
        // Both edges touching b died with it; c->a survives.
        assert_eq!(g.n_edges(), 1);
        assert_eq!(g.partitions_of(a).count(), 0, "a's partition emptied out");
        assert_eq!(g.partitions_of(c).count(), 1);
        // Ids stay stable: c is still VertexId(2).
        assert_eq!(c, VertexId(2));
        assert_eq!(g.vertex(c).label(), "c");
        let s = g.journal().summary_since(rev);
        assert_eq!(s.vertices_removed, 1);
        assert_eq!(s.edges_removed, 2);
        // Double removal is an error, as is touching a dead vertex.
        assert!(g.remove_vertex(b).is_err());
        assert!(g.touch_vertex(b).is_err());
    }

    #[test]
    fn fingerprints_track_the_right_mutations() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let v0 = g.vertices_fingerprint();
        let p0 = g.partitions_fingerprint();
        let f0 = g.fingerprint();
        // Adding an edge changes topology + whole, not vertices.
        g.add_edge(a, b, "p");
        assert_eq!(g.vertices_fingerprint(), v0, "edge must not dirty placement");
        assert_ne!(g.partitions_fingerprint(), p0);
        assert_ne!(g.fingerprint(), f0);
        // Adding a vertex changes the vertex digest.
        g.add_vertex(TestVertex::arc("c"));
        assert_ne!(g.vertices_fingerprint(), v0);
        // Touch bumps the vertex digest without structural change.
        let v1 = g.vertices_fingerprint();
        g.touch_vertex(a).unwrap();
        assert_ne!(g.vertices_fingerprint(), v1);
        // Fingerprints are content functions: same build, same digests.
        let rebuild = || {
            let mut g2 = MachineGraph::new();
            let a2 = g2.add_vertex(TestVertex::arc("a"));
            let b2 = g2.add_vertex(TestVertex::arc("b"));
            g2.add_edge(a2, b2, "p");
            g2.fingerprint()
        };
        assert_eq!(rebuild(), rebuild());
    }

    #[test]
    fn remove_edge_alone() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let e1 = g.add_edge(a, b, "p");
        let e2 = g.add_edge(a, b, "p");
        g.remove_edge(e1).unwrap();
        assert_eq!(g.n_edges(), 1);
        let p = g.partition(a, "p").unwrap();
        assert_eq!(p.edges, vec![e2]);
        assert!(g.remove_edge(e1).is_err());
    }
}
