//! The machine graph: vertices that each fit one core, machine edges,
//! and outgoing edge partitions (Figure 6 a/b).

use std::collections::BTreeMap;
use std::sync::Arc;



use super::vertex::MachineVertexImpl;

/// Handle to a machine vertex within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VertexId(pub u32);

/// Handle to a machine edge within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EdgeId(pub u32);

/// Communication from `pre` to `post` (§5.2: "an edge represents some
/// communication that will take place from a source ... to a target").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachineEdge {
    pub pre: VertexId,
    pub post: VertexId,
}

/// All edges leaving one vertex under one message type / key-space
/// (Figure 6 b). Each partition gets its own multicast key range.
#[derive(Debug, Clone)]
pub struct OutgoingEdgePartition {
    pub pre: VertexId,
    pub id: String,
    pub edges: Vec<EdgeId>,
}

/// The default partition id used when callers don't need multiple
/// message types from one vertex.
pub const DEFAULT_PARTITION: &str = "default";

/// A machine graph (vertices + edges + partitions). Deterministic
/// iteration everywhere: mapping results must be reproducible.
#[derive(Default, Clone)]
pub struct MachineGraph {
    vertices: Vec<Arc<dyn MachineVertexImpl>>,
    edges: Vec<MachineEdge>,
    /// (pre, partition id) -> partition, insertion-ordered by BTreeMap.
    partitions: BTreeMap<(VertexId, String), OutgoingEdgePartition>,
    /// edge -> partition id (reverse index).
    edge_partition: Vec<String>,
}

impl MachineGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_vertex(&mut self, v: Arc<dyn MachineVertexImpl>) -> VertexId {
        let id = VertexId(self.vertices.len() as u32);
        self.vertices.push(v);
        id
    }

    /// Add an edge in the given outgoing edge partition of `pre`.
    pub fn add_edge(&mut self, pre: VertexId, post: VertexId, partition: &str) -> EdgeId {
        assert!((pre.0 as usize) < self.vertices.len(), "bad pre vertex");
        assert!((post.0 as usize) < self.vertices.len(), "bad post vertex");
        let eid = EdgeId(self.edges.len() as u32);
        self.edges.push(MachineEdge { pre, post });
        self.edge_partition.push(partition.to_string());
        self.partitions
            .entry((pre, partition.to_string()))
            .or_insert_with(|| OutgoingEdgePartition {
                pre,
                id: partition.to_string(),
                edges: Vec::new(),
            })
            .edges
            .push(eid);
        eid
    }

    pub fn vertex(&self, id: VertexId) -> &Arc<dyn MachineVertexImpl> {
        &self.vertices[id.0 as usize]
    }

    pub fn n_vertices(&self) -> usize {
        self.vertices.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn vertex_ids(&self) -> impl Iterator<Item = VertexId> {
        (0..self.vertices.len() as u32).map(VertexId)
    }

    pub fn vertices(&self) -> impl Iterator<Item = (VertexId, &Arc<dyn MachineVertexImpl>)> {
        self.vertices
            .iter()
            .enumerate()
            .map(|(i, v)| (VertexId(i as u32), v))
    }

    pub fn edge(&self, id: EdgeId) -> MachineEdge {
        self.edges[id.0 as usize]
    }

    pub fn edges(&self) -> impl Iterator<Item = (EdgeId, MachineEdge)> + '_ {
        self.edges
            .iter()
            .enumerate()
            .map(|(i, e)| (EdgeId(i as u32), *e))
    }

    pub fn partition_of_edge(&self, id: EdgeId) -> String {
        self.edge_partition[id.0 as usize].clone()
    }

    pub fn partitions(&self) -> impl Iterator<Item = &OutgoingEdgePartition> {
        self.partitions.values()
    }

    pub fn n_partitions(&self) -> usize {
        self.partitions.len()
    }

    /// Partitions leaving one vertex (§5.2: "there can be more than one
    /// outgoing edge partition for each source vertex").
    pub fn partitions_of(&self, v: VertexId) -> impl Iterator<Item = &OutgoingEdgePartition> {
        self.partitions
            .range((v, String::new())..=(v, "\u{10ffff}".to_string()))
            .map(|(_, p)| p)
    }

    pub fn partition(&self, pre: VertexId, id: &str) -> Option<&OutgoingEdgePartition> {
        self.partitions.get(&(pre, id.to_string()))
    }

    /// The target vertices of one partition (deduplicated, ordered).
    pub fn partition_targets(&self, p: &OutgoingEdgePartition) -> Vec<VertexId> {
        let mut targets: Vec<VertexId> =
            p.edges.iter().map(|e| self.edge(*e).post).collect();
        targets.sort();
        targets.dedup();
        targets
    }

    /// Edges arriving at `v`.
    pub fn incoming_edges(&self, v: VertexId) -> Vec<EdgeId> {
        self.edges()
            .filter(|(_, e)| e.post == v)
            .map(|(id, _)| id)
            .collect()
    }

    /// Edges leaving `v` (all partitions).
    pub fn outgoing_edges(&self, v: VertexId) -> Vec<EdgeId> {
        self.edges()
            .filter(|(_, e)| e.pre == v)
            .map(|(id, _)| id)
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    //! A minimal vertex for graph/mapping unit tests.
    use std::any::Any;
    use std::sync::Arc;

    use crate::graph::resources::ResourceRequirements;
    use crate::graph::vertex::{DataGenContext, DataRegion, MachineVertexImpl};
    use crate::machine::CoreLocation;

    #[derive(Debug)]
    pub struct TestVertex {
        pub name: String,
        pub sdram: u64,
        pub constraint: Option<CoreLocation>,
    }

    impl TestVertex {
        pub fn arc(name: &str) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), sdram: 1024, constraint: None })
        }

        pub fn with_sdram(name: &str, sdram: u64) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), sdram, constraint: None })
        }

        pub fn constrained(name: &str, loc: CoreLocation) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), sdram: 1024, constraint: Some(loc) })
        }
    }

    impl MachineVertexImpl for TestVertex {
        fn label(&self) -> String {
            self.name.clone()
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements::with_sdram(self.sdram)
        }
        fn binary_name(&self) -> String {
            "test.aplx".into()
        }
        fn generate_data(&self, _ctx: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn placement_constraint(&self) -> Option<CoreLocation> {
            self.constraint
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::TestVertex;
    use super::*;

    #[test]
    fn add_vertices_and_edges() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let e = g.add_edge(a, b, DEFAULT_PARTITION);
        assert_eq!(g.n_vertices(), 2);
        assert_eq!(g.n_edges(), 1);
        assert_eq!(g.edge(e).pre, a);
        assert_eq!(g.edge(e).post, b);
        assert_eq!(g.partition_of_edge(e), DEFAULT_PARTITION);
    }

    #[test]
    fn partitions_group_edges_by_type() {
        // Figure 6(b): one vertex, two message types to two target sets.
        let mut g = MachineGraph::new();
        let src = g.add_vertex(TestVertex::arc("src"));
        let t1 = g.add_vertex(TestVertex::arc("t1"));
        let t2 = g.add_vertex(TestVertex::arc("t2"));
        let t3 = g.add_vertex(TestVertex::arc("t3"));
        g.add_edge(src, t1, "solid");
        g.add_edge(src, t2, "solid");
        g.add_edge(src, t2, "dashed");
        g.add_edge(src, t3, "dashed");
        assert_eq!(g.n_partitions(), 2);
        assert_eq!(g.partitions_of(src).count(), 2);
        let solid = g.partition(src, "solid").unwrap();
        assert_eq!(g.partition_targets(solid), vec![t1, t2]);
        let dashed = g.partition(src, "dashed").unwrap();
        assert_eq!(g.partition_targets(dashed), vec![t2, t3]);
    }

    #[test]
    fn incoming_outgoing() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        let c = g.add_vertex(TestVertex::arc("c"));
        g.add_edge(a, b, DEFAULT_PARTITION);
        g.add_edge(c, b, DEFAULT_PARTITION);
        assert_eq!(g.incoming_edges(b).len(), 2);
        assert_eq!(g.outgoing_edges(a).len(), 1);
        assert_eq!(g.incoming_edges(a).len(), 0);
    }

    #[test]
    fn partition_targets_dedup() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        let b = g.add_vertex(TestVertex::arc("b"));
        g.add_edge(a, b, "p");
        g.add_edge(a, b, "p");
        let p = g.partition(a, "p").unwrap();
        assert_eq!(p.edges.len(), 2);
        assert_eq!(g.partition_targets(p), vec![b]);
    }

    #[test]
    #[should_panic]
    fn edge_to_unknown_vertex_panics() {
        let mut g = MachineGraph::new();
        let a = g.add_vertex(TestVertex::arc("a"));
        g.add_edge(a, VertexId(99), DEFAULT_PARTITION);
    }
}
