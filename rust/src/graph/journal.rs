//! The graph change journal: the §6.5 "graph changed" detector made
//! precise. Both graph levels carry one of these; every mutation bumps a
//! monotone revision and appends a typed delta, so the front end can ask
//! "what changed since the mapping at revision R?" and re-run only the
//! invalidated pipeline stages (DESIGN.md §7) instead of tearing the
//! whole run state down.
//!
//! Ids are stored raw (`u32`) so one journal type serves both
//! [`crate::graph::VertexId`] and [`crate::graph::AppVertexId`] spaces.

/// One recorded mutation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphDelta {
    VertexAdded(u32),
    VertexRemoved(u32),
    EdgeAdded(u32),
    EdgeRemoved(u32),
    /// The vertex's resources / generated data must be treated as
    /// changed (no structural delta). The vertex stays pinned if its new
    /// footprint still fits its chip (the incremental placer re-charges
    /// current resources); otherwise the re-map falls back to full.
    VertexTouched(u32),
}

/// Counts of each delta kind over a revision window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeltaSummary {
    pub vertices_added: usize,
    pub vertices_removed: usize,
    pub edges_added: usize,
    pub edges_removed: usize,
    pub vertices_touched: usize,
}

impl DeltaSummary {
    pub fn is_empty(&self) -> bool {
        *self == DeltaSummary::default()
    }
}

/// Monotone revision counter plus the typed delta log.
#[derive(Debug, Clone, Default)]
pub struct ChangeJournal {
    revision: u64,
    /// (revision the delta produced, what changed).
    deltas: Vec<(u64, GraphDelta)>,
}

impl ChangeJournal {
    pub fn new() -> Self {
        Self::default()
    }

    /// The current revision. `0` means "never mutated".
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Record one mutation, bumping the revision.
    pub fn record(&mut self, delta: GraphDelta) {
        self.revision += 1;
        self.deltas.push((self.revision, delta));
    }

    /// Deltas recorded strictly after `revision`, oldest first.
    pub fn deltas_since(&self, revision: u64) -> impl Iterator<Item = GraphDelta> + '_ {
        self.deltas
            .iter()
            .filter(move |(r, _)| *r > revision)
            .map(|(_, d)| *d)
    }

    /// Per-kind counts of the deltas strictly after `revision`.
    pub fn summary_since(&self, revision: u64) -> DeltaSummary {
        let mut s = DeltaSummary::default();
        for d in self.deltas_since(revision) {
            match d {
                GraphDelta::VertexAdded(_) => s.vertices_added += 1,
                GraphDelta::VertexRemoved(_) => s.vertices_removed += 1,
                GraphDelta::EdgeAdded(_) => s.edges_added += 1,
                GraphDelta::EdgeRemoved(_) => s.edges_removed += 1,
                GraphDelta::VertexTouched(_) => s.vertices_touched += 1,
            }
        }
        s
    }

    /// Number of logged deltas (all revisions).
    pub fn len(&self) -> usize {
        self.deltas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Drop the delta log. The revision counter is kept monotone so
    /// stale "since" markers held by callers can never alias a future
    /// revision; [`SpiNNTools::reset`](crate::front::SpiNNTools::reset)
    /// uses this to make a reset run provably from-scratch.
    pub fn clear(&mut self) {
        self.deltas.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_windows() {
        let mut j = ChangeJournal::new();
        assert_eq!(j.revision(), 0);
        j.record(GraphDelta::VertexAdded(0));
        j.record(GraphDelta::EdgeAdded(0));
        let at = j.revision();
        j.record(GraphDelta::VertexRemoved(0));
        assert_eq!(j.revision(), 3);
        assert_eq!(j.deltas_since(at).count(), 1);
        let s = j.summary_since(0);
        assert_eq!(s.vertices_added, 1);
        assert_eq!(s.edges_added, 1);
        assert_eq!(s.vertices_removed, 1);
        assert!(j.summary_since(3).is_empty());
    }

    #[test]
    fn clear_keeps_revision_monotone() {
        let mut j = ChangeJournal::new();
        j.record(GraphDelta::VertexAdded(7));
        let r = j.revision();
        j.clear();
        assert!(j.is_empty());
        assert_eq!(j.revision(), r);
        j.record(GraphDelta::VertexTouched(7));
        assert_eq!(j.revision(), r + 1);
        assert_eq!(j.summary_since(r).vertices_touched, 1);
    }
}
