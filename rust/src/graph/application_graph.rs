//! The application graph (Figure 6 c/d): vertices holding atoms, split
//! into machine vertices by the graph-partitioning step of mapping.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;

use super::journal::{ChangeJournal, GraphDelta};
use super::vertex::ApplicationVertexImpl;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AppVertexId(pub u32);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AppEdgeId(pub u32);

/// An application edge: communication between atom groups. `payload`
/// carries application-specific connectivity (e.g. the synaptic
/// connector of §7.2) that machine-vertex creation consumes.
#[derive(Clone)]
pub struct ApplicationEdge {
    pub pre: AppVertexId,
    pub post: AppVertexId,
    pub payload: Option<Arc<dyn Any + Send + Sync>>,
}

/// All edges leaving one application vertex under one message type.
#[derive(Debug, Clone)]
pub struct AppOutgoingPartition {
    pub pre: AppVertexId,
    pub id: String,
    pub edges: Vec<AppEdgeId>,
}

/// The application-level graph (§5.2). It is an error to mix application
/// and machine graphs in one run (§6.2) — the front end enforces that.
#[derive(Default, Clone)]
pub struct ApplicationGraph {
    vertices: Vec<Arc<dyn ApplicationVertexImpl>>,
    edges: Vec<ApplicationEdge>,
    partitions: BTreeMap<(AppVertexId, String), AppOutgoingPartition>,
    edge_partition: Vec<String>,
    journal: ChangeJournal,
}

impl ApplicationGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// The change journal. Application-graph deltas always force a full
    /// re-split + re-map (splitting is a global optimisation; there is
    /// no sound per-vertex pinning across it), so the front end only
    /// consults the revision, never the per-delta log.
    pub fn journal(&self) -> &ChangeJournal {
        &self.journal
    }

    pub fn revision(&self) -> u64 {
        self.journal.revision()
    }

    pub fn clear_journal(&mut self) {
        self.journal.clear();
    }

    /// FNV-1a digest over the canonical content (labels, atom counts,
    /// edges and their partitions).
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::FNV_OFFSET;
        let mut put = |bytes: &[u8]| crate::util::fnv1a_64_extend(&mut h, bytes);
        for (vid, vertex) in self.vertices() {
            put(&vid.0.to_le_bytes());
            put(vertex.label().as_bytes());
            put(&vertex.n_atoms().to_le_bytes());
            put(&vertex.max_atoms_per_core().to_le_bytes());
        }
        for (eid, e) in self.edges() {
            put(&eid.0.to_le_bytes());
            put(&e.pre.0.to_le_bytes());
            put(&e.post.0.to_le_bytes());
            put(self.partition_of_edge(eid).as_bytes());
        }
        h
    }

    pub fn add_vertex(&mut self, v: Arc<dyn ApplicationVertexImpl>) -> AppVertexId {
        let id = AppVertexId(self.vertices.len() as u32);
        self.vertices.push(v);
        self.journal.record(GraphDelta::VertexAdded(id.0));
        id
    }

    pub fn add_edge(
        &mut self,
        pre: AppVertexId,
        post: AppVertexId,
        partition: &str,
        payload: Option<Arc<dyn Any + Send + Sync>>,
    ) -> AppEdgeId {
        assert!((pre.0 as usize) < self.vertices.len(), "bad pre vertex");
        assert!((post.0 as usize) < self.vertices.len(), "bad post vertex");
        let id = AppEdgeId(self.edges.len() as u32);
        self.edges.push(ApplicationEdge { pre, post, payload });
        self.edge_partition.push(partition.to_string());
        self.partitions
            .entry((pre, partition.to_string()))
            .or_insert_with(|| AppOutgoingPartition {
                pre,
                id: partition.to_string(),
                edges: Vec::new(),
            })
            .edges
            .push(id);
        self.journal.record(GraphDelta::EdgeAdded(id.0));
        id
    }

    pub fn vertex(&self, id: AppVertexId) -> &Arc<dyn ApplicationVertexImpl> {
        &self.vertices[id.0 as usize]
    }

    pub fn n_vertices(&self) -> usize {
        self.vertices.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn vertices(&self) -> impl Iterator<Item = (AppVertexId, &Arc<dyn ApplicationVertexImpl>)> {
        self.vertices
            .iter()
            .enumerate()
            .map(|(i, v)| (AppVertexId(i as u32), v))
    }

    pub fn edge(&self, id: AppEdgeId) -> &ApplicationEdge {
        &self.edges[id.0 as usize]
    }

    pub fn edges(&self) -> impl Iterator<Item = (AppEdgeId, &ApplicationEdge)> {
        self.edges
            .iter()
            .enumerate()
            .map(|(i, e)| (AppEdgeId(i as u32), e))
    }

    pub fn partition_of_edge(&self, id: AppEdgeId) -> &str {
        &self.edge_partition[id.0 as usize]
    }

    pub fn partitions(&self) -> impl Iterator<Item = &AppOutgoingPartition> {
        self.partitions.values()
    }

    /// Total atoms across all vertices (used for machine sizing, §6.3.1).
    pub fn total_atoms(&self) -> u64 {
        self.vertices.iter().map(|v| v.n_atoms() as u64).sum()
    }

    pub fn incoming_edges(&self, v: AppVertexId) -> Vec<AppEdgeId> {
        self.edges()
            .filter(|(_, e)| e.post == v)
            .map(|(id, _)| id)
            .collect()
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use std::any::Any;
    use std::sync::Arc;

    use crate::graph::resources::ResourceRequirements;
    use crate::graph::vertex::{
        ApplicationVertexImpl, DataGenContext, DataRegion, MachineVertexImpl, Slice,
    };

    /// An app vertex whose machine vertices are plain test vertices, with
    /// per-atom SDRAM cost so splitting decisions are observable.
    #[derive(Debug)]
    pub struct TestAppVertex {
        pub name: String,
        pub atoms: u32,
        pub max_per_core: u32,
        pub sdram_per_atom: u64,
    }

    impl TestAppVertex {
        pub fn arc(name: &str, atoms: u32, max_per_core: u32) -> Arc<dyn ApplicationVertexImpl> {
            Arc::new(Self {
                name: name.into(),
                atoms,
                max_per_core,
                sdram_per_atom: 100,
            })
        }
    }

    #[derive(Debug)]
    pub struct TestAppMachineVertex {
        pub name: String,
        pub slice: Slice,
        pub sdram: u64,
    }

    impl MachineVertexImpl for TestAppMachineVertex {
        fn label(&self) -> String {
            format!("{}{}", self.name, self.slice)
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements::with_sdram(self.sdram)
        }
        fn binary_name(&self) -> String {
            "test.aplx".into()
        }
        fn generate_data(&self, _ctx: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    impl ApplicationVertexImpl for TestAppVertex {
        fn label(&self) -> String {
            self.name.clone()
        }
        fn n_atoms(&self) -> u32 {
            self.atoms
        }
        fn max_atoms_per_core(&self) -> u32 {
            self.max_per_core
        }
        fn resources_for(&self, slice: Slice) -> ResourceRequirements {
            ResourceRequirements::with_sdram(self.sdram_per_atom * slice.n_atoms() as u64)
        }
        fn create_machine_vertex(&self, slice: Slice) -> Arc<dyn MachineVertexImpl> {
            Arc::new(TestAppMachineVertex {
                name: self.name.clone(),
                slice,
                sdram: self.sdram_per_atom * slice.n_atoms() as u64,
            })
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::TestAppVertex;
    use super::*;

    #[test]
    fn build_application_graph() {
        let mut g = ApplicationGraph::new();
        let a = g.add_vertex(TestAppVertex::arc("a", 100, 10));
        let b = g.add_vertex(TestAppVertex::arc("b", 50, 25));
        let e = g.add_edge(a, b, "spikes", None);
        assert_eq!(g.n_vertices(), 2);
        assert_eq!(g.total_atoms(), 150);
        assert_eq!(g.partition_of_edge(e), "spikes");
        assert_eq!(g.incoming_edges(b), vec![e]);
    }

    #[test]
    fn payload_downcasts() {
        let mut g = ApplicationGraph::new();
        let a = g.add_vertex(TestAppVertex::arc("a", 1, 1));
        let e = g.add_edge(a, a, "loop", Some(Arc::new(42u64)));
        let payload = g.edge(e).payload.as_ref().unwrap();
        assert_eq!(*payload.downcast_ref::<u64>().unwrap(), 42);
    }
}
