//! Graph data structures (§5.2, Figures 6 & 7).
//!
//! Two graph levels, exactly as the paper defines them:
//!
//! - a [`MachineGraph`] of [`MachineVertexImpl`]s, each guaranteed to fit
//!   one SpiNNaker core, connected by machine edges grouped into
//!   *outgoing edge partitions* (one multicast key-space per partition);
//! - an [`ApplicationGraph`] of [`ApplicationVertexImpl`]s holding
//!   `n_atoms` atomic units of computation each, split by the mapping
//!   layer ([`crate::mapping::splitter`]) into machine vertices over
//!   contiguous atom [`Slice`]s.
//!
//! Vertices are trait objects: applications (see [`crate::apps`]) extend
//! the vertex types with their own resource models, data generation and
//! recording behaviour, mirroring how users subclass the Python classes.

pub mod application_graph;
pub mod journal;
pub mod machine_graph;
pub mod resources;
pub mod vertex;

pub use application_graph::{
    AppEdgeId, AppOutgoingPartition, AppVertexId, ApplicationEdge, ApplicationGraph,
};
pub use journal::{ChangeJournal, DeltaSummary, GraphDelta};
pub use machine_graph::{
    EdgeId, MachineEdge, MachineGraph, OutgoingEdgePartition, VertexId, DEFAULT_PARTITION,
};
pub use resources::{IpTagRequest, ResourceRequirements, ReverseIpTagRequest};
pub use vertex::{
    AllocatedIpTag, AllocatedReverseIpTag, ApplicationVertexImpl, DataGenContext, DataRegion,
    KeyRange, MachineVertexImpl, Slice, VirtualLink, WrappedMachineVertex,
};
