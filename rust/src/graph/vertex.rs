//! Vertex traits and the data-generation context.
//!
//! Applications extend [`MachineVertexImpl`] / [`ApplicationVertexImpl`]
//! the way users subclass the Python vertex classes (§6.2): a vertex
//! declares its resources, its binary, how to generate its SDRAM data
//! from the mapping results, and its recording behaviour.

use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;



use crate::machine::{ChipCoord, CoreLocation, Direction};

use super::machine_graph::{MachineGraph, VertexId};
use super::resources::ResourceRequirements;

/// A contiguous range of atoms `[lo, hi)` of an application vertex.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Slice {
    pub lo: u32,
    pub hi: u32,
}

impl Slice {
    pub fn new(lo: u32, hi: u32) -> Self {
        assert!(lo < hi, "empty slice {lo}..{hi}");
        Self { lo, hi }
    }

    pub fn n_atoms(&self) -> u32 {
        self.hi - self.lo
    }

    pub fn contains(&self, atom: u32) -> bool {
        (self.lo..self.hi).contains(&atom)
    }

    /// Whole-vertex slice.
    pub fn all(n_atoms: u32) -> Self {
        Self::new(0, n_atoms)
    }
}

impl std::fmt::Display for Slice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}:{})", self.lo, self.hi)
    }
}

/// A multicast key allocation for one outgoing edge partition: keys
/// `base ..= base | !mask`, one per atom (key of atom i = base + i).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeyRange {
    pub base: u32,
    pub mask: u32,
}

impl KeyRange {
    pub fn new(base: u32, mask: u32) -> Self {
        debug_assert_eq!(base & !mask, 0, "base has bits outside the mask");
        Self { base, mask }
    }

    pub fn n_keys(&self) -> u64 {
        (!self.mask) as u64 + 1
    }

    pub fn key_for_atom(&self, atom: u32) -> u32 {
        debug_assert!((atom as u64) < self.n_keys());
        self.base | atom
    }

    pub fn contains(&self, key: u32) -> bool {
        key & self.mask == self.base
    }

    pub fn atom_for_key(&self, key: u32) -> u32 {
        key & !self.mask
    }
}

/// Where a virtual (device) vertex hangs off the machine (§5.1, §7.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VirtualLink {
    /// The real chip the device's wire is plugged into.
    pub attached_to: ChipCoord,
    /// The link direction (from the real chip) the device sits on.
    pub direction: Direction,
}

/// One region of SDRAM data produced by data generation (§6.3.3). The
/// region table (id -> offset) is written by the loader; the C-side
/// library equivalent reads regions by id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DataRegion {
    pub id: u32,
    pub data: Vec<u8>,
}

/// An IP tag after allocation (mapping output).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllocatedIpTag {
    pub board: ChipCoord,
    pub tag: u8,
    pub host: String,
    pub port: u16,
    pub strip_sdp: bool,
}

/// A reverse IP tag after allocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllocatedReverseIpTag {
    pub board: ChipCoord,
    pub tag: u8,
    pub port: u16,
    pub destination: CoreLocation,
}

/// Everything data generation may consult (§6.3.3: "this can make use of
/// the mapping information ... for example, the routing keys and IP tags
/// allocated to the vertex").
pub struct DataGenContext<'a> {
    pub vertex: VertexId,
    pub placement: CoreLocation,
    pub timestep_us: u32,
    pub graph: &'a MachineGraph,
    pub placements: &'a BTreeMap<VertexId, CoreLocation>,
    /// (vertex, partition id) -> allocated key range.
    pub keys: &'a BTreeMap<(VertexId, String), KeyRange>,
    /// (vertex, tag label) -> allocated IP tag.
    pub iptags: &'a BTreeMap<(VertexId, String), AllocatedIpTag>,
    pub reverse_iptags: &'a BTreeMap<(VertexId, String), AllocatedReverseIpTag>,
    /// Present when the machine graph came from an application graph:
    /// lets data generation consult atom-level structures (e.g. the
    /// synaptic connectors on application edges, §7.2).
    pub app_graph: Option<&'a super::application_graph::ApplicationGraph>,
    pub graph_mapping: Option<&'a crate::mapping::splitter::GraphMapping>,
}

impl<'a> DataGenContext<'a> {
    /// The key range this vertex sends on, for one of its partitions.
    pub fn outgoing_key(&self, partition: &str) -> Option<KeyRange> {
        self.keys.get(&(self.vertex, partition.to_string())).copied()
    }

    /// All (pre-vertex, partition, keys) triples this vertex receives.
    pub fn incoming_keys(&self) -> Vec<(VertexId, String, KeyRange)> {
        let mut out = Vec::new();
        for (edge_id, edge) in self.graph.edges() {
            if edge.post != self.vertex {
                continue;
            }
            let partition = self.graph.partition_of_edge(edge_id);
            if let Some(kr) = self.keys.get(&(edge.pre, partition.clone())) {
                out.push((edge.pre, partition, *kr));
            }
        }
        out.sort_by_key(|(v, p, _)| (*v, p.clone()));
        out.dedup();
        out
    }

    pub fn iptag(&self, label: &str) -> Option<&AllocatedIpTag> {
        self.iptags.get(&(self.vertex, label.to_string()))
    }

    pub fn reverse_iptag(&self, label: &str) -> Option<&AllocatedReverseIpTag> {
        self.reverse_iptags.get(&(self.vertex, label.to_string()))
    }
}

/// A unit of computation guaranteed to fit one core (§5.2).
pub trait MachineVertexImpl: Send + Sync + std::fmt::Debug {
    fn label(&self) -> String;

    /// What this vertex needs from its core (checked by the placer).
    fn resources(&self) -> ResourceRequirements;

    /// The application binary this vertex runs. At load time the
    /// simulator resolves this through [`crate::apps::AppRegistry`] —
    /// the moral equivalent of the `.aplx` file name.
    fn binary_name(&self) -> String;

    /// Produce the SDRAM data regions for this vertex (§6.3.3).
    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion>;

    /// How many distinct multicast keys this vertex sends on the given
    /// outgoing partition (one per atom for split application vertices;
    /// 1 for simple machine vertices). Key allocation rounds this up to
    /// a power of two.
    fn n_keys_for_partition(&self, partition: &str) -> u32 {
        let _ = partition;
        1
    }

    /// If this vertex records: how many timesteps fit into `bytes` of
    /// recording SDRAM (Figure 9's "asked for the number of time steps
    /// it can be run for before filling up the SDRAM").
    fn steps_per_recording_space(&self, bytes: u64) -> Option<u64> {
        let _ = bytes;
        None
    }

    /// Minimum recording space this vertex insists on reserving.
    fn min_recording_bytes(&self) -> u64 {
        0
    }

    /// Fix this vertex to a specific core (placement constraint), e.g.
    /// gatherer vertices that must sit on an Ethernet chip.
    fn placement_constraint(&self) -> Option<CoreLocation> {
        None
    }

    /// Constrain this vertex to some chip (softer than a core constraint).
    fn chip_constraint(&self) -> Option<ChipCoord> {
        None
    }

    /// Non-None marks this as a virtual (device) vertex: it is "placed"
    /// on a virtual chip and nothing is loaded for it (§5.1, §7.2).
    fn virtual_link(&self) -> Option<VirtualLink> {
        None
    }

    fn as_any(&self) -> &dyn Any;
}

/// A group of `n_atoms` atomic computation units, splittable across
/// cores (§5.2).
pub trait ApplicationVertexImpl: Send + Sync + std::fmt::Debug {
    fn label(&self) -> String;

    fn n_atoms(&self) -> u32;

    /// The most atoms the binary can handle on one core (may be
    /// effectively unlimited).
    fn max_atoms_per_core(&self) -> u32 {
        u32::MAX
    }

    /// Resources for a contiguous slice of atoms — slice-specific, so
    /// heterogeneous atoms can cost differently.
    fn resources_for(&self, slice: Slice) -> ResourceRequirements;

    /// Create the machine vertex covering `slice`.
    fn create_machine_vertex(&self, slice: Slice) -> Arc<dyn MachineVertexImpl>;

    fn virtual_link(&self) -> Option<VirtualLink> {
        None
    }

    fn as_any(&self) -> &dyn Any;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_basics() {
        let s = Slice::new(10, 20);
        assert_eq!(s.n_atoms(), 10);
        assert!(s.contains(10) && s.contains(19) && !s.contains(20));
        assert_eq!(Slice::all(5), Slice::new(0, 5));
    }

    #[test]
    #[should_panic]
    fn empty_slice_panics() {
        Slice::new(5, 5);
    }

    #[test]
    fn key_range_math() {
        let kr = KeyRange::new(0x1000, 0xffff_ff00);
        assert_eq!(kr.n_keys(), 256);
        assert_eq!(kr.key_for_atom(0), 0x1000);
        assert_eq!(kr.key_for_atom(255), 0x10ff);
        assert!(kr.contains(0x10ab));
        assert!(!kr.contains(0x1100));
        assert_eq!(kr.atom_for_key(0x10ab), 0xab);
    }
}

/// Adapter implementing the paper's §8 future-work item: "allow an
/// application graph to contain machine vertices, which are then simply
/// copied to the machine graph during the conversion" — so utility
/// vertices like the Live Packet Gatherer don't need dual app/machine
/// implementations.
#[derive(Debug)]
pub struct WrappedMachineVertex {
    inner: Arc<dyn MachineVertexImpl>,
}

impl WrappedMachineVertex {
    pub fn arc(inner: Arc<dyn MachineVertexImpl>) -> Arc<dyn ApplicationVertexImpl> {
        Arc::new(Self { inner })
    }
}

impl ApplicationVertexImpl for WrappedMachineVertex {
    fn label(&self) -> String {
        self.inner.label()
    }

    fn n_atoms(&self) -> u32 {
        1
    }

    fn max_atoms_per_core(&self) -> u32 {
        1
    }

    fn resources_for(&self, _slice: Slice) -> crate::graph::ResourceRequirements {
        self.inner.resources()
    }

    /// "Simply copied to the machine graph during the conversion."
    fn create_machine_vertex(&self, slice: Slice) -> Arc<dyn MachineVertexImpl> {
        debug_assert_eq!(slice, Slice::all(1));
        self.inner.clone()
    }

    fn virtual_link(&self) -> Option<VirtualLink> {
        self.inner.virtual_link()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}
