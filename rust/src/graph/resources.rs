//! Resource requirement descriptions (§5.2: vertices "communicate their
//! resource requirements, in terms of the amount of DTCM and SDRAM ...
//! the number of CPU cycles ... and any IP Tags or Reverse IP Tags").



/// A request for an outbound IP tag on the Ethernet chip (§3): traffic
/// tagged with the allocated tag id is forwarded to `host:port`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IpTagRequest {
    pub host: String,
    pub port: u16,
    /// Strip the SDP header before forwarding (the fast data-extraction
    /// protocol of §6.8 uses this).
    pub strip_sdp: bool,
    /// Label used to find the allocated tag at data-generation time.
    pub label: String,
}

/// A request for a reverse IP tag: UDP arriving on `port` at the board's
/// Ethernet is forwarded to the requesting core as SDP.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReverseIpTagRequest {
    pub port: u16,
    pub label: String,
}

/// What one machine vertex needs from the core it is placed on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResourceRequirements {
    /// Core-local data memory: locally-scoped data + stack (64 KiB cap).
    pub dtcm_bytes: u32,
    /// Instruction memory (32 KiB cap) — the compiled binary size.
    pub itcm_bytes: u32,
    /// Node-local SDRAM, *excluding* recording space (which the buffer
    /// manager sizes separately per Figure 9).
    pub sdram_bytes: u64,
    /// CPU cycles needed per simulation timestep.
    pub cpu_cycles_per_step: u64,
    pub iptags: Vec<IpTagRequest>,
    pub reverse_iptags: Vec<ReverseIpTagRequest>,
}

impl ResourceRequirements {
    pub fn with_sdram(sdram_bytes: u64) -> Self {
        Self { sdram_bytes, ..Default::default() }
    }

    /// Component-wise sum (used when accounting chip totals).
    pub fn add(&mut self, other: &ResourceRequirements) {
        self.dtcm_bytes += other.dtcm_bytes;
        self.itcm_bytes += other.itcm_bytes;
        self.sdram_bytes += other.sdram_bytes;
        self.cpu_cycles_per_step += other.cpu_cycles_per_step;
        self.iptags.extend(other.iptags.iter().cloned());
        self.reverse_iptags.extend(other.reverse_iptags.iter().cloned());
    }

    /// Whether a single core can host this requirement at all.
    pub fn fits_core(&self, dtcm_cap: u32, itcm_cap: u32, cycles_cap: u64) -> bool {
        self.dtcm_bytes <= dtcm_cap
            && self.itcm_bytes <= itcm_cap
            && (self.cpu_cycles_per_step == 0 || self.cpu_cycles_per_step <= cycles_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates() {
        let mut a = ResourceRequirements::with_sdram(100);
        a.dtcm_bytes = 10;
        let mut b = ResourceRequirements::with_sdram(50);
        b.iptags.push(IpTagRequest {
            host: "h".into(),
            port: 1,
            strip_sdp: false,
            label: "t".into(),
        });
        a.add(&b);
        assert_eq!(a.sdram_bytes, 150);
        assert_eq!(a.dtcm_bytes, 10);
        assert_eq!(a.iptags.len(), 1);
    }

    #[test]
    fn fits_core_checks_all_axes() {
        let mut r = ResourceRequirements::default();
        r.dtcm_bytes = 64 * 1024;
        r.itcm_bytes = 32 * 1024;
        r.cpu_cycles_per_step = 200_000;
        assert!(r.fits_core(64 * 1024, 32 * 1024, 200_000));
        assert!(!r.fits_core(64 * 1024 - 1, 32 * 1024, 200_000));
        assert!(!r.fits_core(64 * 1024, 32 * 1024, 199_999));
        r.cpu_cycles_per_step = 0; // "no timing constraint"
        assert!(r.fits_core(64 * 1024, 32 * 1024, 1));
    }
}
