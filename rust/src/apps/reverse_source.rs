//! The Reverse IP Tag Multicast Source (§6.9, Figure 12): external
//! applications send EIEIO-over-UDP to a board port; this vertex decodes
//! the events and multicasts them into the machine, reaching whatever
//! vertices the user connected with graph edges.

use std::any::Any;
use std::sync::Arc;

use crate::graph::{
    DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements, ReverseIpTagRequest,
};
use crate::simulator::{CoreApp, CoreCtx};
use crate::transport::{EieioMessage, SdpMessage};
use crate::util::bytes::{ByteReader, ByteWriter};

pub const BINARY: &str = "reverse_iptag_source.aplx";
pub const RTAG_LABEL: &str = "rts";
pub const OUT_PARTITION: &str = "out";
const REGION_CONFIG: u32 = 0;

/// The RIPTMS vertex: external events on `udp_port` become multicast
/// packets with this vertex's allocated keys (base + event id).
#[derive(Debug)]
pub struct ReverseIpTagSourceVertex {
    pub label: String,
    pub udp_port: u16,
    /// Number of distinct event ids the external source may send.
    pub n_keys: u32,
}

impl ReverseIpTagSourceVertex {
    pub fn arc(label: &str, udp_port: u16, n_keys: u32) -> Arc<dyn MachineVertexImpl> {
        Arc::new(Self { label: label.into(), udp_port, n_keys })
    }
}

impl MachineVertexImpl for ReverseIpTagSourceVertex {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: 8 * 1024,
            itcm_bytes: 8 * 1024,
            sdram_bytes: 512,
            reverse_iptags: vec![ReverseIpTagRequest {
                port: self.udp_port,
                label: RTAG_LABEL.into(),
            }],
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        BINARY.into()
    }

    fn n_keys_for_partition(&self, _partition: &str) -> u32 {
        self.n_keys
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        let key = ctx.outgoing_key(OUT_PARTITION);
        let mut w = ByteWriter::new();
        w.u32(key.map(|k| k.base).unwrap_or(u32::MAX));
        w.u32(key.map(|k| k.mask).unwrap_or(0));
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The RIPTMS binary.
pub struct ReverseIpTagSourceApp {
    key_base: u32,
    key_mask: u32,
}

impl ReverseIpTagSourceApp {
    pub fn new() -> Self {
        Self { key_base: u32::MAX, key_mask: 0 }
    }
}

impl Default for ReverseIpTagSourceApp {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreApp for ReverseIpTagSourceApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        let mut r = ByteReader::new(&config);
        self.key_base = r.u32()?;
        self.key_mask = r.u32()?;
        Ok(())
    }

    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn on_sdp(&mut self, msg: &SdpMessage, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let eieio = EieioMessage::decode(&msg.data)?;
        for (event, payload) in eieio.events {
            // External apps send event ids; keys come from our range.
            let key = self.key_base | (event & !self.key_mask);
            ctx.send_mc(key, payload);
            ctx.count("events_injected", 1);
        }
        Ok(())
    }
}
