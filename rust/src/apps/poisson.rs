//! The Poisson spike source (§7.2): "generate spikes randomly with a
//! given rate using a Poisson process". The Bernoulli thinning runs in
//! the AOT `poisson_step_n256` artifact; the RNG stream (like the
//! on-core RNG state of the real binary) lives in the app.

use std::any::Any;
use std::rc::Rc;
use std::sync::Arc;

use crate::graph::{
    ApplicationVertexImpl, DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements,
    Slice,
};
use crate::runtime::{HostTensor, Runtime};
use crate::simulator::{CoreApp, CoreCtx};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::SplitMix64;

pub const BINARY: &str = "poisson_source.aplx";
pub const SPIKES_PARTITION: &str = "spikes";
pub const SPIKES_CHANNEL: u32 = 0;
const REGION_CONFIG: u32 = 0;
const PAD: u32 = 256; // single compiled artifact size

/// A population of independent Poisson spike generators.
#[derive(Debug)]
pub struct PoissonSourceVertex {
    pub label: String,
    pub n_sources: u32,
    pub rate_hz: f32,
    pub seed: u64,
    pub record_spikes: bool,
}

impl PoissonSourceVertex {
    pub fn arc(
        label: &str,
        n_sources: u32,
        rate_hz: f32,
        seed: u64,
        record_spikes: bool,
    ) -> Arc<dyn ApplicationVertexImpl> {
        Arc::new(Self {
            label: label.into(),
            n_sources,
            rate_hz,
            seed,
            record_spikes,
        })
    }
}

impl ApplicationVertexImpl for PoissonSourceVertex {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn n_atoms(&self) -> u32 {
        self.n_sources
    }

    fn max_atoms_per_core(&self) -> u32 {
        PAD
    }

    fn resources_for(&self, slice: Slice) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: slice.n_atoms() * 8 + 1024,
            itcm_bytes: 8 * 1024,
            sdram_bytes: 1024,
            cpu_cycles_per_step: slice.n_atoms() as u64 * 40 + 2_000,
            ..Default::default()
        }
    }

    fn create_machine_vertex(&self, slice: Slice) -> Arc<dyn MachineVertexImpl> {
        Arc::new(PoissonMachineVertex {
            label: format!("{}{}", self.label, slice),
            slice,
            rate_hz: self.rate_hz,
            // distinct stream per slice, deterministic per vertex
            seed: self.seed ^ ((slice.lo as u64) << 20),
            record_spikes: self.record_spikes,
        })
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[derive(Debug)]
pub struct PoissonMachineVertex {
    pub label: String,
    pub slice: Slice,
    pub rate_hz: f32,
    pub seed: u64,
    pub record_spikes: bool,
}

impl MachineVertexImpl for PoissonMachineVertex {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: self.slice.n_atoms() * 8 + 1024,
            itcm_bytes: 8 * 1024,
            sdram_bytes: 1024,
            cpu_cycles_per_step: self.slice.n_atoms() as u64 * 40 + 2_000,
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        BINARY.into()
    }

    fn n_keys_for_partition(&self, _partition: &str) -> u32 {
        self.slice.n_atoms()
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        let key_base = ctx
            .outgoing_key(SPIKES_PARTITION)
            .map(|k| k.base)
            .unwrap_or(u32::MAX);
        let rate_per_step = self.rate_hz * ctx.timestep_us as f32 / 1_000_000.0;
        let mut w = ByteWriter::new();
        w.u32(self.slice.n_atoms());
        w.u32(key_base);
        w.f32(rate_per_step);
        w.u64(self.seed);
        w.u32(self.record_spikes as u32);
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn steps_per_recording_space(&self, bytes: u64) -> Option<u64> {
        self.record_spikes
            .then(|| bytes / ((self.slice.n_atoms() as u64).div_ceil(32) * 4))
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The source binary.
pub struct PoissonSourceApp {
    runtime: Rc<Runtime>,
    n: u32,
    key_base: u32,
    rate_per_step: f32,
    rng: SplitMix64,
    record: bool,
}

impl PoissonSourceApp {
    pub fn new(runtime: Rc<Runtime>) -> Self {
        Self {
            runtime,
            n: 0,
            key_base: u32::MAX,
            rate_per_step: 0.0,
            rng: SplitMix64::new(0),
            record: false,
        }
    }
}

impl CoreApp for PoissonSourceApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        let mut r = ByteReader::new(&config);
        self.n = r.u32()?;
        self.key_base = r.u32()?;
        self.rate_per_step = r.f32()?;
        self.rng = SplitMix64::new(r.u64()?);
        self.record = r.u32()? != 0;
        Ok(())
    }

    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        // Draw uniforms on-core, thin in the AOT kernel.
        let unif: Vec<f32> = (0..PAD).map(|_| self.rng.next_f32()).collect();
        let out = self.runtime.exec(
            "poisson_step_n256",
            &[HostTensor::F32(unif), HostTensor::ScalarF32(self.rate_per_step)],
        )?;
        let spikes = out.into_iter().next().unwrap().into_f32()?;
        let words = (self.n as usize).div_ceil(32);
        let mut bitmap = vec![0u32; words];
        for atom in 0..self.n {
            if spikes[atom as usize] != 0.0 {
                if self.key_base != u32::MAX {
                    ctx.send_mc(self.key_base + atom, None);
                }
                bitmap[(atom / 32) as usize] |= 1 << (atom % 32);
                ctx.count("spikes_out", 1);
            }
        }
        if self.record {
            let mut bytes = Vec::with_capacity(words * 4);
            for w in &bitmap {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            ctx.record(SPIKES_CHANNEL, &bytes);
        }
        Ok(())
    }

    fn snapshot_state(&self) -> Option<Vec<u8>> {
        // Config is re-read by `on_start`; the only evolving state is
        // the RNG position in its stream.
        let mut w = ByteWriter::new();
        w.u64(self.rng.state());
        Some(w.finish())
    }

    fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        self.rng = SplitMix64::new(r.u64()?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::MachineGraph;
    use crate::mapping::{keys, placer};
    use crate::machine::MachineBuilder;

    #[test]
    fn slice_seeds_differ() {
        let v = PoissonSourceVertex {
            label: "p".into(),
            n_sources: 600,
            rate_hz: 10.0,
            seed: 99,
            record_spikes: false,
        };
        let a = v.create_machine_vertex(Slice::new(0, 256));
        let b = v.create_machine_vertex(Slice::new(256, 512));
        let pa = a.as_any().downcast_ref::<PoissonMachineVertex>().unwrap();
        let pb = b.as_any().downcast_ref::<PoissonMachineVertex>().unwrap();
        assert_ne!(pa.seed, pb.seed);
    }

    #[test]
    fn data_region_encodes_rate_per_step() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let v = g.add_vertex(Arc::new(PoissonMachineVertex {
            label: "p".into(),
            slice: Slice::new(0, 100),
            rate_hz: 50.0,
            seed: 1,
            record_spikes: true,
        }));
        // a second vertex so the partition exists
        let t = g.add_vertex(crate::graph::machine_graph::test_support::TestVertex::arc("t"));
        g.add_edge(v, t, SPIKES_PARTITION);
        let p = placer::place(&m, &g).unwrap();
        let k = keys::allocate_keys(&g).unwrap();
        let placements: std::collections::BTreeMap<_, _> = p.iter().collect();
        let ctx = DataGenContext {
            vertex: v,
            placement: p.of(v).unwrap(),
            timestep_us: 1000,
            graph: &g,
            placements: &placements,
            keys: &k,
            iptags: &Default::default(),
            reverse_iptags: &Default::default(),
            app_graph: None,
            graph_mapping: None,
        };
        let regions = g.vertex(v).generate_data(&ctx);
        let mut r = ByteReader::new(&regions[0].data);
        assert_eq!(r.u32().unwrap(), 100);
        let key = r.u32().unwrap();
        assert_eq!(key, k[&(v, SPIKES_PARTITION.to_string())].base);
        let rate = r.f32().unwrap();
        assert!((rate - 0.05).abs() < 1e-6, "50 Hz at 1 ms = 0.05/step");
    }
}
