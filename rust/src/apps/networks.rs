//! Workload builders for the paper's two use cases (§7), shared by the
//! examples, the benches and the integration tests.

use std::collections::BTreeMap;

use crate::front::SpiNNTools;
use crate::graph::{AppVertexId, ApplicationGraph, MachineGraph, VertexId};
use crate::machine::Machine;

use super::conway::{ConwayCellVertex, STATE_PARTITION};
use super::neuron::{Connector, LifParams, LifPopulationVertex, SynapseSpec, SPIKES_PARTITION};
use super::poisson::PoissonSourceVertex;

/// Build the §7.1 Conway machine graph: an `rows x cols` grid of cell
/// vertices, each bidirectionally connected to its 8 neighbours
/// (Figure 13). Returns vertex ids in row-major order.
pub fn build_conway_grid(
    tools: &mut SpiNNTools,
    rows: u32,
    cols: u32,
    live: &[(u32, u32)],
) -> anyhow::Result<Vec<VertexId>> {
    let mut ids = Vec::with_capacity((rows * cols) as usize);
    for r in 0..rows {
        for c in 0..cols {
            let alive = live.contains(&(r, c));
            ids.push(tools.add_machine_vertex(ConwayCellVertex::arc(r, c, alive))?);
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < rows as i64 && c < cols as i64)
            .then_some((r * cols as i64 + c) as usize)
    };
    for r in 0..rows as i64 {
        for c in 0..cols as i64 {
            for dr in -1..=1i64 {
                for dc in -1..=1i64 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        tools.add_machine_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION)?;
                    }
                }
            }
        }
    }
    Ok(ids)
}

/// The §7.1 grid as a *bare* machine graph — no [`SpiNNTools`] — for
/// mapping-only benches and tests: one cell vertex per grid square
/// (liveness chosen by `alive`), each bidirectionally connected to its
/// 8 neighbours in [`STATE_PARTITION`].
pub fn conway_machine_graph(
    rows: u32,
    cols: u32,
    alive: impl Fn(u32, u32) -> bool,
) -> MachineGraph {
    let mut g = MachineGraph::new();
    let mut ids = Vec::with_capacity((rows * cols) as usize);
    for r in 0..rows {
        for c in 0..cols {
            ids.push(g.add_vertex(ConwayCellVertex::arc(r, c, alive(r, c))));
        }
    }
    let idx = |r: i64, c: i64| -> Option<usize> {
        (r >= 0 && c >= 0 && r < rows as i64 && c < cols as i64)
            .then_some((r * cols as i64 + c) as usize)
    };
    for r in 0..rows as i64 {
        for c in 0..cols as i64 {
            for dr in -1..=1i64 {
                for dc in -1..=1i64 {
                    if (dr, dc) == (0, 0) {
                        continue;
                    }
                    if let Some(n) = idx(r + dr, c + dc) {
                        g.add_edge(ids[idx(r, c).unwrap()], ids[n], STATE_PARTITION);
                    }
                }
            }
        }
    }
    g
}

/// Population names of the Potjans–Diesmann microcircuit (Figure 14).
pub const PD_POPULATIONS: [&str; 8] =
    ["L23E", "L23I", "L4E", "L4I", "L5E", "L5I", "L6E", "L6I"];

/// Full-scale population sizes (Potjans & Diesmann 2014, Table 1).
pub const PD_SIZES: [u32; 8] = [20683, 5834, 21915, 5479, 4850, 1065, 14395, 2948];

/// Connection probabilities target<-source (Potjans & Diesmann 2014,
/// Table 5; rows = target population, columns = source population).
pub const PD_CONN: [[f64; 8]; 8] = [
    [0.1009, 0.1689, 0.0437, 0.0818, 0.0323, 0.0000, 0.0076, 0.0000],
    [0.1346, 0.1371, 0.0316, 0.0515, 0.0755, 0.0000, 0.0042, 0.0000],
    [0.0077, 0.0059, 0.0497, 0.1350, 0.0067, 0.0003, 0.0453, 0.0000],
    [0.0691, 0.0029, 0.0794, 0.1597, 0.0033, 0.0000, 0.1057, 0.0000],
    [0.1004, 0.0622, 0.0505, 0.0057, 0.0831, 0.3726, 0.0204, 0.0000],
    [0.0548, 0.0269, 0.0257, 0.0022, 0.0600, 0.3158, 0.0086, 0.0000],
    [0.0156, 0.0066, 0.0211, 0.0166, 0.0572, 0.0197, 0.0396, 0.2252],
    [0.0364, 0.0010, 0.0034, 0.0005, 0.0277, 0.0080, 0.0658, 0.1443],
];

/// External (background) input rates per population, in expected spikes
/// per neuron per timestep at full scale (derived from the paper's
/// 8 Hz x K_ext background).
pub const PD_EXT_INPUTS: [u32; 8] = [1600, 1500, 2100, 1900, 2000, 1900, 2900, 2100];

/// A built microcircuit: application vertex handles per population.
pub struct Microcircuit {
    pub populations: BTreeMap<&'static str, AppVertexId>,
    pub sources: BTreeMap<&'static str, AppVertexId>,
    pub sizes: BTreeMap<&'static str, u32>,
}

/// Build a scaled Potjans–Diesmann cortical microcircuit (§7.2,
/// Figure 14): 8 LIF populations with the PD connectivity map, each
/// driven by its own Poisson background source.
///
/// `scale` scales the population sizes; connection probabilities are
/// kept and weights are synapse-count-preserving-ish for small scales.
pub fn build_microcircuit(
    tools: &mut SpiNNTools,
    scale: f64,
    seed: u64,
    record: bool,
) -> anyhow::Result<Microcircuit> {
    // Weights tuned for the scaled network: exc PSP-equivalent current,
    // inhibition at the paper's g = -4 relative strength.
    let w_exc = 1.2f32;
    let g = 5.0f32;
    let params = LifParams::default();

    let mut populations = BTreeMap::new();
    let mut sources = BTreeMap::new();
    let mut sizes = BTreeMap::new();
    for (i, name) in PD_POPULATIONS.iter().enumerate() {
        let n = ((PD_SIZES[i] as f64 * scale).round() as u32).max(8);
        sizes.insert(*name, n);
        let pop = tools.add_application_vertex(LifPopulationVertex::arc(
            name,
            n,
            params.clone(),
            record,
        ))?;
        populations.insert(*name, pop);
        // Background drive: the paper's K_ext independent 8 Hz inputs per
        // neuron are aggregated into ONE Poisson source per neuron whose
        // weight preserves the mean input current (K_ext * 8 Hz * w_exc).
        // DESIGN.md documents this variance-reducing substitution.
        let src_rate_hz = 500.0f32;
        let ext_events_per_ms = PD_EXT_INPUTS[i] as f64 * 8.0 / 1000.0;
        // 0.66: operating point just below threshold, so firing is
        // fluctuation-driven (the PD asynchronous-irregular regime)
        // rather than mean-driven.
        let w_bg = (ext_events_per_ms / (src_rate_hz as f64 / 1000.0)) * w_exc as f64 * 0.66;
        let src = tools.add_application_vertex(PoissonSourceVertex::arc(
            &format!("ext_{name}"),
            n,
            src_rate_hz,
            seed ^ (i as u64) << 8,
            false,
        ))?;
        sources.insert(*name, src);
        tools.add_application_edge(
            src,
            pop,
            SPIKES_PARTITION,
            Some(SynapseSpec::excitatory(w_bg as f32, Connector::OneToOne, seed ^ 0xEE)),
        )?;
    }

    // Recurrent connectivity (probabilities preserved; at small scales
    // the in-degree shrinks with n_pre, partially offset by weight).
    let comp = (1.0 / scale.sqrt()).min(6.0) as f32;
    for (t, target) in PD_POPULATIONS.iter().enumerate() {
        for (s, source) in PD_POPULATIONS.iter().enumerate() {
            let p = PD_CONN[t][s];
            if p == 0.0 {
                continue;
            }
            let inhibitory = s % 2 == 1;
            let w = if inhibitory { w_exc * g * comp } else { w_exc * comp };
            let spec = std::sync::Arc::new(SynapseSpec {
                weight: w,
                inhibitory,
                connector: Connector::FixedProbability(p),
                seed: seed ^ ((t as u64) << 32 | s as u64),
            });
            tools.add_application_edge(
                populations[source],
                populations[target],
                SPIKES_PARTITION,
                Some(spec),
            )?;
        }
    }

    Ok(Microcircuit { populations, sources, sizes })
}

/// The §7.2 microcircuit as a *bare* application graph — no
/// [`SpiNNTools`]: the same populations, background sources and PD
/// connectivity map as [`build_microcircuit`], with nominal weights,
/// for mapping-only benches and tests that never run the network
/// (mapping never samples the synaptic matrices).
pub fn microcircuit_app_graph(scale: f64, seed: u64) -> ApplicationGraph {
    let mut app = ApplicationGraph::new();
    let mut pops = Vec::new();
    for (i, name) in PD_POPULATIONS.iter().enumerate() {
        let n = ((PD_SIZES[i] as f64 * scale).round() as u32).max(8);
        let pop =
            app.add_vertex(LifPopulationVertex::arc(name, n, LifParams::default(), false));
        let src = app.add_vertex(PoissonSourceVertex::arc(
            &format!("ext_{name}"),
            n,
            500.0,
            seed ^ (i as u64),
            false,
        ));
        app.add_edge(
            src,
            pop,
            SPIKES_PARTITION,
            Some(SynapseSpec::excitatory(1.2, Connector::OneToOne, seed)),
        );
        pops.push(pop);
    }
    for (t, _target) in PD_POPULATIONS.iter().enumerate() {
        for (s, _source) in PD_POPULATIONS.iter().enumerate() {
            let p = PD_CONN[t][s];
            if p == 0.0 {
                continue;
            }
            let spec = if s % 2 == 1 {
                SynapseSpec::inhibitory(4.8, Connector::FixedProbability(p), seed)
            } else {
                SynapseSpec::excitatory(1.2, Connector::FixedProbability(p), seed)
            };
            app.add_edge(pops[s], pops[t], SPIKES_PARTITION, Some(spec));
        }
    }
    app
}

/// [`microcircuit_app_graph`] split into a machine graph for `machine`.
pub fn microcircuit_machine_graph(
    machine: &Machine,
    scale: f64,
    seed: u64,
) -> anyhow::Result<MachineGraph> {
    Ok(crate::mapping::splitter::split_graph(&microcircuit_app_graph(scale, seed), machine)?.0)
}

/// Per-population firing rates (Hz) from recorded spike bitmaps.
pub fn firing_rates(
    tools: &SpiNNTools,
    circuit: &Microcircuit,
    run_ms: f64,
) -> BTreeMap<&'static str, f64> {
    let mut rates = BTreeMap::new();
    for (name, pop) in &circuit.populations {
        let n = circuit.sizes[name];
        let mut spikes = 0usize;
        for (slice, data) in tools.app_recordings(*pop) {
            spikes += super::neuron::decode_spike_bitmaps(data, slice.n_atoms()).len();
        }
        let rate = spikes as f64 / n as f64 / (run_ms / 1000.0);
        rates.insert(*name, rate);
    }
    rates
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pd_tables_consistent() {
        assert_eq!(PD_POPULATIONS.len(), 8);
        assert_eq!(PD_SIZES.iter().sum::<u32>(), 77169);
        for row in &PD_CONN {
            for p in row {
                assert!((0.0..=1.0).contains(p));
            }
        }
    }
}
