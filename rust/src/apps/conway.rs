//! Conway's Game of Life (§7.1, Figure 13).
//!
//! Two formulations, both from the paper:
//!
//! - [`ConwayCellVertex`] / [`ConwayCellApp`]: one cell per machine
//!   vertex, bidirectional machine edges to the 8 neighbours, state
//!   exchanged as multicast packets each timestep — the archetype graph
//!   of §7.1, pure rust on the simulated core.
//! - [`ConwayTileVertex`] / [`ConwayTileApp`]: the "future version ...
//!   multiple cells within each machine vertex" sketched at the end of
//!   §7.1 — a whole tile stepped by the AOT-compiled Pallas kernel
//!   (`conway_step_{16,32,64}`) through the PJRT runtime.

use std::any::Any;
use std::rc::Rc;
use std::sync::Arc;

use crate::graph::{DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements};
use crate::runtime::{HostTensor, Runtime};
use crate::simulator::{CoreApp, CoreCtx};
use crate::util::bytes::{ByteReader, ByteWriter};

pub const CELL_BINARY: &str = "conway_cell.aplx";
pub const TILE_BINARY: &str = "conway_tile.aplx";

/// The outgoing partition carrying cell state.
pub const STATE_PARTITION: &str = "state";

/// Recording channel for cell state.
pub const STATE_CHANNEL: u32 = 0;

const REGION_CONFIG: u32 = 0;

// ---------------------------------------------------------------------------
// One-cell-per-vertex formulation

/// A single Life cell (§7.1's machine vertex).
#[derive(Debug)]
pub struct ConwayCellVertex {
    pub row: u32,
    pub col: u32,
    pub alive: bool,
}

impl ConwayCellVertex {
    pub fn arc(row: u32, col: u32, alive: bool) -> Arc<dyn MachineVertexImpl> {
        Arc::new(Self { row, col, alive })
    }
}

impl MachineVertexImpl for ConwayCellVertex {
    fn label(&self) -> String {
        format!("cell_{}_{}", self.row, self.col)
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: 256,
            itcm_bytes: 4 * 1024,
            sdram_bytes: 64,
            cpu_cycles_per_step: 1_000,
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        CELL_BINARY.into()
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        // Config: own key, initial state, the keys of the 8 (or fewer)
        // neighbours we must fold into the rule.
        let key = ctx
            .outgoing_key(STATE_PARTITION)
            .map(|k| k.base)
            .unwrap_or(0);
        let mut w = ByteWriter::new();
        w.u32(key);
        w.u32(self.alive as u32);
        let incoming = ctx.incoming_keys();
        w.u32(incoming.len() as u32);
        for (_, _, kr) in &incoming {
            w.u32(kr.base);
        }
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn steps_per_recording_space(&self, bytes: u64) -> Option<u64> {
        Some(bytes) // one byte of state per step
    }

    fn min_recording_bytes(&self) -> u64 {
        16
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The cell binary: fold neighbour states received since the previous
/// tick, update, multicast the new state, record it.
pub struct ConwayCellApp {
    key: u32,
    alive: bool,
    n_neighbours: u32,
    alive_neighbours: u32,
    received: u32,
}

impl ConwayCellApp {
    pub fn new() -> Self {
        Self { key: 0, alive: false, n_neighbours: 0, alive_neighbours: 0, received: 0 }
    }
}

impl Default for ConwayCellApp {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreApp for ConwayCellApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let region = ctx.read_region(REGION_CONFIG)?;
        let mut r = ByteReader::new(&region);
        self.key = r.u32()?;
        self.alive = r.u32()? != 0;
        self.n_neighbours = r.u32()?;
        Ok(())
    }

    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        if ctx.tick > 1 {
            // Synchronous phase update (§7.1): B3/S23 on last phase's states.
            if self.received != self.n_neighbours {
                ctx.count("missed_neighbour_states", 1);
            }
            let n = self.alive_neighbours;
            self.alive = matches!((self.alive, n), (true, 2) | (true, 3) | (false, 3));
        }
        self.alive_neighbours = 0;
        self.received = 0;
        ctx.send_mc(self.key, Some(self.alive as u32));
        ctx.record(STATE_CHANNEL, &[self.alive as u8]);
        Ok(())
    }

    fn on_mc_packet(&mut self, _key: u32, payload: Option<u32>, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        self.received += 1;
        if payload.unwrap_or(0) != 0 {
            self.alive_neighbours += 1;
        }
        let _ = ctx;
        Ok(())
    }

    fn on_resume(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn snapshot_state(&self) -> Option<Vec<u8>> {
        // `key`/`n_neighbours` are static config re-read by `on_start`;
        // the evolving state is the cell itself plus the mid-phase fold.
        let mut w = ByteWriter::new();
        w.u32(self.alive as u32).u32(self.alive_neighbours).u32(self.received);
        Some(w.finish())
    }

    fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        self.alive = r.u32()? != 0;
        self.alive_neighbours = r.u32()?;
        self.received = r.u32()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Tile formulation (HLO-backed)

/// A whole tile of cells stepped by the AOT Pallas kernel.
#[derive(Debug)]
pub struct ConwayTileVertex {
    pub side: u32,
    pub initial: Vec<u8>,
}

impl ConwayTileVertex {
    /// `side` must be one of the compiled tile sizes (16, 32, 64).
    pub fn arc(side: u32, initial: Vec<u8>) -> Arc<dyn MachineVertexImpl> {
        assert!(matches!(side, 16 | 32 | 64), "no conway artifact for side {side}");
        assert_eq!(initial.len(), (side * side) as usize);
        Arc::new(Self { side, initial })
    }
}

impl MachineVertexImpl for ConwayTileVertex {
    fn label(&self) -> String {
        format!("conway_tile_{0}x{0}", self.side)
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: self.side * self.side * 4,
            itcm_bytes: 16 * 1024,
            sdram_bytes: (self.side * self.side) as u64 + 64,
            cpu_cycles_per_step: (self.side * self.side * 20) as u64,
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        TILE_BINARY.into()
    }

    fn generate_data(&self, _ctx: &DataGenContext) -> Vec<DataRegion> {
        let mut w = ByteWriter::new();
        w.u32(self.side);
        w.bytes(&self.initial);
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn steps_per_recording_space(&self, bytes: u64) -> Option<u64> {
        Some(bytes / (self.side * self.side) as u64)
    }

    fn min_recording_bytes(&self) -> u64 {
        (self.side * self.side) as u64
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The tile binary: one PJRT execution of the Pallas kernel per tick.
pub struct ConwayTileApp {
    runtime: Rc<Runtime>,
    side: u32,
    board: Vec<i32>,
}

impl ConwayTileApp {
    pub fn new(runtime: Rc<Runtime>) -> Self {
        Self { runtime, side: 0, board: Vec::new() }
    }

    fn model(&self) -> String {
        format!("conway_step_{0}x{0}", self.side)
    }
}

impl CoreApp for ConwayTileApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let region = ctx.read_region(REGION_CONFIG)?;
        let mut r = ByteReader::new(&region);
        self.side = r.u32()?;
        self.board = (0..self.side * self.side)
            .map(|_| r.u8().map(|b| b as i32))
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            self.runtime.has_model(&self.model()),
            "artifact {} missing",
            self.model()
        );
        Ok(())
    }

    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let out = self
            .runtime
            .exec(&self.model(), &[HostTensor::I32(self.board.clone())])?;
        self.board = out.into_iter().next().unwrap().into_i32()?;
        let bytes: Vec<u8> = self.board.iter().map(|c| *c as u8).collect();
        ctx.record(STATE_CHANNEL, &bytes);
        ctx.count("tile_steps", 1);
        Ok(())
    }

    fn snapshot_state(&self) -> Option<Vec<u8>> {
        // `side` (and the runtime handle) come back via `on_start`; the
        // board is the only evolving state. Cells are 0/1, one byte each.
        let mut w = ByteWriter::new();
        w.u32(self.board.len() as u32);
        w.bytes(&self.board.iter().map(|c| *c as u8).collect::<Vec<u8>>());
        Some(w.finish())
    }

    fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let n = r.u32()? as usize;
        self.board = r.bytes(n)?.iter().map(|b| *b as i32).collect();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::machine::{CoreLocation, MachineBuilder};
    use crate::simulator::{scamp, SimConfig, SimMachine};

    #[test]
    fn cell_app_blinker_without_graph() {
        // Hand-wire a 1D "blinker" of 3 cells on one chip: routing via
        // per-key entries delivering to neighbour cores.
        use crate::machine::router::{Route, RoutingEntry, RoutingTable};
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        // cores 1,2,3 = cells A,B,C; A and C neighbour B; B neighbours both.
        let entries = vec![
            RoutingEntry::new(0x1, !0, Route::EMPTY.with_processor(2)),
            RoutingEntry::new(0x2, !0, Route::EMPTY.with_processor(1).with_processor(3)),
            RoutingEntry::new(0x3, !0, Route::EMPTY.with_processor(2)),
        ];
        sim.chip_mut((0, 0)).unwrap().install_table(RoutingTable::from_entries(entries));
        for (p, key, alive, neighbours) in
            [(1u8, 0x1u32, true, vec![0x2u32]), (2, 0x2, true, vec![0x1, 0x3]), (3, 0x3, true, vec![0x2])]
        {
            let mut w = ByteWriter::new();
            w.u32(key).u32(alive as u32).u32(neighbours.len() as u32);
            for k in neighbours {
                w.u32(k);
            }
            let mut regions = BTreeMap::new();
            regions.insert(REGION_CONFIG, w.finish());
            let mut rec = BTreeMap::new();
            rec.insert(STATE_CHANNEL, 64u32);
            scamp::load_app(
                &mut sim,
                CoreLocation::new(0, 0, p),
                Box::new(ConwayCellApp::new()),
                regions,
                rec,
            )
            .unwrap();
        }
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(4);
        sim.run_until_idle().unwrap();
        // 1D line of 3 live cells under B3/S23: ends die (1 neighbour),
        // middle survives only if 2or3 -> has 2 -> survives; then middle
        // alone dies next step.
        let read = |sim: &mut SimMachine, p: u8| {
            let (addr, len, _) =
                scamp::recording_info(sim, CoreLocation::new(0, 0, p), STATE_CHANNEL).unwrap();
            scamp::read_sdram(sim, (0, 0), addr, len).unwrap()
        };
        assert_eq!(read(&mut sim, 1), vec![1, 0, 0, 0]);
        assert_eq!(read(&mut sim, 2), vec![1, 1, 0, 0]);
        assert_eq!(read(&mut sim, 3), vec![1, 0, 0, 0]);
    }

    #[test]
    fn tile_app_blinker_via_hlo() {
        let Ok(rt) = Runtime::open_default() else {
            // Needs the `pjrt` feature and built artifacts (`make artifacts`).
            eprintln!("skipping: PJRT runtime/artifacts unavailable");
            return;
        };
        let rt = Rc::new(rt);
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let side = 16u32;
        let mut initial = vec![0u8; (side * side) as usize];
        for c in 1..4 {
            initial[(2 * side + c) as usize] = 1; // horizontal blinker
        }
        let mut w = ByteWriter::new();
        w.u32(side).bytes(&initial);
        let mut regions = BTreeMap::new();
        regions.insert(REGION_CONFIG, w.finish());
        let mut rec = BTreeMap::new();
        rec.insert(STATE_CHANNEL, side * side * 4);
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(ConwayTileApp::new(rt)), regions, rec).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(2);
        sim.run_until_idle().unwrap();
        let (addr, len, _) = scamp::recording_info(&sim, loc, STATE_CHANNEL).unwrap();
        assert_eq!(len, (side * side * 2) as usize);
        let data = scamp::read_sdram(&mut sim, (0, 0), addr, len).unwrap();
        let step1 = &data[..(side * side) as usize];
        let step2 = &data[(side * side) as usize..];
        // vertical after one step
        assert_eq!(step1[(1 * side + 2) as usize], 1);
        assert_eq!(step1[(2 * side + 2) as usize], 1);
        assert_eq!(step1[(3 * side + 2) as usize], 1);
        assert_eq!(step1.iter().map(|b| *b as u32).sum::<u32>(), 3);
        // back to horizontal after two
        assert_eq!(step2[(2 * side + 1) as usize], 1);
        assert_eq!(step2[(2 * side + 3) as usize], 1);
    }
}
