//! Built-in application vertices and their simulated core binaries.
//!
//! Each submodule pairs a vertex type (graph-side: resources, data
//! generation, recording model) with a [`CoreApp`] (machine-side: the
//! event-driven "binary"), connected by the binary name through
//! [`AppRegistry`] — the moral equivalent of naming an `.aplx` file.
//!
//! - [`conway`]: the §7.1 use case (one cell per vertex, plus the
//!   HLO-backed whole-tile variant sketched at the end of §7.1);
//! - [`neuron`]: the §7.2 LIF population vertex backed by the AOT
//!   `lif_step_*` artifacts;
//! - [`poisson`]: the §7.2 Poisson spike source (HLO thinning);
//! - [`gatherer`]: the Live Packet Gatherer (§6.9, Figure 12);
//! - [`reverse_source`]: the Reverse IP Tag Multicast Source (§6.9);
//! - [`speedup`]: the fast data-extraction protocol cores (§6.8,
//!   Figure 11 bottom).

pub mod conway;
pub mod gatherer;
pub mod networks;
pub mod neuron;
pub mod poisson;
pub mod reverse_source;
pub mod speedup;

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::runtime::Runtime;
use crate::simulator::CoreApp;

/// Creates the core app for a binary name at load time (§6.3.4). Apps
/// read their configuration from their SDRAM data regions in
/// `on_start`, exactly as the C binaries do.
pub type AppFactory = Box<dyn Fn() -> Box<dyn CoreApp>>;

/// Binary name -> app factory.
pub struct AppRegistry {
    factories: BTreeMap<String, AppFactory>,
}

impl AppRegistry {
    pub fn empty() -> Self {
        Self { factories: BTreeMap::new() }
    }

    /// The standard registry with every built-in binary. `runtime` is
    /// shared by the HLO-backed binaries (neuron, poisson, conway tile);
    /// pass `None` to register only the pure-rust binaries.
    pub fn standard(runtime: Option<Rc<Runtime>>) -> Self {
        let mut reg = Self::empty();
        reg.register(conway::CELL_BINARY, || Box::new(conway::ConwayCellApp::new()));
        reg.register(gatherer::BINARY, || Box::new(gatherer::LivePacketGathererApp::new()));
        reg.register(reverse_source::BINARY, || {
            Box::new(reverse_source::ReverseIpTagSourceApp::new())
        });
        reg.register(speedup::READER_BINARY, || Box::new(speedup::DataSpeedUpReaderApp::new()));
        reg.register(speedup::GATHERER_BINARY, || {
            Box::new(speedup::DataSpeedUpGathererApp::new())
        });
        if let Some(rt) = runtime {
            let r1 = rt.clone();
            reg.register(neuron::BINARY, move || {
                Box::new(neuron::LifPopulationApp::new(r1.clone()))
            });
            let r2 = rt.clone();
            reg.register(poisson::BINARY, move || {
                Box::new(poisson::PoissonSourceApp::new(r2.clone()))
            });
            let r3 = rt;
            reg.register(conway::TILE_BINARY, move || {
                Box::new(conway::ConwayTileApp::new(r3.clone()))
            });
        }
        reg
    }

    pub fn register(
        &mut self,
        binary: &str,
        factory: impl Fn() -> Box<dyn CoreApp> + 'static,
    ) {
        self.factories.insert(binary.to_string(), Box::new(factory));
    }

    pub fn create(&self, binary: &str) -> anyhow::Result<Box<dyn CoreApp>> {
        Ok(self
            .factories
            .get(binary)
            .ok_or_else(|| anyhow::anyhow!("no binary '{binary}' registered"))?())
    }

    pub fn has(&self, binary: &str) -> bool {
        self.factories.contains_key(binary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_registry_has_pure_rust_binaries() {
        let reg = AppRegistry::standard(None);
        assert!(reg.has(conway::CELL_BINARY));
        assert!(reg.has(gatherer::BINARY));
        assert!(reg.has(reverse_source::BINARY));
        assert!(reg.has(speedup::READER_BINARY));
        assert!(!reg.has(neuron::BINARY), "HLO binaries need a runtime");
        assert!(reg.create(conway::CELL_BINARY).is_ok());
        assert!(reg.create("missing.aplx").is_err());
    }
}
