//! The Live Packet Gatherer (§6.9, Figure 12): taps existing multicast
//! streams — wired by simply adding graph edges — and forwards them to
//! an external application as EIEIO-over-UDP via its IP tag.

use std::any::Any;
use std::sync::Arc;

use crate::graph::{
    DataGenContext, DataRegion, IpTagRequest, MachineVertexImpl, ResourceRequirements,
};
use crate::machine::ChipCoord;
use crate::simulator::{CoreApp, CoreCtx};
use crate::transport::{EieioMessage, EieioType, SdpHeader, SdpMessage};
use crate::util::bytes::{ByteReader, ByteWriter};

pub const BINARY: &str = "live_packet_gather.aplx";
pub const IPTAG_LABEL: &str = "lpg";
const REGION_CONFIG: u32 = 0;

/// The LPG vertex. Must sit on an Ethernet chip (it owns an IP tag).
#[derive(Debug)]
pub struct LivePacketGathererVertex {
    pub label: String,
    /// External listener endpoint.
    pub host: String,
    pub port: u16,
    /// The Ethernet chip to pin to.
    pub chip: ChipCoord,
}

impl LivePacketGathererVertex {
    pub fn arc(label: &str, host: &str, port: u16, chip: ChipCoord) -> Arc<dyn MachineVertexImpl> {
        Arc::new(Self { label: label.into(), host: host.into(), port, chip })
    }
}

impl MachineVertexImpl for LivePacketGathererVertex {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: 16 * 1024,
            itcm_bytes: 8 * 1024,
            sdram_bytes: 1024,
            iptags: vec![IpTagRequest {
                host: self.host.clone(),
                port: self.port,
                strip_sdp: true,
                label: IPTAG_LABEL.into(),
            }],
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        BINARY.into()
    }

    fn chip_constraint(&self) -> Option<ChipCoord> {
        Some(self.chip)
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        let tag = ctx.iptag(IPTAG_LABEL).map(|t| t.tag).unwrap_or(0);
        let mut w = ByteWriter::new();
        w.u32(tag as u32);
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The LPG binary: buffer multicast arrivals, flush one EIEIO batch per
/// timer tick through the IP tag.
pub struct LivePacketGathererApp {
    tag: u8,
    buffer: Vec<(u32, Option<u32>)>,
}

impl LivePacketGathererApp {
    pub fn new() -> Self {
        Self { tag: 0, buffer: Vec::new() }
    }

    fn flush(&mut self, ctx: &mut CoreCtx) {
        if self.buffer.is_empty() {
            return;
        }
        let with_payload = self.buffer.iter().any(|(_, p)| p.is_some());
        let ty = if with_payload {
            EieioType::Key32Payload
        } else {
            EieioType::Key32
        };
        for batch in EieioMessage::batched(ty, &self.buffer) {
            let mut header = SdpHeader::to_core(ctx.loc, 1);
            header.tag = self.tag;
            ctx.send_sdp(SdpMessage::new(header, batch.encode()));
        }
        ctx.count("events_forwarded", self.buffer.len() as u64);
        self.buffer.clear();
    }
}

impl Default for LivePacketGathererApp {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreApp for LivePacketGathererApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        self.tag = ByteReader::new(&config).u32()? as u8;
        Ok(())
    }

    fn on_mc_packet(&mut self, key: u32, payload: Option<u32>, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        self.buffer.push((key, payload));
        Ok(())
    }

    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        self.flush(ctx);
        Ok(())
    }

    fn on_pause(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        self.flush(ctx);
        Ok(())
    }
}
