//! The fast bulk-data protocols (§6.8, Figure 11 bottom) — both
//! directions of the data plane.
//!
//! **Extraction (data out).** One reader core per chip streams SDRAM as
//! multicast packets to a gatherer core on its board's Ethernet chip,
//! which reassembles them into sequence-numbered SDP frames for the
//! host. The host re-requests missing sequences (the machine is
//! configured so the single-path stream is loss-free, but the logic
//! exists and is tested). Compared with SCAMP reads: no per-256-byte
//! request/response round trip and no SDP headers crossing the fabric —
//! which is exactly why the paper measures ~40 Mb/s from *any* chip
//! versus 8/2 Mb/s over SCAMP.
//!
//! **Loading (data in).** The mirror image: the host sends
//! sequence-numbered UDP frames (framed by [`crate::transport::bulk`])
//! to a dispatcher core on each board's Ethernet chip, which fans each
//! frame out as multicast packets on the target chip's stream key; a
//! writer core on the target chip assembles the words back into SDRAM.
//! The writer tracks which sequences arrived, and the host queries it
//! for the missing ones and re-sends only those — the same re-request
//! vocabulary as extraction, pointed the other way.

use std::any::Any;
use std::sync::Arc;

use crate::graph::{
    DataGenContext, DataRegion, IpTagRequest, MachineVertexImpl, ResourceRequirements,
};
use crate::machine::ChipCoord;
use crate::simulator::{CoreApp, CoreCtx};
use crate::transport::{bulk, SdpHeader, SdpMessage};
use crate::util::bytes::{ByteReader, ByteWriter};

pub const READER_BINARY: &str = "data_speed_up_reader.aplx";
pub const GATHERER_BINARY: &str = "data_speed_up_gather.aplx";
pub const WRITER_BINARY: &str = "data_in_writer.aplx";
pub const DISPATCHER_BINARY: &str = "data_in_dispatch.aplx";
pub const STREAM_PARTITION: &str = "stream";
pub const IPTAG_LABEL: &str = "dsg";
const REGION_CONFIG: u32 = 0;

/// SDP port the reader listens for read commands on.
pub const READER_SDP_PORT: u8 = 2;

/// SDP port the data-in writer listens for session commands on.
pub const WRITER_SDP_PORT: u8 = 3;

/// Words per host-bound SDP frame (64 x 4 B = 256 B of data).
const WORDS_PER_FRAME: usize = bulk::WORDS_PER_FRAME;

/// High bit of a stream-header payload marking an *explicit* frame
/// label: re-requested frames are re-sent under their original sequence
/// numbers (low 31 bits) so the gatherer emits them where the host is
/// actually missing them. Initial-stream headers carry the total word
/// count instead (always < 2^31: SDRAM is 128 MiB).
pub const EXPLICIT_SEQ_FLAG: u32 = 0x8000_0000;

/// Command message: "stream `len` bytes from `addr`" (host → reader).
pub fn encode_read_command(addr: u32, len: u32) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(0xDA7A_0001); // magic
    w.u32(addr);
    w.u32(len);
    w.finish()
}

/// Re-request command for missing sequence numbers.
pub fn encode_rerequest(addr: u32, len: u32, missing: &[u32]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u32(0xDA7A_0002);
    w.u32(addr);
    w.u32(len);
    w.u32(missing.len() as u32);
    w.u32s(missing);
    w.finish()
}

// ---------------------------------------------------------------------------
// Reader (one per chip being read from)

/// The per-chip reader vertex.
#[derive(Debug)]
pub struct DataSpeedUpReaderVertex {
    pub chip: ChipCoord,
}

impl DataSpeedUpReaderVertex {
    pub fn arc(chip: ChipCoord) -> Arc<dyn MachineVertexImpl> {
        Arc::new(Self { chip })
    }
}

impl MachineVertexImpl for DataSpeedUpReaderVertex {
    fn label(&self) -> String {
        format!("ds_reader_{}_{}", self.chip.0, self.chip.1)
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: 8 * 1024,
            itcm_bytes: 8 * 1024,
            sdram_bytes: 256,
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        READER_BINARY.into()
    }

    fn chip_constraint(&self) -> Option<ChipCoord> {
        Some(self.chip)
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        let key = ctx.outgoing_key(STREAM_PARTITION);
        let mut w = ByteWriter::new();
        w.u32(key.map(|k| k.base).unwrap_or(u32::MAX));
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The reader binary: on command, DMA SDRAM and stream it as multicast
/// words (one 32-bit payload per packet; the stream key identifies the
/// transfer).
pub struct DataSpeedUpReaderApp {
    stream_key: u32,
}

impl DataSpeedUpReaderApp {
    pub fn new() -> Self {
        Self { stream_key: u32::MAX }
    }

    fn stream(&self, ctx: &mut CoreCtx, addr: u32, len: u32, only: Option<Vec<u32>>) -> anyhow::Result<()> {
        fn send_words(ctx: &mut CoreCtx, key: u32, data: &[u8]) {
            for chunk in data.chunks(4) {
                let mut word = [0u8; 4];
                word[..chunk.len()].copy_from_slice(chunk);
                ctx.send_mc(key, Some(u32::from_le_bytes(word)));
            }
        }
        let mut streamed = 0u64;
        match only {
            None => {
                let data = ctx.read_sdram(addr, len as usize)?;
                let n_words = data.len().div_ceil(4);
                // Header packet: total word count (payload), distinguished
                // by key | 1 (the stream key range is 2 keys wide).
                ctx.send_mc(self.stream_key | 1, Some(n_words as u32));
                send_words(ctx, self.stream_key, &data);
                streamed = n_words as u64;
            }
            Some(missing) => {
                // Re-request: each frame is DMAd and re-sent on its own,
                // under an explicit sequence label so the gatherer emits
                // it with the number the host is actually missing.
                for frame in missing {
                    let lo = frame as usize * WORDS_PER_FRAME * 4;
                    if lo >= len as usize {
                        continue;
                    }
                    let n = (len as usize - lo).min(WORDS_PER_FRAME * 4);
                    let data = ctx.read_sdram(addr + lo as u32, n)?;
                    ctx.send_mc(self.stream_key | 1, Some(EXPLICIT_SEQ_FLAG | frame));
                    send_words(ctx, self.stream_key, &data);
                    streamed += data.len().div_ceil(4) as u64;
                }
            }
        }
        ctx.count("words_streamed", streamed);
        Ok(())
    }
}

impl Default for DataSpeedUpReaderApp {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreApp for DataSpeedUpReaderApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        self.stream_key = ByteReader::new(&config).u32()?;
        Ok(())
    }

    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn on_sdp(&mut self, msg: &SdpMessage, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let mut r = ByteReader::new(&msg.data);
        match r.u32()? {
            0xDA7A_0001 => {
                let addr = r.u32()?;
                let len = r.u32()?;
                self.stream(ctx, addr, len, None)
            }
            0xDA7A_0002 => {
                let addr = r.u32()?;
                let len = r.u32()?;
                let n = r.u32()?;
                let missing = r.u32s(n as usize)?;
                self.stream(ctx, addr, len, Some(missing))
            }
            other => anyhow::bail!("unknown speed-up command {other:#x}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Gatherer (one on the Ethernet chip)

/// The Ethernet-chip gatherer vertex.
#[derive(Debug)]
pub struct DataSpeedUpGathererVertex {
    pub host: String,
    pub port: u16,
    pub chip: ChipCoord,
}

impl DataSpeedUpGathererVertex {
    pub fn arc(host: &str, port: u16, chip: ChipCoord) -> Arc<dyn MachineVertexImpl> {
        Arc::new(Self { host: host.into(), port, chip })
    }
}

impl MachineVertexImpl for DataSpeedUpGathererVertex {
    fn label(&self) -> String {
        format!("ds_gather_{}_{}", self.chip.0, self.chip.1)
    }

    fn resources(&self) -> ResourceRequirements {
        ResourceRequirements {
            dtcm_bytes: 32 * 1024,
            itcm_bytes: 8 * 1024,
            sdram_bytes: 1024,
            iptags: vec![IpTagRequest {
                host: self.host.clone(),
                port: self.port,
                strip_sdp: true,
                label: IPTAG_LABEL.into(),
            }],
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        GATHERER_BINARY.into()
    }

    fn chip_constraint(&self) -> Option<ChipCoord> {
        Some(self.chip)
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        let tag = ctx.iptag(IPTAG_LABEL).map(|t| t.tag).unwrap_or(0);
        let mut w = ByteWriter::new();
        w.u32(tag as u32);
        vec![DataRegion { id: REGION_CONFIG, data: w.finish() }]
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The gatherer binary: reassemble the word stream into 256-byte
/// sequence-numbered SDP frames for the host ("the SDP is only formed
/// at the Ethernet chip", §6.8).
pub struct DataSpeedUpGathererApp {
    tag: u8,
    expected_words: Option<usize>,
    words: Vec<u32>,
    seq: u32,
}

impl DataSpeedUpGathererApp {
    pub fn new() -> Self {
        Self { tag: 0, expected_words: None, words: Vec::new(), seq: 0 }
    }

    fn flush_frames(&mut self, ctx: &mut CoreCtx, force: bool) {
        while self.words.len() >= WORDS_PER_FRAME
            || (force && !self.words.is_empty())
        {
            let take = self.words.len().min(WORDS_PER_FRAME);
            let frame: Vec<u32> = self.words.drain(..take).collect();
            let mut w = ByteWriter::new();
            w.u32(self.seq);
            w.u32s(&frame);
            let mut header = SdpHeader::to_core(ctx.loc, 1);
            header.tag = self.tag;
            ctx.send_sdp(SdpMessage::new(header, w.finish()));
            self.seq += 1;
        }
    }
}

impl Default for DataSpeedUpGathererApp {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreApp for DataSpeedUpGathererApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        self.tag = ByteReader::new(&config).u32()? as u8;
        Ok(())
    }

    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn on_mc_packet(&mut self, key: u32, payload: Option<u32>, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let payload = payload.unwrap_or(0);
        if key & 1 == 1 {
            if payload & EXPLICIT_SEQ_FLAG != 0 {
                // Re-requested frame: emit the following words under the
                // original sequence number.
                self.words.clear();
                self.seq = payload & !EXPLICIT_SEQ_FLAG;
            } else {
                // Stream header: expected length; reset reassembly.
                self.expected_words = Some(payload as usize);
                self.words.clear();
                self.seq = 0;
            }
            return Ok(());
        }
        self.words.push(payload);
        let done = self
            .expected_words
            .map(|n| self.seq as usize * WORDS_PER_FRAME + self.words.len() >= n)
            .unwrap_or(false);
        self.flush_frames(ctx, done);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Data-in dispatcher (one on each Ethernet chip)

/// The data-in dispatcher binary: each UDP frame from the host (arriving
/// as SDP through the board's reverse IP tag) is fanned out as multicast
/// packets on the target chip's stream key — a header packet (`key | 1`)
/// carrying the sequence number, then one packet per payload word. The
/// host paces frames so one frame's words are on the wire before the
/// next frame arrives (see `front::extraction`).
#[derive(Debug, Default)]
pub struct DataInDispatcherApp;

impl CoreApp for DataInDispatcherApp {
    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn on_sdp(&mut self, msg: &SdpMessage, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let frame = bulk::decode_data_frame(&msg.data)?;
        ctx.send_mc(frame.key | 1, Some(frame.seq));
        for w in &frame.words {
            ctx.send_mc(frame.key, Some(*w));
        }
        ctx.count("frames_dispatched", 1);
        ctx.count("words_dispatched", frame.words.len() as u64);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Data-in writer (one per chip being written to)

/// The per-chip data-in writer binary: assembles the dispatcher's word
/// stream back into SDRAM. A write *session* (opened by SDP command)
/// names the target address and length; the writer marks each frame
/// sequence as it arrives and answers missing-sequence queries with the
/// `transport::bulk` report messages, tagged for the host.
pub struct DataInWriterApp {
    stream_key: u32,
    reply_tag: u8,
    addr: u32,
    len: usize,
    /// Per-frame arrival map of the current session.
    received: Vec<bool>,
    cur_seq: u32,
    cur_word: usize,
}

impl DataInWriterApp {
    pub fn new() -> Self {
        Self {
            stream_key: u32::MAX,
            reply_tag: 0,
            addr: 0,
            len: 0,
            received: Vec::new(),
            cur_seq: 0,
            cur_word: 0,
        }
    }
}

impl Default for DataInWriterApp {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreApp for DataInWriterApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        let mut r = ByteReader::new(&config);
        self.stream_key = r.u32()?;
        self.reply_tag = r.u32()? as u8;
        Ok(())
    }

    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn on_mc_packet(&mut self, key: u32, payload: Option<u32>, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let payload = payload.unwrap_or(0);
        if key & 1 == 1 {
            // Frame header: the following words belong to this sequence.
            self.cur_seq = payload;
            self.cur_word = 0;
            match self.received.get_mut(payload as usize) {
                Some(seen) => {
                    *seen = true;
                    ctx.count("frames_received", 1);
                }
                None => ctx.count("unknown_seq", 1),
            }
            return Ok(());
        }
        let offset = self.cur_seq as usize * bulk::BYTES_PER_FRAME + self.cur_word * 4;
        self.cur_word += 1;
        if offset >= self.len {
            ctx.count("words_overrun", 1);
            return Ok(());
        }
        let word = payload.to_le_bytes();
        let n = (self.len - offset).min(4);
        ctx.write_sdram(self.addr + offset as u32, &word[..n])?;
        ctx.count("bytes_written", n as u64);
        Ok(())
    }

    fn on_sdp(&mut self, msg: &SdpMessage, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let mut r = ByteReader::new(&msg.data);
        match r.u32()? {
            bulk::WRITE_CMD_MAGIC => {
                self.addr = r.u32()?;
                self.len = r.u32()? as usize;
                self.received = vec![false; bulk::frames_of(self.len)];
                ctx.count("write_sessions", 1);
                Ok(())
            }
            bulk::CHECK_CMD_MAGIC => {
                let missing: Vec<u32> = self
                    .received
                    .iter()
                    .enumerate()
                    .filter(|(_, seen)| !**seen)
                    .map(|(seq, _)| seq as u32)
                    .collect();
                ctx.count("missing_reported", missing.len() as u64);
                for report in bulk::encode_missing_reports(&missing) {
                    let mut header = SdpHeader::to_core(ctx.loc, 1);
                    header.tag = self.reply_tag;
                    ctx.send_sdp(SdpMessage::new(header, report));
                }
                Ok(())
            }
            other => anyhow::bail!("unknown data-in command {other:#x}"),
        }
    }
}

/// Host-side reassembly of the gatherer's frames: returns (data,
/// missing frame sequence numbers).
pub fn reassemble(frames: &[Vec<u8>], len: usize) -> (Vec<u8>, Vec<u32>) {
    let n_words = len.div_ceil(4);
    let n_frames = n_words.div_ceil(WORDS_PER_FRAME);
    let mut by_seq: Vec<Option<&[u8]>> = vec![None; n_frames];
    for f in frames {
        if f.len() < 4 {
            continue;
        }
        let seq = u32::from_le_bytes(f[..4].try_into().unwrap()) as usize;
        if seq < n_frames {
            by_seq[seq] = Some(&f[4..]);
        }
    }
    let mut data = Vec::with_capacity(len);
    let mut missing = Vec::new();
    for (seq, frame) in by_seq.iter().enumerate() {
        match frame {
            Some(bytes) => data.extend_from_slice(bytes),
            None => missing.push(seq as u32),
        }
    }
    data.truncate(len);
    (data, missing)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_round_trip() {
        let cmd = encode_read_command(0x6000_0100, 4096);
        let mut r = ByteReader::new(&cmd);
        assert_eq!(r.u32().unwrap(), 0xDA7A_0001);
        assert_eq!(r.u32().unwrap(), 0x6000_0100);
        assert_eq!(r.u32().unwrap(), 4096);
    }

    #[test]
    fn reassemble_in_order() {
        // 2 frames of 64 words + 1 word tail.
        let len = (64 * 2 + 1) * 4;
        let mut frames = Vec::new();
        for seq in 0..3u32 {
            let mut w = ByteWriter::new();
            w.u32(seq);
            let n = if seq == 2 { 1 } else { 64 };
            for i in 0..n {
                w.u32(seq * 1000 + i);
            }
            frames.push(w.finish());
        }
        let (data, missing) = reassemble(&frames, len);
        assert!(missing.is_empty());
        assert_eq!(data.len(), len);
        assert_eq!(u32::from_le_bytes(data[..4].try_into().unwrap()), 0);
        assert_eq!(
            u32::from_le_bytes(data[64 * 4..64 * 4 + 4].try_into().unwrap()),
            1000
        );
    }

    #[test]
    fn reassemble_detects_missing() {
        let len = 64 * 3 * 4;
        let mut frames = Vec::new();
        for seq in [0u32, 2] {
            let mut w = ByteWriter::new();
            w.u32(seq);
            for i in 0..64 {
                w.u32(i);
            }
            frames.push(w.finish());
        }
        let (_, missing) = reassemble(&frames, len);
        assert_eq!(missing, vec![1]);
    }
}
