//! The LIF neuron population vertex (§7.2; model details follow the
//! sPyNNaker neuron binary of Rhodes et al. 2018).
//!
//! An application vertex holds a population of current-based
//! exponential-synapse LIF point neurons; the splitter slices it into
//! machine vertices of at most 256 neurons (the largest AOT artifact).
//! Each machine vertex's data generation builds the *synaptic matrices*
//! — one row set per source machine vertex, expanded from the
//! application edge's [`SynapseSpec`] connector — so the binary can
//! demultiplex received spike keys to per-neuron input currents.
//! The per-tick neuron state update is the AOT-compiled Pallas kernel
//! `lif_step_n{64,128,256}` executed through PJRT.

use std::any::Any;
use std::rc::Rc;
use std::sync::Arc;

use crate::graph::{
    ApplicationVertexImpl, DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements,
    Slice,
};
use crate::runtime::{HostTensor, Runtime};
use crate::simulator::{CoreApp, CoreCtx};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::SplitMix64;

pub const BINARY: &str = "lif_neuron.aplx";

/// The outgoing partition carrying spikes.
pub const SPIKES_PARTITION: &str = "spikes";

/// Recording channel for spike bitmaps.
pub const SPIKES_CHANNEL: u32 = 0;

const REGION_CONFIG: u32 = 0;
const REGION_SYNAPSES: u32 = 1;

/// Artifact sizes compiled by aot.py, smallest first.
const ARTIFACT_SIZES: [u32; 3] = [64, 128, 256];

fn pad_size(n: u32) -> u32 {
    *ARTIFACT_SIZES
        .iter()
        .find(|s| **s >= n)
        .expect("slice wider than largest artifact")
}

/// LIF neuron parameters (PyNN names, per §7.2's cortical models).
#[derive(Debug, Clone)]
pub struct LifParams {
    pub tau_m_ms: f32,
    pub tau_syn_e_ms: f32,
    pub tau_syn_i_ms: f32,
    pub v_rest_mv: f32,
    pub v_reset_mv: f32,
    pub v_thresh_mv: f32,
    pub tau_refrac_ms: f32,
    pub i_offset: f32,
    pub v_init_mv: f32,
}

impl Default for LifParams {
    fn default() -> Self {
        // Potjans & Diesmann (2014) microcircuit constants.
        Self {
            tau_m_ms: 10.0,
            tau_syn_e_ms: 0.5,
            tau_syn_i_ms: 0.5,
            v_rest_mv: -65.0,
            v_reset_mv: -65.0,
            v_thresh_mv: -50.0,
            tau_refrac_ms: 2.0,
            i_offset: 0.0,
            v_init_mv: -65.0,
        }
    }
}

impl LifParams {
    /// The f32[8] params vector of the kernel (ref.py layout).
    pub fn to_kernel_vec(&self, timestep_ms: f32) -> Vec<f32> {
        vec![
            (-timestep_ms / self.tau_m_ms).exp(),
            (-timestep_ms / self.tau_syn_e_ms).exp(),
            (-timestep_ms / self.tau_syn_i_ms).exp(),
            self.v_rest_mv,
            self.v_reset_mv,
            self.v_thresh_mv,
            (self.tau_refrac_ms / timestep_ms).round(),
            self.i_offset,
        ]
    }
}

/// Connectivity pattern of an application edge (§7.2: "details of the
/// neuron-to-neuron connectivity to allow the generation of the
/// synaptic matrices").
#[derive(Debug, Clone)]
pub enum Connector {
    AllToAll,
    OneToOne,
    /// Each (pre, post) pair connected independently with probability p.
    FixedProbability(f64),
}

/// The payload attached to neural application edges.
#[derive(Debug, Clone)]
pub struct SynapseSpec {
    pub weight: f32,
    pub inhibitory: bool,
    pub connector: Connector,
    pub seed: u64,
}

impl SynapseSpec {
    pub fn excitatory(weight: f32, connector: Connector, seed: u64) -> Arc<Self> {
        Arc::new(Self { weight, inhibitory: false, connector, seed })
    }

    pub fn inhibitory(weight: f32, connector: Connector, seed: u64) -> Arc<Self> {
        Arc::new(Self { weight, inhibitory: true, connector, seed })
    }

    /// Deterministic connectivity decision for (pre, post) global ids.
    pub fn connected(&self, pre: u32, post: u32) -> bool {
        match self.connector {
            Connector::AllToAll => true,
            Connector::OneToOne => pre == post,
            Connector::FixedProbability(p) => {
                let mut rng =
                    SplitMix64::new(self.seed ^ ((pre as u64) << 32 | post as u64));
                rng.next_f64() < p
            }
        }
    }
}

/// The application vertex: a population of LIF neurons.
#[derive(Debug)]
pub struct LifPopulationVertex {
    pub label: String,
    pub n_neurons: u32,
    pub params: LifParams,
    pub record_spikes: bool,
}

impl LifPopulationVertex {
    pub fn arc(
        label: &str,
        n_neurons: u32,
        params: LifParams,
        record_spikes: bool,
    ) -> Arc<dyn ApplicationVertexImpl> {
        Arc::new(Self { label: label.into(), n_neurons, params, record_spikes })
    }
}

impl ApplicationVertexImpl for LifPopulationVertex {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn n_atoms(&self) -> u32 {
        self.n_neurons
    }

    fn max_atoms_per_core(&self) -> u32 {
        *ARTIFACT_SIZES.last().unwrap()
    }

    fn resources_for(&self, slice: Slice) -> ResourceRequirements {
        let n = slice.n_atoms();
        ResourceRequirements {
            // 6 state vectors + bookkeeping in DTCM.
            dtcm_bytes: n * 6 * 4 + 2048,
            itcm_bytes: 24 * 1024,
            // Synaptic matrices live in SDRAM; a conservative estimate
            // before expansion (actual size checked at generation).
            sdram_bytes: n as u64 * 2048 + 4096,
            // ~120 cycles per neuron state update + spike handling slack.
            cpu_cycles_per_step: n as u64 * 120 + 10_000,
            ..Default::default()
        }
    }

    fn create_machine_vertex(&self, slice: Slice) -> Arc<dyn MachineVertexImpl> {
        Arc::new(LifMachineVertex {
            label: format!("{}{}", self.label, slice),
            slice,
            params: self.params.clone(),
            record_spikes: self.record_spikes,
        })
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Bytes per recorded timestep: a spike bitmap over the slice.
fn bitmap_bytes(n: u32) -> u64 {
    (n as u64).div_ceil(32) * 4
}

/// One core's worth of neurons.
#[derive(Debug)]
pub struct LifMachineVertex {
    pub label: String,
    pub slice: Slice,
    pub params: LifParams,
    pub record_spikes: bool,
}

impl MachineVertexImpl for LifMachineVertex {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn resources(&self) -> ResourceRequirements {
        let n = self.slice.n_atoms();
        ResourceRequirements {
            dtcm_bytes: n * 6 * 4 + 2048,
            itcm_bytes: 24 * 1024,
            sdram_bytes: n as u64 * 2048 + 4096,
            cpu_cycles_per_step: n as u64 * 120 + 10_000,
            ..Default::default()
        }
    }

    fn binary_name(&self) -> String {
        BINARY.into()
    }

    fn n_keys_for_partition(&self, _partition: &str) -> u32 {
        self.slice.n_atoms()
    }

    fn generate_data(&self, ctx: &DataGenContext) -> Vec<DataRegion> {
        let n = self.slice.n_atoms();
        let key_base = ctx
            .outgoing_key(SPIKES_PARTITION)
            .map(|k| k.base)
            .unwrap_or(u32::MAX);

        let mut config = ByteWriter::new();
        config.u32(n);
        config.u32(pad_size(n));
        config.u32(key_base);
        config.u32(self.record_spikes as u32);
        let timestep_ms = ctx.timestep_us as f32 / 1000.0;
        config.f32s(&self.params.to_kernel_vec(timestep_ms));
        config.f32(self.params.v_init_mv);

        // Synaptic matrices: one block per incoming machine edge,
        // expanded from the application edge's connector over the pre
        // and post slices (§7.2).
        let mut synapses = ByteWriter::new();
        let mut blocks: Vec<(u32, u32, bool, Vec<(u16, u16, f32)>)> = Vec::new();
        if let (Some(app_graph), Some(mapping)) = (ctx.app_graph, ctx.graph_mapping) {
            for edge_id in ctx.graph.incoming_edges(ctx.vertex) {
                let edge = ctx.graph.edge(edge_id);
                let partition = ctx.graph.partition_of_edge(edge_id);
                let Some(key) = ctx.keys.get(&(edge.pre, partition.clone())) else {
                    continue;
                };
                let Some(app_edge_id) = mapping.app_edge_of.get(&edge_id) else {
                    continue;
                };
                let app_edge = app_graph.edge(*app_edge_id);
                let Some(spec) = app_edge
                    .payload
                    .as_ref()
                    .and_then(|p| p.downcast_ref::<SynapseSpec>())
                else {
                    continue;
                };
                let (_, pre_slice) = mapping.app_vertex_of[&edge.pre];
                let mut entries = Vec::new();
                for pre_local in 0..pre_slice.n_atoms() {
                    let pre_global = pre_slice.lo + pre_local;
                    for post_local in 0..n {
                        let post_global = self.slice.lo + post_local;
                        if spec.connected(pre_global, post_global) {
                            entries.push((pre_local as u16, post_local as u16, spec.weight));
                        }
                    }
                }
                blocks.push((key.base, key.mask, spec.inhibitory, entries));
            }
        }
        synapses.u32(blocks.len() as u32);
        for (base, mask, inh, entries) in &blocks {
            synapses.u32(*base).u32(*mask).u32(*inh as u32);
            synapses.u32(entries.len() as u32);
            for (pre, post, w) in entries {
                synapses.u16(*pre).u16(*post).f32(*w);
            }
        }

        vec![
            DataRegion { id: REGION_CONFIG, data: config.finish() },
            DataRegion { id: REGION_SYNAPSES, data: synapses.finish() },
        ]
    }

    fn steps_per_recording_space(&self, bytes: u64) -> Option<u64> {
        // §7.2: "sized assuming that every neuron spikes on every time
        // step" — the bitmap makes that exact.
        self.record_spikes
            .then(|| bytes / bitmap_bytes(self.slice.n_atoms()))
    }

    fn min_recording_bytes(&self) -> u64 {
        if self.record_spikes {
            bitmap_bytes(self.slice.n_atoms()) * 16
        } else {
            0
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// One source's expanded synapse rows, indexed by pre-local atom.
struct SourceBlock {
    key_base: u32,
    key_mask: u32,
    inhibitory: bool,
    /// rows[pre_local] = [(post_local, weight)].
    rows: Vec<Vec<(u16, f32)>>,
}

/// The neuron binary.
///
/// State is kept *packed*: one `f32[6 * pad]` buffer whose rows are
/// [v, i_exc, i_inh, refrac, in_exc, in_inh], matching the packed AOT
/// artifact (`lif_step_packed_n*`). Packing cuts the per-tick PJRT
/// boundary from 7 in / 5 out buffers to 2 in / 1 out — measured ~1.9x
/// lower dispatch overhead (EXPERIMENTS.md §Perf).
pub struct LifPopulationApp {
    runtime: Rc<Runtime>,
    n: u32,
    pad: u32,
    key_base: u32,
    record: bool,
    params: Vec<f32>,
    /// Packed state rows x pad: [v | i_exc | i_inh | refrac | in_exc | in_inh].
    state: Vec<f32>,
    sources: Vec<SourceBlock>,
}

/// Packed-state row offsets.
const ROW_V: usize = 0;
const ROW_IN_EXC: usize = 4;
const ROW_IN_INH: usize = 5;

impl LifPopulationApp {
    pub fn new(runtime: Rc<Runtime>) -> Self {
        Self {
            runtime,
            n: 0,
            pad: 0,
            key_base: u32::MAX,
            record: false,
            params: Vec::new(),
            state: Vec::new(),
            sources: Vec::new(),
        }
    }

    fn model(&self) -> String {
        format!("lif_step_packed_n{}", self.pad)
    }
}

impl CoreApp for LifPopulationApp {
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let config = ctx.read_region(REGION_CONFIG)?;
        let mut r = ByteReader::new(&config);
        self.n = r.u32()?;
        self.pad = r.u32()?;
        self.key_base = r.u32()?;
        self.record = r.u32()? != 0;
        self.params = r.f32s(8)?;
        let v_init = r.f32()?;
        let p = self.pad as usize;
        self.state = vec![0.0; 6 * p];
        self.state[ROW_V * p..(ROW_V + 1) * p].fill(v_init);

        let syn = ctx.read_region(REGION_SYNAPSES)?;
        let mut r = ByteReader::new(&syn);
        let n_blocks = r.u32()?;
        for _ in 0..n_blocks {
            let key_base = r.u32()?;
            let key_mask = r.u32()?;
            let inhibitory = r.u32()? != 0;
            let n_entries = r.u32()?;
            let n_pre = (!key_mask as u64 + 1) as usize;
            let mut rows = vec![Vec::new(); n_pre];
            for _ in 0..n_entries {
                let pre = r.u16()?;
                let post = r.u16()?;
                let w = r.f32()?;
                rows[pre as usize].push((post, w));
            }
            self.sources.push(SourceBlock { key_base, key_mask, inhibitory, rows });
        }
        anyhow::ensure!(
            self.runtime.has_model(&self.model()),
            "artifact {} missing",
            self.model()
        );
        Ok(())
    }

    fn on_mc_packet(&mut self, key: u32, _payload: Option<u32>, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        // Demultiplex: find the source block whose key range matches.
        let mut matched = false;
        for src in &self.sources {
            if key & src.key_mask == src.key_base {
                let pre = (key & !src.key_mask) as usize;
                if let Some(row) = src.rows.get(pre) {
                    let p = self.pad as usize;
                    let base = if src.inhibitory { ROW_IN_INH } else { ROW_IN_EXC } * p;
                    for (post, w) in row {
                        self.state[base + *post as usize] += *w;
                    }
                }
                matched = true;
                break;
            }
        }
        if matched {
            ctx.count("spikes_in", 1);
        } else {
            ctx.count("spikes_unmatched", 1);
        }
        Ok(())
    }

    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let p = self.pad as usize;
        let out = self.runtime.exec(
            &self.model(),
            &[
                HostTensor::F32(std::mem::take(&mut self.state)),
                HostTensor::F32(self.params.clone()),
            ],
        )?;
        // Output rows: [v', i_exc', i_inh', refrac', spiked].
        let packed = out.into_iter().next().unwrap().into_f32()?;
        let spiked = packed[4 * p..5 * p].to_vec();
        self.state = vec![0.0; 6 * p];
        self.state[..4 * p].copy_from_slice(&packed[..4 * p]);

        // Emit spikes + record the bitmap.
        let words = (self.n as usize).div_ceil(32);
        let mut bitmap = vec![0u32; words];
        for atom in 0..self.n {
            if spiked[atom as usize] != 0.0 {
                if self.key_base != u32::MAX {
                    ctx.send_mc(self.key_base + atom, None);
                }
                bitmap[(atom / 32) as usize] |= 1 << (atom % 32);
                ctx.count("spikes_out", 1);
            }
        }
        if self.record {
            let mut bytes = Vec::with_capacity(words * 4);
            for w in &bitmap {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            ctx.record(SPIKES_CHANNEL, &bytes);
        }
        Ok(())
    }

    fn snapshot_state(&self) -> Option<Vec<u8>> {
        // Config, params and synaptic matrices are rebuilt from the
        // regions by `on_start`; the packed f32[6*pad] buffer is the
        // evolving state.
        let mut w = ByteWriter::new();
        w.u32(self.state.len() as u32);
        w.f32s(&self.state);
        Some(w.finish())
    }

    fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let mut r = ByteReader::new(bytes);
        let n = r.u32()? as usize;
        self.state = r.f32s(n)?;
        Ok(())
    }
}

/// Decode a recorded spike bitmap back into (tick, atom) pairs; ticks
/// count from 1 (first timer event).
pub fn decode_spike_bitmaps(data: &[u8], n_atoms: u32) -> Vec<(u64, u32)> {
    let words = (n_atoms as usize).div_ceil(32);
    let step_bytes = words * 4;
    let mut out = Vec::new();
    for (step, chunk) in data.chunks(step_bytes).enumerate() {
        if chunk.len() < step_bytes {
            break;
        }
        for (wi, wb) in chunk.chunks(4).enumerate() {
            let word = u32::from_le_bytes(wb.try_into().unwrap());
            for bit in 0..32 {
                if word & (1 << bit) != 0 {
                    let atom = (wi * 32 + bit) as u32;
                    if atom < n_atoms {
                        out.push((step as u64 + 1, atom));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_size_picks_smallest_artifact() {
        assert_eq!(pad_size(1), 64);
        assert_eq!(pad_size(64), 64);
        assert_eq!(pad_size(65), 128);
        assert_eq!(pad_size(200), 256);
    }

    #[test]
    fn kernel_vec_layout() {
        let p = LifParams::default();
        let v = p.to_kernel_vec(1.0);
        assert_eq!(v.len(), 8);
        assert!((v[0] - (-0.1f32).exp()).abs() < 1e-6);
        assert_eq!(v[3], -65.0);
        assert_eq!(v[6], 2.0); // refractory steps
    }

    #[test]
    fn connector_semantics() {
        let all = SynapseSpec::excitatory(1.0, Connector::AllToAll, 0);
        assert!(all.connected(0, 5) && all.connected(3, 3));
        let oto = SynapseSpec::excitatory(1.0, Connector::OneToOne, 0);
        assert!(oto.connected(4, 4) && !oto.connected(4, 5));
        let p = SynapseSpec::excitatory(1.0, Connector::FixedProbability(0.5), 42);
        // deterministic
        assert_eq!(p.connected(1, 2), p.connected(1, 2));
        let hits = (0..1000)
            .filter(|i| p.connected(*i, 1000 + *i))
            .count();
        assert!((400..600).contains(&hits), "p=0.5 gave {hits}/1000");
    }

    #[test]
    fn bitmap_decode_round_trip() {
        let n = 40u32;
        let words = 2;
        // two steps: step1 spikes {0, 33}, step2 spikes {39}
        let mut data = Vec::new();
        let mut s1 = vec![0u32; words];
        s1[0] |= 1;
        s1[1] |= 1 << 1;
        let mut s2 = vec![0u32; words];
        s2[1] |= 1 << 7;
        for w in s1.iter().chain(s2.iter()) {
            data.extend_from_slice(&w.to_le_bytes());
        }
        let spikes = decode_spike_bitmaps(&data, n);
        assert_eq!(spikes, vec![(1, 0), (1, 33), (2, 39)]);
    }

    #[test]
    fn bitmap_bytes_rounding() {
        assert_eq!(bitmap_bytes(1), 4);
        assert_eq!(bitmap_bytes(32), 4);
        assert_eq!(bitmap_bytes(33), 8);
        assert_eq!(bitmap_bytes(256), 32);
    }
}
