//! The simulated application core: the [`CoreApp`] event interface
//! (mirroring Spin1API's event-driven model, §3) and per-core state.
//!
//! A core app receives the same events a Spin1API binary registers
//! callbacks for: start, the periodic timer, multicast packet arrival,
//! SDP arrival — plus pause/resume hooks used by the Figure-9 run-cycle
//! machinery. All interaction with the machine goes through [`CoreCtx`]
//! (send packets, read data regions, record, count provenance), which
//! the simulator translates into scheduled events.

use std::collections::BTreeMap;

use crate::machine::CoreLocation;
use crate::transport::SdpMessage;

use super::sdram::SdramStore;

/// Run states, matching the states SCAMP reports for real cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    /// No application loaded.
    Idle,
    /// Loaded, waiting for the start signal.
    Ready,
    Running,
    /// Reached its tick target; waiting for more run time (Figure 9).
    Paused,
    /// Called `exit()` — a completion state (§6.3).
    Finished,
    /// The app returned an error (§6.3.5's failure detection).
    RunTimeError,
    /// The core stopped servicing its timer and the hardware watchdog
    /// fired — the state SCAMP reports for a hung core. Reached only via
    /// injected stall faults (the chaos engine) on this simulator.
    Watchdog,
}

/// A recording channel: a region of SDRAM with a write cursor (the
/// "recording regions" the buffer manager drains, §6.8).
#[derive(Debug, Clone)]
pub struct RecordingChannel {
    pub addr: u32,
    pub capacity: usize,
    pub write_pos: usize,
    /// Bytes that did not fit (reported via provenance).
    pub lost_bytes: u64,
}

/// The API surface a core app sees (the Spin1API + recording library
/// equivalent).
pub struct CoreCtx<'a> {
    pub loc: CoreLocation,
    pub time_ns: u64,
    /// Current timer tick (0 before the first tick).
    pub tick: u64,
    pub(super) mc_out: Vec<(u32, Option<u32>)>,
    pub(super) sdp_out: Vec<SdpMessage>,
    pub(super) regions: &'a BTreeMap<u32, (u32, u32)>,
    pub(super) recordings: &'a mut BTreeMap<u32, RecordingChannel>,
    pub(super) sdram: &'a mut SdramStore,
    pub(super) provenance: &'a mut BTreeMap<String, u64>,
    pub(super) iobuf: &'a mut String,
    pub(super) exit_requested: &'a mut bool,
}

impl<'a> CoreCtx<'a> {
    /// Send a multicast packet (key, optional payload).
    pub fn send_mc(&mut self, key: u32, payload: Option<u32>) {
        self.mc_out.push((key, payload));
    }

    /// Send an SDP message (e.g. to the host via an IP tag).
    pub fn send_sdp(&mut self, msg: SdpMessage) {
        self.sdp_out.push(msg);
    }

    /// Read a data region written by the loader (§6.3.3).
    pub fn read_region(&self, id: u32) -> anyhow::Result<Vec<u8>> {
        let (addr, len) = self
            .regions
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("core {} has no region {id}", self.loc))?;
        self.sdram.read(*addr, *len as usize)
    }

    /// Append to a recording channel. Returns false (and counts the
    /// loss) if the buffer is full — the situation the Figure-9 cycle
    /// sizing exists to avoid.
    pub fn record(&mut self, channel: u32, bytes: &[u8]) -> bool {
        let Some(ch) = self.recordings.get_mut(&channel) else {
            *self.provenance.entry("record_no_channel".into()).or_insert(0) += 1;
            return false;
        };
        if ch.write_pos + bytes.len() > ch.capacity {
            ch.lost_bytes += bytes.len() as u64;
            *self.provenance.entry("recording_overflow".into()).or_insert(0) += 1;
            return false;
        }
        self.sdram
            .write(ch.addr + ch.write_pos as u32, bytes)
            .expect("recording buffer write");
        ch.write_pos += bytes.len();
        true
    }

    pub fn recording_space_left(&self, channel: u32) -> usize {
        self.recordings
            .get(&channel)
            .map(|c| c.capacity - c.write_pos)
            .unwrap_or(0)
    }

    /// DMA read from an arbitrary SDRAM address (the data speed-up
    /// reader streams recording buffers this way, §6.8).
    pub fn read_sdram(&self, addr: u32, len: usize) -> anyhow::Result<Vec<u8>> {
        self.sdram.read(addr, len)
    }

    /// DMA write to an arbitrary SDRAM address.
    pub fn write_sdram(&mut self, addr: u32, data: &[u8]) -> anyhow::Result<()> {
        self.sdram.write(addr, data)
    }

    /// Bump a named provenance counter (§6.3.5's "custom core-level
    /// statistics"). Counters are bumped per packet on hot paths, so the
    /// repeat case avoids allocating a `String` for a key that already
    /// exists.
    pub fn count(&mut self, name: &str, delta: u64) {
        if let Some(v) = self.provenance.get_mut(name) {
            *v += delta;
        } else {
            self.provenance.insert(name.to_string(), delta);
        }
    }

    /// Append a line to the core's IOBUF — the SARK `io_printf` buffer
    /// the host reads back with `CMD_IOBUF` after a failure
    /// ([`crate::simulator::scamp::read_iobuf`]).
    pub fn log(&mut self, msg: &str) {
        self.iobuf.push_str(msg);
        if !msg.ends_with('\n') {
            self.iobuf.push('\n');
        }
    }

    /// Enter the Finished completion state after this event.
    pub fn exit(&mut self) {
        *self.exit_requested = true;
    }
}

/// A simulated application binary (the Spin1API callback set).
///
/// Not `Send`: apps may hold `Arc<crate::runtime::Runtime>` (PJRT client
/// handles are not thread-safe) and the simulator is single-threaded.
pub trait CoreApp {
    /// Called once when the start signal arrives.
    fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let _ = ctx;
        Ok(())
    }

    /// The periodic timer event (tick counts from 1).
    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()>;

    /// A multicast packet arrived.
    fn on_mc_packet(
        &mut self,
        key: u32,
        payload: Option<u32>,
        ctx: &mut CoreCtx,
    ) -> anyhow::Result<()> {
        let _ = (key, payload, ctx);
        Ok(())
    }

    /// An SDP message arrived on this core's port.
    fn on_sdp(&mut self, msg: &SdpMessage, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let _ = (msg, ctx);
        Ok(())
    }

    /// The run was paused (end of a Figure-9 cycle).
    fn on_pause(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let _ = ctx;
        Ok(())
    }

    /// The run resumed; recording buffers were drained and reset.
    fn on_resume(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let _ = ctx;
        Ok(())
    }

    /// Serialize the app's *evolving* state for a run snapshot.
    ///
    /// Only state that changes after `on_start` belongs here — static
    /// configuration is re-read from the data regions when the restored
    /// binary's `on_start` runs again, so apps that keep no evolving
    /// state (gatherers, dispatchers, sources driven purely by region
    /// data) can keep the default `None` and restore for free.
    fn snapshot_state(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore state captured by [`CoreApp::snapshot_state`]. Called
    /// after `on_start` has re-initialised the app from its regions, so
    /// implementations only overwrite the evolving fields.
    fn restore_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let _ = bytes;
        anyhow::bail!("app recorded snapshot state but has no restore_state")
    }
}

/// Per-core simulator state.
pub(crate) struct SimCore {
    pub app: Option<Box<dyn CoreApp>>,
    pub state: CoreState,
    /// Kept for debugging/provenance displays.
    #[allow(dead_code)]
    pub binary_name: String,
    /// region id -> (sdram addr, length).
    pub regions: BTreeMap<u32, (u32, u32)>,
    pub recordings: BTreeMap<u32, RecordingChannel>,
    pub provenance: BTreeMap<String, u64>,
    /// The SARK IOBUF: `io_printf` text plus error blobs appended by the
    /// simulator when the app faults, read back via `scamp::read_iobuf`.
    pub iobuf: String,
    /// Ticks completed so far.
    pub ticks_done: u64,
    /// Target tick count for the current run cycle.
    pub run_until: u64,
    /// The core's transmitter is busy until this time: callbacks that
    /// overlap an earlier callback's paced packet train queue behind it
    /// instead of interleaving with it (see `SimMachine::with_core_app`).
    pub tx_busy_ns: u64,
}

impl SimCore {
    pub fn idle() -> Self {
        Self {
            app: None,
            state: CoreState::Idle,
            binary_name: String::new(),
            regions: BTreeMap::new(),
            recordings: BTreeMap::new(),
            provenance: BTreeMap::new(),
            iobuf: String::new(),
            ticks_done: 0,
            run_until: 0,
            tx_busy_ns: 0,
        }
    }
}
