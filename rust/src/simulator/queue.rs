//! Event queues for the simulator fabric (DESIGN.md §5, "fabric fast
//! path").
//!
//! The dispatch loop needs a priority queue ordered by `(time,
//! insertion order)`: events at equal times must come out in the order
//! they were scheduled, which is what makes the whole simulation
//! deterministic. Two implementations share that contract:
//!
//! - [`HeapQueue`] — the legacy `BinaryHeap<Reverse<(time, seq)>>`
//!   implementation, kept as the before/after baseline for experiment
//!   E11 (`benches/fabric.rs`) and for the equivalence suite.
//! - [`CalendarQueue`] — a hierarchical bucketed calendar queue. The
//!   common case in the fabric is large same-cycle fan-out: one timer
//!   tick produces thousands of packet events within a few microseconds
//!   of virtual time. Those land in exact-nanosecond FIFO buckets, so
//!   push and pop are O(1) with no comparisons at all.
//!
//! # Ordering contract
//!
//! Within one exact timestamp, events pop in push order (the simulator
//! pushes with monotonically increasing sequence, so FIFO per timestamp
//! *is* sequence order). Pushing strictly into the past is clamped to
//! the read cursor — the fabric never does this (events are always
//! scheduled at or after the current virtual time), the clamp just
//! guarantees no event can be orphaned behind the cursor.
//!
//! # Structure of the calendar
//!
//! - **Level 0**: `L0_SPAN` buckets of exactly one nanosecond each,
//!   covering the current *chunk* `[chunk * L0_SPAN, (chunk+1) *
//!   L0_SPAN)`. A bucket is a FIFO of events sharing that timestamp.
//! - **Level 1**: `L1_BUCKETS` ring slots of one chunk each, covering
//!   the next ~16.8 ms of virtual time. Slot `c % L1_BUCKETS` holds the
//!   events of chunk `c` unsorted; when the cursor enters chunk `c` the
//!   slot is drained into level 0 (exact-ns distribution preserves the
//!   per-timestamp FIFO order).
//! - **Overflow**: a `BTreeMap<time, Vec>` for events beyond the level-1
//!   horizon (timer ticks are ~1 ms, so almost nothing lands here).
//!   Entries migrate into the ring as the horizon advances.

use std::collections::{BTreeMap, BinaryHeap, VecDeque};

/// Level-0 bucket count (and span in nanoseconds): one chunk.
const L0_BITS: u32 = 12;
const L0_SPAN: u64 = 1 << L0_BITS;
const L0_MASK: u64 = L0_SPAN - 1;

/// Level-1 ring slots, one chunk each (~16.8 ms horizon).
const L1_BUCKETS: u64 = 1 << 12;
const L1_MASK: u64 = L1_BUCKETS - 1;

/// Hierarchical bucketed calendar queue: O(1) push/pop for the fabric's
/// same-cycle fan-out traffic. See the module docs for the layout and
/// the ordering contract.
pub struct CalendarQueue<T> {
    /// Exact-nanosecond FIFO buckets of the current chunk.
    l0: Vec<VecDeque<T>>,
    /// One slot per upcoming chunk (ring, aliased modulo `L1_BUCKETS`).
    l1: Vec<Vec<(u64, T)>>,
    /// Events beyond the level-1 horizon, keyed by exact timestamp.
    overflow: BTreeMap<u64, Vec<T>>,
    /// The chunk the cursor is in (`cursor >> L0_BITS == chunk`).
    chunk: u64,
    /// All events before this time have been popped.
    cursor: u64,
    count: usize,
    l0_count: usize,
    l1_count: usize,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        Self {
            l0: (0..L0_SPAN).map(|_| VecDeque::new()).collect(),
            l1: (0..L1_BUCKETS).map(|_| Vec::new()).collect(),
            overflow: BTreeMap::new(),
            chunk: 0,
            cursor: 0,
            count: 0,
            l0_count: 0,
            l1_count: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn push(&mut self, time: u64, item: T) {
        // The fabric never schedules into the past; clamping (rather
        // than asserting) keeps a stale timestamp from orphaning an
        // event behind the cursor.
        debug_assert!(time >= self.cursor, "event scheduled in the past");
        let t = time.max(self.cursor);
        self.count += 1;
        let c = t >> L0_BITS;
        if c == self.chunk {
            self.l0[(t & L0_MASK) as usize].push_back(item);
            self.l0_count += 1;
        } else if c - self.chunk <= L1_BUCKETS {
            self.l1[(c & L1_MASK) as usize].push((t, item));
            self.l1_count += 1;
        } else {
            self.overflow.entry(t).or_default().push(item);
        }
    }

    pub fn pop(&mut self) -> Option<(u64, T)> {
        if self.count == 0 {
            return None;
        }
        loop {
            if self.l0_count > 0 {
                // Scan the current chunk forward from the cursor; the
                // occupancy count guarantees a hit within the window.
                loop {
                    let b = (self.cursor & L0_MASK) as usize;
                    if let Some(item) = self.l0[b].pop_front() {
                        self.count -= 1;
                        self.l0_count -= 1;
                        return Some((self.cursor, item));
                    }
                    self.cursor += 1;
                    debug_assert!(
                        self.cursor >> L0_BITS <= self.chunk,
                        "level-0 occupancy out of sync"
                    );
                }
            }
            if self.l1_count > 0 {
                self.advance_one_chunk();
            } else {
                // Everything pending is in the overflow: jump straight
                // to its first timestamp (the ladder between is empty).
                let &t = self.overflow.keys().next().expect("count > 0 with empty levels");
                self.chunk = t >> L0_BITS;
                self.cursor = self.chunk << L0_BITS;
                self.pull_overflow();
            }
        }
    }

    /// Move the cursor into the next chunk: drain its ring slot into
    /// level 0 and migrate any overflow entries the horizon now covers.
    fn advance_one_chunk(&mut self) {
        self.chunk += 1;
        self.cursor = self.chunk << L0_BITS;
        let s = (self.chunk & L1_MASK) as usize;
        let mut slot = std::mem::take(&mut self.l1[s]);
        for (t, item) in slot.drain(..) {
            debug_assert_eq!(t >> L0_BITS, self.chunk, "ring slot aliased a wrong chunk");
            self.l0[(t & L0_MASK) as usize].push_back(item);
            self.l1_count -= 1;
            self.l0_count += 1;
        }
        self.l1[s] = slot; // keep the slot's capacity
        self.pull_overflow();
    }

    /// Migrate overflow entries that fall inside the level-1 horizon
    /// (or the current chunk itself, after a jump). Overflow entries
    /// always predate ring/level-0 entries for the same timestamp, so
    /// appending preserves per-timestamp FIFO order.
    fn pull_overflow(&mut self) {
        let horizon = self.chunk + L1_BUCKETS;
        loop {
            let Some(&t) = self.overflow.keys().next() else { return };
            let c = t >> L0_BITS;
            if c > horizon {
                return;
            }
            let items = self.overflow.remove(&t).expect("key just observed");
            if c == self.chunk {
                let b = (t & L0_MASK) as usize;
                for item in items {
                    self.l0[b].push_back(item);
                    self.l0_count += 1;
                }
            } else {
                let s = (c & L1_MASK) as usize;
                for item in items {
                    self.l1[s].push((t, item));
                    self.l1_count += 1;
                }
            }
        }
    }
}

/// The legacy event queue: a binary heap over `(time, sequence)`. Kept
/// as the E11 baseline and as the reference model for the equivalence
/// suite — it is exactly the pre-fast-path fabric ordering.
pub struct HeapQueue<T> {
    heap: BinaryHeap<std::cmp::Reverse<HeapEntry<T>>>,
    seq: u64,
}

struct HeapEntry<T> {
    time: u64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for HeapEntry<T> {}
impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

impl<T> Default for HeapQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> HeapQueue<T> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn push(&mut self, time: u64, item: T) {
        self.seq += 1;
        self.heap.push(std::cmp::Reverse(HeapEntry { time, seq: self.seq, item }));
    }

    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|std::cmp::Reverse(e)| (e.time, e.item))
    }
}

/// Runtime-selectable queue backing, chosen by
/// [`crate::simulator::FabricMode`]. The enum dispatch is one predicted
/// branch; both variants honour the same ordering contract.
pub enum EventQueue<T> {
    Calendar(CalendarQueue<T>),
    Heap(HeapQueue<T>),
}

impl<T> EventQueue<T> {
    #[inline]
    pub fn push(&mut self, time: u64, item: T) {
        match self {
            EventQueue::Calendar(q) => q.push(time, item),
            EventQueue::Heap(q) => q.push(time, item),
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<(u64, T)> {
        match self {
            EventQueue::Calendar(q) => q.pop(),
            EventQueue::Heap(q) => q.pop(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            EventQueue::Calendar(q) => q.len(),
            EventQueue::Heap(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    /// Drive both queues with the same (time, id) stream and compare the
    /// full pop sequences. `HeapQueue` is the reference: it is the
    /// pre-E11 fabric ordering by construction.
    fn run_storm(seed: u64, ops: usize) -> (Vec<(u64, u32)>, Vec<(u64, u32)>) {
        let mut rng = SplitMix64::new(seed);
        let mut cal: CalendarQueue<u32> = CalendarQueue::new();
        let mut heap: HeapQueue<u32> = HeapQueue::new();
        let mut cal_out = Vec::new();
        let mut heap_out = Vec::new();
        let mut now = 0u64;
        let mut next_id = 0u32;
        for _ in 0..ops {
            if rng.next_f64() < 0.6 || cal.is_empty() {
                // Push at `now + delta`, mixing same-instant fan-out,
                // router-scale deltas, tick-scale deltas and far-future
                // (overflow-territory) deltas.
                let delta = match rng.below(10) {
                    0..=3 => 0,
                    4..=6 => rng.next_u64() % 2_000,
                    7 => 1_000_000,
                    8 => rng.next_u64() % 5_000_000,
                    _ => 20_000_000 + rng.next_u64() % 200_000_000,
                };
                let t = now + delta;
                cal.push(t, next_id);
                heap.push(t, next_id);
                next_id += 1;
            } else {
                let a = cal.pop().expect("non-empty");
                let b = heap.pop().expect("queues in lockstep");
                now = a.0;
                cal_out.push(a);
                heap_out.push(b);
            }
            assert_eq!(cal.len(), heap.len());
        }
        while let Some(a) = cal.pop() {
            let b = heap.pop().expect("queues in lockstep");
            cal_out.push(a);
            heap_out.push(b);
        }
        assert!(heap.pop().is_none());
        (cal_out, heap_out)
    }

    #[test]
    fn calendar_matches_heap_on_random_storms() {
        for seed in [1u64, 42, 0xE11, 0xDEAD_BEEF] {
            let (cal, heap) = run_storm(seed, 4000);
            assert_eq!(cal, heap, "seed {seed}");
        }
    }

    #[test]
    fn same_timestamp_pops_in_push_order() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        for i in 0..100 {
            q.push(777, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((777, i)));
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn time_order_across_levels() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        // One event per structural level, pushed out of time order.
        q.push(300_000_000, 3); // overflow
        q.push(1_000_000, 2); // level-1 ring
        q.push(10, 1); // level 0
        q.push(0, 0); // level 0, first bucket
        assert_eq!(q.pop(), Some((0, 0)));
        assert_eq!(q.pop(), Some((10, 1)));
        assert_eq!(q.pop(), Some((1_000_000, 2)));
        assert_eq!(q.pop(), Some((300_000_000, 3)));
        assert!(q.is_empty());
    }

    #[test]
    fn jump_over_long_idle_gap() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        q.push(5, 0);
        assert_eq!(q.pop(), Some((5, 0)));
        // Nothing pending between the cursor and an event ~10 s away.
        q.push(10_000_000_000, 1);
        assert_eq!(q.pop(), Some((10_000_000_000, 1)));
        // And the queue keeps working past the jump.
        q.push(10_000_000_001, 2);
        q.push(10_000_000_001, 3);
        assert_eq!(q.pop(), Some((10_000_000_001, 2)));
        assert_eq!(q.pop(), Some((10_000_000_001, 3)));
    }

    #[test]
    fn interleaved_push_during_drain() {
        // Mirrors dispatch: each pop schedules new events slightly ahead.
        let mut q: CalendarQueue<u64> = CalendarQueue::new();
        q.push(0, 0);
        let mut popped = Vec::new();
        while let Some((t, id)) = q.pop() {
            popped.push((t, id));
            if popped.len() < 500 {
                q.push(t + 166, id + 1);
                if id % 7 == 0 {
                    q.push(t + 1_000_000, id + 1000);
                }
            }
        }
        // Times never go backwards.
        for w in popped.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        assert!(popped.len() >= 500);
    }

    #[test]
    fn len_tracks_pushes_and_pops() {
        let mut q: CalendarQueue<u8> = CalendarQueue::new();
        assert!(q.is_empty());
        q.push(1, 1);
        q.push(2_000_000, 2);
        q.push(2_000_000_000, 3);
        assert_eq!(q.len(), 3);
        q.pop();
        assert_eq!(q.len(), 2);
        q.pop();
        q.pop();
        assert!(q.is_empty());
    }
}
