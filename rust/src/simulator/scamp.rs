//! Host-side SCAMP operations (§3): what ybug/SpiNNMan would issue over
//! SDP, with the §6.8 protocol cost model applied to reads and writes —
//! every 256-byte chunk pays one request/response round trip, plus the
//! P2P relay penalty when the target chip is not the Ethernet chip
//! (Figure 11 middle). These costs are what experiment E1 measures.

use std::collections::BTreeMap;

use crate::machine::router::RoutingTable;
use crate::machine::{ChipCoord, CoreLocation, ROUTER_ENTRIES};

use super::core::{CoreState, RecordingChannel, SimCore};
use super::{CoreApp, SimMachine};

/// SCAMP read chunk size (§6.8: "up to 256 bytes").
pub const SCP_CHUNK: usize = 256;

/// The protocol cost of moving one chunk to/from `chip`.
fn chunk_cost(sim: &SimMachine, chip: ChipCoord) -> u64 {
    let wire = &sim.config.wire;
    let eth = sim.machine.nearest_ethernet(chip).unwrap_or((0, 0));
    if chip == eth {
        wire.eth_read_rtt_ns
    } else {
        let hops = sim.machine.hop_distance(eth, chip) as u64;
        wire.eth_read_rtt_ns + wire.p2p_read_penalty_ns + hops * wire.p2p_per_hop_ns
    }
}

/// One reliable SCP conversation: `chunks` sequenced request/response
/// pairs to `chip`, each costing `cost` virtual time on success.
///
/// On a clean wire this is exactly `advance_host_time(chunks * cost)` —
/// draw-free and bit-identical to the pre-reliability cost model (the
/// E1 ratio tests pin it). Under a seeded [`super::WireFaults`] plan
/// each request draws its fate: a lost request or reply burns the
/// per-request timeout plus exponential backoff and is retransmitted; a
/// re-delivered command (earlier attempt arrived but its reply was
/// lost, or the wire duplicated the frame) is discarded by SCAMP's
/// sequence check so the operation executes exactly once — which is why
/// non-idempotent ops (alloc, signal) ride this path too; duplicated
/// replies are discarded by the host's own sequence check. When one
/// request exhausts the retry budget the board is escalated — the
/// supervisor sees its cores vanish and heals around it — and a
/// distinguishable error is returned instead of hanging.
fn scp_exchange(sim: &mut SimMachine, chip: ChipCoord, chunks: u64, cost: u64) -> anyhow::Result<()> {
    if !sim.wire_active() {
        sim.advance_host_time(cost.saturating_mul(chunks));
        return Ok(());
    }
    let board = sim.machine.nearest_ethernet(chip).unwrap_or(chip);
    let timeout = sim.config.wire.scp_timeout_ns;
    let budget = sim.config.wire.scp_retries;
    for _ in 0..chunks {
        let mut delivered_before = false;
        let mut attempt: u32 = 0;
        loop {
            let outcome = sim.wire_scp_attempt(board, delivered_before);
            delivered_before |= outcome.delivered;
            if outcome.replied {
                sim.advance_host_time(cost);
                break;
            }
            // No reply inside the request window: timeout.
            sim.wire_stats_mut().scp_timeouts += 1;
            if attempt >= budget {
                sim.note_wire_escalation(board);
                anyhow::bail!(
                    "board {board:?} silent: no SCP reply from chip {chip:?} after {} attempts \
                     (escalated to the supervisor)",
                    attempt + 1
                );
            }
            // Exponential backoff: double the wait per retry, capped.
            let backoff = timeout.saturating_mul(1 << attempt.min(6));
            sim.advance_host_time(timeout + backoff);
            let stats = sim.wire_stats_mut();
            stats.backoff_wait_ns += backoff;
            stats.scp_retries += 1;
            attempt += 1;
        }
    }
    Ok(())
}

/// The board SCAMP broadcast commands (signals) are issued through —
/// the first Ethernet chip inside the session scope, so a tenant's
/// signals never cross into (or depend on) another tenant's boards.
fn root_board(sim: &SimMachine) -> Option<ChipCoord> {
    sim.machine
        .chips()
        .filter(|c| c.is_ethernet() && !c.is_virtual && sim.in_scope((c.x, c.y)))
        .map(|c| (c.x, c.y))
        .next()
}

/// Allocate a segment of SDRAM on a chip (the SCAMP `sdram_alloc` call).
/// Rides the reliable exchange: allocation is non-idempotent, so the
/// machine-side duplicate-command check is what keeps a retransmitted
/// alloc from leaking a second segment.
pub fn alloc_sdram(sim: &mut SimMachine, chip: ChipCoord, len: u32) -> anyhow::Result<u32> {
    scp_exchange(sim, chip, 1, 0)?;
    sim.chip_mut(chip)?.sdram.alloc(len)
}

pub fn free_sdram_bytes(sim: &SimMachine, chip: ChipCoord) -> anyhow::Result<u32> {
    Ok(sim.chip(chip)?.sdram.free_bytes())
}

/// Read SDRAM over the SCAMP SDP path (slow path, Figure 11 middle).
pub fn read_sdram(
    sim: &mut SimMachine,
    chip: ChipCoord,
    addr: u32,
    len: usize,
) -> anyhow::Result<Vec<u8>> {
    let cost = chunk_cost(sim, chip);
    let chunks = len.div_ceil(SCP_CHUNK).max(1) as u64;
    scp_exchange(sim, chip, chunks, cost)?;
    sim.chip(chip)?.sdram.read(addr, len)
}

/// Write SDRAM over the SCAMP SDP path (same per-chunk costs).
pub fn write_sdram(
    sim: &mut SimMachine,
    chip: ChipCoord,
    addr: u32,
    data: &[u8],
) -> anyhow::Result<()> {
    let cost = chunk_cost(sim, chip);
    let chunks = data.len().div_ceil(SCP_CHUNK).max(1) as u64;
    scp_exchange(sim, chip, chunks, cost)?;
    sim.chip_mut(chip)?.sdram.write(addr, data)
}

/// Write SDRAM over SCAMP with a pipelined command window: the host
/// keeps `wire.scp_pipeline_window` write commands in flight and only
/// waits for an acknowledgement at window boundaries, so in-window
/// chunks pay the one-way serialisation cost (half the RTT) instead of
/// a full round trip each. This is the fastest loading the monitor
/// protocol alone can offer — the slow-path fallback when a chip has no
/// data-in writer core — and the baseline the E12 bench measures the
/// fast data-in protocol against.
pub fn write_sdram_batched(
    sim: &mut SimMachine,
    chip: ChipCoord,
    addr: u32,
    data: &[u8],
) -> anyhow::Result<()> {
    let cost = chunk_cost(sim, chip);
    let window = sim.config.wire.scp_pipeline_window.max(1);
    let chunks = data.len().div_ceil(SCP_CHUNK).max(1) as u64;
    let windows = chunks.div_ceil(window);
    if !sim.wire_active() {
        sim.advance_host_time(chunks * (cost / 2) + windows * cost);
        return sim.chip_mut(chip)?.sdram.write(addr, data);
    }
    // Window-aware retransmission: only the window-boundary exchange is
    // acknowledged, so when it times out the host must stream the whole
    // window again (go-back-N) — each failed attempt re-pays the
    // in-window serialisation cost before the next boundary exchange.
    let board = sim.machine.nearest_ethernet(chip).unwrap_or(chip);
    let timeout = sim.config.wire.scp_timeout_ns;
    let budget = sim.config.wire.scp_retries;
    let mut remaining = chunks;
    while remaining > 0 {
        let in_window = remaining.min(window);
        let mut delivered_before = false;
        let mut attempt: u32 = 0;
        loop {
            sim.advance_host_time(in_window * (cost / 2));
            let outcome = sim.wire_scp_attempt(board, delivered_before);
            delivered_before |= outcome.delivered;
            if outcome.replied {
                sim.advance_host_time(cost);
                break;
            }
            sim.wire_stats_mut().scp_timeouts += 1;
            if attempt >= budget {
                sim.note_wire_escalation(board);
                anyhow::bail!(
                    "board {board:?} silent: batched write window to chip {chip:?} unacknowledged \
                     after {} attempts (escalated to the supervisor)",
                    attempt + 1
                );
            }
            let backoff = timeout.saturating_mul(1 << attempt.min(6));
            sim.advance_host_time(timeout + backoff);
            let stats = sim.wire_stats_mut();
            stats.backoff_wait_ns += backoff;
            stats.scp_retries += 1;
            attempt += 1;
        }
        remaining -= in_window;
    }
    sim.chip_mut(chip)?.sdram.write(addr, data)
}

/// Load the multicast routing table of a chip (§6.3.4). Enforces the
/// hardware TCAM limit — oversubscribed tables must be compressed first.
pub fn load_routing_table(
    sim: &mut SimMachine,
    chip: ChipCoord,
    table: RoutingTable,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        table.len() <= ROUTER_ENTRIES,
        "routing table for {chip:?} has {} entries (TCAM holds {ROUTER_ENTRIES})",
        table.len()
    );
    let rtt = sim.config.wire.eth_read_rtt_ns;
    scp_exchange(sim, chip, 1, rtt)?;
    // Through install_table so the chip's route cache is invalidated.
    sim.chip_mut(chip)?.install_table(table);
    Ok(())
}

/// Install an IP tag on a board's Ethernet chip (§3).
pub fn set_iptag(
    sim: &mut SimMachine,
    board: ChipCoord,
    tag: u8,
    host: &str,
    port: u16,
    strip_sdp: bool,
) -> anyhow::Result<()> {
    scp_exchange(sim, board, 1, 0)?;
    sim.chip_mut(board)?
        .iptags
        .insert(tag, (host.to_string(), port, strip_sdp));
    Ok(())
}

/// Install a reverse IP tag: UDP on `port` is forwarded to `dest`.
pub fn set_reverse_iptag(
    sim: &mut SimMachine,
    board: ChipCoord,
    port: u16,
    dest: CoreLocation,
) -> anyhow::Result<()> {
    scp_exchange(sim, board, 1, 0)?;
    sim.chip_mut(board)?.reverse_iptags.insert(port, dest);
    Ok(())
}

/// Remove every IP tag and reverse IP tag from a board's Ethernet chip
/// — the multi-tenant service's sweep when a partition is freed, so the
/// next tenant's data plane finds all tag slots free again (the tag
/// allocators seed themselves from what is installed on the chip).
pub fn clear_tags(sim: &mut SimMachine, board: ChipCoord) -> anyhow::Result<()> {
    scp_exchange(sim, board, 1, 0)?;
    let chip = sim.chip_mut(board)?;
    chip.iptags.clear();
    chip.reverse_iptags.clear();
    Ok(())
}

/// Load an application "binary" onto a core with its data regions and
/// recording channels (§6.3.4's loading phase). Data bytes pay the SCAMP
/// write cost; the binary load is flood-filled and charged once.
pub fn load_app(
    sim: &mut SimMachine,
    loc: CoreLocation,
    app: Box<dyn CoreApp>,
    regions: BTreeMap<u32, Vec<u8>>,
    recording_sizes: BTreeMap<u32, u32>,
) -> anyhow::Result<()> {
    load_app_named(sim, loc, "app.aplx", app, regions, recording_sizes)
}

pub fn load_app_named(
    sim: &mut SimMachine,
    loc: CoreLocation,
    binary_name: &str,
    app: Box<dyn CoreApp>,
    regions: BTreeMap<u32, Vec<u8>>,
    recording_sizes: BTreeMap<u32, u32>,
) -> anyhow::Result<()> {
    // Write the data regions (cost-modelled), then wire the region table.
    let mut region_table = BTreeMap::new();
    for (id, data) in &regions {
        let addr = alloc_sdram(sim, loc.chip(), data.len() as u32)?;
        write_sdram(sim, loc.chip(), addr, data)?;
        region_table.insert(*id, (addr, data.len() as u32));
    }
    install_app(sim, loc, binary_name, app, region_table, recording_sizes)
}

/// Attach a binary to a core whose data regions were already allocated
/// and written by some other path (the bulk data plane, batched writes):
/// wires the region table, allocates recording channels and charges the
/// flood-filled binary load — but moves no region bytes itself.
pub fn install_app(
    sim: &mut SimMachine,
    loc: CoreLocation,
    binary_name: &str,
    app: Box<dyn CoreApp>,
    region_table: BTreeMap<u32, (u32, u32)>,
    recording_sizes: BTreeMap<u32, u32>,
) -> anyhow::Result<()> {
    let mut recordings = BTreeMap::new();
    for (channel, size) in &recording_sizes {
        let addr = alloc_sdram(sim, loc.chip(), *size)?;
        recordings.insert(
            *channel,
            RecordingChannel { addr, capacity: *size as usize, write_pos: 0, lost_bytes: 0 },
        );
    }
    let rtt = sim.config.wire.eth_read_rtt_ns;
    scp_exchange(sim, loc.chip(), 1, rtt)?; // binary load
    let chip = sim.chip_mut(loc.chip())?;
    let core = chip
        .cores
        .get_mut(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc} (blacklisted?)"))?;
    anyhow::ensure!(
        core.state == CoreState::Idle,
        "core {loc} already loaded ({:?})",
        core.state
    );
    *core = SimCore {
        app: Some(app),
        state: CoreState::Ready,
        binary_name: binary_name.to_string(),
        regions: region_table,
        recordings,
        provenance: BTreeMap::new(),
        iobuf: String::new(),
        ticks_done: 0,
        run_until: 0,
        tx_busy_ns: 0,
    };
    Ok(())
}

/// A core's region table (region id -> (sdram addr, length)) — how the
/// incremental reloader (§6.5 "graph changed" path) finds where a
/// still-valid region already lives so it can skip or overwrite it
/// in place instead of re-transferring everything.
pub fn region_table(
    sim: &SimMachine,
    loc: CoreLocation,
) -> anyhow::Result<BTreeMap<u32, (u32, u32)>> {
    Ok(sim
        .chip(loc.chip())?
        .cores
        .get(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?
        .regions
        .clone())
}

/// Unload a core entirely (back to Idle, app dropped). Used when a
/// graph mutation removed the vertex that lived there. The bump
/// allocator does not reclaim the core's SDRAM; stray multicast packets
/// to an idle core are silently ignored by the fabric.
pub fn unload_app(sim: &mut SimMachine, loc: CoreLocation) -> anyhow::Result<()> {
    let chip = sim.chip_mut(loc.chip())?;
    let core = chip
        .cores
        .get_mut(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
    anyhow::ensure!(core.state != CoreState::Idle, "core {loc} is not loaded");
    *core = SimCore::idle();
    Ok(())
}

/// Replace the binary on an already-loaded core for a re-mapped run:
/// the fresh `app` starts from Ready with tick counters zeroed, the
/// given region table (regions themselves were written by the caller —
/// often just the old ones, verified unchanged by digest), and
/// recording channels reused in place when their capacity matches the
/// request (write cursors reset), reallocated otherwise. Charges one
/// flood-fill like the first load.
pub fn reload_app(
    sim: &mut SimMachine,
    loc: CoreLocation,
    binary_name: &str,
    app: Box<dyn CoreApp>,
    region_table: BTreeMap<u32, (u32, u32)>,
    recording_sizes: BTreeMap<u32, u32>,
) -> anyhow::Result<()> {
    // Harvest reusable recording channels from the outgoing core.
    let old_recordings = {
        let chip = sim.chip_mut(loc.chip())?;
        let core = chip
            .cores
            .get_mut(loc.p)
            .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
        anyhow::ensure!(core.state != CoreState::Idle, "core {loc} is not loaded; install instead");
        std::mem::take(&mut core.recordings)
    };
    let mut recordings = BTreeMap::new();
    for (channel, size) in &recording_sizes {
        let reuse = old_recordings
            .get(channel)
            .filter(|ch| ch.capacity == *size as usize)
            .map(|ch| ch.addr);
        let addr = match reuse {
            Some(addr) => addr,
            None => alloc_sdram(sim, loc.chip(), *size)?,
        };
        recordings.insert(
            *channel,
            RecordingChannel { addr, capacity: *size as usize, write_pos: 0, lost_bytes: 0 },
        );
    }
    let rtt = sim.config.wire.eth_read_rtt_ns;
    scp_exchange(sim, loc.chip(), 1, rtt)?; // binary load
    let chip = sim.chip_mut(loc.chip())?;
    let core = chip
        .cores
        .get_mut(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
    *core = SimCore {
        app: Some(app),
        state: CoreState::Ready,
        binary_name: binary_name.to_string(),
        regions: region_table,
        recordings,
        provenance: BTreeMap::new(),
        iobuf: String::new(),
        ticks_done: 0,
        run_until: 0,
        tx_busy_ns: 0,
    };
    Ok(())
}

/// Start signal: every Ready core runs `on_start` and becomes Running
/// (it will not tick until a run cycle begins). The signal command is
/// one broadcast through the reliable exchange — duplicated signal
/// frames are dropped by SCAMP's sequence check, so a run never starts
/// twice.
pub fn signal_start(sim: &mut SimMachine) -> anyhow::Result<()> {
    signal_exchange(sim)?;
    let locs = cores_in_state(sim, CoreState::Ready);
    for loc in locs {
        sim.with_core_app(loc, |app, ctx| app.on_start(ctx))?;
        set_state(sim, loc, CoreState::Running)?;
    }
    sim.run_until_idle()
}

/// The reliable exchange carrying one broadcast signal (start / resume /
/// stop), issued via the root board.
fn signal_exchange(sim: &mut SimMachine) -> anyhow::Result<()> {
    match root_board(sim) {
        Some(board) => scp_exchange(sim, board, 1, 0),
        None => Ok(()),
    }
}

/// Resume signal after a pause: `on_resume` for every Paused core.
pub fn signal_resume(sim: &mut SimMachine) -> anyhow::Result<()> {
    signal_exchange(sim)?;
    let locs = cores_in_state(sim, CoreState::Paused);
    for loc in locs {
        sim.with_core_app(loc, |app, ctx| app.on_resume(ctx))?;
    }
    Ok(())
}

/// Stop signal: running/paused cores become Finished.
pub fn signal_stop(sim: &mut SimMachine) -> anyhow::Result<()> {
    signal_exchange(sim)?;
    for state in [CoreState::Running, CoreState::Paused] {
        for loc in cores_in_state(sim, state) {
            set_state(sim, loc, CoreState::Finished)?;
        }
    }
    Ok(())
}

fn cores_in_state(sim: &SimMachine, want: CoreState) -> Vec<CoreLocation> {
    let mut out = Vec::new();
    for c in sim.machine.chip_coords().collect::<Vec<_>>() {
        if !sim.in_scope(c) {
            continue;
        }
        if let Ok(chip) = sim.chip(c) {
            for (p, core) in chip.cores.iter() {
                if core.state == want {
                    out.push(CoreLocation::new(c.0, c.1, p));
                }
            }
        }
    }
    out
}

fn set_state(sim: &mut SimMachine, loc: CoreLocation, state: CoreState) -> anyhow::Result<()> {
    let chip = sim.chip_mut(loc.chip())?;
    let core = chip
        .cores
        .get_mut(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
    // Do not clobber failure states reached during callbacks or injected
    // by the chaos engine.
    if !matches!(
        core.state,
        CoreState::RunTimeError | CoreState::Finished | CoreState::Watchdog
    ) || state == CoreState::Finished
    {
        core.state = state;
    }
    Ok(())
}

/// One core's run state (the CMD_CORE_STATE poll of §6.3.5). Errors
/// when the core's board is host-unreachable (silent or escalated wire)
/// — the poll cannot cross a dark link.
pub fn core_state(sim: &SimMachine, loc: CoreLocation) -> anyhow::Result<CoreState> {
    anyhow::ensure!(
        !sim.host_unreachable(loc.chip()),
        "chip {:?} unreachable (board host link silent)",
        loc.chip()
    );
    Ok(sim
        .chip(loc.chip())?
        .cores
        .get(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?
        .state)
}

/// All loaded cores and their states. Chips behind a silent board do
/// not answer and are absent from the scan — exactly what the run
/// supervisor observes as "cores vanished" and converts into a heal.
/// Confined to the session scope when one is set: a tenant's poll
/// neither sees nor pays for other tenants' cores.
pub fn core_states(sim: &SimMachine) -> BTreeMap<CoreLocation, CoreState> {
    let mut out = BTreeMap::new();
    for c in sim.machine.chip_coords().collect::<Vec<_>>() {
        if sim.host_unreachable(c) || !sim.in_scope(c) {
            continue;
        }
        if let Ok(chip) = sim.chip(c) {
            for (p, core) in chip.cores.iter() {
                if core.state != CoreState::Idle {
                    out.insert(CoreLocation::new(c.0, c.1, p), core.state);
                }
            }
        }
    }
    out
}

/// A core's provenance counters (§6.3.5).
pub fn provenance(sim: &SimMachine, loc: CoreLocation) -> anyhow::Result<BTreeMap<String, u64>> {
    Ok(sim
        .chip(loc.chip())?
        .cores
        .get(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?
        .provenance
        .clone())
}

/// Read a core's IOBUF (the `CMD_IOBUF` error readback of §6.3.5: the
/// tools pull the SARK `io_printf` buffer off every failed core so the
/// error text reaches the user). Charged like an SDRAM read of the
/// buffer's length. Errors for dead/unreachable chips — a dead chip's
/// IOBUF is gone with it.
pub fn read_iobuf(sim: &mut SimMachine, loc: CoreLocation) -> anyhow::Result<String> {
    let text = sim
        .chip(loc.chip())?
        .cores
        .get(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?
        .iobuf
        .clone();
    let cost = chunk_cost(sim, loc.chip());
    let chunks = text.len().div_ceil(SCP_CHUNK).max(1) as u64;
    scp_exchange(sim, loc.chip(), chunks, cost)?;
    Ok(text)
}

/// Re-discover the machine after runtime faults (§6.3.1, run again):
/// returns the degraded [`Machine`] view with every newly-dead resource
/// excluded — chips and links the chaos engine killed are already gone
/// from the live `sim.machine`, and this adds the *core*-level
/// blacklist: cores currently in `RunTimeError`/`Watchdog` plus any in
/// `extra_excluded` (cores a supervisor quarantined in an earlier heal,
/// whose states have since been reset by unloading). Charged one SCP
/// round trip per chip, like the initial discovery sweep.
pub fn rediscover_machine(
    sim: &mut SimMachine,
    extra_excluded: &std::collections::BTreeSet<CoreLocation>,
) -> crate::machine::Machine {
    let mut machine = sim.machine.clone();
    let mut excluded: Vec<CoreLocation> = extra_excluded.iter().copied().collect();
    for (loc, state) in core_states(sim) {
        if matches!(state, CoreState::RunTimeError | CoreState::Watchdog) {
            excluded.push(loc);
        }
    }
    for loc in excluded {
        if let Some(chip) = machine.chip_mut(loc.chip()) {
            chip.remove_processor(loc.p);
        }
    }
    // Sweep chip state through the reliable SCP layer, one exchange per
    // chip: ordinary frame loss is retried invisibly, while a board
    // whose host link is dark (or that exhausts its retry budget
    // mid-sweep) is dropped from the discovered view with all its
    // chips, exactly as a dead board would be.
    let rtt = sim.config.wire.eth_read_rtt_ns;
    let coords: Vec<ChipCoord> = machine.chip_coords().collect();
    let mut dark_boards = std::collections::BTreeSet::new();
    for c in coords {
        // Out-of-scope chips belong to other tenants: the sweep does not
        // touch (or pay for) them, and their boards cannot be declared
        // dark by this session.
        if !sim.in_scope(c) {
            continue;
        }
        let board = sim.machine.nearest_ethernet(c).unwrap_or(c);
        if dark_boards.contains(&board) {
            continue;
        }
        if sim.host_unreachable(c) || scp_exchange(sim, c, 1, rtt).is_err() {
            dark_boards.insert(board);
        }
    }
    if !dark_boards.is_empty() {
        let dark_chips: Vec<ChipCoord> = machine
            .chip_coords()
            .filter(|c| {
                sim.machine
                    .nearest_ethernet(*c)
                    .is_some_and(|b| dark_boards.contains(&b))
            })
            .collect();
        for c in dark_chips {
            machine.remove_chip(c);
        }
    }
    machine
}

/// Recording-channel descriptor: (sdram addr, bytes written, capacity).
pub fn recording_info(
    sim: &SimMachine,
    loc: CoreLocation,
    channel: u32,
) -> anyhow::Result<(u32, usize, usize)> {
    let core = sim
        .chip(loc.chip())?
        .cores
        .get(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
    let ch = core
        .recordings
        .get(&channel)
        .ok_or_else(|| anyhow::anyhow!("core {loc} has no recording channel {channel}"))?;
    Ok((ch.addr, ch.write_pos, ch.capacity))
}

/// One core's captured run state: everything a checkpoint needs to put
/// an equivalent core back on (possibly different) silicon. App state
/// comes from [`CoreApp::snapshot_state`]; recording buffers carry the
/// bytes written since the last Figure-9 drain.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreSnapshot {
    /// Evolving app state, if the binary keeps any.
    pub app_state: Option<Vec<u8>>,
    /// channel -> (undrained buffer bytes, lost_bytes counter).
    pub recordings: BTreeMap<u32, (Vec<u8>, u64)>,
    pub provenance: BTreeMap<String, u64>,
    pub iobuf: String,
    pub ticks_done: u64,
}

/// Capture a loaded core's run state. A host-side operation, charged
/// like the SDRAM reads it is made of.
pub fn capture_core(sim: &mut SimMachine, loc: CoreLocation) -> anyhow::Result<CoreSnapshot> {
    let (snap, bytes_moved) = {
        let chip = sim.chip(loc.chip())?;
        let core = chip
            .cores
            .get(loc.p)
            .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
        anyhow::ensure!(core.state != CoreState::Idle, "core {loc} is not loaded");
        let app_state = core.app.as_ref().and_then(|a| a.snapshot_state());
        let mut recordings = BTreeMap::new();
        let mut moved = app_state.as_ref().map(|s| s.len()).unwrap_or(0);
        for (id, ch) in &core.recordings {
            let data = chip.sdram.read(ch.addr, ch.write_pos)?;
            moved += data.len();
            recordings.insert(*id, (data, ch.lost_bytes));
        }
        (
            CoreSnapshot {
                app_state,
                recordings,
                provenance: core.provenance.clone(),
                iobuf: core.iobuf.clone(),
                ticks_done: core.ticks_done,
            },
            moved,
        )
    };
    let cost = chunk_cost(sim, loc.chip());
    let chunks = bytes_moved.div_ceil(SCP_CHUNK).max(1) as u64;
    scp_exchange(sim, loc.chip(), chunks, cost)?;
    Ok(snap)
}

/// Restore a captured core onto a loaded-and-started core: overwrite
/// the evolving app state (static config was re-read by `on_start`),
/// refill the recording buffers at their *current* addresses, put back
/// provenance/IOBUF, and park the core `Paused` at `resume_tick` so the
/// next run cycle continues the tail instead of replaying history.
pub fn restore_core(
    sim: &mut SimMachine,
    loc: CoreLocation,
    snap: &CoreSnapshot,
    resume_tick: u64,
) -> anyhow::Result<()> {
    let bytes_moved = snap.app_state.as_ref().map(|s| s.len()).unwrap_or(0)
        + snap.recordings.values().map(|(d, _)| d.len()).sum::<usize>();
    let cost = chunk_cost(sim, loc.chip());
    let chunks = bytes_moved.div_ceil(SCP_CHUNK).max(1) as u64;
    scp_exchange(sim, loc.chip(), chunks, cost)?;
    let chip = sim.chip_mut(loc.chip())?;
    let core = chip
        .cores
        .get_mut(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
    anyhow::ensure!(core.state != CoreState::Idle, "core {loc} is not loaded");
    for (id, (data, lost)) in &snap.recordings {
        let ch = core.recordings.get_mut(id).ok_or_else(|| {
            anyhow::anyhow!("core {loc} has no recording channel {id} to restore")
        })?;
        anyhow::ensure!(
            data.len() <= ch.capacity,
            "snapshot channel {id} holds {} bytes, buffer capacity is {}",
            data.len(),
            ch.capacity
        );
        chip.sdram.write(ch.addr, data)?;
        ch.write_pos = data.len();
        ch.lost_bytes = *lost;
    }
    if let Some(state) = &snap.app_state {
        let app = core
            .app
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("core {loc} has snapshot state but no app"))?;
        app.restore_state(state)?;
    }
    core.provenance = snap.provenance.clone();
    core.iobuf = snap.iobuf.clone();
    core.ticks_done = resume_tick;
    core.run_until = resume_tick;
    core.state = CoreState::Paused;
    Ok(())
}

/// Reset a recording channel after extraction (the Figure-9 flush).
pub fn clear_recording(sim: &mut SimMachine, loc: CoreLocation, channel: u32) -> anyhow::Result<()> {
    let chip = sim.chip_mut(loc.chip())?;
    let core = chip
        .cores
        .get_mut(loc.p)
        .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
    let ch = core
        .recordings
        .get_mut(&channel)
        .ok_or_else(|| anyhow::anyhow!("no channel {channel}"))?;
    ch.write_pos = 0;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;
    use crate::simulator::{CoreCtx, SimConfig};

    struct Recorder;
    impl CoreApp for Recorder {
        fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            let tick = ctx.tick as u32;
            ctx.record(0, &tick.to_le_bytes());
            Ok(())
        }
    }

    #[test]
    fn sdram_read_write_via_scamp() {
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let addr = alloc_sdram(&mut sim, (0, 0), 1024).unwrap();
        let data: Vec<u8> = (0..255).collect();
        write_sdram(&mut sim, (0, 0), addr, &data).unwrap();
        assert_eq!(read_sdram(&mut sim, (0, 0), addr, 255).unwrap(), data);
    }

    #[test]
    fn batched_writes_are_cheaper_and_identical() {
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let len = 64 * 1024;
        let data: Vec<u8> = (0..len).map(|i| (i % 253) as u8).collect();
        let chip = (7, 7);
        let a = alloc_sdram(&mut sim, chip, len as u32).unwrap();
        let t0 = sim.now_ns();
        write_sdram(&mut sim, chip, a, &data).unwrap();
        let naive = sim.now_ns() - t0;
        let b = alloc_sdram(&mut sim, chip, len as u32).unwrap();
        let t1 = sim.now_ns();
        write_sdram_batched(&mut sim, chip, b, &data).unwrap();
        let batched = sim.now_ns() - t1;
        // Window of 8: in-window chunks at half cost + one RTT per window
        // => ~0.625x the naive cost. Faster, but far from free.
        assert!(batched < naive, "batched {batched} ns vs naive {naive} ns");
        assert!(batched * 2 > naive, "batching cannot beat the protocol itself");
        assert_eq!(read_sdram(&mut sim, chip, b, len).unwrap(), data);
    }

    #[test]
    fn read_costs_match_fig11_ratios() {
        // E1 calibration: ethernet-chip reads ~8 Mb/s; distant chip ~2 Mb/s.
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let len = 64 * 1024;
        let a = alloc_sdram(&mut sim, (0, 0), len as u32).unwrap();
        let t0 = sim.now_ns();
        read_sdram(&mut sim, (0, 0), a, len).unwrap();
        let eth_time = sim.now_ns() - t0;
        let b = alloc_sdram(&mut sim, (7, 7), len as u32).unwrap();
        let t1 = sim.now_ns();
        read_sdram(&mut sim, (7, 7), b, len).unwrap();
        let far_time = sim.now_ns() - t1;
        let eth_mbps = (len as f64 * 8.0) / (eth_time as f64 / 1e9) / 1e6;
        let far_mbps = (len as f64 * 8.0) / (far_time as f64 / 1e9) / 1e6;
        assert!((7.0..9.0).contains(&eth_mbps), "eth {eth_mbps} Mb/s");
        assert!((1.5..2.5).contains(&far_mbps), "far {far_mbps} Mb/s");
    }

    #[test]
    fn recording_and_clear_cycle() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        let mut rec = BTreeMap::new();
        rec.insert(0u32, 1024u32);
        load_app(&mut sim, loc, Box::new(Recorder), BTreeMap::new(), rec).unwrap();
        signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        let (addr, written, cap) = recording_info(&sim, loc, 0).unwrap();
        assert_eq!(written, 20); // 5 ticks x 4 bytes
        assert_eq!(cap, 1024);
        let data = read_sdram(&mut sim, loc.chip(), addr, written).unwrap();
        let ticks: Vec<u32> = data
            .chunks(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(ticks, vec![1, 2, 3, 4, 5]);
        clear_recording(&mut sim, loc, 0).unwrap();
        let (_, w2, _) = recording_info(&sim, loc, 0).unwrap();
        assert_eq!(w2, 0);
    }

    #[test]
    fn recording_overflow_is_counted_not_fatal() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        let mut rec = BTreeMap::new();
        rec.insert(0u32, 8u32); // room for 2 ticks only
        load_app(&mut sim, loc, Box::new(Recorder), BTreeMap::new(), rec).unwrap();
        signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        let prov = provenance(&sim, loc).unwrap();
        assert_eq!(prov.get("recording_overflow"), Some(&3));
        assert_eq!(core_state(&sim, loc).unwrap(), CoreState::Paused);
    }

    #[test]
    fn iobuf_captures_rte_text_and_rediscovery_excludes_failures() {
        struct BadApp;
        impl CoreApp for BadApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                if ctx.tick >= 2 {
                    anyhow::bail!("synapse row overran DTCM")
                }
                ctx.log("tick ok");
                Ok(())
            }
        }
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(1, 1, 2);
        load_app(&mut sim, loc, Box::new(BadApp), BTreeMap::new(), BTreeMap::new()).unwrap();
        signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        assert_eq!(core_state(&sim, loc).unwrap(), CoreState::RunTimeError);
        let text = read_iobuf(&mut sim, loc).unwrap();
        assert!(text.contains("tick ok"), "{text}");
        assert!(text.contains("RTE at"), "{text}");
        assert!(text.contains("synapse row overran DTCM"), "{text}");
        // Re-discovery blacklists the failed core but keeps the chip.
        let degraded = rediscover_machine(&mut sim, &Default::default());
        let chip = degraded.chip((1, 1)).unwrap();
        assert!(chip.processor(2).is_none(), "failed core must be excluded");
        assert_eq!(chip.n_application_cores(), 16);
        // Extra exclusions apply even when states were since reset.
        let mut extra = std::collections::BTreeSet::new();
        extra.insert(CoreLocation::new(0, 1, 5));
        let degraded = rediscover_machine(&mut sim, &extra);
        assert!(degraded.chip((0, 1)).unwrap().processor(5).is_none());
    }

    #[test]
    fn capture_restore_continues_the_tick_stream() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        let mut rec = BTreeMap::new();
        rec.insert(0u32, 1024u32);
        load_app(&mut sim, loc, Box::new(Recorder), BTreeMap::new(), rec.clone()).unwrap();
        signal_start(&mut sim).unwrap();
        sim.start_run_cycle(3);
        sim.run_until_idle().unwrap();
        let snap = capture_core(&mut sim, loc).unwrap();
        assert_eq!(snap.ticks_done, 3);
        assert_eq!(snap.recordings[&0].0.len(), 12);
        // Simulate a reload (fresh binary state, cursors reset), then
        // restore: the tick stream must continue at 4, not replay 1..3.
        reload_app(&mut sim, loc, "app.aplx", Box::new(Recorder), BTreeMap::new(), rec).unwrap();
        signal_start(&mut sim).unwrap();
        restore_core(&mut sim, loc, &snap, 3).unwrap();
        assert_eq!(core_state(&sim, loc).unwrap(), CoreState::Paused);
        sim.start_run_cycle(2);
        sim.run_until_idle().unwrap();
        let (addr, written, _) = recording_info(&sim, loc, 0).unwrap();
        assert_eq!(written, 20);
        let data = read_sdram(&mut sim, loc.chip(), addr, written).unwrap();
        let ticks: Vec<u32> = data
            .chunks(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(ticks, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn double_load_rejected() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        load_app(&mut sim, loc, Box::new(Recorder), BTreeMap::new(), BTreeMap::new()).unwrap();
        assert!(
            load_app(&mut sim, loc, Box::new(Recorder), BTreeMap::new(), BTreeMap::new()).is_err()
        );
    }

    #[test]
    fn oversized_routing_table_rejected() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let entries: Vec<_> = (0..1025)
            .map(|k| {
                crate::machine::router::RoutingEntry::new(
                    k,
                    !0,
                    crate::machine::router::Route::EMPTY.with_processor(1),
                )
            })
            .collect();
        let table = RoutingTable::from_entries(entries);
        assert!(load_routing_table(&mut sim, (0, 0), table).is_err());
    }

    #[test]
    fn region_data_visible_to_core() {
        struct RegionReader;
        impl CoreApp for RegionReader {
            fn on_start(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                let data = ctx.read_region(7)?;
                anyhow::ensure!(data == vec![1, 2, 3, 4], "bad region data");
                ctx.count("region_ok", 1);
                Ok(())
            }
            fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
                Ok(())
            }
        }
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let loc = CoreLocation::new(1, 1, 3);
        let mut regions = BTreeMap::new();
        regions.insert(7u32, vec![1, 2, 3, 4]);
        load_app(&mut sim, loc, Box::new(RegionReader), regions, BTreeMap::new()).unwrap();
        signal_start(&mut sim).unwrap();
        assert_eq!(provenance(&sim, loc).unwrap().get("region_ok"), Some(&1));
    }
}
