//! The chaos engine: seeded runtime fault injection.
//!
//! At the scale the paper targets (a million cores; ten million on
//! SpiNNaker-2) dead cores, chips and links are the steady state, not
//! the exception. Boot-time faults are already first-class — the machine
//! representation excludes blacklisted resources at discovery — but a
//! long run must also survive *mid-execution* failures. A [`ChaosPlan`]
//! schedules such failures as ordinary simulator events: at its tick a
//! [`Fault`] mutates the live [`super::SimMachine`] — dead cores stop
//! dispatching, dead links and chips swallow packets, and core states
//! flip so the front end's run supervisor can observe the failure
//! exactly the way the real tools do (polling core state, §6.3.5).
//!
//! All injection is deterministic: a plan is data, and
//! [`ChaosPlan::single_random`] derives one reproducibly from a seed.

use crate::machine::{ChipCoord, CoreLocation, Direction, Machine, ALL_DIRECTIONS};
use crate::util::SplitMix64;

/// One injectable failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// The application on this core hits a run-time error: the core
    /// enters `RunTimeError`, stops ticking, and an error blob lands in
    /// its IOBUF.
    CoreRte(CoreLocation),
    /// The core hangs (stops servicing its timer); the watchdog fires
    /// and SCAMP reports `Watchdog`.
    CoreStall(CoreLocation),
    /// The whole chip dies: every core stops dispatching, the router
    /// swallows traffic, SCAMP can no longer reach it, and neighbours
    /// lose their links toward it.
    ChipDeath(ChipCoord),
    /// One inter-chip link dies (both directions). Packets routed over
    /// it are gone for good — reinjection replays into the same void.
    LinkDeath(ChipCoord, Direction),
    /// The *host* link of one board degrades: for `duration_ns` every
    /// UDP frame between the host and `board`'s Ethernet chip suffers an
    /// extra `loss_permille` loss on top of the base wire-fault plan.
    /// The fabric itself is untouched — only host traffic suffers.
    LinkBrownout {
        board: ChipCoord,
        loss_permille: u16,
        duration_ns: u64,
    },
    /// The board's host link goes completely dark for `duration_ns`
    /// (`u64::MAX` = permanently): no frame crosses in either direction.
    /// The reliable SCP layer retries, backs off, and finally escalates
    /// the board to the supervisor/heal path.
    BoardSilent { board: ChipCoord, duration_ns: u64 },
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::CoreRte(loc) => write!(f, "core {loc} RTE"),
            Fault::CoreStall(loc) => write!(f, "core {loc} stalled (watchdog)"),
            Fault::ChipDeath(c) => write!(f, "chip {c:?} died"),
            Fault::LinkDeath(c, d) => write!(f, "link {c:?}/{d:?} died"),
            Fault::LinkBrownout { board, loss_permille, duration_ns } => write!(
                f,
                "host link of board {board:?} browned out ({loss_permille}‰ loss for {duration_ns} ns)"
            ),
            Fault::BoardSilent { board, duration_ns } => {
                if *duration_ns == u64::MAX {
                    write!(f, "host link of board {board:?} silent (permanently)")
                } else {
                    write!(f, "host link of board {board:?} silent for {duration_ns} ns")
                }
            }
        }
    }
}

/// A fault scheduled at an absolute run tick (tick `t` means "after
/// timer tick `t` completes, before `t + 1` begins", counting from the
/// start of the run — tick 0 fires before the first timer event).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChaosEvent {
    pub at_tick: u64,
    pub fault: Fault,
}

/// A schedule of mid-run faults, injected via
/// [`crate::front::SpiNNTools::inject_chaos`] (or scheduled directly on
/// a [`super::SimMachine`] in tests).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    pub events: Vec<ChaosEvent>,
}

impl ChaosPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder: add one fault at a tick.
    pub fn with(mut self, at_tick: u64, fault: Fault) -> Self {
        self.events.push(ChaosEvent { at_tick, fault });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// A reproducible single-fault plan: one fault of a seed-chosen kind
    /// at a seed-chosen tick in `1..=max_tick`, targeting a seed-chosen
    /// *eligible* resource of `machine`. Ethernet chips are never killed
    /// (the board would lose its host connection — a failure the tools
    /// cannot heal around), monitor cores are never targeted, and
    /// chip/link targets are real (non-virtual) chips.
    pub fn single_random(seed: u64, machine: &Machine, max_tick: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let at_tick = 1 + rng.below(max_tick.max(1) as usize) as u64;
        let chips: Vec<ChipCoord> = machine
            .chips()
            .filter(|c| !c.is_virtual && !c.is_ethernet())
            .map(|c| (c.x, c.y))
            .collect();
        if chips.is_empty() {
            return Self::new();
        }
        let fault = match rng.below(4) {
            0 => {
                let (loc, _) = pick_core(&mut rng, machine, &chips);
                Fault::CoreRte(loc)
            }
            1 => {
                let (loc, _) = pick_core(&mut rng, machine, &chips);
                Fault::CoreStall(loc)
            }
            2 => Fault::ChipDeath(chips[rng.below(chips.len())]),
            _ => {
                // A link of a non-Ethernet chip that actually works.
                let mut pick = None;
                for _ in 0..64 {
                    let c = chips[rng.below(chips.len())];
                    let d = ALL_DIRECTIONS[rng.below(6)];
                    if machine.link_target(c, d).is_some() {
                        pick = Some((c, d));
                        break;
                    }
                }
                match pick {
                    Some((c, d)) => Fault::LinkDeath(c, d),
                    None => Fault::ChipDeath(chips[rng.below(chips.len())]),
                }
            }
        };
        Self::new().with(at_tick, fault)
    }
}

/// A random application core on a random eligible chip.
fn pick_core(
    rng: &mut SplitMix64,
    machine: &Machine,
    chips: &[ChipCoord],
) -> (CoreLocation, ChipCoord) {
    let c = chips[rng.below(chips.len())];
    let procs: Vec<u8> = machine
        .chip(c)
        .map(|ch| ch.application_processors().map(|p| p.id).collect())
        .unwrap_or_default();
    let p = if procs.is_empty() { 1 } else { procs[rng.below(procs.len())] };
    (CoreLocation::new(c.0, c.1, p), c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;

    #[test]
    fn single_random_is_deterministic_and_eligible() {
        let m = MachineBuilder::spinn5().build();
        for seed in 0..32u64 {
            let a = ChaosPlan::single_random(seed, &m, 8);
            let b = ChaosPlan::single_random(seed, &m, 8);
            assert_eq!(a, b, "plan for seed {seed} not reproducible");
            assert_eq!(a.events.len(), 1);
            let ev = &a.events[0];
            assert!((1..=8).contains(&ev.at_tick));
            let chip_of = |f: &Fault| match f {
                Fault::CoreRte(l) | Fault::CoreStall(l) => l.chip(),
                Fault::ChipDeath(c) => *c,
                Fault::LinkDeath(c, _) => *c,
                // Wire faults target the host link, never drawn by
                // single_random (they are scheduled explicitly).
                Fault::LinkBrownout { board, .. } | Fault::BoardSilent { board, .. } => *board,
            };
            let chip = m.chip(chip_of(&ev.fault)).expect("fault targets a real chip");
            assert!(!chip.is_ethernet(), "must not target the Ethernet chip");
            assert!(!chip.is_virtual);
            if let Fault::CoreRte(l) | Fault::CoreStall(l) = &ev.fault {
                assert_ne!(l.p, 0, "must not target the monitor core");
            }
        }
    }

    #[test]
    fn seeds_cover_every_fault_kind() {
        let m = MachineBuilder::spinn5().build();
        let mut kinds = [false; 4];
        for seed in 0..64u64 {
            match ChaosPlan::single_random(seed, &m, 4).events[0].fault {
                Fault::CoreRte(_) => kinds[0] = true,
                Fault::CoreStall(_) => kinds[1] = true,
                Fault::ChipDeath(_) => kinds[2] = true,
                Fault::LinkDeath(_, _) => kinds[3] = true,
                Fault::LinkBrownout { .. } | Fault::BoardSilent { .. } => {
                    panic!("single_random never draws wire faults")
                }
            }
        }
        assert!(kinds.iter().all(|k| *k), "kinds seen: {kinds:?}");
    }
}
