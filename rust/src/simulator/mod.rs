//! A discrete-event simulator of a SpiNNaker machine.
//!
//! The hardware substitute for this reproduction (DESIGN.md §4): a
//! cycle-approximate model of the router fabric (TCAM matching, default
//! routing, bounded output queues with the §2 drop-after-wait behaviour
//! and the single dropped-packet register of §6.10), per-chip SDRAM,
//! per-core event-driven applications ([`CoreApp`]), SCAMP-style host
//! operations with the §6.8 protocol cost models, IP tag tables and a
//! host UDP inbox.
//!
//! Virtual time is nanoseconds. All behaviour is deterministic: events
//! at equal times are ordered by insertion sequence.
//!
//! # The fabric fast path (experiment E11)
//!
//! The per-packet-per-hop hot path runs on three structures chosen by
//! [`FabricMode`] (DESIGN.md §5): a flat chip arena indexed `y * width
//! + x` with per-(chip, link) busy cursors and frozen link targets in
//! dense slots, a per-chip [`RouteCache`] memoising the first-match
//! TCAM scan, and a bucketed calendar [`queue::CalendarQueue`] making
//! same-cycle fan-out O(1). `FabricMode::Legacy` keeps the original
//! `BTreeMap` + linear-scan + `BinaryHeap` fabric for before/after
//! benchmarking; `tests/fabric_equivalence.rs` proves the two modes
//! byte-identical.

pub mod chaos;
mod core;
pub mod queue;
pub mod scamp;
mod sdram;

pub use self::core::{CoreApp, CoreCtx, CoreState, RecordingChannel};
pub use chaos::{ChaosEvent, ChaosPlan, Fault};
pub use sdram::{SdramStore, SDRAM_BASE};

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::machine::router::{PacketSource, Route, RouteCache, RoutingDecision, RoutingTable};
use crate::machine::{Chip, ChipCoord, CoreLocation, Direction, Machine, ALL_DIRECTIONS};
use crate::transport::SdpMessage;
use crate::util::SplitMix64;

use self::core::SimCore;
use self::queue::{CalendarQueue, EventQueue, HeapQueue};

/// Which fabric implementation the simulator runs on. The two modes are
/// behaviourally identical — same event order, same statistics, same
/// results (enforced by `tests/fabric_equivalence.rs`); `Legacy` exists
/// so experiment E11 can measure the fast path against the real
/// pre-change fabric rather than a remembered number.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FabricMode {
    /// Flat chip arena + per-chip route cache + calendar event queue.
    #[default]
    Fast,
    /// `BTreeMap` chip store, uncached first-match TCAM scans and a
    /// `BinaryHeap` event queue — the pre-E11 fabric.
    Legacy,
}

/// Wire/latency model. Defaults are calibrated so the three §6.8 data
/// paths reproduce the paper's measured throughputs (see DESIGN.md E1):
/// ~8 Mb/s SCAMP reads on the Ethernet chip, ~2 Mb/s off it, ~40 Mb/s
/// for the multicast streaming protocol from any chip.
#[derive(Debug, Clone)]
pub struct WireModel {
    /// Round trip for one 256-byte SCAMP read at the Ethernet chip
    /// (request + response through the UDP stack): 256 B / 8 Mb/s.
    pub eth_read_rtt_ns: u64,
    /// Extra cost per 256-byte SCAMP read when the target chip is not
    /// the Ethernet chip: the request/response must be broken into
    /// 24-bit P2P messages and reassembled (Figure 11 middle).
    pub p2p_read_penalty_ns: u64,
    /// Additional per-hop cost of the P2P relay.
    pub p2p_per_hop_ns: u64,
    /// Latency of one UDP frame between host and board.
    pub udp_frame_ns: u64,
    /// Chunks per pipelined window in *batched* SCAMP writes
    /// (`scamp::write_sdram_batched`): in-window chunks stream at half
    /// the round-trip cost and only the window boundary pays a full
    /// acknowledged RTT. `1` degenerates to the unbatched cost.
    pub scp_pipeline_window: u64,
    /// Host NIC serialisation gap between successive outbound UDP
    /// frames — the *aggregate* data-in ceiling across boards (per-board
    /// throughput is bounded by the dispatcher core's fan-out rate, see
    /// `front::extraction`). 5 µs/frame ≈ 400 Mb/s ≈ gigabit Ethernet
    /// with headroom.
    pub host_udp_gap_ns: u64,
    /// Per-request timeout before the host's reliable SCP layer
    /// retransmits (SpiNNMan uses 1 s wall-clock; virtual time here).
    pub scp_timeout_ns: u64,
    /// Retransmissions per SCP request before the board is declared
    /// silent and escalated to the supervisor/heal path.
    pub scp_retries: u32,
    /// Re-request/retransmission rounds in the bulk data plane
    /// (`front::extraction`) before a transport error is surfaced.
    pub bulk_retry_rounds: u32,
    /// Seeded fault plan applied to every host↔machine UDP frame.
    pub faults: WireFaults,
}

impl Default for WireModel {
    fn default() -> Self {
        Self {
            // 256 B * 8 bits / 8 Mb/s = 256 us.
            eth_read_rtt_ns: 256_000,
            // Total off-chip read ~ 1024 us/256 B => ~2 Mb/s.
            p2p_read_penalty_ns: 744_000,
            p2p_per_hop_ns: 4_000,
            udp_frame_ns: 50_000,
            scp_pipeline_window: 8,
            host_udp_gap_ns: 5_000,
            scp_timeout_ns: 1_000_000,
            scp_retries: 8,
            bulk_retry_rounds: 8,
            faults: WireFaults::none(),
        }
    }
}

/// Seeded fault plan for the host↔machine wire: the UDP leg between the
/// tools and the board Ethernet chips loses, duplicates, reorders and
/// delays frames. Probabilities are in permille (integer — the plan is
/// embedded in `Eq` types like [`chaos::Fault`]) and drawn from a
/// deterministic [`crate::util::SplitMix64`] stream seeded at boot, so a
/// given (seed, workload) pair always observes the same fault pattern.
/// The all-zero plan is the default and takes a draw-free fast path that
/// leaves timing bit-identical to a faultless build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFaults {
    /// RNG seed for the fault stream.
    pub seed: u64,
    /// Host→machine frame loss probability, permille.
    pub loss_h2m_permille: u16,
    /// Machine→host frame loss probability, permille.
    pub loss_m2h_permille: u16,
    /// Host→machine frame duplication probability, permille.
    pub dup_h2m_permille: u16,
    /// Machine→host frame duplication probability, permille.
    pub dup_m2h_permille: u16,
    /// Frames are delayed by up to this much extra (uniform), which
    /// reorders frames relative to each other.
    pub reorder_window_ns: u64,
    /// Additional per-frame latency jitter (uniform in `[0, jitter]`).
    pub jitter_ns: u64,
}

impl WireFaults {
    /// A perfect wire (the default): no draws, no overhead.
    pub fn none() -> Self {
        Self {
            seed: 0,
            loss_h2m_permille: 0,
            loss_m2h_permille: 0,
            dup_h2m_permille: 0,
            dup_m2h_permille: 0,
            reorder_window_ns: 0,
            jitter_ns: 0,
        }
    }

    /// Symmetric loss-only plan.
    pub fn lossy(seed: u64, loss_permille: u16) -> Self {
        Self {
            seed,
            loss_h2m_permille: loss_permille,
            loss_m2h_permille: loss_permille,
            ..Self::none()
        }
    }

    /// The adversarial plan used by the CI `WIRE_SEED` matrix: 5% loss
    /// each way, 2% duplication, 20 µs reordering window, 5 µs jitter.
    pub fn from_seed(seed: u64) -> Self {
        Self {
            seed,
            loss_h2m_permille: 50,
            loss_m2h_permille: 50,
            dup_h2m_permille: 20,
            dup_m2h_permille: 20,
            reorder_window_ns: 20_000,
            jitter_ns: 5_000,
        }
    }

    /// True when no fault can ever fire (the zero-overhead fast path).
    pub fn is_clean(&self) -> bool {
        self.loss_h2m_permille == 0
            && self.loss_m2h_permille == 0
            && self.dup_h2m_permille == 0
            && self.dup_m2h_permille == 0
            && self.reorder_window_ns == 0
            && self.jitter_ns == 0
    }
}

impl Default for WireFaults {
    fn default() -> Self {
        Self::none()
    }
}

/// Per-run counters of the reliable transport layer, surfaced in
/// provenance and in each `HealReport`. On a clean wire every field
/// stays zero (asserted by `tests/wire.rs` and E16). Integer-only so it
/// can ride in `Eq` report types.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// UDP frames eaten by the wire (either direction).
    pub frames_lost: u64,
    /// UDP frames the wire delivered twice.
    pub frames_duplicated: u64,
    /// UDP frames delivered late (jitter/reorder draw > 0).
    pub frames_delayed: u64,
    /// SCP requests that timed out awaiting a reply.
    pub scp_timeouts: u64,
    /// SCP retransmissions issued after a timeout.
    pub scp_retries: u64,
    /// Duplicate SCP replies discarded by the host's sequence check.
    pub dup_replies_dropped: u64,
    /// Duplicate SCP commands discarded by SCAMP's sequence check —
    /// what keeps non-idempotent ops (alloc, signal) exactly-once.
    pub dup_commands_dropped: u64,
    /// Virtual time spent in timeout + exponential backoff.
    pub backoff_wait_ns: u64,
    /// Bulk-plane retry rounds that came back empty and paid the
    /// timeout + backoff wait (the data plane's analogue of
    /// `scp_retries`; what lets a fast plane ride out a brownout).
    pub bulk_retry_waits: u64,
    /// Boards declared silent after the retry budget exhausted.
    pub escalations: u64,
    /// Live-output multicast keys the mapping database could not
    /// attribute to any vertex (surfaced as a provenance anomaly).
    pub unknown_live_keys: u64,
}

/// Direction of a host↔machine UDP frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WireDirection {
    HostToMachine,
    MachineToHost,
}

/// A scheduled wire degradation episode on one board's host link
/// (installed by [`chaos::Fault::LinkBrownout`] / `BoardSilent`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WireEpisodeKind {
    /// Extra frame loss on top of the base plan.
    Brownout { loss_permille: u16 },
    /// The board answers nothing at all.
    Silent,
}

#[derive(Debug, Clone, Copy)]
pub(crate) struct WireEpisode {
    pub board: ChipCoord,
    pub from_ns: u64,
    /// `u64::MAX` = until further notice.
    pub until_ns: u64,
    pub kind: WireEpisodeKind,
}

/// Outcome of one SCP request/response attempt on a faulty wire (see
/// [`SimMachine::wire_scp_attempt`]).
#[derive(Debug, Clone, Copy)]
pub(crate) struct ScpAttempt {
    /// The command reached SCAMP on this attempt.
    pub delivered: bool,
    /// The reply made it back to the host.
    pub replied: bool,
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Simulation timestep (the timer period), microseconds.
    pub timestep_us: u32,
    /// Serialisation time of one multicast packet on an inter-chip link
    /// (~6 M packets/s on silicon → ~166 ns).
    pub link_packet_ns: u64,
    /// Router pipeline latency per hop.
    pub router_pipeline_ns: u64,
    /// Delivery latency into a core's incoming queue.
    pub local_deliver_ns: u64,
    /// Output-queue depth per link; beyond this the router waits...
    pub link_queue_depth: u64,
    /// ...up to this long, then drops the packet (§2). The tools
    /// configure generous router timeouts in production; congestion
    /// experiments override this downwards.
    pub drop_wait_ns: u64,
    /// Spacing between successive packets emitted by one core within a
    /// single callback: a core produces packets as it iterates its
    /// neurons (~200 MHz ARM), not as an instantaneous burst.
    pub send_spacing_ns: u64,
    /// Keys at or above this value are flow-controlled, never dropped —
    /// the §6.8 fast-extraction configuration ("the machine is set up so
    /// that packets are guaranteed to arrive"; single path, no deadlock).
    pub lossless_key_min: u32,
    /// Whether chips run the dropped-packet reinjector (§6.10).
    pub reinjection: bool,
    /// Delay before the reinjection core re-issues a dropped packet.
    pub reinject_delay_ns: u64,
    /// Which fabric implementation to run on (E11). Purely a host
    /// wall-clock knob: results are identical in both modes.
    pub fabric: FabricMode,
    pub wire: WireModel,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            timestep_us: 1000,
            link_packet_ns: 166,
            router_pipeline_ns: 100,
            local_deliver_ns: 200,
            link_queue_depth: 16,
            drop_wait_ns: 200_000,
            send_spacing_ns: 500,
            lossless_key_min: 0xFF00_0000,
            reinjection: true,
            reinject_delay_ns: 10_000,
            fabric: FabricMode::default(),
            wire: WireModel::default(),
        }
    }
}

/// Router statistics per chip (§6.3.5 provenance: "router statistics,
/// including dropped multicast packets").
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    pub mc_routed: u64,
    pub mc_default_routed: u64,
    pub mc_dropped: u64,
    pub mc_reinjected: u64,
    /// Drops that hit an occupied register and are unrecoverable (§6.10).
    pub mc_lost_forever: u64,
    /// Route-cache hits (fast fabric only; always zero on the legacy
    /// path, which scans the TCAM per packet).
    pub cache_hits: u64,
    /// Route-cache misses (first sighting of a key, or after a table
    /// load invalidated the cache).
    pub cache_misses: u64,
    /// Packets routed into a link that no longer exists — zero on a
    /// healthy run (mapping never routes over boot-time-dead links), so
    /// any non-zero value means a link died *under* an installed route:
    /// the signal the run supervisor heals on.
    pub mc_dead_link: u64,
}

impl RouterStats {
    /// The routing-semantics counters — identical across [`FabricMode`]s
    /// even though the cache counters differ (the legacy path never
    /// caches). The equivalence suite compares these.
    pub fn semantic(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.mc_routed,
            self.mc_default_routed,
            self.mc_dropped,
            self.mc_reinjected,
            self.mc_lost_forever,
        )
    }
}

/// Whole-machine counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimStats {
    pub events_processed: u64,
    pub mc_sent: u64,
    pub mc_delivered: u64,
    pub sdp_sent: u64,
}

/// Hot/cold split core store for one chip (DESIGN.md §12): presence is
/// a 32-bit mask (hot — every router delivery checks it), and the heavy
/// [`SimCore`] records (app box, recordings, provenance, IOBUF) are
/// materialised lazily on first *mutation*. At SpiNNaker2 scale most
/// chips never have an app loaded, so a booted 100k-chip fabric carries
/// 100k masks instead of 1.8M `BTreeMap` nodes. All mutation goes
/// through [`CoreMap::get_mut`], so a present-but-unmaterialised core is
/// observably identical to a fresh `SimCore::idle()` — `get` serves
/// those from one shared idle stand-in per chip. (The stand-in is a
/// field, not a `static`: `SimCore` holds a `Box<dyn CoreApp>` slot and
/// is not `Sync`; it costs ~150 inline bytes and no heap.)
pub(crate) struct CoreMap {
    /// Bit `p` set ⇒ core `p` present (mirrors `Chip::core_mask`).
    present: u32,
    /// Materialised cores, sorted by id; empty until a core is touched.
    cores: Vec<(u8, SimCore)>,
    /// Read-only stand-in for present-but-untouched cores.
    idle: SimCore,
}

impl CoreMap {
    pub fn from_mask(present: u32) -> CoreMap {
        CoreMap { present, cores: Vec::new(), idle: SimCore::idle() }
    }

    #[inline]
    pub fn contains(&self, p: u8) -> bool {
        p < 32 && self.present & (1 << p) != 0
    }

    #[inline]
    pub fn get(&self, p: u8) -> Option<&SimCore> {
        if !self.contains(p) {
            return None;
        }
        match self.cores.binary_search_by_key(&p, |(id, _)| *id) {
            Ok(i) => Some(&self.cores[i].1),
            Err(_) => Some(&self.idle),
        }
    }

    #[inline]
    pub fn get_mut(&mut self, p: u8) -> Option<&mut SimCore> {
        if !self.contains(p) {
            return None;
        }
        let i = match self.cores.binary_search_by_key(&p, |(id, _)| *id) {
            Ok(i) => i,
            Err(i) => {
                self.cores.insert(i, (p, SimCore::idle()));
                i
            }
        };
        Some(&mut self.cores[i].1)
    }

    /// Present cores in ascending id order (the legacy `BTreeMap`
    /// iteration order); untouched cores yield the shared idle record.
    pub fn iter(&self) -> impl Iterator<Item = (u8, &SimCore)> {
        let mut mask = self.present;
        std::iter::from_fn(move || {
            if mask == 0 {
                return None;
            }
            let b = mask.trailing_zeros() as u8;
            mask &= mask - 1;
            Some(b)
        })
        .map(move |p| match self.cores.binary_search_by_key(&p, |(id, _)| *id) {
            Ok(i) => (p, &self.cores[i].1),
            Err(_) => (p, &self.idle),
        })
    }
}

pub(crate) struct SimChip {
    pub table: RoutingTable,
    /// Memoised TCAM lookups (fast fabric); cleared on every table load.
    pub route_cache: RouteCache,
    pub sdram: SdramStore,
    pub cores: CoreMap,
    /// tag id -> (host, port, strip_sdp).
    pub iptags: BTreeMap<u8, (String, u16, bool)>,
    /// udp port -> destination core.
    pub reverse_iptags: BTreeMap<u16, CoreLocation>,
    pub router_stats: RouterStats,
    /// The single hardware dropped-packet register (§6.10).
    pub dropped_register: Option<(u32, Option<u32>)>,
    pub drop_overflow: bool,
    /// Chip killed mid-run by a [`Fault::ChipDeath`]: cores stop
    /// dispatching, the router swallows traffic, and every SCAMP access
    /// errors ("unreachable"). The husk stays in the store so in-flight
    /// events land somewhere harmless.
    pub dead: bool,
}

impl SimChip {
    fn boot_from(chip: &Chip) -> SimChip {
        SimChip {
            table: RoutingTable::new(),
            route_cache: RouteCache::new(),
            sdram: SdramStore::new(chip.sdram.user_size()),
            cores: CoreMap::from_mask(chip.core_mask()),
            iptags: BTreeMap::new(),
            reverse_iptags: BTreeMap::new(),
            router_stats: RouterStats::default(),
            dropped_register: None,
            drop_overflow: false,
            dead: false,
        }
    }

    /// Replace the routing table, invalidating the route cache. Every
    /// table load — §6.3.4 loading, the fast-path stream entries, tests
    /// — must go through here; assigning `.table` directly would leave
    /// stale memoised routes behind.
    pub(crate) fn install_table(&mut self, table: RoutingTable) {
        self.table = table;
        self.route_cache.clear();
    }
}

/// Where one (chip, link) leads, frozen at boot ([`Machine::link_target`]
/// is pure after boot: the simulator owns the machine and nothing
/// rewires links mid-run).
#[derive(Debug, Clone, Copy)]
enum LinkDest {
    /// No working link: packets routed here are gone for good.
    Dead,
    /// Another chip's router.
    Chip(ChipCoord),
    /// A virtual (device) chip: packets land in the device inbox.
    Device(ChipCoord),
}

fn classify_link(machine: &Machine, from: ChipCoord, d: Direction) -> LinkDest {
    match machine.link_target(from, d) {
        None => LinkDest::Dead,
        Some(next) => {
            if machine.chip(next).map(|c| c.is_virtual).unwrap_or(false) {
                LinkDest::Device(next)
            } else {
                LinkDest::Chip(next)
            }
        }
    }
}

/// Chip + link-state storage, selected by [`FabricMode`]. `Fast` is a
/// flat arena with dense slot ids (`slot = y * width + x`, link slot =
/// `slot * 6 + link id`); `Legacy` is the original `BTreeMap` layout.
enum ChipStore {
    Fast {
        width: u32,
        height: u32,
        slots: Vec<Option<SimChip>>,
        /// slot * 6 + link id -> serialisation cursor of that output link.
        link_busy: Vec<u64>,
        /// slot -> serialisation cursor of the chip's UDP uplink.
        udp_busy: Vec<u64>,
        /// slot * 6 + link id -> frozen link target.
        link_dest: Vec<LinkDest>,
    },
    Legacy {
        chips: BTreeMap<ChipCoord, SimChip>,
        link_busy: BTreeMap<(ChipCoord, Direction), u64>,
        udp_busy: BTreeMap<ChipCoord, u64>,
    },
}

impl ChipStore {
    fn boot_from(machine: &Machine, mode: FabricMode) -> ChipStore {
        match mode {
            FabricMode::Fast => {
                let (width, height) = machine.real_extent();
                let n = (width as usize) * (height as usize);
                let mut slots: Vec<Option<SimChip>> = (0..n).map(|_| None).collect();
                let mut link_dest = vec![LinkDest::Dead; n * 6];
                for chip in machine.chips().filter(|c| !c.is_virtual) {
                    let slot = (chip.y * width + chip.x) as usize;
                    for d in ALL_DIRECTIONS {
                        link_dest[slot * 6 + d.id() as usize] =
                            classify_link(machine, (chip.x, chip.y), d);
                    }
                    slots[slot] = Some(SimChip::boot_from(chip));
                }
                ChipStore::Fast {
                    width,
                    height,
                    slots,
                    link_busy: vec![0; n * 6],
                    udp_busy: vec![0; n],
                    link_dest,
                }
            }
            FabricMode::Legacy => ChipStore::Legacy {
                chips: machine
                    .chips()
                    .filter(|c| !c.is_virtual)
                    .map(|c| ((c.x, c.y), SimChip::boot_from(c)))
                    .collect(),
                link_busy: BTreeMap::new(),
                udp_busy: BTreeMap::new(),
            },
        }
    }

    #[inline]
    fn slot_of(width: u32, height: u32, c: ChipCoord) -> Option<usize> {
        if c.0 < width && c.1 < height {
            Some((c.1 * width + c.0) as usize)
        } else {
            None
        }
    }

    #[inline]
    fn get(&self, c: ChipCoord) -> Option<&SimChip> {
        match self {
            ChipStore::Fast { width, height, slots, .. } => {
                Self::slot_of(*width, *height, c).and_then(|i| slots[i].as_ref())
            }
            ChipStore::Legacy { chips, .. } => chips.get(&c),
        }
    }

    #[inline]
    fn get_mut(&mut self, c: ChipCoord) -> Option<&mut SimChip> {
        match self {
            ChipStore::Fast { width, height, slots, .. } => {
                Self::slot_of(*width, *height, c).and_then(|i| slots[i].as_mut())
            }
            ChipStore::Legacy { chips, .. } => chips.get_mut(&c),
        }
    }

    #[inline]
    fn link_dest(&self, machine: &Machine, c: ChipCoord, d: Direction) -> LinkDest {
        match self {
            ChipStore::Fast { width, height, link_dest, .. } => {
                match Self::slot_of(*width, *height, c) {
                    Some(i) => link_dest[i * 6 + d.id() as usize],
                    None => LinkDest::Dead,
                }
            }
            ChipStore::Legacy { .. } => classify_link(machine, c, d),
        }
    }

    #[inline]
    fn link_busy(&self, c: ChipCoord, d: Direction) -> u64 {
        match self {
            ChipStore::Fast { width, height, link_busy, .. } => {
                match Self::slot_of(*width, *height, c) {
                    Some(i) => link_busy[i * 6 + d.id() as usize],
                    None => 0,
                }
            }
            ChipStore::Legacy { link_busy, .. } => {
                link_busy.get(&(c, d)).copied().unwrap_or(0)
            }
        }
    }

    #[inline]
    fn set_link_busy(&mut self, c: ChipCoord, d: Direction, until: u64) {
        match self {
            ChipStore::Fast { width, height, link_busy, .. } => {
                if let Some(i) = Self::slot_of(*width, *height, c) {
                    link_busy[i * 6 + d.id() as usize] = until;
                }
            }
            ChipStore::Legacy { link_busy, .. } => {
                link_busy.insert((c, d), until);
            }
        }
    }

    #[inline]
    fn udp_busy(&self, c: ChipCoord) -> u64 {
        match self {
            ChipStore::Fast { width, height, udp_busy, .. } => {
                match Self::slot_of(*width, *height, c) {
                    Some(i) => udp_busy[i],
                    None => 0,
                }
            }
            ChipStore::Legacy { udp_busy, .. } => udp_busy.get(&c).copied().unwrap_or(0),
        }
    }

    #[inline]
    fn set_udp_busy(&mut self, c: ChipCoord, until: u64) {
        match self {
            ChipStore::Fast { width, height, udp_busy, .. } => {
                if let Some(i) = Self::slot_of(*width, *height, c) {
                    udp_busy[i] = until;
                }
            }
            ChipStore::Legacy { udp_busy, .. } => {
                udp_busy.insert(c, until);
            }
        }
    }

    /// Kill one direction of a link in the frozen fast-fabric link map
    /// (the legacy store consults the live [`Machine`] per hop, which
    /// the fault handler mutates, so it needs no update here).
    fn kill_link_slot(&mut self, c: ChipCoord, d: Direction) {
        if let ChipStore::Fast { width, height, link_dest, .. } = self {
            if let Some(i) = Self::slot_of(*width, *height, c) {
                link_dest[i * 6 + d.id() as usize] = LinkDest::Dead;
            }
        }
    }

    /// Mark a chip dead in place (see [`SimChip::dead`]).
    fn kill_chip(&mut self, c: ChipCoord) {
        if let Some(chip) = self.get_mut(c) {
            chip.dead = true;
        }
        for d in ALL_DIRECTIONS {
            self.kill_link_slot(c, d);
        }
    }

    /// Chips in `(x, y)`-lexicographic order — exactly the iteration
    /// order of the legacy `BTreeMap<ChipCoord, _>`, so anything that
    /// schedules events while iterating (e.g. [`SimMachine::
    /// start_run_cycle`]) produces identical sequences in both modes.
    fn ordered(&self) -> Vec<(ChipCoord, &SimChip)> {
        match self {
            ChipStore::Fast { width, height, slots, .. } => {
                let mut out = Vec::new();
                for x in 0..*width {
                    for y in 0..*height {
                        if let Some(chip) = slots[(y * width + x) as usize].as_ref() {
                            out.push(((x, y), chip));
                        }
                    }
                }
                out
            }
            ChipStore::Legacy { chips, .. } => {
                chips.iter().map(|(c, chip)| (*c, chip)).collect()
            }
        }
    }
}

#[derive(Debug)]
enum EventKind {
    /// Timer event for one core.
    Tick(CoreLocation),
    /// A multicast packet at a chip's router.
    Router {
        chip: ChipCoord,
        entered: PacketSource,
        key: u32,
        payload: Option<u32>,
    },
    /// Deliver a multicast packet into a core.
    DeliverMc {
        loc: CoreLocation,
        key: u32,
        payload: Option<u32>,
    },
    /// Deliver an SDP message to a core.
    DeliverSdp(SdpMessage),
    /// A UDP frame reaches the host.
    HostUdp { port: u16, data: Vec<u8> },
    /// The reinjection core services the dropped-packet register.
    Reinject(ChipCoord),
    /// A scheduled chaos fault strikes (see [`chaos`]).
    Fault(Fault),
}

/// The simulated machine.
pub struct SimMachine {
    pub machine: Machine,
    pub config: SimConfig,
    time_ns: u64,
    events: EventQueue<EventKind>,
    store: ChipStore,
    /// Packets consumed by external devices on virtual chips.
    pub device_inbox: BTreeMap<ChipCoord, Vec<(u32, Option<u32>)>>,
    /// UDP frames that reached the host: (arrival time, port, payload).
    pub host_inbox: VecDeque<(u64, u16, Vec<u8>)>,
    pub stats: SimStats,
    /// Every fault applied so far, with its strike time — the chaos
    /// engine's own provenance, and how the front end learns which
    /// chips died (the machine no longer lists them).
    pub fault_log: Vec<(u64, Fault)>,
    /// Reusable outbox buffers for [`Self::with_core_app`], so the per-
    /// callback allocations disappear from the hot path.
    scratch_mc: Vec<(u32, Option<u32>)>,
    scratch_sdp: Vec<SdpMessage>,
    /// Deterministic stream the wire-fault plan draws from. Touched only
    /// when a fault can actually fire — a clean wire is draw-free.
    wire_rng: SplitMix64,
    /// Link degradation episodes installed by chaos faults.
    wire_episodes: Vec<WireEpisode>,
    /// Boards whose SCP retry budget exhausted: the host treats them as
    /// unreachable until the heal path powers them off.
    wire_escalated: BTreeSet<ChipCoord>,
    /// Reliable-transport counters (see [`WireStats`]).
    wire_stats: WireStats,
    /// Session scope: when set, host-side machine-wide sweeps (run-cycle
    /// scheduling, core-state scans, broadcast signals, provenance) are
    /// confined to these chips. This is how the multi-tenant
    /// [`crate::front::MachineService`] multiplexes one machine: the
    /// fabric itself stays global (a misrouted packet still crosses the
    /// boundary and is observable), but a tenant's control plane only
    /// ever touches its own partition. `None` = the whole machine.
    scope: Option<BTreeSet<ChipCoord>>,
}

impl SimMachine {
    /// Boot a simulated machine with the given geometry. (Plays the role
    /// of powering on + SCAMP flood-boot: afterwards the host can query
    /// the machine and load applications.)
    pub fn boot(machine: Machine, config: SimConfig) -> Self {
        let store = ChipStore::boot_from(&machine, config.fabric);
        let events = match config.fabric {
            FabricMode::Fast => EventQueue::Calendar(CalendarQueue::new()),
            FabricMode::Legacy => EventQueue::Heap(HeapQueue::new()),
        };
        let device_inbox = machine
            .chips()
            .filter(|c| c.is_virtual)
            .map(|c| ((c.x, c.y), Vec::new()))
            .collect();
        let wire_rng = SplitMix64::new(config.wire.faults.seed ^ 0x5A17_E00D);
        Self {
            machine,
            config,
            time_ns: 0,
            events,
            store,
            device_inbox,
            host_inbox: VecDeque::new(),
            stats: SimStats::default(),
            fault_log: Vec::new(),
            scratch_mc: Vec::new(),
            scratch_sdp: Vec::new(),
            wire_rng,
            wire_episodes: Vec::new(),
            wire_escalated: BTreeSet::new(),
            wire_stats: WireStats::default(),
            scope: None,
        }
    }

    /// A chipless placeholder machine — what a multi-tenant session
    /// holds while its real simulator is lent back to the service
    /// between run quanta. Every SCAMP operation against it errors
    /// ("no such chip"), so accidental use is loud, not silent.
    pub fn hollow() -> Self {
        Self::boot(Machine::new(1, 1, false), SimConfig::default())
    }

    /// Confine host-side machine-wide sweeps to `scope` (see the field
    /// doc). `None` restores whole-machine behaviour.
    pub fn set_scope(&mut self, scope: Option<BTreeSet<ChipCoord>>) {
        self.scope = scope;
    }

    /// The current session scope, if any.
    pub fn scope(&self) -> Option<&BTreeSet<ChipCoord>> {
        self.scope.as_ref()
    }

    /// Is `c` visible to the current session? Always true when no scope
    /// is set.
    pub fn in_scope(&self, c: ChipCoord) -> bool {
        self.scope.as_ref().map_or(true, |s| s.contains(&c))
    }

    pub fn now_ns(&self) -> u64 {
        self.time_ns
    }

    /// Advance the host clock (host-side protocol costs).
    pub(crate) fn advance_host_time(&mut self, ns: u64) {
        self.time_ns += ns;
    }

    #[inline]
    fn push_event(&mut self, time: u64, kind: EventKind) {
        self.events.push(time, kind);
    }

    pub(crate) fn chip(&self, c: ChipCoord) -> anyhow::Result<&SimChip> {
        match self.store.get(c) {
            Some(chip) if chip.dead => anyhow::bail!("chip {c:?} unreachable (dead)"),
            Some(chip) => Ok(chip),
            None => anyhow::bail!("no such chip {c:?}"),
        }
    }

    pub(crate) fn chip_mut(&mut self, c: ChipCoord) -> anyhow::Result<&mut SimChip> {
        match self.store.get_mut(c) {
            Some(chip) if chip.dead => anyhow::bail!("chip {c:?} unreachable (dead)"),
            Some(chip) => Ok(chip),
            None => anyhow::bail!("no such chip {c:?}"),
        }
    }

    // -- chaos (runtime fault injection) --------------------------------

    /// Schedule a fault `delay_ns` into the simulated future. The fault
    /// strikes during the next `run_until_idle`, interleaved
    /// deterministically with ordinary traffic.
    pub fn schedule_fault(&mut self, delay_ns: u64, fault: Fault) {
        let t = self.time_ns + delay_ns;
        self.push_event(t, EventKind::Fault(fault));
    }

    /// Chips killed at runtime so far (from the fault log).
    pub fn dead_chips(&self) -> std::collections::BTreeSet<ChipCoord> {
        self.fault_log
            .iter()
            .filter_map(|(_, f)| match f {
                Fault::ChipDeath(c) => Some(*c),
                _ => None,
            })
            .collect()
    }

    // -- the unreliable wire (seeded host-link faults, E16) -------------

    /// Reliable-transport counters for this run so far.
    pub fn wire_stats(&self) -> WireStats {
        self.wire_stats
    }

    pub(crate) fn wire_stats_mut(&mut self) -> &mut WireStats {
        &mut self.wire_stats
    }

    /// True when any wire fault can fire; the clean wire skips every
    /// draw so fault-free runs are timing-identical to a faultless
    /// build (`legacy_fabric_is_byte_identical` pins this).
    pub(crate) fn wire_active(&self) -> bool {
        !self.config.wire.faults.is_clean()
            || !self.wire_episodes.is_empty()
            || !self.wire_escalated.is_empty()
    }

    /// Is `board`'s host link answering nothing at `at_ns`?
    pub(crate) fn wire_board_silent(&self, board: ChipCoord, at_ns: u64) -> bool {
        self.wire_escalated.contains(&board)
            || self.wire_episodes.iter().any(|e| {
                e.board == board
                    && matches!(e.kind, WireEpisodeKind::Silent)
                    && e.from_ns <= at_ns
                    && at_ns < e.until_ns
            })
    }

    /// Effective frame-loss probability (permille) on `board`'s link.
    fn wire_loss_permille(&self, board: ChipCoord, at_ns: u64, dir: WireDirection) -> u64 {
        let f = &self.config.wire.faults;
        let base = match dir {
            WireDirection::HostToMachine => f.loss_h2m_permille,
            WireDirection::MachineToHost => f.loss_m2h_permille,
        } as u64;
        let brown: u64 = self
            .wire_episodes
            .iter()
            .filter(|e| e.board == board && e.from_ns <= at_ns && at_ns < e.until_ns)
            .map(|e| match e.kind {
                WireEpisodeKind::Brownout { loss_permille } => loss_permille as u64,
                WireEpisodeKind::Silent => 0, // handled by wire_board_silent
            })
            .sum();
        (base + brown).min(1000)
    }

    /// Can the host currently talk to `c` at all? True only for chips
    /// behind a silent or escalated board — ordinary frame loss is
    /// recoverable and does not make a chip unreachable.
    pub fn host_unreachable(&self, c: ChipCoord) -> bool {
        match self.machine.nearest_ethernet(c) {
            Some(board) => self.wire_board_silent(board, self.time_ns),
            None => false,
        }
    }

    /// Boards the host currently cannot reach (escalated, or inside a
    /// silent episode) — what the heal path powers off and maps around.
    pub fn wire_unreachable_boards(&self) -> BTreeSet<ChipCoord> {
        let now = self.time_ns;
        let mut out = self.wire_escalated.clone();
        for e in &self.wire_episodes {
            if matches!(e.kind, WireEpisodeKind::Silent) && e.from_ns <= now && now < e.until_ns {
                out.insert(e.board);
            }
        }
        out
    }

    /// Record that `board` exhausted its SCP retry budget: from now on
    /// the host treats every chip behind it as unreachable, which the
    /// supervisor observes as missing cores and converts into a heal.
    pub(crate) fn note_wire_escalation(&mut self, board: ChipCoord) {
        if self.wire_escalated.insert(board) {
            self.wire_stats.escalations += 1;
        }
    }

    /// Power a host-unreachable board off (the allocator's response to a
    /// dead host link): every chip on the board dies, so placement,
    /// routing and re-discovery treat it exactly like chip death.
    pub fn power_off_board(&mut self, board: ChipCoord) -> anyhow::Result<()> {
        let chips: Vec<ChipCoord> = self
            .machine
            .chip_coords()
            .filter(|c| self.machine.nearest_ethernet(*c) == Some(board))
            .collect();
        for c in chips {
            self.apply_fault(Fault::ChipDeath(c))?;
        }
        self.wire_escalated.remove(&board);
        Ok(())
    }

    /// The wire's verdict for one host↔machine UDP frame leaving at
    /// `base_ns`: up to two delivery times (none = lost, two = the wire
    /// duplicated it). The clean wire answers without consuming a draw.
    fn wire_frame_times(
        &mut self,
        board: ChipCoord,
        dir: WireDirection,
        base_ns: u64,
    ) -> ([u64; 2], usize) {
        if !self.wire_active() {
            return ([base_ns, 0], 1);
        }
        if self.wire_board_silent(board, base_ns) {
            self.wire_stats.frames_lost += 1;
            return ([0, 0], 0);
        }
        let loss = self.wire_loss_permille(board, base_ns, dir);
        if loss > 0 && (self.wire_rng.below(1000) as u64) < loss {
            self.wire_stats.frames_lost += 1;
            return ([0, 0], 0);
        }
        let f = self.config.wire.faults;
        let spread = f.jitter_ns + f.reorder_window_ns;
        let mut t = base_ns;
        if spread > 0 {
            let d = self.wire_rng.below(spread as usize + 1) as u64;
            if d > 0 {
                self.wire_stats.frames_delayed += 1;
            }
            t += d;
        }
        let dup = match dir {
            WireDirection::HostToMachine => f.dup_h2m_permille,
            WireDirection::MachineToHost => f.dup_m2h_permille,
        } as u64;
        if dup > 0 && (self.wire_rng.below(1000) as u64) < dup {
            self.wire_stats.frames_duplicated += 1;
            // The copy trails the original by at least 1 ns (so the
            // receiver sees original-then-copy) and at most the spread.
            let lag = 1 + self.wire_rng.below(spread.max(1) as usize) as u64;
            return ([t, t + lag], 2);
        }
        ([t, 0], 1)
    }

    /// The wire's verdict on one SCP request/response attempt against
    /// `board` at the current host time (the synchronous-cost-model twin
    /// of [`Self::wire_frame_times`], used by `scamp`'s reliable
    /// exchange). Draws and counts loss and duplication for both legs;
    /// duplicates are recorded as dropped by the respective sequence
    /// check, never surfaced. `delivered_before` means an earlier
    /// attempt of the same request reached SCAMP (its reply was lost) —
    /// the retransmission is then counted against SCAMP's
    /// duplicate-command check, which is what keeps non-idempotent
    /// operations exactly-once.
    pub(crate) fn wire_scp_attempt(
        &mut self,
        board: ChipCoord,
        delivered_before: bool,
    ) -> ScpAttempt {
        let now = self.time_ns;
        if self.wire_board_silent(board, now) {
            return ScpAttempt { delivered: false, replied: false };
        }
        let f = self.config.wire.faults;
        let loss_req = self.wire_loss_permille(board, now, WireDirection::HostToMachine);
        if loss_req > 0 && (self.wire_rng.below(1000) as u64) < loss_req {
            self.wire_stats.frames_lost += 1;
            return ScpAttempt { delivered: false, replied: false };
        }
        if delivered_before {
            self.wire_stats.dup_commands_dropped += 1;
        }
        if f.dup_h2m_permille > 0
            && (self.wire_rng.below(1000) as u64) < f.dup_h2m_permille as u64
        {
            // The wire duplicated the command; SCAMP's check eats it.
            self.wire_stats.frames_duplicated += 1;
            self.wire_stats.dup_commands_dropped += 1;
        }
        let loss_rep = self.wire_loss_permille(board, now, WireDirection::MachineToHost);
        if loss_rep > 0 && (self.wire_rng.below(1000) as u64) < loss_rep {
            self.wire_stats.frames_lost += 1;
            return ScpAttempt { delivered: true, replied: false };
        }
        if f.dup_m2h_permille > 0
            && (self.wire_rng.below(1000) as u64) < f.dup_m2h_permille as u64
        {
            self.wire_stats.frames_duplicated += 1;
            self.wire_stats.dup_replies_dropped += 1;
        }
        ScpAttempt { delivered: true, replied: true }
    }

    /// Apply one fault to the live machine, immediately. Chip and link
    /// deaths mutate [`Self::machine`] itself (the degraded topology is
    /// what a re-discovery reads back) *and* the fabric's frozen link
    /// map; core faults flip the core's run state and write an error
    /// blob into its IOBUF.
    pub fn apply_fault(&mut self, fault: Fault) -> anyhow::Result<()> {
        let now = self.time_ns;
        match &fault {
            Fault::CoreRte(loc) | Fault::CoreStall(loc) => {
                let rte = matches!(fault, Fault::CoreRte(_));
                let Ok(chip) = self.chip_mut(loc.chip()) else {
                    return Ok(()); // chip already dead: nothing left to fail
                };
                let Some(core) = chip.cores.get_mut(loc.p) else {
                    return Ok(());
                };
                if matches!(core.state, CoreState::Idle | CoreState::Finished) {
                    return Ok(()); // nothing running to kill
                }
                if rte {
                    core.state = CoreState::RunTimeError;
                    core.iobuf.push_str(&format!(
                        "[chaos] RTE injected at {now} ns (tick {})\n",
                        core.ticks_done
                    ));
                    *core.provenance.entry("chaos_rte".into()).or_insert(0) += 1;
                } else {
                    core.state = CoreState::Watchdog;
                    core.iobuf.push_str(&format!(
                        "[chaos] core stalled at {now} ns (tick {}); watchdog fired\n",
                        core.ticks_done
                    ));
                    *core.provenance.entry("chaos_stall".into()).or_insert(0) += 1;
                }
            }
            Fault::ChipDeath(c) => {
                self.machine.remove_chip(*c);
                self.store.kill_chip(*c);
                // Neighbours' frozen links toward the corpse go dead
                // (their Machine links were pruned by remove_chip).
                for d in ALL_DIRECTIONS {
                    if let Some(n) = self.machine.neighbour_coord(*c, d) {
                        self.store.kill_link_slot(n, d.opposite());
                    }
                }
            }
            Fault::LinkDeath(c, d) => {
                let target = self.machine.link_target(*c, *d);
                self.machine.remove_link(*c, *d);
                self.store.kill_link_slot(*c, *d);
                if let Some(n) = target {
                    self.store.kill_link_slot(n, d.opposite());
                }
            }
            Fault::LinkBrownout { board, loss_permille, duration_ns } => {
                self.wire_episodes.push(WireEpisode {
                    board: *board,
                    from_ns: now,
                    until_ns: now.saturating_add(*duration_ns),
                    kind: WireEpisodeKind::Brownout { loss_permille: *loss_permille },
                });
            }
            Fault::BoardSilent { board, duration_ns } => {
                self.wire_episodes.push(WireEpisode {
                    board: *board,
                    from_ns: now,
                    until_ns: now.saturating_add(*duration_ns),
                    kind: WireEpisodeKind::Silent,
                });
            }
        }
        self.fault_log.push((now, fault));
        Ok(())
    }

    /// Router stats for provenance extraction (`None` for missing or
    /// dead chips — a dead chip's counters cannot be read back).
    pub fn router_stats(&self, c: ChipCoord) -> Option<RouterStats> {
        self.store.get(c).filter(|ch| !ch.dead).map(|ch| ch.router_stats)
    }

    /// Sum of router stats across the machine (the session scope, when
    /// one is set — a tenant only reads its own routers).
    pub fn total_router_stats(&self) -> RouterStats {
        let mut out = RouterStats::default();
        for (c, ch) in self.store.ordered() {
            if ch.dead {
                continue; // a dead chip's counters are unreadable
            }
            if !self.in_scope(c) {
                continue;
            }
            out.mc_routed += ch.router_stats.mc_routed;
            out.mc_default_routed += ch.router_stats.mc_default_routed;
            out.mc_dropped += ch.router_stats.mc_dropped;
            out.mc_reinjected += ch.router_stats.mc_reinjected;
            out.mc_lost_forever += ch.router_stats.mc_lost_forever;
            out.cache_hits += ch.router_stats.cache_hits;
            out.cache_misses += ch.router_stats.cache_misses;
            out.mc_dead_link += ch.router_stats.mc_dead_link;
        }
        out
    }

    /// Inject a multicast packet from a core (hot path of the fabric).
    /// Public: tests and custom harnesses inject traffic directly.
    pub fn inject_mc(&mut self, from: CoreLocation, key: u32, payload: Option<u32>) {
        self.inject_mc_after(from, key, payload, 0);
    }

    pub(crate) fn inject_mc_after(
        &mut self,
        from: CoreLocation,
        key: u32,
        payload: Option<u32>,
        delay_ns: u64,
    ) {
        self.stats.mc_sent += 1;
        let t = self.time_ns + delay_ns;
        self.push_event(
            t + self.config.router_pipeline_ns,
            EventKind::Router {
                chip: from.chip(),
                entered: PacketSource::Local(from.p),
                key,
                payload,
            },
        );
    }

    /// Process events until the queue is empty.
    pub fn run_until_idle(&mut self) -> anyhow::Result<()> {
        while let Some((time, kind)) = self.events.pop() {
            debug_assert!(time >= self.time_ns, "time went backwards");
            self.time_ns = time;
            self.stats.events_processed += 1;
            self.dispatch(kind)?;
        }
        Ok(())
    }

    fn dispatch(&mut self, kind: EventKind) -> anyhow::Result<()> {
        match kind {
            EventKind::Tick(loc) => self.handle_tick(loc),
            EventKind::Router { chip, entered, key, payload } => {
                self.handle_router(chip, entered, key, payload)
            }
            EventKind::DeliverMc { loc, key, payload } => {
                self.stats.mc_delivered += 1;
                self.with_core_app(loc, |app, ctx| app.on_mc_packet(key, payload, ctx))
            }
            EventKind::DeliverSdp(msg) => {
                let loc = msg.header.dest();
                self.with_core_app(loc, |app, ctx| app.on_sdp(&msg, ctx))
            }
            EventKind::HostUdp { port, data } => {
                self.host_inbox.push_back((self.time_ns, port, data));
                Ok(())
            }
            EventKind::Reinject(chip) => self.handle_reinject(chip),
            EventKind::Fault(fault) => self.apply_fault(fault),
        }
    }

    fn handle_router(
        &mut self,
        chip: ChipCoord,
        entered: PacketSource,
        key: u32,
        payload: Option<u32>,
    ) -> anyhow::Result<()> {
        let cached = self.config.fabric == FabricMode::Fast;
        let Some(sim_chip) = self.store.get_mut(chip) else {
            // Packet wandered onto a dead/virtual chip — treat as device
            // consumption if virtual, else drop.
            if let Some(inbox) = self.device_inbox.get_mut(&chip) {
                inbox.push((key, payload));
            }
            return Ok(());
        };
        if sim_chip.dead {
            // A dead chip's router forwards nothing; in-flight packets
            // vanish (its statistics are unreadable anyway).
            return Ok(());
        }
        let decision = if cached {
            let SimChip { table, route_cache, router_stats, .. } = &mut *sim_chip;
            let (decision, hit) = route_cache.route(table, key, entered);
            if hit {
                router_stats.cache_hits += 1;
            } else {
                router_stats.cache_misses += 1;
            }
            decision
        } else {
            sim_chip.table.route_packet(key, entered)
        };
        match decision {
            RoutingDecision::Routed(route) => {
                sim_chip.router_stats.mc_routed += 1;
                self.forward(chip, route, key, payload)?;
            }
            RoutingDecision::DefaultRouted(d) => {
                sim_chip.router_stats.mc_default_routed += 1;
                self.forward(chip, Route::EMPTY.with_link(d), key, payload)?;
            }
            RoutingDecision::Dropped => {
                // A locally-injected packet with no matching entry is
                // simply discarded (§2) — it never reaches the dropped-
                // packet register, so reinjection cannot resurrect it.
                sim_chip.router_stats.mc_dropped += 1;
            }
        }
        Ok(())
    }

    fn forward(
        &mut self,
        chip: ChipCoord,
        route: Route,
        key: u32,
        payload: Option<u32>,
    ) -> anyhow::Result<()> {
        let now = self.time_ns;
        for p in route.processors() {
            self.push_event(
                now + self.config.local_deliver_ns,
                EventKind::DeliverMc {
                    loc: CoreLocation::new(chip.0, chip.1, p),
                    key,
                    payload,
                },
            );
        }
        for d in route.links() {
            let (next, is_device) = match self.store.link_dest(&self.machine, chip, d) {
                LinkDest::Dead => {
                    // Route over a dead link: the packet is gone for good —
                    // reinjection would just replay it into the same void.
                    if let Some(c) = self.store.get_mut(chip) {
                        c.router_stats.mc_dropped += 1;
                        c.router_stats.mc_lost_forever += 1;
                        c.router_stats.mc_dead_link += 1;
                    }
                    continue;
                }
                LinkDest::Chip(n) => (n, false),
                LinkDest::Device(n) => (n, true),
            };
            // Congestion model: bounded output queue, drop after wait (§2)
            // — except for flow-controlled (lossless) key ranges.
            let busy = self.store.link_busy(chip, d);
            let depart = busy.max(now);
            let backlog = depart.saturating_sub(now);
            if backlog > self.config.drop_wait_ns && key < self.config.lossless_key_min {
                self.drop_packet(chip, key, payload);
                continue;
            }
            self.store
                .set_link_busy(chip, d, depart + self.config.link_packet_ns);
            let arrive = depart + self.config.link_packet_ns + self.config.router_pipeline_ns;
            if is_device {
                self.device_inbox.entry(next).or_default().push((key, payload));
            } else {
                self.push_event(
                    arrive,
                    EventKind::Router {
                        chip: next,
                        entered: PacketSource::Link(d.opposite()),
                        key,
                        payload,
                    },
                );
            }
        }
        Ok(())
    }

    /// §6.10 drop semantics: one hardware register; a second drop while
    /// it is occupied is unrecoverable and only counted.
    fn drop_packet(&mut self, chip: ChipCoord, key: u32, payload: Option<u32>) {
        let reinjection = self.config.reinjection;
        let delay = self.config.reinject_delay_ns;
        let now = self.time_ns;
        let Some(c) = self.store.get_mut(chip) else { return };
        c.router_stats.mc_dropped += 1;
        if c.dropped_register.is_none() {
            c.dropped_register = Some((key, payload));
            if reinjection {
                self.push_event(now + delay, EventKind::Reinject(chip));
            }
        } else {
            c.drop_overflow = true;
            c.router_stats.mc_lost_forever += 1;
        }
    }

    fn handle_reinject(&mut self, chip: ChipCoord) -> anyhow::Result<()> {
        let now = self.time_ns;
        let Some(c) = self.store.get_mut(chip) else {
            return Ok(());
        };
        if c.dead {
            return Ok(());
        }
        if let Some((key, payload)) = c.dropped_register.take() {
            c.router_stats.mc_reinjected += 1;
            // Re-issue as if sent by the monitor core.
            self.push_event(
                now + self.config.router_pipeline_ns,
                EventKind::Router {
                    chip,
                    entered: PacketSource::Local(0),
                    key,
                    payload,
                },
            );
        }
        Ok(())
    }

    fn handle_tick(&mut self, loc: CoreLocation) -> anyhow::Result<()> {
        // Check run state first. A tick landing on a dead chip (the chip
        // died with ticks in flight) simply evaporates.
        {
            let Some(chip) = self.store.get_mut(loc.chip()) else {
                return Ok(());
            };
            if chip.dead {
                return Ok(());
            }
            let core = chip
                .cores
                .get_mut(loc.p)
                .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
            if core.state != CoreState::Running {
                return Ok(());
            }
            if core.ticks_done >= core.run_until {
                core.state = CoreState::Paused;
                return Ok(());
            }
            core.ticks_done += 1;
        }
        let timestep_ns = self.config.timestep_us as u64 * 1000;
        self.with_core_app(loc, |app, ctx| app.on_timer(ctx))?;
        // Schedule the next tick (or pause at the boundary). The chip may
        // have died *during* the callback's event; then there is nothing
        // left to schedule.
        let Some((done, until, state)) = ({
            let chip = self.store.get(loc.chip()).filter(|c| !c.dead);
            chip.map(|c| {
                let core = c.cores.get(loc.p).expect("ticked core exists");
                (core.ticks_done, core.run_until, core.state)
            })
        }) else {
            return Ok(());
        };
        if state == CoreState::Running {
            if done < until {
                let t = self.time_ns + timestep_ns;
                self.push_event(t, EventKind::Tick(loc));
            } else {
                let mut pause_needed = false;
                {
                    let chip = self.chip_mut(loc.chip())?;
                    let core = chip.cores.get_mut(loc.p).unwrap();
                    if core.state == CoreState::Running {
                        core.state = CoreState::Paused;
                        pause_needed = true;
                    }
                }
                if pause_needed {
                    self.with_core_app(loc, |app, ctx| app.on_pause(ctx))?;
                }
            }
        }
        Ok(())
    }

    /// Run one core-app callback with a properly wired [`CoreCtx`], then
    /// flush its outboxes into events. The outbox buffers are recycled
    /// across calls (`scratch_mc`/`scratch_sdp`) so the per-event
    /// allocations vanish from the fabric hot path.
    pub(crate) fn with_core_app(
        &mut self,
        loc: CoreLocation,
        f: impl FnOnce(&mut dyn CoreApp, &mut CoreCtx) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let time_ns = self.time_ns;
        // Taking leaves fresh empty vecs behind; the cold early-return
        // paths below simply drop these and the next call re-allocates.
        let mc_buf = std::mem::take(&mut self.scratch_mc);
        let sdp_buf = std::mem::take(&mut self.scratch_sdp);
        let (mut app, mut mc_out, mut sdp_out, result, exit_requested) = {
            let chip = self
                .store
                .get_mut(loc.chip())
                .ok_or_else(|| anyhow::anyhow!("no chip {:?}", loc.chip()))?;
            if chip.dead {
                return Ok(()); // event to a dead chip: evaporates
            }
            let core = chip
                .cores
                .get_mut(loc.p)
                .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
            if matches!(core.state, CoreState::RunTimeError | CoreState::Watchdog) {
                return Ok(()); // failed cores dispatch nothing further
            }
            let Some(mut app) = core.app.take() else {
                return Ok(()); // packet to an idle core: silently ignored
            };
            let mut exit_requested = false;
            let mut ctx = CoreCtx {
                loc,
                time_ns,
                tick: core.ticks_done,
                mc_out: mc_buf,
                sdp_out: sdp_buf,
                regions: &core.regions,
                recordings: &mut core.recordings,
                sdram: &mut chip.sdram,
                provenance: &mut core.provenance,
                iobuf: &mut core.iobuf,
                exit_requested: &mut exit_requested,
            };
            let result = f(app.as_mut(), &mut ctx);
            let mc_out = std::mem::take(&mut ctx.mc_out);
            let sdp_out = std::mem::take(&mut ctx.sdp_out);
            (app, mc_out, sdp_out, result, exit_requested)
        };
        // Put the app back and update state.
        {
            let chip = self.store.get_mut(loc.chip()).unwrap();
            let core = chip.cores.get_mut(loc.p).unwrap();
            core.app = Some(std::mem::replace(&mut app, Box::new(NullApp)));
            drop(app);
            if result.is_err() {
                core.state = CoreState::RunTimeError;
            } else if exit_requested {
                core.state = CoreState::Finished;
            }
        }
        // Flush outboxes. Successive packets from one callback are
        // spaced out as the core would actually produce them, and the
        // core's transmitter is serialised *across* callbacks: when a
        // second callback fires while an earlier one's packets are still
        // being issued (a duplicated wire command re-triggering a bulk
        // stream, say), its packets queue behind them rather than
        // interleaving mid-stream. With no overlap — every workload on a
        // clean wire — `start == time_ns` and timing is unchanged.
        let spacing = self.config.send_spacing_ns;
        if !mc_out.is_empty() {
            let start = {
                let chip = self.store.get_mut(loc.chip()).unwrap();
                let core = chip.cores.get_mut(loc.p).unwrap();
                let start = core.tx_busy_ns.max(time_ns);
                core.tx_busy_ns = start + mc_out.len() as u64 * spacing;
                start
            };
            let head_delay = start - time_ns;
            for (i, (key, payload)) in mc_out.drain(..).enumerate() {
                self.inject_mc_after(loc, key, payload, head_delay + i as u64 * spacing);
            }
        }
        for msg in sdp_out.drain(..) {
            self.route_sdp(loc, msg)?;
        }
        // Hand the (drained) buffers back for the next callback.
        self.scratch_mc = mc_out;
        self.scratch_sdp = sdp_out;
        // A failing callback marks the core RTE but does not stop the
        // simulation: the tools detect the state afterwards (§6.3.5) and
        // read the error text back out of the IOBUF.
        if let Err(e) = result {
            let chip = self.store.get_mut(loc.chip()).unwrap();
            let core = chip.cores.get_mut(loc.p).unwrap();
            core.provenance
                .insert(format!("rte: {e}"), 1);
            core.iobuf
                .push_str(&format!("RTE at {time_ns} ns: {e}\n"));
        }
        Ok(())
    }

    /// SDP routing: tagged messages go out via the board's Ethernet
    /// (consulting the IP tag table, §3); untagged go core-to-core.
    pub(crate) fn route_sdp(&mut self, from: CoreLocation, msg: SdpMessage) -> anyhow::Result<()> {
        self.stats.sdp_sent += 1;
        let now = self.time_ns;
        if msg.header.tag != 0xff {
            // Host-bound: relay to the Ethernet chip (P2P cost if the
            // source is elsewhere), then UDP to the host.
            let eth = self
                .machine
                .nearest_ethernet(from.chip())
                .ok_or_else(|| anyhow::anyhow!("no ethernet for {from}"))?;
            let hops = self.machine.hop_distance(from.chip(), eth) as u64;
            let relay = hops * self.config.wire.p2p_per_hop_ns;
            let Ok(chip) = self.chip(eth) else {
                // The board's Ethernet chip died under us: the message is
                // lost, but a surviving sender must not crash the run.
                return Ok(());
            };
            let Some((_, port, strip)) = chip.iptags.get(&msg.header.tag).cloned() else {
                anyhow::bail!("SDP with unset IP tag {} at {eth:?}", msg.header.tag)
            };
            let data = if strip { msg.data.clone() } else { msg.encode() };
            // Serialise on the Ethernet uplink: one frame per slot.
            let ready = now + relay;
            let busy = self.store.udp_busy(eth);
            let depart = busy.max(ready);
            self.store
                .set_udp_busy(eth, depart + self.config.wire.udp_frame_ns);
            let t0 = depart + self.config.wire.udp_frame_ns;
            let (times, n) = self.wire_frame_times(eth, WireDirection::MachineToHost, t0);
            match n {
                0 => {} // the wire ate the frame; the host re-requests
                1 => self.push_event(times[0], EventKind::HostUdp { port, data }),
                _ => {
                    self.push_event(
                        times[0],
                        EventKind::HostUdp { port, data: data.clone() },
                    );
                    self.push_event(times[1], EventKind::HostUdp { port, data });
                }
            }
        } else {
            // On-machine SDP: hop-proportional latency.
            let dest = msg.header.dest();
            let hops = self.machine.hop_distance(from.chip(), dest.chip()) as u64;
            self.push_event(
                now + (hops + 1) * self.config.wire.p2p_per_hop_ns,
                EventKind::DeliverSdp(msg),
            );
        }
        Ok(())
    }

    /// Host → machine SDP (via the board's Ethernet chip and the P2P
    /// fabric): how the tools command individual cores, e.g. the fast
    /// data-extraction reader (§6.8).
    pub fn host_send_sdp(&mut self, msg: SdpMessage) -> anyhow::Result<()> {
        let now = self.time_ns;
        let dest = msg.header.dest();
        let eth = self
            .machine
            .nearest_ethernet(dest.chip())
            .ok_or_else(|| anyhow::anyhow!("no ethernet for {dest}"))?;
        let hops = self.machine.hop_distance(eth, dest.chip()) as u64;
        let t0 = now + self.config.wire.udp_frame_ns + hops * self.config.wire.p2p_per_hop_ns;
        let (times, n) = self.wire_frame_times(eth, WireDirection::HostToMachine, t0);
        match n {
            0 => {} // lost on the wire; recovered by retry/re-request
            1 => self.push_event(times[0], EventKind::DeliverSdp(msg)),
            _ => {
                self.push_event(times[0], EventKind::DeliverSdp(msg.clone()));
                self.push_event(times[1], EventKind::DeliverSdp(msg));
            }
        }
        Ok(())
    }

    /// Host → machine UDP (reverse IP tag path, §3/§6.9): deliver the
    /// frame as SDP to the core registered for `port` on `board`.
    pub fn host_send_udp(&mut self, board: ChipCoord, port: u16, data: Vec<u8>) -> anyhow::Result<()> {
        self.host_send_udp_after(board, port, data, 0)
    }

    /// [`Self::host_send_udp`] scheduled `delay_ns` into the future —
    /// how the host paces a burst of frames (the data-in loader) without
    /// advancing its own clock between sends: the pacing plan is laid
    /// out as future events, then one `run_until_idle` lets streams to
    /// different boards overlap in simulated time.
    pub fn host_send_udp_after(
        &mut self,
        board: ChipCoord,
        port: u16,
        data: Vec<u8>,
        delay_ns: u64,
    ) -> anyhow::Result<()> {
        let now = self.time_ns + delay_ns;
        let chip = self.chip(board)?;
        let dest = *chip
            .reverse_iptags
            .get(&port)
            .ok_or_else(|| anyhow::anyhow!("no reverse IP tag for port {port} on {board:?}"))?;
        let mut header = crate::transport::SdpHeader::to_core(dest, 1);
        header.src_port = 7; // came from the outside world
        let msg = SdpMessage::new(header, data);
        let hops = self.machine.hop_distance(board, dest.chip()) as u64;
        let t0 = now + self.config.wire.udp_frame_ns + hops * self.config.wire.p2p_per_hop_ns;
        let (times, n) = self.wire_frame_times(board, WireDirection::HostToMachine, t0);
        match n {
            0 => {} // lost; the writer's missing-seq report re-requests it
            1 => self.push_event(times[0], EventKind::DeliverSdp(msg)),
            _ => {
                self.push_event(times[0], EventKind::DeliverSdp(msg.clone()));
                self.push_event(times[1], EventKind::DeliverSdp(msg));
            }
        }
        Ok(())
    }

    /// Drain host-bound UDP frames for one port (the front end's
    /// listener pump).
    pub fn take_host_udp(&mut self, port: u16) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        self.host_inbox.retain(|(_, p, data)| {
            if *p == port {
                out.push(data.clone());
                false
            } else {
                true
            }
        });
        out
    }

    /// Schedule the first tick for every Running core (start of a run
    /// cycle). `run_ticks` is added to each core's target.
    pub fn start_run_cycle(&mut self, run_ticks: u64) {
        let timestep_ns = self.config.timestep_us as u64 * 1000;
        let mut locs: Vec<CoreLocation> = Vec::new();
        for (c, chip) in self.store.ordered() {
            if chip.dead || !self.in_scope(c) {
                continue;
            }
            for (p, core) in chip.cores.iter() {
                if matches!(core.state, CoreState::Running | CoreState::Paused) {
                    locs.push(CoreLocation::new(c.0, c.1, p));
                }
            }
        }
        let now = self.time_ns;
        for loc in locs {
            let chip = self.store.get_mut(loc.chip()).unwrap();
            let core = chip.cores.get_mut(loc.p).unwrap();
            core.run_until += run_ticks;
            core.state = CoreState::Running;
            self.push_event(now + timestep_ns, EventKind::Tick(loc));
        }
    }
}

/// Placeholder used while swapping apps in/out of cores.
struct NullApp;
impl CoreApp for NullApp {
    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::router::RoutingEntry;
    use crate::machine::MachineBuilder;

    /// An app that sends one packet per tick and records received keys.
    struct PingApp {
        key: u32,
        received: std::sync::Arc<std::sync::Mutex<Vec<u32>>>,
    }

    impl CoreApp for PingApp {
        fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            ctx.send_mc(self.key, Some(ctx.tick as u32));
            Ok(())
        }
        fn on_mc_packet(&mut self, key: u32, _p: Option<u32>, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            self.received.lock().unwrap().push(key);
            ctx.count("packets_in", 1);
            Ok(())
        }
    }

    fn shared() -> std::sync::Arc<std::sync::Mutex<Vec<u32>>> {
        std::sync::Arc::new(std::sync::Mutex::new(Vec::new()))
    }

    fn ping_exchange(mode: FabricMode) -> (Vec<u32>, Vec<u32>, SimMachine) {
        let machine = MachineBuilder::spinn3().build();
        let config = SimConfig { fabric: mode, ..SimConfig::default() };
        let mut sim = SimMachine::boot(machine, config);
        let rx_a = shared();
        let rx_b = shared();
        let a = CoreLocation::new(0, 0, 1);
        let b = CoreLocation::new(1, 0, 1);
        // routing: key 0x10 a->b, key 0x20 b->a
        sim.chip_mut((0, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(0x10, !0, Route::EMPTY.with_link(Direction::East)),
            RoutingEntry::new(0x20, !0, Route::EMPTY.with_processor(1)),
        ]));
        sim.chip_mut((1, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(0x10, !0, Route::EMPTY.with_processor(1)),
            RoutingEntry::new(0x20, !0, Route::EMPTY.with_link(Direction::West)),
        ]));
        scamp::load_app(&mut sim, a, Box::new(PingApp { key: 0x10, received: rx_a.clone() }), Default::default(), Default::default()).unwrap();
        scamp::load_app(&mut sim, b, Box::new(PingApp { key: 0x20, received: rx_b.clone() }), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(10);
        sim.run_until_idle().unwrap();
        let got_a = rx_a.lock().unwrap().clone();
        let got_b = rx_b.lock().unwrap().clone();
        (got_a, got_b, sim)
    }

    #[test]
    fn two_cores_exchange_packets() {
        let (rx_a, rx_b, sim) = ping_exchange(FabricMode::Fast);
        assert_eq!(rx_a.len(), 10, "a receives b's 10 packets");
        assert!(rx_a.iter().all(|k| *k == 0x20));
        assert_eq!(rx_b.len(), 10);
        let a = CoreLocation::new(0, 0, 1);
        assert_eq!(scamp::core_state(&sim, a).unwrap(), CoreState::Paused);
        let prov = scamp::provenance(&sim, a).unwrap();
        assert_eq!(prov.get("packets_in"), Some(&10));
        // The cache served every repeat of the two keys.
        let stats = sim.router_stats((0, 0)).unwrap();
        assert!(stats.cache_hits > 0);
        assert!(stats.cache_misses >= 1);
    }

    #[test]
    fn legacy_fabric_is_byte_identical() {
        let (fast_a, fast_b, fast_sim) = ping_exchange(FabricMode::Fast);
        let (legacy_a, legacy_b, legacy_sim) = ping_exchange(FabricMode::Legacy);
        assert_eq!(fast_a, legacy_a);
        assert_eq!(fast_b, legacy_b);
        assert_eq!(fast_sim.stats, legacy_sim.stats);
        assert_eq!(fast_sim.now_ns(), legacy_sim.now_ns());
        assert_eq!(
            fast_sim.total_router_stats().semantic(),
            legacy_sim.total_router_stats().semantic()
        );
        // The legacy path never touches the cache.
        let legacy_total = legacy_sim.total_router_stats();
        assert_eq!((legacy_total.cache_hits, legacy_total.cache_misses), (0, 0));
    }

    #[test]
    fn unrouted_local_packet_counts_as_drop() {
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(PingApp { key: 0x99, received: shared() }), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        let stats = sim.router_stats((0, 0)).unwrap();
        assert_eq!(stats.mc_dropped, 5);
    }

    #[test]
    fn finished_state_on_exit() {
        struct ExitApp;
        impl CoreApp for ExitApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                if ctx.tick >= 3 {
                    ctx.exit();
                }
                Ok(())
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(ExitApp), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(100);
        sim.run_until_idle().unwrap();
        assert_eq!(scamp::core_state(&sim, loc).unwrap(), CoreState::Finished);
    }

    #[test]
    fn rte_state_on_error() {
        struct BadApp;
        impl CoreApp for BadApp {
            fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
                anyhow::bail!("deliberate failure")
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let loc = CoreLocation::new(1, 1, 2);
        scamp::load_app(&mut sim, loc, Box::new(BadApp), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        assert_eq!(scamp::core_state(&sim, loc).unwrap(), CoreState::RunTimeError);
    }

    fn congestion_run(mode: FabricMode) -> (RouterStats, u64) {
        // Many cores on one chip all hammering the same outbound link in
        // the same instant overflows the output queue.
        struct BurstApp {
            key: u32,
        }
        impl CoreApp for BurstApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                for _ in 0..8 {
                    ctx.send_mc(self.key, None);
                }
                Ok(())
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let config = SimConfig {
            link_queue_depth: 2,
            drop_wait_ns: 400,  // tiny patience
            send_spacing_ns: 0, // instantaneous burst
            fabric: mode,
            ..SimConfig::default()
        };
        let mut sim = SimMachine::boot(machine, config);
        // All keys routed East out of (0,0); receiver on (1,0) core 1.
        sim.chip_mut((0, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(0, 0, Route::EMPTY.with_link(Direction::East)),
        ]));
        sim.chip_mut((1, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(0, 0, Route::EMPTY.with_processor(1)),
        ]));
        let rx = shared();
        scamp::load_app(&mut sim, CoreLocation::new(1, 0, 1), Box::new(PingAppSilent { received: rx.clone() }), Default::default(), Default::default()).unwrap();
        for p in 1..=8 {
            scamp::load_app(&mut sim, CoreLocation::new(0, 0, p), Box::new(BurstApp { key: p as u32 }), Default::default(), Default::default()).unwrap();
        }
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(3);
        sim.run_until_idle().unwrap();
        let stats = sim.router_stats((0, 0)).unwrap();
        let delivered = rx.lock().unwrap().len() as u64;
        (stats, delivered)
    }

    #[test]
    fn congestion_drops_and_reinjects() {
        let (stats, delivered) = congestion_run(FabricMode::Fast);
        assert!(stats.mc_dropped > 0, "expected congestion drops");
        assert!(stats.mc_reinjected > 0, "reinjector should recover some");
        // Reinjection recovered at least the register-held packets:
        // delivered + lost_forever == sent (64 per tick * 3 - receiver's own sends).
        assert_eq!(delivered + stats.mc_lost_forever, 8 * 8 * 3);
    }

    #[test]
    fn congestion_identical_across_fabrics() {
        // The congestion/reinjection path is the most ordering-sensitive
        // part of the fabric; both modes must agree packet for packet.
        let (fast, fast_delivered) = congestion_run(FabricMode::Fast);
        let (legacy, legacy_delivered) = congestion_run(FabricMode::Legacy);
        assert_eq!(fast.semantic(), legacy.semantic());
        assert_eq!(fast_delivered, legacy_delivered);
    }

    struct PingAppSilent {
        received: std::sync::Arc<std::sync::Mutex<Vec<u32>>>,
    }
    impl CoreApp for PingAppSilent {
        fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
            Ok(())
        }
        fn on_mc_packet(&mut self, key: u32, _p: Option<u32>, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
            self.received.lock().unwrap().push(key);
            Ok(())
        }
    }

    #[test]
    fn reinjection_disabled_loses_packets() {
        struct BurstApp;
        impl CoreApp for BurstApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                for _ in 0..16 {
                    ctx.send_mc(7, None);
                }
                Ok(())
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let config = SimConfig {
            link_queue_depth: 2,
            drop_wait_ns: 400,
            send_spacing_ns: 0,
            reinjection: false,
            ..SimConfig::default()
        };
        let mut sim = SimMachine::boot(machine, config);
        sim.chip_mut((0, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(7, !0, Route::EMPTY.with_link(Direction::East)),
        ]));
        sim.chip_mut((1, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(7, !0, Route::EMPTY.with_processor(1)),
        ]));
        let rx = shared();
        scamp::load_app(&mut sim, CoreLocation::new(1, 0, 1), Box::new(PingAppSilent { received: rx.clone() }), Default::default(), Default::default()).unwrap();
        scamp::load_app(&mut sim, CoreLocation::new(0, 0, 1), Box::new(BurstApp), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(2);
        sim.run_until_idle().unwrap();
        let stats = sim.router_stats((0, 0)).unwrap();
        assert!(stats.mc_dropped > 0);
        assert_eq!(stats.mc_reinjected, 0);
        assert!((rx.lock().unwrap().len() as u64) < 32, "some packets must be lost");
    }

    fn chaos_pair(mode: FabricMode) -> SimMachine {
        // a on (0,0) sends key 0x10 East to b on (1,0); b replies 0x20.
        let machine = MachineBuilder::spinn3().build();
        let config = SimConfig { fabric: mode, ..SimConfig::default() };
        let mut sim = SimMachine::boot(machine, config);
        sim.chip_mut((0, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(0x10, !0, Route::EMPTY.with_link(Direction::East)),
            RoutingEntry::new(0x20, !0, Route::EMPTY.with_processor(1)),
        ]));
        sim.chip_mut((1, 0)).unwrap().install_table(RoutingTable::from_entries(vec![
            RoutingEntry::new(0x10, !0, Route::EMPTY.with_processor(1)),
            RoutingEntry::new(0x20, !0, Route::EMPTY.with_link(Direction::West)),
        ]));
        sim
    }

    #[test]
    fn chip_death_mid_run_swallows_traffic_and_hides_the_chip() {
        for mode in [FabricMode::Fast, FabricMode::Legacy] {
            let mut sim = chaos_pair(mode);
            let rx_a = shared();
            let a = CoreLocation::new(0, 0, 1);
            let b = CoreLocation::new(1, 0, 1);
            scamp::load_app(&mut sim, a, Box::new(PingApp { key: 0x10, received: rx_a.clone() }), Default::default(), Default::default()).unwrap();
            scamp::load_app(&mut sim, b, Box::new(PingApp { key: 0x20, received: shared() }), Default::default(), Default::default()).unwrap();
            scamp::signal_start(&mut sim).unwrap();
            // Kill (1,0) halfway through a 10-tick run.
            let timestep = sim.config.timestep_us as u64 * 1000;
            sim.schedule_fault(5 * timestep + timestep / 2, Fault::ChipDeath((1, 0)));
            sim.start_run_cycle(10);
            sim.run_until_idle().unwrap();
            // b's replies stop at the fault: a hears ~5 of 10.
            let heard = rx_a.lock().unwrap().len();
            assert!((4..=6).contains(&heard), "mode {mode:?}: a heard {heard}");
            // The dead chip is gone from machine and SCAMP's view.
            assert!(sim.machine.chip((1, 0)).is_none());
            assert!(scamp::core_state(&sim, b).is_err());
            assert!(!scamp::core_states(&sim).contains_key(&b));
            assert_eq!(sim.dead_chips().into_iter().collect::<Vec<_>>(), vec![(1, 0)]);
            // a survives the whole run.
            assert_eq!(scamp::core_state(&sim, a).unwrap(), CoreState::Paused);
        }
    }

    #[test]
    fn link_death_mid_run_counts_dead_link_drops() {
        for mode in [FabricMode::Fast, FabricMode::Legacy] {
            let mut sim = chaos_pair(mode);
            let a = CoreLocation::new(0, 0, 1);
            let rx_b = shared();
            scamp::load_app(&mut sim, a, Box::new(PingApp { key: 0x10, received: shared() }), Default::default(), Default::default()).unwrap();
            scamp::load_app(&mut sim, CoreLocation::new(1, 0, 1), Box::new(PingAppSilent { received: rx_b.clone() }), Default::default(), Default::default()).unwrap();
            scamp::signal_start(&mut sim).unwrap();
            let timestep = sim.config.timestep_us as u64 * 1000;
            sim.schedule_fault(4 * timestep + timestep / 2, Fault::LinkDeath((0, 0), Direction::East));
            sim.start_run_cycle(10);
            sim.run_until_idle().unwrap();
            let heard = rx_b.lock().unwrap().len();
            assert_eq!(heard, 4, "mode {mode:?}: packets before the cut arrive");
            let stats = sim.router_stats((0, 0)).unwrap();
            assert_eq!(stats.mc_dead_link, 6, "mode {mode:?}: post-cut sends die on the link");
            assert_eq!(sim.machine.link_target((0, 0), Direction::East), None);
        }
    }

    #[test]
    fn core_faults_flip_state_and_write_iobuf() {
        let mut sim = chaos_pair(FabricMode::Fast);
        let a = CoreLocation::new(0, 0, 1);
        let b = CoreLocation::new(1, 0, 1);
        scamp::load_app(&mut sim, a, Box::new(PingApp { key: 0x10, received: shared() }), Default::default(), Default::default()).unwrap();
        scamp::load_app(&mut sim, b, Box::new(PingApp { key: 0x20, received: shared() }), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        let timestep = sim.config.timestep_us as u64 * 1000;
        sim.schedule_fault(2 * timestep + timestep / 2, Fault::CoreRte(a));
        sim.schedule_fault(3 * timestep + timestep / 2, Fault::CoreStall(b));
        sim.start_run_cycle(8);
        sim.run_until_idle().unwrap();
        assert_eq!(scamp::core_state(&sim, a).unwrap(), CoreState::RunTimeError);
        assert_eq!(scamp::core_state(&sim, b).unwrap(), CoreState::Watchdog);
        let iobuf_a = scamp::read_iobuf(&mut sim, a).unwrap();
        assert!(iobuf_a.contains("[chaos] RTE injected"), "{iobuf_a}");
        let iobuf_b = scamp::read_iobuf(&mut sim, b).unwrap();
        assert!(iobuf_b.contains("watchdog fired"), "{iobuf_b}");
        // Failed cores stop mid-run and never reach the tick target.
        let prov = scamp::provenance(&sim, a).unwrap();
        assert_eq!(prov.get("chaos_rte"), Some(&1));
        assert_eq!(sim.fault_log.len(), 2);
    }

    #[test]
    fn table_reload_invalidates_route_cache() {
        // Route key 5 to core 1, warm the cache, then reroute to core 2:
        // deliveries must follow the new table immediately.
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let rx1 = shared();
        let rx2 = shared();
        scamp::load_app(&mut sim, CoreLocation::new(0, 0, 1), Box::new(PingAppSilent { received: rx1.clone() }), Default::default(), Default::default()).unwrap();
        scamp::load_app(&mut sim, CoreLocation::new(0, 0, 2), Box::new(PingAppSilent { received: rx2.clone() }), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        scamp::load_routing_table(
            &mut sim,
            (0, 0),
            RoutingTable::from_entries(vec![RoutingEntry::new(5, !0, Route::EMPTY.with_processor(1))]),
        )
        .unwrap();
        sim.inject_mc(CoreLocation::new(0, 0, 3), 5, None);
        sim.run_until_idle().unwrap();
        scamp::load_routing_table(
            &mut sim,
            (0, 0),
            RoutingTable::from_entries(vec![RoutingEntry::new(5, !0, Route::EMPTY.with_processor(2))]),
        )
        .unwrap();
        sim.inject_mc(CoreLocation::new(0, 0, 3), 5, None);
        sim.run_until_idle().unwrap();
        assert_eq!(rx1.lock().unwrap().len(), 1, "first packet to the old route");
        assert_eq!(rx2.lock().unwrap().len(), 1, "second must see the reloaded table");
        let stats = sim.router_stats((0, 0)).unwrap();
        assert_eq!(stats.cache_misses, 2, "reload must force a fresh TCAM scan");
    }
}
