//! A discrete-event simulator of a SpiNNaker machine.
//!
//! The hardware substitute for this reproduction (DESIGN.md §2): a
//! cycle-approximate model of the router fabric (TCAM matching, default
//! routing, bounded output queues with the §2 drop-after-wait behaviour
//! and the single dropped-packet register of §6.10), per-chip SDRAM,
//! per-core event-driven applications ([`CoreApp`]), SCAMP-style host
//! operations with the §6.8 protocol cost models, IP tag tables and a
//! host UDP inbox.
//!
//! Virtual time is nanoseconds. All behaviour is deterministic: events
//! at equal times are ordered by insertion sequence.

mod core;
pub mod scamp;
mod sdram;

pub use self::core::{CoreApp, CoreCtx, CoreState, RecordingChannel};
pub use sdram::{SdramStore, SDRAM_BASE};

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::machine::router::{PacketSource, Route, RoutingDecision, RoutingTable};
use crate::machine::{ChipCoord, CoreLocation, Direction, Machine};
use crate::transport::SdpMessage;

use self::core::SimCore;

/// Wire/latency model. Defaults are calibrated so the three §6.8 data
/// paths reproduce the paper's measured throughputs (see DESIGN.md E1):
/// ~8 Mb/s SCAMP reads on the Ethernet chip, ~2 Mb/s off it, ~40 Mb/s
/// for the multicast streaming protocol from any chip.
#[derive(Debug, Clone)]
pub struct WireModel {
    /// Round trip for one 256-byte SCAMP read at the Ethernet chip
    /// (request + response through the UDP stack): 256 B / 8 Mb/s.
    pub eth_read_rtt_ns: u64,
    /// Extra cost per 256-byte SCAMP read when the target chip is not
    /// the Ethernet chip: the request/response must be broken into
    /// 24-bit P2P messages and reassembled (Figure 11 middle).
    pub p2p_read_penalty_ns: u64,
    /// Additional per-hop cost of the P2P relay.
    pub p2p_per_hop_ns: u64,
    /// Latency of one UDP frame between host and board.
    pub udp_frame_ns: u64,
}

impl Default for WireModel {
    fn default() -> Self {
        Self {
            // 256 B * 8 bits / 8 Mb/s = 256 us.
            eth_read_rtt_ns: 256_000,
            // Total off-chip read ~ 1024 us/256 B => ~2 Mb/s.
            p2p_read_penalty_ns: 744_000,
            p2p_per_hop_ns: 4_000,
            udp_frame_ns: 50_000,
        }
    }
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Simulation timestep (the timer period), microseconds.
    pub timestep_us: u32,
    /// Serialisation time of one multicast packet on an inter-chip link
    /// (~6 M packets/s on silicon → ~166 ns).
    pub link_packet_ns: u64,
    /// Router pipeline latency per hop.
    pub router_pipeline_ns: u64,
    /// Delivery latency into a core's incoming queue.
    pub local_deliver_ns: u64,
    /// Output-queue depth per link; beyond this the router waits...
    pub link_queue_depth: u64,
    /// ...up to this long, then drops the packet (§2). The tools
    /// configure generous router timeouts in production; congestion
    /// experiments override this downwards.
    pub drop_wait_ns: u64,
    /// Spacing between successive packets emitted by one core within a
    /// single callback: a core produces packets as it iterates its
    /// neurons (~200 MHz ARM), not as an instantaneous burst.
    pub send_spacing_ns: u64,
    /// Keys at or above this value are flow-controlled, never dropped —
    /// the §6.8 fast-extraction configuration ("the machine is set up so
    /// that packets are guaranteed to arrive"; single path, no deadlock).
    pub lossless_key_min: u32,
    /// Whether chips run the dropped-packet reinjector (§6.10).
    pub reinjection: bool,
    /// Delay before the reinjection core re-issues a dropped packet.
    pub reinject_delay_ns: u64,
    pub wire: WireModel,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            timestep_us: 1000,
            link_packet_ns: 166,
            router_pipeline_ns: 100,
            local_deliver_ns: 200,
            link_queue_depth: 16,
            drop_wait_ns: 200_000,
            send_spacing_ns: 500,
            lossless_key_min: 0xFF00_0000,
            reinjection: true,
            reinject_delay_ns: 10_000,
            wire: WireModel::default(),
        }
    }
}

/// Router statistics per chip (§6.3.5 provenance: "router statistics,
/// including dropped multicast packets").
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    pub mc_routed: u64,
    pub mc_default_routed: u64,
    pub mc_dropped: u64,
    pub mc_reinjected: u64,
    /// Drops that hit an occupied register and are unrecoverable (§6.10).
    pub mc_lost_forever: u64,
}

/// Whole-machine counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimStats {
    pub events_processed: u64,
    pub mc_sent: u64,
    pub mc_delivered: u64,
    pub sdp_sent: u64,
}

pub(crate) struct SimChip {
    pub table: RoutingTable,
    pub sdram: SdramStore,
    pub cores: BTreeMap<u8, SimCore>,
    /// tag id -> (host, port, strip_sdp).
    pub iptags: BTreeMap<u8, (String, u16, bool)>,
    /// udp port -> destination core.
    pub reverse_iptags: BTreeMap<u16, CoreLocation>,
    pub router_stats: RouterStats,
    /// The single hardware dropped-packet register (§6.10).
    pub dropped_register: Option<(u32, Option<u32>)>,
    pub drop_overflow: bool,
}

#[derive(Debug)]
enum EventKind {
    /// Timer event for one core.
    Tick(CoreLocation),
    /// A multicast packet at a chip's router.
    Router {
        chip: ChipCoord,
        entered: PacketSource,
        key: u32,
        payload: Option<u32>,
    },
    /// Deliver a multicast packet into a core.
    DeliverMc {
        loc: CoreLocation,
        key: u32,
        payload: Option<u32>,
    },
    /// Deliver an SDP message to a core.
    DeliverSdp(SdpMessage),
    /// A UDP frame reaches the host.
    HostUdp { port: u16, data: Vec<u8> },
    /// The reinjection core services the dropped-packet register.
    Reinject(ChipCoord),
}

struct Event {
    time: u64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// The simulated machine.
pub struct SimMachine {
    pub machine: Machine,
    pub config: SimConfig,
    time_ns: u64,
    seq: u64,
    events: BinaryHeap<Reverse<Event>>,
    chips: BTreeMap<ChipCoord, SimChip>,
    /// Packets consumed by external devices on virtual chips.
    pub device_inbox: BTreeMap<ChipCoord, Vec<(u32, Option<u32>)>>,
    /// UDP frames that reached the host: (arrival time, port, payload).
    pub host_inbox: VecDeque<(u64, u16, Vec<u8>)>,
    link_busy: BTreeMap<(ChipCoord, Direction), u64>,
    /// Serialisation cursor of each Ethernet chip's UDP uplink — the
    /// bandwidth bottleneck that makes the §6.8 throughput numbers real.
    udp_busy: BTreeMap<ChipCoord, u64>,
    pub stats: SimStats,
}

impl SimMachine {
    /// Boot a simulated machine with the given geometry. (Plays the role
    /// of powering on + SCAMP flood-boot: afterwards the host can query
    /// the machine and load applications.)
    pub fn boot(machine: Machine, config: SimConfig) -> Self {
        let mut chips = BTreeMap::new();
        for chip in machine.chips() {
            if chip.is_virtual {
                continue;
            }
            let mut cores = BTreeMap::new();
            for p in chip.processors.iter() {
                cores.insert(p.id, SimCore::idle());
            }
            chips.insert(
                (chip.x, chip.y),
                SimChip {
                    table: RoutingTable::new(),
                    sdram: SdramStore::new(chip.sdram.user_size()),
                    cores,
                    iptags: BTreeMap::new(),
                    reverse_iptags: BTreeMap::new(),
                    router_stats: RouterStats::default(),
                    dropped_register: None,
                    drop_overflow: false,
                },
            );
        }
        let device_inbox = machine
            .chips()
            .filter(|c| c.is_virtual)
            .map(|c| ((c.x, c.y), Vec::new()))
            .collect();
        Self {
            machine,
            config,
            time_ns: 0,
            seq: 0,
            events: BinaryHeap::new(),
            chips,
            device_inbox,
            host_inbox: VecDeque::new(),
            link_busy: BTreeMap::new(),
            udp_busy: BTreeMap::new(),
            stats: SimStats::default(),
        }
    }

    pub fn now_ns(&self) -> u64 {
        self.time_ns
    }

    /// Advance the host clock (host-side protocol costs).
    pub(crate) fn advance_host_time(&mut self, ns: u64) {
        self.time_ns += ns;
    }

    fn push_event(&mut self, time: u64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Reverse(Event { time, seq: self.seq, kind }));
    }

    pub(crate) fn chip(&self, c: ChipCoord) -> anyhow::Result<&SimChip> {
        self.chips
            .get(&c)
            .ok_or_else(|| anyhow::anyhow!("no such chip {c:?}"))
    }

    pub(crate) fn chip_mut(&mut self, c: ChipCoord) -> anyhow::Result<&mut SimChip> {
        self.chips
            .get_mut(&c)
            .ok_or_else(|| anyhow::anyhow!("no such chip {c:?}"))
    }

    /// Router stats for provenance extraction.
    pub fn router_stats(&self, c: ChipCoord) -> Option<RouterStats> {
        self.chips.get(&c).map(|ch| ch.router_stats)
    }

    /// Sum of router stats across the machine.
    pub fn total_router_stats(&self) -> RouterStats {
        let mut out = RouterStats::default();
        for ch in self.chips.values() {
            out.mc_routed += ch.router_stats.mc_routed;
            out.mc_default_routed += ch.router_stats.mc_default_routed;
            out.mc_dropped += ch.router_stats.mc_dropped;
            out.mc_reinjected += ch.router_stats.mc_reinjected;
            out.mc_lost_forever += ch.router_stats.mc_lost_forever;
        }
        out
    }

    /// Inject a multicast packet from a core (hot path of the fabric).
    /// Public: tests and custom harnesses inject traffic directly.
    pub fn inject_mc(&mut self, from: CoreLocation, key: u32, payload: Option<u32>) {
        self.inject_mc_after(from, key, payload, 0);
    }

    pub(crate) fn inject_mc_after(
        &mut self,
        from: CoreLocation,
        key: u32,
        payload: Option<u32>,
        delay_ns: u64,
    ) {
        self.stats.mc_sent += 1;
        let t = self.time_ns + delay_ns;
        self.push_event(
            t + self.config.router_pipeline_ns,
            EventKind::Router {
                chip: from.chip(),
                entered: PacketSource::Local(from.p),
                key,
                payload,
            },
        );
    }

    /// Process events until the queue is empty.
    pub fn run_until_idle(&mut self) -> anyhow::Result<()> {
        while let Some(Reverse(ev)) = self.events.pop() {
            debug_assert!(ev.time >= self.time_ns, "time went backwards");
            self.time_ns = ev.time;
            self.stats.events_processed += 1;
            self.dispatch(ev.kind)?;
        }
        Ok(())
    }

    fn dispatch(&mut self, kind: EventKind) -> anyhow::Result<()> {
        match kind {
            EventKind::Tick(loc) => self.handle_tick(loc),
            EventKind::Router { chip, entered, key, payload } => {
                self.handle_router(chip, entered, key, payload)
            }
            EventKind::DeliverMc { loc, key, payload } => {
                self.stats.mc_delivered += 1;
                self.with_core_app(loc, |app, ctx| app.on_mc_packet(key, payload, ctx))
            }
            EventKind::DeliverSdp(msg) => {
                let loc = msg.header.dest();
                self.with_core_app(loc, |app, ctx| app.on_sdp(&msg, ctx))
            }
            EventKind::HostUdp { port, data } => {
                self.host_inbox.push_back((self.time_ns, port, data));
                Ok(())
            }
            EventKind::Reinject(chip) => self.handle_reinject(chip),
        }
    }

    fn handle_router(
        &mut self,
        chip: ChipCoord,
        entered: PacketSource,
        key: u32,
        payload: Option<u32>,
    ) -> anyhow::Result<()> {
        let Some(sim_chip) = self.chips.get(&chip) else {
            // Packet wandered onto a dead/virtual chip — treat as device
            // consumption if virtual, else drop.
            if let Some(inbox) = self.device_inbox.get_mut(&chip) {
                inbox.push((key, payload));
            }
            return Ok(());
        };
        let decision = sim_chip.table.route_packet(key, entered);
        match decision {
            RoutingDecision::Routed(route) => {
                self.chips.get_mut(&chip).unwrap().router_stats.mc_routed += 1;
                self.forward(chip, route, key, payload)?;
            }
            RoutingDecision::DefaultRouted(d) => {
                self.chips.get_mut(&chip).unwrap().router_stats.mc_default_routed += 1;
                self.forward(chip, Route::EMPTY.with_link(d), key, payload)?;
            }
            RoutingDecision::Dropped => {
                // A locally-injected packet with no matching entry is
                // simply discarded (§2) — it never reaches the dropped-
                // packet register, so reinjection cannot resurrect it.
                if let Some(c) = self.chips.get_mut(&chip) {
                    c.router_stats.mc_dropped += 1;
                }
            }
        }
        Ok(())
    }

    fn forward(
        &mut self,
        chip: ChipCoord,
        route: Route,
        key: u32,
        payload: Option<u32>,
    ) -> anyhow::Result<()> {
        let now = self.time_ns;
        for p in route.processors() {
            self.push_event(
                now + self.config.local_deliver_ns,
                EventKind::DeliverMc {
                    loc: CoreLocation::new(chip.0, chip.1, p),
                    key,
                    payload,
                },
            );
        }
        for d in route.links() {
            let Some(next) = self.machine.link_target(chip, d) else {
                // Route over a dead link: the packet is gone for good —
                // reinjection would just replay it into the same void.
                if let Some(c) = self.chips.get_mut(&chip) {
                    c.router_stats.mc_dropped += 1;
                    c.router_stats.mc_lost_forever += 1;
                }
                continue;
            };
            // Congestion model: bounded output queue, drop after wait (§2)
            // — except for flow-controlled (lossless) key ranges.
            let busy = self.link_busy.get(&(chip, d)).copied().unwrap_or(0);
            let depart = busy.max(now);
            let backlog = depart.saturating_sub(now);
            if backlog > self.config.drop_wait_ns && key < self.config.lossless_key_min {
                self.drop_packet(chip, key, payload);
                continue;
            }
            self.link_busy
                .insert((chip, d), depart + self.config.link_packet_ns);
            let arrive = depart + self.config.link_packet_ns + self.config.router_pipeline_ns;
            if self
                .machine
                .chip(next)
                .map(|c| c.is_virtual)
                .unwrap_or(false)
            {
                self.device_inbox.entry(next).or_default().push((key, payload));
            } else {
                self.push_event(
                    arrive,
                    EventKind::Router {
                        chip: next,
                        entered: PacketSource::Link(d.opposite()),
                        key,
                        payload,
                    },
                );
            }
        }
        Ok(())
    }

    /// §6.10 drop semantics: one hardware register; a second drop while
    /// it is occupied is unrecoverable and only counted.
    fn drop_packet(&mut self, chip: ChipCoord, key: u32, payload: Option<u32>) {
        let reinjection = self.config.reinjection;
        let delay = self.config.reinject_delay_ns;
        let now = self.time_ns;
        let Some(c) = self.chips.get_mut(&chip) else { return };
        c.router_stats.mc_dropped += 1;
        if c.dropped_register.is_none() {
            c.dropped_register = Some((key, payload));
            if reinjection {
                self.push_event(now + delay, EventKind::Reinject(chip));
            }
        } else {
            c.drop_overflow = true;
            c.router_stats.mc_lost_forever += 1;
        }
    }

    fn handle_reinject(&mut self, chip: ChipCoord) -> anyhow::Result<()> {
        let now = self.time_ns;
        let Some(c) = self.chips.get_mut(&chip) else {
            return Ok(());
        };
        if let Some((key, payload)) = c.dropped_register.take() {
            c.router_stats.mc_reinjected += 1;
            // Re-issue as if sent by the monitor core.
            self.push_event(
                now + self.config.router_pipeline_ns,
                EventKind::Router {
                    chip,
                    entered: PacketSource::Local(0),
                    key,
                    payload,
                },
            );
        }
        Ok(())
    }

    fn handle_tick(&mut self, loc: CoreLocation) -> anyhow::Result<()> {
        // Check run state first.
        {
            let chip = self.chip_mut(loc.chip())?;
            let core = chip
                .cores
                .get_mut(&loc.p)
                .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
            if core.state != CoreState::Running {
                return Ok(());
            }
            if core.ticks_done >= core.run_until {
                core.state = CoreState::Paused;
                return Ok(());
            }
            core.ticks_done += 1;
        }
        let timestep_ns = self.config.timestep_us as u64 * 1000;
        self.with_core_app(loc, |app, ctx| app.on_timer(ctx))?;
        // Schedule the next tick (or pause at the boundary).
        let (done, until, state) = {
            let chip = self.chip(loc.chip())?;
            let core = &chip.cores[&loc.p];
            (core.ticks_done, core.run_until, core.state)
        };
        if state == CoreState::Running {
            if done < until {
                let t = self.time_ns + timestep_ns;
                self.push_event(t, EventKind::Tick(loc));
            } else {
                let mut pause_needed = false;
                {
                    let chip = self.chip_mut(loc.chip())?;
                    let core = chip.cores.get_mut(&loc.p).unwrap();
                    if core.state == CoreState::Running {
                        core.state = CoreState::Paused;
                        pause_needed = true;
                    }
                }
                if pause_needed {
                    self.with_core_app(loc, |app, ctx| app.on_pause(ctx))?;
                }
            }
        }
        Ok(())
    }

    /// Run one core-app callback with a properly wired [`CoreCtx`], then
    /// flush its outboxes into events.
    pub(crate) fn with_core_app(
        &mut self,
        loc: CoreLocation,
        f: impl FnOnce(&mut dyn CoreApp, &mut CoreCtx) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        let time_ns = self.time_ns;
        let (mut app, mut mc_out, mut sdp_out, result, exit_requested) = {
            let chip = self
                .chips
                .get_mut(&loc.chip())
                .ok_or_else(|| anyhow::anyhow!("no chip {:?}", loc.chip()))?;
            let core = chip
                .cores
                .get_mut(&loc.p)
                .ok_or_else(|| anyhow::anyhow!("no core {loc}"))?;
            let Some(mut app) = core.app.take() else {
                return Ok(()); // packet to an idle core: silently ignored
            };
            let mut exit_requested = false;
            let mut ctx = CoreCtx {
                loc,
                time_ns,
                tick: core.ticks_done,
                mc_out: Vec::new(),
                sdp_out: Vec::new(),
                regions: &core.regions,
                recordings: &mut core.recordings,
                sdram: &mut chip.sdram,
                provenance: &mut core.provenance,
                exit_requested: &mut exit_requested,
            };
            let result = f(app.as_mut(), &mut ctx);
            let mc_out = std::mem::take(&mut ctx.mc_out);
            let sdp_out = std::mem::take(&mut ctx.sdp_out);
            (app, mc_out, sdp_out, result, exit_requested)
        };
        // Put the app back and update state.
        {
            let chip = self.chips.get_mut(&loc.chip()).unwrap();
            let core = chip.cores.get_mut(&loc.p).unwrap();
            core.app = Some(std::mem::replace(&mut app, Box::new(NullApp)));
            drop(app);
            if result.is_err() {
                core.state = CoreState::RunTimeError;
            } else if exit_requested {
                core.state = CoreState::Finished;
            }
        }
        // Flush outboxes. Successive packets from one callback are
        // spaced out as the core would actually produce them.
        let spacing = self.config.send_spacing_ns;
        for (i, (key, payload)) in mc_out.drain(..).enumerate() {
            self.inject_mc_after(loc, key, payload, i as u64 * spacing);
        }
        for msg in sdp_out.drain(..) {
            self.route_sdp(loc, msg)?;
        }
        // A failing callback marks the core RTE but does not stop the
        // simulation: the tools detect the state afterwards (§6.3.5).
        if let Err(e) = result {
            let chip = self.chips.get_mut(&loc.chip()).unwrap();
            let core = chip.cores.get_mut(&loc.p).unwrap();
            core.provenance
                .insert(format!("rte: {e}"), 1);
        }
        Ok(())
    }

    /// SDP routing: tagged messages go out via the board's Ethernet
    /// (consulting the IP tag table, §3); untagged go core-to-core.
    pub(crate) fn route_sdp(&mut self, from: CoreLocation, msg: SdpMessage) -> anyhow::Result<()> {
        self.stats.sdp_sent += 1;
        let now = self.time_ns;
        if msg.header.tag != 0xff {
            // Host-bound: relay to the Ethernet chip (P2P cost if the
            // source is elsewhere), then UDP to the host.
            let eth = self
                .machine
                .nearest_ethernet(from.chip())
                .ok_or_else(|| anyhow::anyhow!("no ethernet for {from}"))?;
            let hops = self.machine.hop_distance(from.chip(), eth) as u64;
            let relay = hops * self.config.wire.p2p_per_hop_ns;
            let chip = self.chip(eth)?;
            let Some((_, port, strip)) = chip.iptags.get(&msg.header.tag).cloned() else {
                anyhow::bail!("SDP with unset IP tag {} at {eth:?}", msg.header.tag)
            };
            let data = if strip { msg.data.clone() } else { msg.encode() };
            // Serialise on the Ethernet uplink: one frame per slot.
            let ready = now + relay;
            let busy = self.udp_busy.get(&eth).copied().unwrap_or(0);
            let depart = busy.max(ready);
            self.udp_busy
                .insert(eth, depart + self.config.wire.udp_frame_ns);
            self.push_event(
                depart + self.config.wire.udp_frame_ns,
                EventKind::HostUdp { port, data },
            );
        } else {
            // On-machine SDP: hop-proportional latency.
            let dest = msg.header.dest();
            let hops = self.machine.hop_distance(from.chip(), dest.chip()) as u64;
            self.push_event(
                now + (hops + 1) * self.config.wire.p2p_per_hop_ns,
                EventKind::DeliverSdp(msg),
            );
        }
        Ok(())
    }

    /// Host → machine SDP (via the board's Ethernet chip and the P2P
    /// fabric): how the tools command individual cores, e.g. the fast
    /// data-extraction reader (§6.8).
    pub fn host_send_sdp(&mut self, msg: SdpMessage) -> anyhow::Result<()> {
        let now = self.time_ns;
        let dest = msg.header.dest();
        let eth = self
            .machine
            .nearest_ethernet(dest.chip())
            .ok_or_else(|| anyhow::anyhow!("no ethernet for {dest}"))?;
        let hops = self.machine.hop_distance(eth, dest.chip()) as u64;
        self.push_event(
            now + self.config.wire.udp_frame_ns + hops * self.config.wire.p2p_per_hop_ns,
            EventKind::DeliverSdp(msg),
        );
        Ok(())
    }

    /// Host → machine UDP (reverse IP tag path, §3/§6.9): deliver the
    /// frame as SDP to the core registered for `port` on `board`.
    pub fn host_send_udp(&mut self, board: ChipCoord, port: u16, data: Vec<u8>) -> anyhow::Result<()> {
        let now = self.time_ns;
        let chip = self.chip(board)?;
        let dest = *chip
            .reverse_iptags
            .get(&port)
            .ok_or_else(|| anyhow::anyhow!("no reverse IP tag for port {port} on {board:?}"))?;
        let mut header = crate::transport::SdpHeader::to_core(dest, 1);
        header.src_port = 7; // came from the outside world
        let msg = SdpMessage::new(header, data);
        let hops = self.machine.hop_distance(board, dest.chip()) as u64;
        self.push_event(
            now + self.config.wire.udp_frame_ns + hops * self.config.wire.p2p_per_hop_ns,
            EventKind::DeliverSdp(msg),
        );
        Ok(())
    }

    /// Drain host-bound UDP frames for one port (the front end's
    /// listener pump).
    pub fn take_host_udp(&mut self, port: u16) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        self.host_inbox.retain(|(_, p, data)| {
            if *p == port {
                out.push(data.clone());
                false
            } else {
                true
            }
        });
        out
    }

    /// Schedule the first tick for every Running core (start of a run
    /// cycle). `run_ticks` is added to each core's target.
    pub fn start_run_cycle(&mut self, run_ticks: u64) {
        let timestep_ns = self.config.timestep_us as u64 * 1000;
        let locs: Vec<CoreLocation> = self
            .chips
            .iter()
            .flat_map(|(c, chip)| {
                chip.cores.iter().filter_map(move |(p, core)| {
                    matches!(core.state, CoreState::Running | CoreState::Paused)
                        .then_some(CoreLocation::new(c.0, c.1, *p))
                })
            })
            .collect();
        let now = self.time_ns;
        for loc in locs {
            let chip = self.chips.get_mut(&loc.chip()).unwrap();
            let core = chip.cores.get_mut(&loc.p).unwrap();
            core.run_until += run_ticks;
            core.state = CoreState::Running;
            self.push_event(now + timestep_ns, EventKind::Tick(loc));
        }
    }
}

/// Placeholder used while swapping apps in/out of cores.
struct NullApp;
impl CoreApp for NullApp {
    fn on_timer(&mut self, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::router::RoutingEntry;
    use crate::machine::MachineBuilder;

    /// An app that sends one packet per tick and records received keys.
    struct PingApp {
        key: u32,
        received: std::sync::Arc<std::sync::Mutex<Vec<u32>>>,
    }

    impl CoreApp for PingApp {
        fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            ctx.send_mc(self.key, Some(ctx.tick as u32));
            Ok(())
        }
        fn on_mc_packet(&mut self, key: u32, _p: Option<u32>, ctx: &mut CoreCtx) -> anyhow::Result<()> {
            self.received.lock().unwrap().push(key);
            ctx.count("packets_in", 1);
            Ok(())
        }
    }

    fn shared() -> std::sync::Arc<std::sync::Mutex<Vec<u32>>> {
        std::sync::Arc::new(std::sync::Mutex::new(Vec::new()))
    }

    #[test]
    fn two_cores_exchange_packets() {
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let rx_a = shared();
        let rx_b = shared();
        let a = CoreLocation::new(0, 0, 1);
        let b = CoreLocation::new(1, 0, 1);
        // routing: key 0x10 a->b, key 0x20 b->a
        sim.chip_mut((0, 0)).unwrap().table = RoutingTable::from_entries(vec![
            RoutingEntry::new(0x10, !0, Route::EMPTY.with_link(Direction::East)),
            RoutingEntry::new(0x20, !0, Route::EMPTY.with_processor(1)),
        ]);
        sim.chip_mut((1, 0)).unwrap().table = RoutingTable::from_entries(vec![
            RoutingEntry::new(0x10, !0, Route::EMPTY.with_processor(1)),
            RoutingEntry::new(0x20, !0, Route::EMPTY.with_link(Direction::West)),
        ]);
        scamp::load_app(&mut sim, a, Box::new(PingApp { key: 0x10, received: rx_a.clone() }), Default::default(), Default::default()).unwrap();
        scamp::load_app(&mut sim, b, Box::new(PingApp { key: 0x20, received: rx_b.clone() }), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(10);
        sim.run_until_idle().unwrap();
        assert_eq!(rx_a.lock().unwrap().len(), 10, "a receives b's 10 packets");
        assert!(rx_a.lock().unwrap().iter().all(|k| *k == 0x20));
        assert_eq!(rx_b.lock().unwrap().len(), 10);
        assert_eq!(scamp::core_state(&sim, a).unwrap(), CoreState::Paused);
        let prov = scamp::provenance(&sim, a).unwrap();
        assert_eq!(prov.get("packets_in"), Some(&10));
    }

    #[test]
    fn unrouted_local_packet_counts_as_drop() {
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(PingApp { key: 0x99, received: shared() }), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        let stats = sim.router_stats((0, 0)).unwrap();
        assert_eq!(stats.mc_dropped, 5);
    }

    #[test]
    fn finished_state_on_exit() {
        struct ExitApp;
        impl CoreApp for ExitApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                if ctx.tick >= 3 {
                    ctx.exit();
                }
                Ok(())
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let loc = CoreLocation::new(0, 0, 1);
        scamp::load_app(&mut sim, loc, Box::new(ExitApp), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(100);
        sim.run_until_idle().unwrap();
        assert_eq!(scamp::core_state(&sim, loc).unwrap(), CoreState::Finished);
    }

    #[test]
    fn rte_state_on_error() {
        struct BadApp;
        impl CoreApp for BadApp {
            fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
                anyhow::bail!("deliberate failure")
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(machine, SimConfig::default());
        let loc = CoreLocation::new(1, 1, 2);
        scamp::load_app(&mut sim, loc, Box::new(BadApp), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(5);
        sim.run_until_idle().unwrap();
        assert_eq!(scamp::core_state(&sim, loc).unwrap(), CoreState::RunTimeError);
    }

    #[test]
    fn congestion_drops_and_reinjects() {
        // Many cores on one chip all hammering the same outbound link in
        // the same instant overflows the output queue.
        struct BurstApp {
            key: u32,
        }
        impl CoreApp for BurstApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                for _ in 0..8 {
                    ctx.send_mc(self.key, None);
                }
                Ok(())
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let mut config = SimConfig::default();
        config.link_queue_depth = 2;
        config.drop_wait_ns = 400; // tiny patience
        config.send_spacing_ns = 0; // instantaneous burst
        let mut sim = SimMachine::boot(machine, config);
        // All keys routed East out of (0,0); receiver on (1,0) core 1.
        sim.chip_mut((0, 0)).unwrap().table = RoutingTable::from_entries(vec![
            RoutingEntry::new(0, 0, Route::EMPTY.with_link(Direction::East)),
        ]);
        sim.chip_mut((1, 0)).unwrap().table = RoutingTable::from_entries(vec![
            RoutingEntry::new(0, 0, Route::EMPTY.with_processor(1)),
        ]);
        let rx = shared();
        scamp::load_app(&mut sim, CoreLocation::new(1, 0, 1), Box::new(PingAppSilent { received: rx.clone() }), Default::default(), Default::default()).unwrap();
        for p in 1..=8 {
            scamp::load_app(&mut sim, CoreLocation::new(0, 0, p), Box::new(BurstApp { key: p as u32 }), Default::default(), Default::default()).unwrap();
        }
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(3);
        sim.run_until_idle().unwrap();
        let stats = sim.router_stats((0, 0)).unwrap();
        assert!(stats.mc_dropped > 0, "expected congestion drops");
        assert!(stats.mc_reinjected > 0, "reinjector should recover some");
        // Reinjection recovered at least the register-held packets:
        // delivered + lost_forever == sent (64 per tick * 3 - receiver's own sends).
        let delivered = rx.lock().unwrap().len() as u64;
        assert_eq!(delivered + stats.mc_lost_forever, 8 * 8 * 3);
    }

    struct PingAppSilent {
        received: std::sync::Arc<std::sync::Mutex<Vec<u32>>>,
    }
    impl CoreApp for PingAppSilent {
        fn on_timer(&mut self, _: &mut CoreCtx) -> anyhow::Result<()> {
            Ok(())
        }
        fn on_mc_packet(&mut self, key: u32, _p: Option<u32>, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
            self.received.lock().unwrap().push(key);
            Ok(())
        }
    }

    #[test]
    fn reinjection_disabled_loses_packets() {
        struct BurstApp;
        impl CoreApp for BurstApp {
            fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
                for _ in 0..16 {
                    ctx.send_mc(7, None);
                }
                Ok(())
            }
        }
        let machine = MachineBuilder::spinn3().build();
        let mut config = SimConfig::default();
        config.link_queue_depth = 2;
        config.drop_wait_ns = 400;
        config.send_spacing_ns = 0;
        config.reinjection = false;
        let mut sim = SimMachine::boot(machine, config);
        sim.chip_mut((0, 0)).unwrap().table = RoutingTable::from_entries(vec![
            RoutingEntry::new(7, !0, Route::EMPTY.with_link(Direction::East)),
        ]);
        sim.chip_mut((1, 0)).unwrap().table = RoutingTable::from_entries(vec![
            RoutingEntry::new(7, !0, Route::EMPTY.with_processor(1)),
        ]);
        let rx = shared();
        scamp::load_app(&mut sim, CoreLocation::new(1, 0, 1), Box::new(PingAppSilent { received: rx.clone() }), Default::default(), Default::default()).unwrap();
        scamp::load_app(&mut sim, CoreLocation::new(0, 0, 1), Box::new(BurstApp), Default::default(), Default::default()).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        sim.start_run_cycle(2);
        sim.run_until_idle().unwrap();
        let stats = sim.router_stats((0, 0)).unwrap();
        assert!(stats.mc_dropped > 0);
        assert_eq!(stats.mc_reinjected, 0);
        assert!((rx.lock().unwrap().len() as u64) < 32, "some packets must be lost");
    }
}
