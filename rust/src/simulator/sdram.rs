//! Per-chip SDRAM model: a segment allocator over the 128 MiB address
//! space. Segments are allocated by the loader (data regions, recording
//! buffers) and read back by the extraction paths — the same addresses
//! flow through SCAMP reads and the fast gatherer protocol, so both
//! extraction paths exercise real address arithmetic.

use std::collections::BTreeMap;

/// SDRAM base address on real hardware (for address realism).
pub const SDRAM_BASE: u32 = 0x6000_0000;

#[derive(Debug, Default)]
pub struct SdramStore {
    /// addr -> segment bytes.
    segments: BTreeMap<u32, Vec<u8>>,
    next: u32,
    size: u32,
}

impl SdramStore {
    pub fn new(size: u32) -> Self {
        Self { segments: BTreeMap::new(), next: SDRAM_BASE, size }
    }

    /// Allocate a zeroed segment, word-aligned.
    pub fn alloc(&mut self, len: u32) -> anyhow::Result<u32> {
        let len = len.max(1).div_ceil(4) * 4;
        anyhow::ensure!(
            self.next - SDRAM_BASE + len <= self.size,
            "SDRAM exhausted: {} of {} used, {len} requested",
            self.next - SDRAM_BASE,
            self.size
        );
        let addr = self.next;
        self.segments.insert(addr, vec![0u8; len as usize]);
        self.next += len;
        Ok(addr)
    }

    pub fn free_bytes(&self) -> u32 {
        self.size - (self.next - SDRAM_BASE)
    }

    /// The segment containing `addr`, with the offset into it.
    fn locate(&self, addr: u32) -> anyhow::Result<(u32, usize)> {
        let (base, seg) = self
            .segments
            .range(..=addr)
            .next_back()
            .ok_or_else(|| anyhow::anyhow!("address {addr:#x} before any segment"))?;
        let off = (addr - base) as usize;
        anyhow::ensure!(
            off < seg.len(),
            "address {addr:#x} outside segment at {base:#x} (len {})",
            seg.len()
        );
        Ok((*base, off))
    }

    pub fn write(&mut self, addr: u32, data: &[u8]) -> anyhow::Result<()> {
        let (base, off) = self.locate(addr)?;
        let seg = self.segments.get_mut(&base).unwrap();
        anyhow::ensure!(
            off + data.len() <= seg.len(),
            "write of {} bytes at {addr:#x} overruns segment",
            data.len()
        );
        seg[off..off + data.len()].copy_from_slice(data);
        Ok(())
    }

    pub fn read(&self, addr: u32, len: usize) -> anyhow::Result<Vec<u8>> {
        let (base, off) = self.locate(addr)?;
        let seg = &self.segments[&base];
        anyhow::ensure!(
            off + len <= seg.len(),
            "read of {len} bytes at {addr:#x} overruns segment"
        );
        Ok(seg[off..off + len].to_vec())
    }

    /// Zero a segment region (recording-buffer flush between run cycles).
    pub fn clear(&mut self, addr: u32, len: usize) -> anyhow::Result<()> {
        let (base, off) = self.locate(addr)?;
        let seg = self.segments.get_mut(&base).unwrap();
        anyhow::ensure!(off + len <= seg.len(), "clear overruns segment");
        seg[off..off + len].fill(0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_write_read() {
        let mut s = SdramStore::new(1024 * 1024);
        let a = s.alloc(100).unwrap();
        assert_eq!(a, SDRAM_BASE);
        s.write(a, &[1, 2, 3]).unwrap();
        assert_eq!(s.read(a, 3).unwrap(), vec![1, 2, 3]);
        // offset read
        s.write(a + 50, &[9]).unwrap();
        assert_eq!(s.read(a + 50, 1).unwrap(), vec![9]);
    }

    #[test]
    fn allocations_do_not_overlap() {
        let mut s = SdramStore::new(1024);
        let a = s.alloc(10).unwrap();
        let b = s.alloc(10).unwrap();
        assert!(b >= a + 10);
        s.write(a, &[0xAA; 10]).unwrap();
        s.write(b, &[0xBB; 10]).unwrap();
        assert_eq!(s.read(a, 10).unwrap(), vec![0xAA; 10]);
        assert_eq!(s.read(b, 10).unwrap(), vec![0xBB; 10]);
    }

    #[test]
    fn exhaustion_errors() {
        let mut s = SdramStore::new(128);
        assert!(s.alloc(100).is_ok());
        assert!(s.alloc(100).is_err());
        assert!(s.free_bytes() < 100);
    }

    #[test]
    fn out_of_bounds_access_errors() {
        let mut s = SdramStore::new(1024);
        let a = s.alloc(8).unwrap();
        assert!(s.read(a, 100).is_err());
        assert!(s.write(a + 6, &[1, 2, 3, 4]).is_err());
        assert!(s.read(a - 4, 4).is_err());
    }

    #[test]
    fn clear_zeroes() {
        let mut s = SdramStore::new(1024);
        let a = s.alloc(16).unwrap();
        s.write(a, &[0xFF; 16]).unwrap();
        s.clear(a, 8).unwrap();
        assert_eq!(s.read(a, 9).unwrap()[..8], vec![0u8; 8][..]);
        assert_eq!(s.read(a + 8, 1).unwrap(), vec![0xFF]);
    }
}
