//! Host-side live interaction (§6.9): an event listener decoding the
//! Live Packet Gatherer's EIEIO stream using the mapping database, and
//! an injector feeding the Reverse IP Tag Multicast Source.

use crate::machine::ChipCoord;
use crate::mapping::database::MappingDatabase;
use crate::simulator::SimMachine;
use crate::transport::{EieioMessage, EieioType};

/// Decodes LPG output into (vertex label, partition, atom) events.
pub struct LiveEventListener {
    port: u16,
    db: MappingDatabase,
}

impl LiveEventListener {
    /// Built once the mapping database is ready (the Figure-8
    /// notification handshake).
    pub fn new(port: u16, db: MappingDatabase) -> Self {
        Self { port, db }
    }

    /// Drain pending events from the host inbox.
    pub fn poll(&self, sim: &mut SimMachine) -> anyhow::Result<Vec<LiveEvent>> {
        let mut out = Vec::new();
        for frame in sim.take_host_udp(self.port) {
            let msg = EieioMessage::decode(&frame)?;
            for (key, payload) in msg.events {
                match self.db.source_of_key(key) {
                    Some((vertex, partition, atom)) => out.push(LiveEvent {
                        vertex: vertex.to_string(),
                        partition: partition.to_string(),
                        atom,
                        payload,
                    }),
                    None => out.push(LiveEvent {
                        vertex: String::new(),
                        partition: String::new(),
                        atom: key,
                        payload,
                    }),
                }
            }
        }
        Ok(out)
    }
}

/// One decoded live event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LiveEvent {
    pub vertex: String,
    pub partition: String,
    pub atom: u32,
    pub payload: Option<u32>,
}

/// Sends events into the machine through a Reverse IP Tag Multicast
/// Source's UDP port.
pub struct LiveInjector {
    board: ChipCoord,
    port: u16,
}

impl LiveInjector {
    pub fn new(board: ChipCoord, port: u16) -> Self {
        Self { board, port }
    }

    /// Inject events by id (the RIPTMS adds its key base).
    pub fn send(&self, sim: &mut SimMachine, event_ids: &[u32]) -> anyhow::Result<()> {
        for batch in EieioMessage::batched(
            EieioType::Key32,
            &event_ids.iter().map(|e| (*e, None)).collect::<Vec<_>>(),
        ) {
            sim.host_send_udp(self.board, self.port, batch.encode())?;
        }
        Ok(())
    }
}
