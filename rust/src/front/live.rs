//! Host-side live interaction (§6.9): an event listener decoding the
//! Live Packet Gatherer's EIEIO stream using the mapping database, and
//! an injector feeding the Reverse IP Tag Multicast Source.

use crate::machine::ChipCoord;
use crate::mapping::database::MappingDatabase;
use crate::simulator::SimMachine;
use crate::transport::{EieioMessage, EieioType};

use super::bus::{EventBus, RunEvent};

/// Decodes LPG output into (vertex label, partition, atom) events.
pub struct LiveEventListener {
    port: u16,
    db: MappingDatabase,
    bus: Option<EventBus>,
}

impl LiveEventListener {
    /// Built once the mapping database is ready (the Figure-8
    /// notification handshake).
    pub fn new(port: u16, db: MappingDatabase) -> Self {
        Self { port, db, bus: None }
    }

    /// Mirror every polled event onto a [`EventBus`] as
    /// [`RunEvent::Live`], alongside returning it to the caller.
    pub fn with_bus(mut self, bus: EventBus) -> Self {
        self.bus = Some(bus);
        self
    }

    /// Drain pending events from the host inbox. A key the mapping
    /// database cannot attribute comes back as
    /// [`LiveSource::Unknown`] (and bumps `unknown_live_keys` in the
    /// wire stats) instead of masquerading as a decoded atom.
    pub fn poll(&self, sim: &mut SimMachine) -> anyhow::Result<Vec<LiveEvent>> {
        let mut out = Vec::new();
        let mut unknown = 0u64;
        for frame in sim.take_host_udp(self.port) {
            let msg = EieioMessage::decode(&frame)?;
            for (key, payload) in msg.events {
                let source = match self.db.source_of_key(key) {
                    Some((vertex, partition, atom)) => LiveSource::Known {
                        vertex: vertex.to_string(),
                        partition: partition.to_string(),
                        atom,
                    },
                    None => {
                        unknown += 1;
                        LiveSource::Unknown { raw_key: key }
                    }
                };
                out.push(LiveEvent { source, payload });
            }
        }
        if unknown > 0 {
            sim.wire_stats_mut().unknown_live_keys += unknown;
        }
        if let Some(bus) = &self.bus {
            if bus.has_sinks() {
                for e in &out {
                    bus.emit(RunEvent::Live(e.clone()));
                }
            }
        }
        Ok(out)
    }
}

/// Where a live event came from: a key the mapping database attributed
/// to a vertex atom, or a raw key it could not (misrouted packet, stale
/// table, foreign tenant) — previously indistinguishable from a real
/// atom of an empty-named vertex.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LiveSource {
    Known { vertex: String, partition: String, atom: u32 },
    Unknown { raw_key: u32 },
}

/// One live event off the LPG stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LiveEvent {
    pub source: LiveSource,
    pub payload: Option<u32>,
}

impl LiveEvent {
    /// Whether the mapping database attributed the key.
    pub fn is_decoded(&self) -> bool {
        matches!(self.source, LiveSource::Known { .. })
    }

    /// The source vertex label (`""` for an unknown key).
    pub fn vertex(&self) -> &str {
        match &self.source {
            LiveSource::Known { vertex, .. } => vertex,
            LiveSource::Unknown { .. } => "",
        }
    }

    /// The outgoing partition (`""` for an unknown key).
    pub fn partition(&self) -> &str {
        match &self.source {
            LiveSource::Known { partition, .. } => partition,
            LiveSource::Unknown { .. } => "",
        }
    }

    /// The atom within the vertex, when decoded.
    pub fn atom(&self) -> Option<u32> {
        match &self.source {
            LiveSource::Known { atom, .. } => Some(*atom),
            LiveSource::Unknown { .. } => None,
        }
    }

    /// The undecodable multicast key, when not.
    pub fn raw_key(&self) -> Option<u32> {
        match &self.source {
            LiveSource::Known { .. } => None,
            LiveSource::Unknown { raw_key } => Some(*raw_key),
        }
    }
}

/// A tenant-lifecycle event of the multi-tenant machine service
/// (DESIGN.md §11): what happened to a named job, in service order.
/// Host-side observers (dashboards, schedulers) subscribe to the log
/// the way live data consumers subscribe to the LPG stream — both are
/// the §6.9 "see what the machine is doing while it runs" channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LifecycleEvent {
    /// The job entered the queue.
    Submitted { tenant: String, boards: usize },
    /// A partition was carved and the session came up on it.
    Admitted { tenant: String, boards: usize, waited_rounds: u64 },
    /// The first run quantum of a tenancy started.
    RunStarted { tenant: String },
    /// A supervised run self-healed inside the tenant's partition.
    Healed { tenant: String, faults: usize },
    /// The tenant was suspended and its partition withdrawn.
    Evicted { tenant: String, reason: String },
    /// The tenant resumed from a snapshot in a fresh partition.
    Resumed { tenant: String, from_tick: u64 },
    /// The job ran to completion and its boards were freed.
    Finished { tenant: String, ticks: u64 },
}

impl LifecycleEvent {
    /// The job the event is about.
    pub fn tenant(&self) -> &str {
        match self {
            LifecycleEvent::Submitted { tenant, .. }
            | LifecycleEvent::Admitted { tenant, .. }
            | LifecycleEvent::RunStarted { tenant }
            | LifecycleEvent::Healed { tenant, .. }
            | LifecycleEvent::Evicted { tenant, .. }
            | LifecycleEvent::Resumed { tenant, .. }
            | LifecycleEvent::Finished { tenant, .. } => tenant,
        }
    }
}

/// Ordered log of every tenant's lifecycle, kept by the service. Backed
/// by the run-event bus: every `push` also publishes
/// [`RunEvent::Lifecycle`] so mid-run subscribers see lifecycle the
/// moment it happens, while the borrowing accessors (`events`,
/// `of_tenant`) keep their pre-bus API.
#[derive(Debug, Default)]
pub struct LifecycleLog {
    events: Vec<LifecycleEvent>,
    bus: Option<EventBus>,
}

impl LifecycleLog {
    /// A log that mirrors every event onto `bus`.
    pub fn with_bus(bus: EventBus) -> Self {
        Self { events: Vec::new(), bus: Some(bus) }
    }

    pub fn push(&mut self, event: LifecycleEvent) {
        if let Some(bus) = &self.bus {
            bus.emit(RunEvent::Lifecycle(event.clone()));
        }
        self.events.push(event);
    }

    pub fn events(&self) -> &[LifecycleEvent] {
        &self.events
    }

    /// The events concerning one job, in order.
    pub fn of_tenant(&self, tenant: &str) -> Vec<&LifecycleEvent> {
        self.events.iter().filter(|e| e.tenant() == tenant).collect()
    }
}

/// Sends events into the machine through a Reverse IP Tag Multicast
/// Source's UDP port.
pub struct LiveInjector {
    board: ChipCoord,
    port: u16,
}

impl LiveInjector {
    pub fn new(board: ChipCoord, port: u16) -> Self {
        Self { board, port }
    }

    /// Inject events by id (the RIPTMS adds its key base).
    pub fn send(&self, sim: &mut SimMachine, event_ids: &[u32]) -> anyhow::Result<()> {
        for batch in EieioMessage::batched(
            EieioType::Key32,
            &event_ids.iter().map(|e| (*e, None)).collect::<Vec<_>>(),
        ) {
            sim.host_send_udp(self.board, self.port, batch.encode())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::front::bus::RingSink;
    use crate::graph::KeyRange;
    use crate::machine::MachineBuilder;
    use crate::simulator::{SimConfig, SimMachine};

    /// A sim plus a listener whose database maps keys 0x100..0x104 to
    /// cell_0's "out" partition; anything else is unattributable.
    fn listener_rig() -> (SimMachine, MappingDatabase) {
        let sim = SimMachine::boot(MachineBuilder::spinn3().build(), SimConfig::default());
        let mut db = MappingDatabase::default();
        db.keys
            .insert(("cell_0".into(), "out".into()), KeyRange::new(0x100, !0x3));
        (sim, db)
    }

    fn inject(sim: &mut SimMachine, port: u16, events: &[(u32, Option<u32>)]) {
        for msg in EieioMessage::batched(EieioType::Key32, events) {
            sim.host_inbox.push_back((0, port, msg.encode()));
        }
    }

    #[test]
    fn poll_decodes_mapped_keys() {
        let (mut sim, db) = listener_rig();
        let listener = LiveEventListener::new(17895, db);
        inject(&mut sim, 17895, &[(0x102, None)]);
        let events = listener.poll(&mut sim).unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert!(e.is_decoded());
        assert_eq!(e.vertex(), "cell_0");
        assert_eq!(e.partition(), "out");
        assert_eq!(e.atom(), Some(2));
        assert_eq!(e.raw_key(), None);
        assert_eq!(sim.wire_stats().unknown_live_keys, 0);
    }

    #[test]
    fn poll_flags_unmapped_keys_instead_of_faking_atoms() {
        let (mut sim, db) = listener_rig();
        let bus = EventBus::new();
        let ring = RingSink::new(8);
        bus.attach(Box::new(ring.clone()));
        let listener = LiveEventListener::new(17895, db).with_bus(bus);
        inject(&mut sim, 17895, &[(0xDEAD, Some(7)), (0x101, None)]);
        let events = listener.poll(&mut sim).unwrap();
        assert_eq!(events.len(), 2);
        let unknown = &events[0];
        assert!(!unknown.is_decoded());
        assert_eq!(unknown.vertex(), "");
        assert_eq!(unknown.atom(), None, "an unmapped key is not an atom");
        assert_eq!(unknown.raw_key(), Some(0xDEAD));
        assert_eq!(unknown.payload, Some(7));
        assert!(events[1].is_decoded());
        assert_eq!(sim.wire_stats().unknown_live_keys, 1);
        // Both mirrored onto the bus as live events.
        assert_eq!(ring.len(), 2);
        assert!(matches!(ring.events()[0].1, RunEvent::Live(_)));
    }

    #[test]
    fn lifecycle_log_mirrors_pushes_onto_the_bus() {
        let bus = EventBus::new();
        let ring = RingSink::new(8);
        bus.attach(Box::new(ring.clone()));
        let mut log = LifecycleLog::with_bus(bus);
        log.push(LifecycleEvent::Submitted { tenant: "a".into(), boards: 1 });
        log.push(LifecycleEvent::Finished { tenant: "a".into(), ticks: 10 });
        assert_eq!(log.events().len(), 2, "borrowing accessor API unchanged");
        let kinds: Vec<&str> = ring.events().iter().map(|(_, e)| e.kind()).collect();
        assert_eq!(kinds, vec!["lifecycle", "lifecycle"]);
    }

    #[test]
    fn lifecycle_log_orders_and_filters_by_tenant() {
        let mut log = LifecycleLog::default();
        log.push(LifecycleEvent::Submitted { tenant: "a".into(), boards: 2 });
        log.push(LifecycleEvent::Submitted { tenant: "b".into(), boards: 1 });
        log.push(LifecycleEvent::Admitted {
            tenant: "a".into(),
            boards: 2,
            waited_rounds: 0,
        });
        log.push(LifecycleEvent::RunStarted { tenant: "a".into() });
        log.push(LifecycleEvent::Evicted {
            tenant: "a".into(),
            reason: "board died".into(),
        });
        log.push(LifecycleEvent::Resumed { tenant: "a".into(), from_tick: 40 });
        log.push(LifecycleEvent::Finished { tenant: "a".into(), ticks: 100 });
        assert_eq!(log.events().len(), 7);

        let a = log.of_tenant("a");
        assert_eq!(a.len(), 6, "b's submission is not a's history");
        assert!(matches!(a[0], LifecycleEvent::Submitted { boards: 2, .. }));
        assert!(matches!(
            a.last().unwrap(),
            LifecycleEvent::Finished { ticks: 100, .. }
        ));
        // An eviction is always followed (for this tenant) by a resume
        // or nothing — here the resume carries the snapshot tick.
        assert!(matches!(a[4], LifecycleEvent::Resumed { from_tick: 40, .. }));
        assert_eq!(log.of_tenant("b").len(), 1);
    }
}
