//! Host-side live interaction (§6.9): an event listener decoding the
//! Live Packet Gatherer's EIEIO stream using the mapping database, and
//! an injector feeding the Reverse IP Tag Multicast Source.

use crate::machine::ChipCoord;
use crate::mapping::database::MappingDatabase;
use crate::simulator::SimMachine;
use crate::transport::{EieioMessage, EieioType};

/// Decodes LPG output into (vertex label, partition, atom) events.
pub struct LiveEventListener {
    port: u16,
    db: MappingDatabase,
}

impl LiveEventListener {
    /// Built once the mapping database is ready (the Figure-8
    /// notification handshake).
    pub fn new(port: u16, db: MappingDatabase) -> Self {
        Self { port, db }
    }

    /// Drain pending events from the host inbox.
    pub fn poll(&self, sim: &mut SimMachine) -> anyhow::Result<Vec<LiveEvent>> {
        let mut out = Vec::new();
        for frame in sim.take_host_udp(self.port) {
            let msg = EieioMessage::decode(&frame)?;
            for (key, payload) in msg.events {
                match self.db.source_of_key(key) {
                    Some((vertex, partition, atom)) => out.push(LiveEvent {
                        vertex: vertex.to_string(),
                        partition: partition.to_string(),
                        atom,
                        payload,
                    }),
                    None => out.push(LiveEvent {
                        vertex: String::new(),
                        partition: String::new(),
                        atom: key,
                        payload,
                    }),
                }
            }
        }
        Ok(out)
    }
}

/// One decoded live event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LiveEvent {
    pub vertex: String,
    pub partition: String,
    pub atom: u32,
    pub payload: Option<u32>,
}

/// A tenant-lifecycle event of the multi-tenant machine service
/// (DESIGN.md §11): what happened to a named job, in service order.
/// Host-side observers (dashboards, schedulers) subscribe to the log
/// the way live data consumers subscribe to the LPG stream — both are
/// the §6.9 "see what the machine is doing while it runs" channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LifecycleEvent {
    /// The job entered the queue.
    Submitted { tenant: String, boards: usize },
    /// A partition was carved and the session came up on it.
    Admitted { tenant: String, boards: usize, waited_rounds: u64 },
    /// The first run quantum of a tenancy started.
    RunStarted { tenant: String },
    /// A supervised run self-healed inside the tenant's partition.
    Healed { tenant: String, faults: usize },
    /// The tenant was suspended and its partition withdrawn.
    Evicted { tenant: String, reason: String },
    /// The tenant resumed from a snapshot in a fresh partition.
    Resumed { tenant: String, from_tick: u64 },
    /// The job ran to completion and its boards were freed.
    Finished { tenant: String, ticks: u64 },
}

impl LifecycleEvent {
    /// The job the event is about.
    pub fn tenant(&self) -> &str {
        match self {
            LifecycleEvent::Submitted { tenant, .. }
            | LifecycleEvent::Admitted { tenant, .. }
            | LifecycleEvent::RunStarted { tenant }
            | LifecycleEvent::Healed { tenant, .. }
            | LifecycleEvent::Evicted { tenant, .. }
            | LifecycleEvent::Resumed { tenant, .. }
            | LifecycleEvent::Finished { tenant, .. } => tenant,
        }
    }
}

/// Ordered log of every tenant's lifecycle, kept by the service.
#[derive(Debug, Default)]
pub struct LifecycleLog {
    events: Vec<LifecycleEvent>,
}

impl LifecycleLog {
    pub fn push(&mut self, event: LifecycleEvent) {
        self.events.push(event);
    }

    pub fn events(&self) -> &[LifecycleEvent] {
        &self.events
    }

    /// The events concerning one job, in order.
    pub fn of_tenant(&self, tenant: &str) -> Vec<&LifecycleEvent> {
        self.events.iter().filter(|e| e.tenant() == tenant).collect()
    }
}

/// Sends events into the machine through a Reverse IP Tag Multicast
/// Source's UDP port.
pub struct LiveInjector {
    board: ChipCoord,
    port: u16,
}

impl LiveInjector {
    pub fn new(board: ChipCoord, port: u16) -> Self {
        Self { board, port }
    }

    /// Inject events by id (the RIPTMS adds its key base).
    pub fn send(&self, sim: &mut SimMachine, event_ids: &[u32]) -> anyhow::Result<()> {
        for batch in EieioMessage::batched(
            EieioType::Key32,
            &event_ids.iter().map(|e| (*e, None)).collect::<Vec<_>>(),
        ) {
            sim.host_send_udp(self.board, self.port, batch.encode())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_log_orders_and_filters_by_tenant() {
        let mut log = LifecycleLog::default();
        log.push(LifecycleEvent::Submitted { tenant: "a".into(), boards: 2 });
        log.push(LifecycleEvent::Submitted { tenant: "b".into(), boards: 1 });
        log.push(LifecycleEvent::Admitted {
            tenant: "a".into(),
            boards: 2,
            waited_rounds: 0,
        });
        log.push(LifecycleEvent::RunStarted { tenant: "a".into() });
        log.push(LifecycleEvent::Evicted {
            tenant: "a".into(),
            reason: "board died".into(),
        });
        log.push(LifecycleEvent::Resumed { tenant: "a".into(), from_tick: 40 });
        log.push(LifecycleEvent::Finished { tenant: "a".into(), ticks: 100 });
        assert_eq!(log.events().len(), 7);

        let a = log.of_tenant("a");
        assert_eq!(a.len(), 6, "b's submission is not a's history");
        assert!(matches!(a[0], LifecycleEvent::Submitted { boards: 2, .. }));
        assert!(matches!(
            a.last().unwrap(),
            LifecycleEvent::Finished { ticks: 100, .. }
        ));
        // An eviction is always followed (for this tenant) by a resume
        // or nothing — here the resume carries the snapshot tick.
        assert!(matches!(a[4], LifecycleEvent::Resumed { from_tick: 40, .. }));
        assert_eq!(log.of_tenant("b").len(), 1);
    }
}
