//! The multi-tenant machine service (DESIGN.md §11): one live machine,
//! partitioned board-by-board among many concurrent jobs.
//!
//! Real SpiNNaker installations put a job manager (spalloc) in front of
//! the machine: users ask for boards, the manager carves a partition,
//! and each job's SpiNNTools session runs against its slice as if it
//! were a private machine. This module reproduces that layer on the
//! simulator. A [`MachineService`] owns the single [`SimMachine`] and
//! round-robins it among admitted tenants, one run *quantum* at a time;
//! each tenant is a full [`SpiNNTools`] session made partition-aware by
//! [`SpiNNTools::make_shared`]:
//!
//! - **placement/routing**: every chip outside the partition is in the
//!   session's forbidden set on every mapping pass, and the sim's sweep
//!   scope confines discovery, polling, signalling and provenance to
//!   the partition while the machine is on loan;
//! - **multicast keys**: each job allocates inside a private 16M-key
//!   window (`job id << 24`), so two tenants' traffic can never share a
//!   key even on the shared router fabric (the data plane's reserved
//!   key ranges above `0xFF00_0000` stay global — its streams are
//!   chip-disjoint by the partition instead);
//! - **host data plane**: per-tenant UDP port windows (64 ports apart)
//!   and per-board IP-tag slots on boards no other tenant owns.
//!
//! Admission is strict FIFO with head-of-line blocking (a small job
//! never overtakes a big one — fairness is checked by the tenant
//! property suite); freed boards return to the pool and are reused;
//! boards that die under a tenant are retired. A tenant whose run fails
//! (e.g. chaos killed enough of its partition that healing is
//! exhausted) is *evicted*: suspended via its newest checkpoint,
//! re-queued at the front, re-admitted into a fresh partition, and
//! resumed from the snapshot (PR 6's suspend/resume machinery).

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;
use std::time::Instant;

use crate::graph::VertexId;
use crate::machine::router::RoutingTable;
use crate::machine::{ChipCoord, Machine};
use crate::simulator::{scamp, ChaosPlan, SimMachine};

use super::allocator::BoardAllocator;
use super::checkpoint::{Checkpointer, MemoryCheckpointer, RunSnapshot};
use super::config::ToolsConfig;
use super::bus::{EventBus, Metrics, RunEvent};
use super::live::{LifecycleEvent, LifecycleLog};
use super::provenance::{ServiceReport, TenantReport};
use super::tools::SpiNNTools;

/// Keys per tenant window: 16M, so 255 windows fit below the data
/// plane's reserved ranges at `0xFF00_0000`.
const SLOT_KEYS: u64 = 1 << 24;

/// Evictions before a job is declared failed instead of re-queued.
const MAX_EVICTIONS: usize = 3;

/// Where a job is in its service lifecycle.
#[derive(Debug, Clone, PartialEq, Eq)]
enum JobPhase {
    /// In the queue (fresh, or suspended awaiting re-admission).
    Waiting,
    /// Owns a partition; gets a quantum every round.
    Active,
    Finished,
    Failed,
}

/// A checkpoint store shared between the service and a tenant session:
/// the session writes snapshots through it during runs, and the
/// service reads the newest one back at eviction — surviving the
/// session's `reset()`, which drops the session's *handle* but not the
/// store.
struct SharedCheckpointer(Rc<RefCell<MemoryCheckpointer>>);

impl Checkpointer for SharedCheckpointer {
    fn put_blob(&mut self, digest: u64, bytes: &[u8]) -> anyhow::Result<()> {
        self.0.borrow_mut().put_blob(digest, bytes)
    }
    fn has_blob(&self, digest: u64) -> bool {
        self.0.borrow().has_blob(digest)
    }
    fn get_blob(&self, digest: u64) -> anyhow::Result<Vec<u8>> {
        self.0.borrow().get_blob(digest)
    }
    fn put_snapshot(&mut self, snapshot: &RunSnapshot) -> anyhow::Result<()> {
        self.0.borrow_mut().put_snapshot(snapshot)
    }
    fn get_snapshot(&self, tick: u64) -> anyhow::Result<RunSnapshot> {
        self.0.borrow().get_snapshot(tick)
    }
    fn remove_snapshot(&mut self, tick: u64) -> anyhow::Result<()> {
        self.0.borrow_mut().remove_snapshot(tick)
    }
    fn snapshot_ticks(&self) -> Vec<u64> {
        self.0.borrow().snapshot_ticks()
    }
}

/// One job and its tenant session.
struct Job {
    name: String,
    want_boards: usize,
    ticks: u64,
    tools: SpiNNTools,
    vertices: Vec<VertexId>,
    phase: JobPhase,
    /// Ethernet chips of the boards currently (or last) held.
    boards: Vec<ChipCoord>,
    key_space: (u64, u64),
    /// Snapshot store surviving session resets (see
    /// [`SharedCheckpointer`]).
    store: Rc<RefCell<MemoryCheckpointer>>,
    /// Snapshot to resume from at the next quantum (set at
    /// re-admission after an eviction).
    resume_snap: Option<RunSnapshot>,
    submitted_round: u64,
    queued_since: u64,
    first_admitted_round: Option<u64>,
    evictions: usize,
    /// Heal reports seen in the *current* run state (resets with it).
    heals_seen: usize,
    /// Heals across the whole job, all tenancies.
    heals_total: usize,
    run_started: bool,
    fail_reason: Option<String>,
}

/// Partitions one simulated machine among many concurrent jobs.
pub struct MachineService {
    config: ToolsConfig,
    /// The one live machine; `None` only transiently while on loan
    /// inside a quantum.
    sim: Option<SimMachine>,
    allocator: BoardAllocator,
    jobs: BTreeMap<u64, Job>,
    /// Job ids awaiting (re-)admission, FIFO; evictions re-queue at
    /// the front.
    queue: VecDeque<u64>,
    next_id: u64,
    /// Ticks each active tenant runs per scheduler round.
    quantum: u64,
    lifecycle: LifecycleLog,
    /// Service-wide event bus; every tenant session and the lifecycle
    /// log publish onto it, so one subscription watches the machine.
    bus: EventBus,
    rounds: u64,
}

impl MachineService {
    /// Boot the machine described by `config` and open the service on
    /// it. `config` is also the template for every tenant session
    /// (supervision, checkpointing, load/extraction methods); the
    /// per-tenant key window and port window are overlaid per job.
    pub fn new(config: ToolsConfig, quantum: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(quantum > 0, "service quantum must be at least one tick");
        let machine = config.machine_builder().build();
        let sim = SimMachine::boot(machine, config.sim.clone());
        let allocator = BoardAllocator::new(&sim.machine);
        anyhow::ensure!(allocator.n_boards() > 0, "machine has no boards to serve");
        let bus = EventBus::new();
        Ok(Self {
            config,
            sim: Some(sim),
            allocator,
            jobs: BTreeMap::new(),
            queue: VecDeque::new(),
            next_id: 0,
            quantum,
            lifecycle: LifecycleLog::with_bus(bus.clone()),
            bus,
            rounds: 0,
        })
    }

    /// Submit a job: `build` constructs its machine graph on a fresh
    /// tenant session immediately; the job then queues for `boards`
    /// connected boards and runs `ticks` timesteps once admitted.
    /// Returns the job id.
    pub fn submit(
        &mut self,
        name: &str,
        boards: usize,
        ticks: u64,
        build: impl FnOnce(&mut SpiNNTools) -> anyhow::Result<Vec<VertexId>>,
    ) -> anyhow::Result<u64> {
        anyhow::ensure!(boards >= 1, "job {name} requests no boards");
        anyhow::ensure!(
            boards <= self.allocator.n_boards(),
            "job {name} wants {boards} board(s); the machine has {}",
            self.allocator.n_boards()
        );
        anyhow::ensure!(ticks >= 1, "job {name} runs no ticks");
        let id = self.next_id;
        anyhow::ensure!(
            id < 255,
            "multicast key space exhausted: at most 255 jobs per service lifetime"
        );
        // Port windows must stay within u16 for the data plane.
        self.config
            .fast_port
            .checked_add((id as u16).saturating_mul(64).saturating_add(63))
            .ok_or_else(|| anyhow::anyhow!("data-plane port window overflows u16"))?;
        self.next_id += 1;
        let mut tools = SpiNNTools::new(self.config.clone())?;
        tools.set_bus(self.bus.clone());
        let vertices = build(&mut tools)?;
        let job = Job {
            name: name.to_string(),
            want_boards: boards,
            ticks,
            tools,
            vertices,
            phase: JobPhase::Waiting,
            boards: Vec::new(),
            key_space: (id * SLOT_KEYS, (id + 1) * SLOT_KEYS),
            store: Rc::new(RefCell::new(MemoryCheckpointer::new())),
            resume_snap: None,
            submitted_round: self.rounds,
            queued_since: self.rounds,
            first_admitted_round: None,
            evictions: 0,
            heals_seen: 0,
            heals_total: 0,
            run_started: false,
            fail_reason: None,
        };
        self.lifecycle.push(LifecycleEvent::Submitted {
            tenant: name.to_string(),
            boards,
        });
        self.jobs.insert(id, job);
        self.queue.push_back(id);
        Ok(id)
    }

    /// One scheduler round: admit from the head of the queue while
    /// partitions fit, then give every active tenant one run quantum.
    pub fn tick_round(&mut self) -> anyhow::Result<()> {
        self.rounds += 1;
        self.admit_waiting()?;
        let active: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.phase == JobPhase::Active)
            .map(|(id, _)| *id)
            .collect();
        for id in active {
            self.run_quantum(id)?;
        }
        Ok(())
    }

    /// Drive scheduler rounds until every job has finished or failed.
    /// A job whose request can no longer be satisfied (the head of the
    /// queue, with nothing running and nothing admissible) is failed
    /// rather than deadlocking the service.
    pub fn run_to_completion(&mut self) -> anyhow::Result<()> {
        while self
            .jobs
            .values()
            .any(|j| matches!(j.phase, JobPhase::Waiting | JobPhase::Active))
        {
            let before = self.progress_key();
            self.tick_round()?;
            if self.progress_key() == before {
                let Some(head) = self.queue.pop_front() else {
                    anyhow::bail!("service stalled with an empty queue");
                };
                let retired = self.allocator.n_retired();
                let job = self
                    .jobs
                    .get_mut(&head)
                    .ok_or_else(|| anyhow::anyhow!("queued job {head} unknown"))?;
                job.phase = JobPhase::Failed;
                job.fail_reason = Some(format!(
                    "no connected set of {} free board(s) can ever form ({} retired)",
                    job.want_boards, retired
                ));
                self.lifecycle.push(LifecycleEvent::Evicted {
                    tenant: job.name.clone(),
                    reason: job.fail_reason.clone().unwrap_or_default(),
                });
            }
        }
        Ok(())
    }

    /// `(ticks run, jobs settled, jobs active)` — unchanged across a
    /// round means the service can make no further progress.
    fn progress_key(&self) -> (u64, usize, usize) {
        (
            self.jobs.values().map(|j| j.tools.ticks_done()).sum(),
            self.jobs
                .values()
                .filter(|j| matches!(j.phase, JobPhase::Finished | JobPhase::Failed))
                .count(),
            self.jobs
                .values()
                .filter(|j| j.phase == JobPhase::Active)
                .count(),
        )
    }

    /// Strict FIFO admission with head-of-line blocking: the head is
    /// admitted as soon as a connected partition of its size exists;
    /// nothing behind it may overtake.
    fn admit_waiting(&mut self) -> anyhow::Result<()> {
        while let Some(&id) = self.queue.front() {
            let want = self
                .jobs
                .get(&id)
                .ok_or_else(|| anyhow::anyhow!("queued job {id} unknown"))?
                .want_boards;
            let Some(boards) = self.allocator.allocate(want) else {
                break;
            };
            self.queue.pop_front();
            self.admit(id, boards)?;
        }
        Ok(())
    }

    fn admit(&mut self, id: u64, boards: Vec<ChipCoord>) -> anyhow::Result<()> {
        let scope = self.allocator.chips_of(&boards);
        let forbidden = self.allocator.chips_outside(&boards);
        let fast_port = self.config.fast_port + (id as u16) * 64;
        let rounds = self.rounds;
        let job = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("admitting unknown job {id}"))?;
        if job.first_admitted_round.is_some() {
            // Re-admission after an eviction: new partition, same key
            // window (the snapshot's key allocations stay valid).
            job.tools.set_partition(scope, forbidden)?;
            let newest = job.store.borrow().snapshot_ticks().last().copied();
            job.resume_snap = match newest {
                Some(tick) => Some(job.store.borrow().get_snapshot(tick)?),
                None => None,
            };
            if let Some(snap) = &mut job.resume_snap {
                // Chaos events captured pending in the snapshot were
                // armed against the *old* partition — replaying them
                // onto the new one (or onto the retired board) would be
                // nonsense, so an eviction discharges them.
                snap.pending_chaos.clear();
            }
        } else {
            job.tools
                .make_shared(scope, forbidden, job.key_space, fast_port)?;
            job.first_admitted_round = Some(rounds);
        }
        // The session's reset() drops its checkpointer handle, so the
        // shared store is (re-)installed at every admission.
        job.tools
            .set_checkpointer(Box::new(SharedCheckpointer(job.store.clone())));
        job.phase = JobPhase::Active;
        job.boards = boards;
        self.lifecycle.push(LifecycleEvent::Admitted {
            tenant: job.name.clone(),
            boards: job.boards.len(),
            waited_rounds: rounds.saturating_sub(job.queued_since + 1),
        });
        Ok(())
    }

    /// Lend the machine to one tenant for a quantum of ticks, then take
    /// it back — on success *and* on failure (a failing tenant must
    /// never walk off with the machine).
    fn run_quantum(&mut self, id: u64) -> anyhow::Result<()> {
        let sim = self
            .sim
            .take()
            .ok_or_else(|| anyhow::anyhow!("service machine missing at quantum start"))?;
        let quantum = self.quantum;
        let job = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("running unknown job {id}"))?;
        // Baseline router totals so the quantum's Metrics sample can
        // report the window *delta* (same semantics as the run-driver
        // path), not the machine's cumulative count.
        let packets_before = if self.bus.has_sinks() {
            let r = sim.total_router_stats();
            Some(r.mc_routed + r.mc_default_routed)
        } else {
            None
        };
        job.tools.lend_sim(sim)?;
        if !job.run_started {
            job.run_started = true;
            self.lifecycle.push(LifecycleEvent::RunStarted {
                tenant: job.name.clone(),
            });
        }
        let ticks_before = job.tools.ticks_done();
        let quantum_started = Instant::now();
        let res = Self::drive_tenant(job, quantum, &mut self.lifecycle);
        let quantum_latency_us = quantum_started.elapsed().as_micros() as u64;
        let sim = job.tools.reclaim_sim()?;
        if self.bus.has_sinks() {
            let wire = sim.wire_stats();
            let wall = quantum_started.elapsed().as_secs_f64().max(1e-9);
            let ticks_run = job.tools.ticks_done().saturating_sub(ticks_before);
            let router = sim.total_router_stats();
            let total = router.mc_routed + router.mc_default_routed;
            // No pre-quantum baseline (a sink attached during the
            // quantum): report an empty window, never a cumulative
            // spike.
            let packets = packets_before.map_or(0, |b| total.saturating_sub(b));
            self.bus.emit(RunEvent::Metrics(Metrics {
                tick: job.tools.ticks_done(),
                sim_ns: sim.now_ns(),
                ticks_per_sec: ticks_run as f64 / wall,
                packets_per_sec: packets as f64 / wall,
                packets,
                wire_retries: wire.scp_retries + wire.bulk_retry_waits,
                tenant: Some(job.name.clone()),
                quantum_latency_us: Some(quantum_latency_us),
            }));
        }
        self.sim = Some(sim);
        // Surface any self-heals that ran inside the quantum.
        let heals = job.tools.heal_reports().len();
        if heals > job.heals_seen {
            let faults: usize = job.tools.heal_reports()[job.heals_seen..]
                .iter()
                .map(|h| h.faults.len())
                .sum();
            job.heals_total += heals - job.heals_seen;
            job.heals_seen = heals;
            self.lifecycle.push(LifecycleEvent::Healed {
                tenant: job.name.clone(),
                faults,
            });
        }
        match res {
            Ok(()) if job.tools.ticks_done() >= job.ticks => self.finish(id),
            Ok(()) => Ok(()),
            Err(e) => self.evict(id, &e.to_string()),
        }
    }

    /// One tenant's quantum: resume from a pending snapshot first
    /// (re-admission), then run up to `quantum` of the remaining ticks.
    fn drive_tenant(
        job: &mut Job,
        quantum: u64,
        lifecycle: &mut LifecycleLog,
    ) -> anyhow::Result<()> {
        if let Some(snap) = job.resume_snap.take() {
            let from = snap.tick;
            job.tools.resume_from(&snap)?;
            lifecycle.push(LifecycleEvent::Resumed {
                tenant: job.name.clone(),
                from_tick: from,
            });
        }
        let remaining = job.ticks.saturating_sub(job.tools.ticks_done());
        if remaining == 0 {
            return Ok(());
        }
        job.tools.run_ticks(remaining.min(quantum))
    }

    /// The job ran all its ticks: sweep its partition clean, free the
    /// boards, keep the session (and its recordings) readable.
    fn finish(&mut self, id: u64) -> anyhow::Result<()> {
        self.release_partition(id)?;
        let job = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("finishing unknown job {id}"))?;
        job.phase = JobPhase::Finished;
        self.lifecycle.push(LifecycleEvent::Finished {
            tenant: job.name.clone(),
            ticks: job.tools.ticks_done(),
        });
        Ok(())
    }

    /// The tenant's quantum failed (typically: chaos outran its healing
    /// budget). Suspend via the newest checkpoint, withdraw the
    /// partition, and re-queue at the front for a fresh one — or fail
    /// the job outright after [`MAX_EVICTIONS`].
    fn evict(&mut self, id: u64, reason: &str) -> anyhow::Result<()> {
        self.release_partition(id)?;
        let rounds = self.rounds;
        let job = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("evicting unknown job {id}"))?;
        job.evictions += 1;
        // The session survives eviction; the run state does not. Its
        // snapshots live in the shared store, picked back up at
        // re-admission.
        job.tools.reset();
        job.heals_seen = 0;
        self.lifecycle.push(LifecycleEvent::Evicted {
            tenant: job.name.clone(),
            reason: reason.to_string(),
        });
        if job.evictions > MAX_EVICTIONS {
            job.phase = JobPhase::Failed;
            job.fail_reason = Some(format!("evicted {} times; last: {reason}", job.evictions));
        } else {
            job.phase = JobPhase::Waiting;
            job.queued_since = rounds;
            self.queue.push_front(id);
        }
        Ok(())
    }

    /// Sweep a leaving tenant's partition (unload cores, clear routing
    /// tables and tags on every board the host can still reach) and
    /// return its boards to the pool, retiring the dead ones.
    fn release_partition(&mut self, id: u64) -> anyhow::Result<()> {
        let job = self
            .jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("releasing unknown job {id}"))?;
        // `job.boards` is kept as "last held" for reporting; the
        // allocator is the owner of record, and a re-admission
        // overwrites it.
        let boards = job.boards.clone();
        let scope = self.allocator.chips_of(&boards);
        let mut sim = self
            .sim
            .take()
            .ok_or_else(|| anyhow::anyhow!("service machine missing at release"))?;
        let swept = Self::sweep_partition(&mut sim, &scope, &boards);
        let dead: BTreeSet<ChipCoord> = {
            let dead_chips = sim.dead_chips();
            boards
                .iter()
                .filter(|b| sim.host_unreachable(**b) || dead_chips.contains(*b))
                .copied()
                .collect()
        };
        self.sim = Some(sim);
        self.allocator.free(&boards, &dead);
        swept
    }

    /// Scrub every trace of a tenancy off its boards, so the next
    /// tenant admitted onto them starts from a machine
    /// indistinguishable from freshly booted (modulo the SDRAM bump
    /// allocator's high-water mark): cores unloaded, routing tables
    /// emptied, IP tag slots freed. Chips the host can no longer reach
    /// are skipped — they are retired with their board.
    fn sweep_partition(
        sim: &mut SimMachine,
        scope: &BTreeSet<ChipCoord>,
        boards: &[ChipCoord],
    ) -> anyhow::Result<()> {
        sim.set_scope(Some(scope.clone()));
        let res = (|| -> anyhow::Result<()> {
            for (loc, _) in scamp::core_states(sim) {
                scamp::unload_app(sim, loc)?;
            }
            let dead = sim.dead_chips();
            for chip in scope {
                if sim.host_unreachable(*chip) || dead.contains(chip) {
                    continue;
                }
                scamp::load_routing_table(sim, *chip, RoutingTable::new())?;
            }
            for board in boards {
                if sim.host_unreachable(*board) || dead.contains(board) {
                    continue;
                }
                scamp::clear_tags(sim, *board)?;
            }
            Ok(())
        })();
        sim.set_scope(None);
        res
    }

    // -- results and introspection ---------------------------------------

    /// A finished (or running) job's recording for one of its vertices.
    pub fn recording(&self, id: u64, v: VertexId) -> &[u8] {
        self.jobs
            .get(&id)
            .map(|j| j.tools.recording(v))
            .unwrap_or(&[])
    }

    /// The vertex ids the job's build closure returned.
    pub fn vertices(&self, id: u64) -> &[VertexId] {
        self.jobs
            .get(&id)
            .map(|j| j.vertices.as_slice())
            .unwrap_or(&[])
    }

    /// Ethernet chips of the boards the job currently holds — or last
    /// held, for a job whose partition has been released.
    pub fn boards_of(&self, id: u64) -> &[ChipCoord] {
        self.jobs
            .get(&id)
            .map(|j| j.boards.as_slice())
            .unwrap_or(&[])
    }

    pub fn is_finished(&self, id: u64) -> bool {
        self.jobs
            .get(&id)
            .is_some_and(|j| j.phase == JobPhase::Finished)
    }

    pub fn is_failed(&self, id: u64) -> bool {
        self.jobs
            .get(&id)
            .is_some_and(|j| j.phase == JobPhase::Failed)
    }

    /// Jobs still queued for admission.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The tenant session (recordings, provenance, mapping) of a job.
    pub fn session(&self, id: u64) -> Option<&SpiNNTools> {
        self.jobs.get(&id).map(|j| &j.tools)
    }

    /// Mutable tenant session — the chaos tests inject fault plans
    /// through this.
    pub fn session_mut(&mut self, id: u64) -> Option<&mut SpiNNTools> {
        self.jobs.get_mut(&id).map(|j| &mut j.tools)
    }

    /// Inject a chaos plan into one tenant's next quantum.
    pub fn inject_chaos(&mut self, id: u64, plan: ChaosPlan) -> anyhow::Result<()> {
        self.jobs
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("chaos for unknown job {id}"))?
            .tools
            .inject_chaos(plan);
        Ok(())
    }

    /// The machine the service is partitioning.
    pub fn machine(&self) -> Option<&Machine> {
        self.sim.as_ref().map(|s| &s.machine)
    }

    /// The ordered tenant-lifecycle log (§6.9 live channel).
    pub fn lifecycle(&self) -> &LifecycleLog {
        &self.lifecycle
    }

    /// The service-wide event bus: every tenant session, the lifecycle
    /// log, and the per-quantum scheduler metrics publish here. Attach
    /// sinks to watch the whole machine; mid-run attachment is fine.
    pub fn bus(&self) -> &EventBus {
        &self.bus
    }

    /// Per-tenant accounting for provenance (DESIGN.md §11).
    pub fn report(&self) -> ServiceReport {
        let rounds = self.rounds;
        let tenants = self
            .jobs
            .values()
            .map(|j| TenantReport {
                name: j.name.clone(),
                boards: j.boards.clone(),
                key_space: j.key_space,
                placements: j
                    .tools
                    .provenance()
                    .vertices
                    .iter()
                    .map(|v| (v.label.clone(), v.placement))
                    .collect(),
                heals: j.heals_total,
                evictions: j.evictions,
                queue_rounds: j
                    .first_admitted_round
                    .unwrap_or(rounds)
                    .saturating_sub(j.submitted_round + 1),
                ticks_done: j.tools.ticks_done(),
            })
            .collect();
        ServiceReport {
            tenants,
            boards_total: self.allocator.n_boards(),
            boards_retired: self.allocator.n_retired(),
            rounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::conway::{ConwayCellVertex, STATE_PARTITION};
    use crate::front::config::MachineSpec;

    /// A 3-cell blinker row: oscillates with period 2.
    fn blinker(tools: &mut SpiNNTools) -> anyhow::Result<Vec<VertexId>> {
        let ids = vec![
            tools.add_machine_vertex(ConwayCellVertex::arc(0, 0, true))?,
            tools.add_machine_vertex(ConwayCellVertex::arc(0, 1, true))?,
            tools.add_machine_vertex(ConwayCellVertex::arc(0, 2, true))?,
        ];
        for a in 0..3usize {
            for b in 0..3usize {
                if a != b {
                    tools.add_machine_edge(ids[a], ids[b], STATE_PARTITION)?;
                }
            }
        }
        Ok(ids)
    }

    #[test]
    fn one_board_machine_serialises_two_jobs_fifo() {
        let config = ToolsConfig::new(MachineSpec::Spinn5);
        let mut svc = MachineService::new(config, 2).unwrap();
        let a = svc.submit("a", 1, 4, blinker).unwrap();
        let b = svc.submit("b", 1, 4, blinker).unwrap();
        // One board: b must wait for a's boards to free.
        svc.tick_round().unwrap();
        assert_eq!(svc.queue_len(), 1, "b queued behind a");
        svc.run_to_completion().unwrap();
        assert!(svc.is_finished(a) && svc.is_finished(b));
        // Both see the same physics, sequentially, on reused boards.
        let va = svc.vertices(a).to_vec();
        let vb = svc.vertices(b).to_vec();
        assert_eq!(svc.recording(a, va[0]), svc.recording(b, vb[0]));
        assert_eq!(svc.recording(a, va[0]), &[1, 1, 1, 1]);
        // FIFO order is visible in the lifecycle log.
        let finishes: Vec<&str> = svc
            .lifecycle()
            .events()
            .iter()
            .filter_map(|e| match e {
                LifecycleEvent::Finished { tenant, .. } => Some(tenant.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(finishes, ["a", "b"]);
        let report = svc.report();
        assert!(report.key_windows_disjoint());
        assert_eq!(report.boards_retired, 0);
    }

    #[test]
    fn two_tenants_share_a_machine_concurrently() {
        let config = ToolsConfig::new(MachineSpec::Boards(3));
        let mut svc = MachineService::new(config, 2).unwrap();
        let a = svc.submit("a", 1, 6, blinker).unwrap();
        let b = svc.submit("b", 1, 6, blinker).unwrap();
        svc.tick_round().unwrap();
        // Both admitted at once on disjoint boards.
        let ba = svc.boards_of(a).to_vec();
        let bb = svc.boards_of(b).to_vec();
        assert!(!ba.is_empty() && !bb.is_empty());
        assert!(ba.iter().all(|x| !bb.contains(x)));
        svc.run_to_completion().unwrap();
        let va = svc.vertices(a).to_vec();
        assert_eq!(svc.recording(a, va[1]), &[1, 1, 1, 1, 1, 1]);
        let vb = svc.vertices(b).to_vec();
        assert_eq!(
            svc.recording(a, va[0]),
            svc.recording(b, vb[0]),
            "tenants on different boards see identical physics"
        );
    }
}
