//! Tool configuration (§6.1: script-level vs user-level options).

use std::path::PathBuf;

use crate::machine::{ChipCoord, CoreLocation, Direction, Machine, MachineBuilder};
use crate::mapping::MappingConfig;
use crate::simulator::SimConfig;

/// Which machine to "discover" (§6.3.1). With no hardware, every spec
/// boots a simulated machine of the corresponding geometry.
#[derive(Debug, Clone)]
pub enum MachineSpec {
    /// A 4-chip SpiNN-3 board.
    Spinn3,
    /// A 48-chip SpiNN-5 board.
    Spinn5,
    /// `n` SpiNN-5 boards (rounded up to whole triads above 1).
    Boards(u32),
    /// A full rectangular grid (testing).
    Grid { width: u32, height: u32, wrap: bool },
}

impl MachineSpec {
    pub fn build(&self) -> MachineBuilder {
        match self {
            MachineSpec::Spinn3 => MachineBuilder::spinn3(),
            MachineSpec::Spinn5 => MachineBuilder::spinn5(),
            MachineSpec::Boards(n) => MachineBuilder::boards(*n),
            MachineSpec::Grid { width, height, wrap } => {
                MachineBuilder::grid(*width, *height, *wrap)
            }
        }
    }

    /// A template machine for resource estimation before discovery.
    pub fn template(&self) -> Machine {
        self.build().build()
    }
}

/// How recorded data is pulled off the machine (§6.8, experiment E1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExtractionMethod {
    /// SCAMP SDP request/response reads (Figure 11 middle).
    Scamp,
    /// The multicast streaming protocol (Figure 11 bottom).
    FastMulticast,
}

/// How generated data regions are loaded onto the machine (§6.3.4 /
/// §6.8's data-in mirror, experiment E12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMethod {
    /// One acknowledged SCAMP write round trip per 256-byte chunk.
    Scamp,
    /// SCAMP writes with a pipelined command window
    /// ([`crate::simulator::scamp::write_sdram_batched`]): the fastest
    /// the monitor protocol alone can load.
    ScampBatched,
    /// The data-in stream protocol: sequence-numbered UDP frames fanned
    /// out as multicast by a per-board dispatcher core. Chips without a
    /// writer core fall back to the batched SCAMP path.
    FastMulticast,
}

/// What the run supervisor does when it catches a runtime failure
/// (dead core, dead chip, dead link) mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealPolicy {
    /// Stop the run with a diagnostic error: the failure classification
    /// plus each failed core's IOBUF text.
    Abort,
    /// Self-heal: re-discover the degraded machine, re-map incrementally
    /// around the dead resources (survivors stay pinned), reload the
    /// displaced vertices, and restart the run from tick 0.
    Remap,
}

/// Run supervision (§6.3.5 taken seriously at million-core scale): poll
/// core states on a cadence *during* the run instead of only at its
/// end, classify failures, and apply a [`HealPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SupervisorConfig {
    /// How many timer ticks run between core-state polls. The run is
    /// executed in chunks of this many ticks (each chunk pauses at its
    /// boundary exactly like a Figure-9 cycle edge).
    pub poll_interval_ticks: u64,
    pub policy: HealPolicy,
    /// Upper bound on heals within one `run_ticks` call — a machine
    /// failing faster than it can be healed must eventually abort.
    pub max_heals: usize,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self { poll_interval_ticks: 1, policy: HealPolicy::Remap, max_heals: 4 }
    }
}

/// Boot-time fault injection (§2's blacklist): resources removed from
/// the machine at discovery, before any mapping happens. The
/// equivalently-degraded twin of a runtime [`crate::simulator::Fault`]
/// set — the chaos property suite compares healed runs against fresh
/// runs built with these.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BootFaults {
    pub chips: Vec<ChipCoord>,
    pub cores: Vec<CoreLocation>,
    pub links: Vec<(ChipCoord, Direction)>,
}

impl BootFaults {
    pub fn is_empty(&self) -> bool {
        self.chips.is_empty() && self.cores.is_empty() && self.links.is_empty()
    }

    /// Apply the blacklist to a machine builder.
    pub fn apply(&self, mut builder: MachineBuilder) -> MachineBuilder {
        for c in &self.chips {
            builder = builder.dead_chip(*c);
        }
        for loc in &self.cores {
            builder = builder.dead_core(loc.chip(), loc.p);
        }
        for (c, d) in &self.links {
            builder = builder.dead_link(*c, *d);
        }
        builder
    }
}

/// Full tool configuration (§6.1).
#[derive(Debug, Clone)]
pub struct ToolsConfig {
    pub machine: MachineSpec,
    /// Simulation timestep in microseconds (script-level option).
    pub timestep_us: u32,
    pub mapping: MappingConfig,
    pub sim: SimConfig,
    /// Artifact directory for the PJRT runtime (None = no HLO binaries
    /// needed, e.g. pure Conway-cell graphs).
    pub artifacts_dir: Option<PathBuf>,
    pub extraction: ExtractionMethod,
    /// How data regions are loaded (§6.3.4; E12).
    pub loading: LoadMethod,
    /// First UDP port of the data plane's per-board port pairs (board
    /// `i` uses `fast_port + 2i` for extraction frames and
    /// `fast_port + 2i + 1` for data-in frames and reports).
    pub fast_port: u16,
    /// Worker threads for the host-side per-board extraction drains
    /// (`0` = one per hardware thread). Purely a host wall-clock knob.
    pub data_plane_threads: usize,
    /// Safety margin of SDRAM per chip left unallocated to recording.
    pub recording_slack_bytes: u64,
    /// Mid-run failure supervision. `None` (the default) keeps the
    /// historical behaviour: core states are only checked when the run
    /// completes.
    pub supervision: Option<SupervisorConfig>,
    /// Resources blacklisted at machine discovery (§2).
    pub boot_faults: BootFaults,
    /// Periodic run snapshots (DESIGN.md §9, E15). `None` (the default)
    /// keeps the historical behaviour: heals and reconciles replay the
    /// whole tick history from tick 0. With a cadence set, they restore
    /// from the newest snapshot and replay only the tail, and
    /// `suspend`/`resume_from` can carry a run across process restarts.
    pub checkpoint: Option<crate::front::checkpoint::CheckpointConfig>,
}

impl ToolsConfig {
    pub fn new(machine: MachineSpec) -> Self {
        Self {
            machine,
            timestep_us: 1000,
            mapping: MappingConfig::default(),
            sim: SimConfig::default(),
            artifacts_dir: None,
            extraction: ExtractionMethod::Scamp,
            loading: LoadMethod::Scamp,
            fast_port: 17895,
            data_plane_threads: 0,
            recording_slack_bytes: 1024 * 1024,
            supervision: None,
            boot_faults: BootFaults::default(),
            checkpoint: None,
        }
    }

    /// The machine builder for discovery, with the boot-time blacklist
    /// applied (§6.3.1 + §2).
    pub fn machine_builder(&self) -> MachineBuilder {
        self.boot_faults.apply(self.machine.build())
    }

    /// A template machine for resource estimation before discovery —
    /// also blacklist-aware, so capacity estimates match what discovery
    /// will actually find.
    pub fn machine_template(&self) -> Machine {
        self.machine_builder().build()
    }

    /// A virtual SpiNN-5 machine of `n` boards.
    pub fn virtual_spinn5(n_boards: u32) -> Self {
        if n_boards <= 1 {
            Self::new(MachineSpec::Spinn5)
        } else {
            Self::new(MachineSpec::Boards(n_boards))
        }
    }

    pub fn with_artifacts(mut self) -> Self {
        self.artifacts_dir = Some(crate::runtime::Runtime::default_dir());
        self
    }

    pub fn with_extraction(mut self, method: ExtractionMethod) -> Self {
        self.extraction = method;
        self
    }

    /// Select the region-loading path (E12).
    pub fn with_loading(mut self, method: LoadMethod) -> Self {
        self.loading = method;
        self
    }

    /// Worker threads for the host-side per-board extraction drains.
    pub fn with_data_plane_threads(mut self, threads: usize) -> Self {
        self.data_plane_threads = threads;
        self
    }

    pub fn with_timestep_us(mut self, us: u32) -> Self {
        self.timestep_us = us;
        self.sim.timestep_us = us;
        self
    }

    /// Select the simulator fabric implementation (experiment E11).
    /// `Legacy` keeps the pre-E11 structures for benchmarking; results
    /// are identical in both modes — this is purely a wall-clock knob.
    pub fn with_fabric(mut self, mode: crate::simulator::FabricMode) -> Self {
        self.sim.fabric = mode;
        self
    }

    /// Worker threads for the shardable mapping stages (NER routing,
    /// table generation, ordered-covering compression). `1` = serial,
    /// `0` = one per hardware thread. Mapping output is byte-identical
    /// at any setting — this is purely a host wall-clock knob (§6.3.2).
    pub fn with_mapping_threads(mut self, threads: usize) -> Self {
        self.mapping.options.threads = threads;
        self
    }

    /// Enable mid-run supervision (poll cadence + heal policy).
    pub fn with_supervision(mut self, supervision: SupervisorConfig) -> Self {
        self.supervision = Some(supervision);
        self
    }

    /// Blacklist resources at machine discovery (§2).
    pub fn with_boot_faults(mut self, faults: BootFaults) -> Self {
        self.boot_faults = faults;
        self
    }

    /// Run every host↔machine exchange over a seeded unreliable wire
    /// (frame loss, duplication, reordering, jitter — DESIGN.md §10).
    /// The reliable transport must make results byte-identical to a
    /// clean-wire run; `WireFaults::none()` restores the clean wire.
    pub fn with_wire_faults(mut self, faults: crate::simulator::WireFaults) -> Self {
        self.sim.wire.faults = faults;
        self
    }

    /// Enable periodic run snapshots (DESIGN.md §9, E15).
    pub fn with_checkpoint(
        mut self,
        checkpoint: crate::front::checkpoint::CheckpointConfig,
    ) -> Self {
        self.checkpoint = Some(checkpoint);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build_expected_sizes() {
        assert_eq!(MachineSpec::Spinn3.template().n_chips(), 4);
        assert_eq!(MachineSpec::Spinn5.template().n_chips(), 48);
        assert_eq!(MachineSpec::Boards(3).template().n_chips(), 144);
        assert_eq!(
            MachineSpec::Grid { width: 4, height: 4, wrap: true }.template().n_chips(),
            16
        );
    }

    #[test]
    fn loading_defaults_to_scamp() {
        let c = ToolsConfig::new(MachineSpec::Spinn3);
        assert_eq!(c.loading, LoadMethod::Scamp);
        let c = c.with_loading(LoadMethod::FastMulticast);
        assert_eq!(c.loading, LoadMethod::FastMulticast);
    }

    #[test]
    fn timestep_propagates_to_sim() {
        let c = ToolsConfig::new(MachineSpec::Spinn3).with_timestep_us(500);
        assert_eq!(c.sim.timestep_us, 500);
    }

    #[test]
    fn boot_faults_shape_the_discovered_machine() {
        let faults = BootFaults {
            chips: vec![(1, 1)],
            cores: vec![CoreLocation::new(0, 1, 3)],
            links: vec![((0, 0), Direction::East)],
        };
        let c = ToolsConfig::new(MachineSpec::Spinn3).with_boot_faults(faults);
        let m = c.machine_template();
        assert!(m.chip((1, 1)).is_none());
        assert!(m.chip((0, 1)).unwrap().processor(3).is_none());
        assert_eq!(m.link_target((0, 0), Direction::East), None);
        // Default config: no blacklist, no supervision.
        let plain = ToolsConfig::new(MachineSpec::Spinn3);
        assert!(plain.boot_faults.is_empty());
        assert!(plain.supervision.is_none());
        assert_eq!(plain.machine_template().n_chips(), 4);
    }

    #[test]
    fn checkpoint_defaults_off() {
        use crate::front::CheckpointConfig;
        let c = ToolsConfig::new(MachineSpec::Spinn3);
        assert!(c.checkpoint.is_none());
        let c = c.with_checkpoint(CheckpointConfig { interval_ticks: 4, keep: 3 });
        assert_eq!(c.checkpoint, Some(CheckpointConfig { interval_ticks: 4, keep: 3 }));
        let d = CheckpointConfig::default();
        assert!(d.interval_ticks >= 1 && d.keep >= 1);
    }

    #[test]
    fn supervisor_defaults() {
        let s = SupervisorConfig::default();
        assert_eq!(s.poll_interval_ticks, 1);
        assert_eq!(s.policy, HealPolicy::Remap);
        assert!(s.max_heals >= 1);
    }

    #[test]
    fn mapping_threads_propagate() {
        let c = ToolsConfig::new(MachineSpec::Spinn3).with_mapping_threads(8);
        assert_eq!(c.mapping.options.threads, 8);
        assert_eq!(c.mapping.options.effective_threads(), 8);
        // Default is serial; 0 resolves to the hardware width.
        assert_eq!(ToolsConfig::new(MachineSpec::Spinn3).mapping.options.threads, 1);
        assert!(
            ToolsConfig::new(MachineSpec::Spinn3)
                .with_mapping_threads(0)
                .mapping
                .options
                .effective_threads()
                >= 1
        );
    }
}
