//! Tool configuration (§6.1: script-level vs user-level options).

use std::path::PathBuf;

use crate::machine::{Machine, MachineBuilder};
use crate::mapping::MappingConfig;
use crate::simulator::SimConfig;

/// Which machine to "discover" (§6.3.1). With no hardware, every spec
/// boots a simulated machine of the corresponding geometry.
#[derive(Debug, Clone)]
pub enum MachineSpec {
    /// A 4-chip SpiNN-3 board.
    Spinn3,
    /// A 48-chip SpiNN-5 board.
    Spinn5,
    /// `n` SpiNN-5 boards (rounded up to whole triads above 1).
    Boards(u32),
    /// A full rectangular grid (testing).
    Grid { width: u32, height: u32, wrap: bool },
}

impl MachineSpec {
    pub fn build(&self) -> MachineBuilder {
        match self {
            MachineSpec::Spinn3 => MachineBuilder::spinn3(),
            MachineSpec::Spinn5 => MachineBuilder::spinn5(),
            MachineSpec::Boards(n) => MachineBuilder::boards(*n),
            MachineSpec::Grid { width, height, wrap } => {
                MachineBuilder::grid(*width, *height, *wrap)
            }
        }
    }

    /// A template machine for resource estimation before discovery.
    pub fn template(&self) -> Machine {
        self.build().build()
    }
}

/// How recorded data is pulled off the machine (§6.8, experiment E1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExtractionMethod {
    /// SCAMP SDP request/response reads (Figure 11 middle).
    Scamp,
    /// The multicast streaming protocol (Figure 11 bottom).
    FastMulticast,
}

/// How generated data regions are loaded onto the machine (§6.3.4 /
/// §6.8's data-in mirror, experiment E12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMethod {
    /// One acknowledged SCAMP write round trip per 256-byte chunk.
    Scamp,
    /// SCAMP writes with a pipelined command window
    /// ([`crate::simulator::scamp::write_sdram_batched`]): the fastest
    /// the monitor protocol alone can load.
    ScampBatched,
    /// The data-in stream protocol: sequence-numbered UDP frames fanned
    /// out as multicast by a per-board dispatcher core. Chips without a
    /// writer core fall back to the batched SCAMP path.
    FastMulticast,
}

/// Full tool configuration (§6.1).
#[derive(Debug, Clone)]
pub struct ToolsConfig {
    pub machine: MachineSpec,
    /// Simulation timestep in microseconds (script-level option).
    pub timestep_us: u32,
    pub mapping: MappingConfig,
    pub sim: SimConfig,
    /// Artifact directory for the PJRT runtime (None = no HLO binaries
    /// needed, e.g. pure Conway-cell graphs).
    pub artifacts_dir: Option<PathBuf>,
    pub extraction: ExtractionMethod,
    /// How data regions are loaded (§6.3.4; E12).
    pub loading: LoadMethod,
    /// First UDP port of the data plane's per-board port pairs (board
    /// `i` uses `fast_port + 2i` for extraction frames and
    /// `fast_port + 2i + 1` for data-in frames and reports).
    pub fast_port: u16,
    /// Worker threads for the host-side per-board extraction drains
    /// (`0` = one per hardware thread). Purely a host wall-clock knob.
    pub data_plane_threads: usize,
    /// Safety margin of SDRAM per chip left unallocated to recording.
    pub recording_slack_bytes: u64,
}

impl ToolsConfig {
    pub fn new(machine: MachineSpec) -> Self {
        Self {
            machine,
            timestep_us: 1000,
            mapping: MappingConfig::default(),
            sim: SimConfig::default(),
            artifacts_dir: None,
            extraction: ExtractionMethod::Scamp,
            loading: LoadMethod::Scamp,
            fast_port: 17895,
            data_plane_threads: 0,
            recording_slack_bytes: 1024 * 1024,
        }
    }

    /// A virtual SpiNN-5 machine of `n` boards.
    pub fn virtual_spinn5(n_boards: u32) -> Self {
        if n_boards <= 1 {
            Self::new(MachineSpec::Spinn5)
        } else {
            Self::new(MachineSpec::Boards(n_boards))
        }
    }

    pub fn with_artifacts(mut self) -> Self {
        self.artifacts_dir = Some(crate::runtime::Runtime::default_dir());
        self
    }

    pub fn with_extraction(mut self, method: ExtractionMethod) -> Self {
        self.extraction = method;
        self
    }

    /// Select the region-loading path (E12).
    pub fn with_loading(mut self, method: LoadMethod) -> Self {
        self.loading = method;
        self
    }

    /// Worker threads for the host-side per-board extraction drains.
    pub fn with_data_plane_threads(mut self, threads: usize) -> Self {
        self.data_plane_threads = threads;
        self
    }

    pub fn with_timestep_us(mut self, us: u32) -> Self {
        self.timestep_us = us;
        self.sim.timestep_us = us;
        self
    }

    /// Select the simulator fabric implementation (experiment E11).
    /// `Legacy` keeps the pre-E11 structures for benchmarking; results
    /// are identical in both modes — this is purely a wall-clock knob.
    pub fn with_fabric(mut self, mode: crate::simulator::FabricMode) -> Self {
        self.sim.fabric = mode;
        self
    }

    /// Worker threads for the shardable mapping stages (NER routing,
    /// table generation, ordered-covering compression). `1` = serial,
    /// `0` = one per hardware thread. Mapping output is byte-identical
    /// at any setting — this is purely a host wall-clock knob (§6.3.2).
    pub fn with_mapping_threads(mut self, threads: usize) -> Self {
        self.mapping.options.threads = threads;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build_expected_sizes() {
        assert_eq!(MachineSpec::Spinn3.template().n_chips(), 4);
        assert_eq!(MachineSpec::Spinn5.template().n_chips(), 48);
        assert_eq!(MachineSpec::Boards(3).template().n_chips(), 144);
        assert_eq!(
            MachineSpec::Grid { width: 4, height: 4, wrap: true }.template().n_chips(),
            16
        );
    }

    #[test]
    fn loading_defaults_to_scamp() {
        let c = ToolsConfig::new(MachineSpec::Spinn3);
        assert_eq!(c.loading, LoadMethod::Scamp);
        let c = c.with_loading(LoadMethod::FastMulticast);
        assert_eq!(c.loading, LoadMethod::FastMulticast);
    }

    #[test]
    fn timestep_propagates_to_sim() {
        let c = ToolsConfig::new(MachineSpec::Spinn3).with_timestep_us(500);
        assert_eq!(c.sim.timestep_us, 500);
    }

    #[test]
    fn mapping_threads_propagate() {
        let c = ToolsConfig::new(MachineSpec::Spinn3).with_mapping_threads(8);
        assert_eq!(c.mapping.options.threads, 8);
        assert_eq!(c.mapping.options.effective_threads(), 8);
        // Default is serial; 0 resolves to the hardware width.
        assert_eq!(ToolsConfig::new(MachineSpec::Spinn3).mapping.options.threads, 1);
        assert!(
            ToolsConfig::new(MachineSpec::Spinn3)
                .with_mapping_threads(0)
                .mapping
                .options
                .effective_threads()
                >= 1
        );
    }
}
