//! Experiment E11 — the fabric-throughput probe harness, shared by
//! `benches/fabric.rs` and the `fabric-smoke` test
//! (`tests/fabric_smoke.rs`) so the bench workloads cannot rot out of
//! the test suite.
//!
//! Two workloads exercise the packet fabric:
//!
//! - **Conway** (§7.1) through the complete SpiNNTools flow — mapping,
//!   loading, Figure-9 run cycles, SCAMP extraction — so the probe also
//!   covers the SDP/host paths.
//! - **Microcircuit storm** (§7.2 topology): the real Potjans–Diesmann
//!   machine graph is mapped (placements, keys, compressed tables) and
//!   then driven by a deterministic pure-Rust traffic generator standing
//!   in for the HLO-backed neuron binaries (which need the `pjrt`
//!   feature). The fabric sees the microcircuit's genuine multicast
//!   trees and fan-out at a configurable firing rate.
//!
//! Each probe runs its workload under one [`FabricMode`] and reports
//! throughput plus a state digest; running both modes and comparing
//! digests (the bench and the equivalence suite both do) proves the
//! fast fabric reproduced the legacy fabric's behaviour exactly.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::apps::networks::{build_conway_grid, microcircuit_machine_graph};
use crate::front::{MachineSpec, SpiNNTools, ToolsConfig};
use crate::machine::MachineBuilder;
use crate::mapping::{map_graph, MappingConfig};
use crate::simulator::{scamp, CoreApp, CoreCtx, FabricMode, SimConfig, SimMachine};
use crate::util::json::Json;
use crate::util::{fnv1a_64_extend as fnv1a, SplitMix64, FNV_OFFSET};

/// Which E11 workload to run.
#[derive(Debug, Clone, Copy)]
pub enum ProbeWorkload {
    /// §7.1: a `side x side` Conway grid via the full tool flow on
    /// `boards` SpiNN-5 boards.
    Conway { side: u32, boards: u32 },
    /// §7.2: the microcircuit topology at `scale`, mapped onto `boards`
    /// boards and driven by storm apps firing each partition with
    /// probability ~0.3 per tick.
    MicrocircuitStorm { scale: f64, boards: u32 },
}

impl ProbeWorkload {
    pub fn name(&self) -> String {
        match self {
            ProbeWorkload::Conway { side, .. } => format!("conway_{side}x{side}"),
            ProbeWorkload::MicrocircuitStorm { scale, .. } => {
                format!("microcircuit_storm_{scale}")
            }
        }
    }
}

/// One measured probe run.
#[derive(Debug, Clone)]
pub struct ProbeResult {
    pub workload: String,
    pub mode: FabricMode,
    /// Timed simulation ticks (a warm-up run of the same length runs
    /// first and is excluded).
    pub ticks: u64,
    pub wall_seconds: f64,
    pub sim_ns: u64,
    pub events: u64,
    pub mc_sent: u64,
    pub mc_delivered: u64,
    /// Router work units over the timed window: matched plus
    /// default-routed packets, summed over every hop. Like every other
    /// counter here, a delta over the timed window only.
    pub hops: u64,
    pub dropped: u64,
    pub reinjected: u64,
    pub lost_forever: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// FNV-1a digest over end-of-run state (semantic router stats, sim
    /// stats, core states, provenance, recordings). Equal digests across
    /// modes mean byte-identical behaviour.
    pub digest: u64,
}

impl ProbeResult {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_seconds.max(1e-9)
    }

    pub fn hops_per_sec(&self) -> f64 {
        self.hops as f64 / self.wall_seconds.max(1e-9)
    }

    pub fn sent_per_sec(&self) -> f64 {
        self.mc_sent as f64 / self.wall_seconds.max(1e-9)
    }

    pub fn mode_name(&self) -> &'static str {
        match self.mode {
            FabricMode::Fast => "fast",
            FabricMode::Legacy => "legacy",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("mode".to_string(), Json::Str(self.mode_name().to_string()));
        o.insert("ticks".to_string(), Json::Num(self.ticks as f64));
        o.insert("wall_seconds".to_string(), Json::Num(self.wall_seconds));
        o.insert("sim_ns".to_string(), Json::Num(self.sim_ns as f64));
        o.insert("events".to_string(), Json::Num(self.events as f64));
        o.insert("mc_sent".to_string(), Json::Num(self.mc_sent as f64));
        o.insert("mc_delivered".to_string(), Json::Num(self.mc_delivered as f64));
        o.insert("hops".to_string(), Json::Num(self.hops as f64));
        o.insert("dropped".to_string(), Json::Num(self.dropped as f64));
        o.insert("reinjected".to_string(), Json::Num(self.reinjected as f64));
        o.insert("lost_forever".to_string(), Json::Num(self.lost_forever as f64));
        o.insert("cache_hits".to_string(), Json::Num(self.cache_hits as f64));
        o.insert("cache_misses".to_string(), Json::Num(self.cache_misses as f64));
        o.insert("events_per_sec".to_string(), Json::Num(self.events_per_sec()));
        o.insert("hops_per_sec".to_string(), Json::Num(self.hops_per_sec()));
        o.insert("packets_per_sec".to_string(), Json::Num(self.sent_per_sec()));
        o.insert("digest".to_string(), Json::Str(format!("{:016x}", self.digest)));
        Json::Obj(o)
    }
}

/// Run one workload under one fabric mode. The workload is warmed up
/// with an identical untimed run first (mapping, loading and allocator
/// warm-up stay out of the measurement), then `ticks` simulation ticks
/// are timed.
pub fn run_fabric_probe(
    workload: ProbeWorkload,
    ticks: u64,
    mode: FabricMode,
) -> anyhow::Result<ProbeResult> {
    match workload {
        ProbeWorkload::Conway { side, boards } => run_conway(side, boards, ticks, mode),
        ProbeWorkload::MicrocircuitStorm { scale, boards } => {
            run_storm(scale, boards, ticks, mode)
        }
    }
    .map(|mut r| {
        r.workload = workload.name();
        r
    })
}

// ---------------------------------------------------------------------------
// digesting

fn fnv1a_u64(h: &mut u64, v: u64) {
    fnv1a(h, &v.to_le_bytes());
}

/// Digest the mode-independent end state of a simulated machine:
/// semantic router stats, sim stats, virtual time, per-core state and
/// provenance. Cache counters are deliberately excluded (the legacy
/// fabric never caches).
fn digest_sim(sim: &SimMachine, h: &mut u64) {
    let t = sim.total_router_stats();
    for v in [
        t.mc_routed,
        t.mc_default_routed,
        t.mc_dropped,
        t.mc_reinjected,
        t.mc_lost_forever,
        sim.stats.events_processed,
        sim.stats.mc_sent,
        sim.stats.mc_delivered,
        sim.stats.sdp_sent,
        sim.now_ns(),
    ] {
        fnv1a_u64(h, v);
    }
    for (loc, state) in scamp::core_states(sim) {
        fnv1a(h, loc.to_string().as_bytes());
        fnv1a(h, format!("{state:?}").as_bytes());
        if let Ok(prov) = scamp::provenance(sim, loc) {
            for (k, v) in prov {
                fnv1a(h, k.as_bytes());
                fnv1a_u64(h, v);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// workload: Conway via the full tool flow

fn run_conway(side: u32, boards: u32, ticks: u64, mode: FabricMode) -> anyhow::Result<ProbeResult> {
    let spec = if boards <= 1 { MachineSpec::Spinn5 } else { MachineSpec::Boards(boards) };
    let mut tools = SpiNNTools::new(ToolsConfig::new(spec).with_fabric(mode))?;
    let live: Vec<(u32, u32)> = (0..side)
        .flat_map(|r| (0..side).map(move |c| (r, c)))
        .filter(|(r, c)| (r * 7 + c * 3) % 5 < 2)
        .collect();
    let ids = build_conway_grid(&mut tools, side, side, &live)?;

    // Warm-up: mapping, data generation, loading and the first `ticks`
    // of simulation. Planning with the full tick count keeps the
    // Figure-9 cycle unit at `ticks`, so the timed resume below is one
    // uninterrupted cycle.
    tools.run_ticks(ticks)?;

    let before = {
        let sim = tools.sim_mut().expect("run started");
        (sim.stats, sim.total_router_stats())
    };
    let t0 = Instant::now();
    tools.run_ticks(ticks)?;
    let wall_seconds = t0.elapsed().as_secs_f64();

    let mut digest = FNV_OFFSET;
    for id in &ids {
        fnv1a(&mut digest, tools.recording(*id));
    }
    let sim = tools.sim_mut().expect("run started");
    let result = windowed_result(sim, mode, ticks, wall_seconds, before);
    let sim = tools.sim_mut().expect("run started");
    digest_sim(sim, &mut digest);
    tools.stop()?;
    Ok(ProbeResult { digest, ..result })
}

/// Assemble a [`ProbeResult`] whose counters are all deltas over the
/// timed window (`before` = stats snapshot at the start of the window).
fn windowed_result(
    sim: &SimMachine,
    mode: FabricMode,
    ticks: u64,
    wall_seconds: f64,
    before: (crate::simulator::SimStats, crate::simulator::RouterStats),
) -> ProbeResult {
    let (s0, r0) = before;
    let t = sim.total_router_stats();
    ProbeResult {
        workload: String::new(), // filled by run_fabric_probe
        mode,
        ticks,
        wall_seconds,
        sim_ns: sim.now_ns(),
        events: sim.stats.events_processed - s0.events_processed,
        mc_sent: sim.stats.mc_sent - s0.mc_sent,
        mc_delivered: sim.stats.mc_delivered - s0.mc_delivered,
        hops: (t.mc_routed + t.mc_default_routed) - (r0.mc_routed + r0.mc_default_routed),
        dropped: t.mc_dropped - r0.mc_dropped,
        reinjected: t.mc_reinjected - r0.mc_reinjected,
        lost_forever: t.mc_lost_forever - r0.mc_lost_forever,
        cache_hits: t.cache_hits - r0.cache_hits,
        cache_misses: t.cache_misses - r0.cache_misses,
        digest: 0,
    }
}

// ---------------------------------------------------------------------------
// workload: microcircuit-shaped storm

/// Deterministic traffic generator: fires each of its allocated
/// partition keys with probability `rate` per tick and counts received
/// packets. A pure-Rust stand-in for the HLO-backed neuron binaries
/// with the same multicast footprint.
struct StormApp {
    keys: Vec<u32>,
    rate: f64,
    rng: SplitMix64,
    received: u64,
}

impl CoreApp for StormApp {
    fn on_timer(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        let rate = self.rate;
        let rng = &mut self.rng;
        for &key in &self.keys {
            if rng.next_f64() < rate {
                ctx.send_mc(key, Some(ctx.tick as u32));
            }
        }
        Ok(())
    }

    fn on_mc_packet(&mut self, _key: u32, _payload: Option<u32>, _ctx: &mut CoreCtx) -> anyhow::Result<()> {
        self.received += 1;
        Ok(())
    }

    fn on_pause(&mut self, ctx: &mut CoreCtx) -> anyhow::Result<()> {
        ctx.count("storm_rx", self.received);
        self.received = 0;
        Ok(())
    }
}

fn run_storm(scale: f64, boards: u32, ticks: u64, mode: FabricMode) -> anyhow::Result<ProbeResult> {
    let seed = 0xE11u64;
    let machine = MachineBuilder::boards(boards).build();
    let graph = microcircuit_machine_graph(&machine, scale, seed)?;
    let mapping = map_graph(&machine, &graph, &MappingConfig::default())?;

    let config = SimConfig { fabric: mode, ..SimConfig::default() };
    let mut sim = SimMachine::boot(machine, config);
    for (chip, table) in &mapping.tables {
        scamp::load_routing_table(&mut sim, *chip, table.clone())?;
    }
    for (vid, _vertex) in graph.vertices() {
        let Some(loc) = mapping.placement(vid) else { continue };
        let keys: Vec<u32> = mapping
            .keys
            .iter()
            .filter(|((v, _), _)| *v == vid)
            .map(|(_, kr)| kr.base)
            .collect();
        scamp::load_app(
            &mut sim,
            loc,
            Box::new(StormApp {
                keys,
                rate: 0.3,
                rng: SplitMix64::new(seed ^ ((vid.0 as u64) << 8)),
                received: 0,
            }),
            BTreeMap::new(),
            BTreeMap::new(),
        )?;
    }
    scamp::signal_start(&mut sim)?;

    // Warm-up cycle (untimed), then the timed cycle.
    sim.start_run_cycle(ticks);
    sim.run_until_idle()?;
    let before = (sim.stats, sim.total_router_stats());
    let t0 = Instant::now();
    sim.start_run_cycle(ticks);
    sim.run_until_idle()?;
    let wall_seconds = t0.elapsed().as_secs_f64();

    let mut digest = FNV_OFFSET;
    digest_sim(&sim, &mut digest);
    let result = windowed_result(&sim, mode, ticks, wall_seconds, before);
    Ok(ProbeResult { digest, ..result })
}
