//! Checkpoint/restore run persistence (DESIGN.md §9, experiment E15).
//!
//! A [`RunSnapshot`] is a serializable capture of everything a run has
//! *computed* so far: per-core evolving app state, recording buffers
//! and cursors, provenance counters and IOBUF text, the host-side
//! recording store, the mapping pipeline's placements and key
//! allocations, and the not-yet-fired tail of any injected chaos plan.
//! SDRAM region bytes are stored once in a digest-keyed blob store —
//! successive snapshots of an interval only add blobs for regions whose
//! bytes actually changed, so a checkpoint cadence costs O(delta), not
//! O(machine).
//!
//! Snapshots are written by the run driver on a
//! [`CheckpointConfig::interval_ticks`] cadence and consumed in three
//! places:
//!
//! - `heal()` restores from the newest snapshot instead of replaying
//!   the whole tick history from tick 0 after a mid-run fault;
//! - `reconcile()` restores the surviving vertices after a graph
//!   mutation, preserving their pre-mutation recordings;
//! - `suspend()` / `resume_from()` carry a run across process restarts.
//!
//! Storage is pluggable through the [`Checkpointer`] trait; the crate
//! ships an in-memory store (tests, single-process runs) and a
//! file-backed store (restart survival).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::graph::{KeyRange, VertexId};
use crate::machine::{CoreLocation, ALL_DIRECTIONS};
use crate::simulator::scamp::CoreSnapshot;
use crate::simulator::{ChaosEvent, Fault};
use crate::util::bytes::{ByteReader, ByteWriter};

/// When and how densely the run driver writes snapshots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// Ticks between snapshot captures. Snapshots land on supervisor
    /// poll boundaries (or run-cycle edges when unsupervised), so the
    /// effective cadence is the next boundary at or after this many
    /// ticks since the previous capture.
    pub interval_ticks: u64,
    /// How many snapshots to retain; older ones are pruned after each
    /// capture. Region blobs are content-addressed and shared between
    /// snapshots, so retention is cheap.
    pub keep: usize,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        Self { interval_ticks: 1, keep: 2 }
    }
}

/// A complete, serializable capture of a run at one tick boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSnapshot {
    /// The tick this snapshot was taken at (all cores had completed
    /// exactly this many ticks).
    pub tick: u64,
    /// The Figure-9 cycle unit the run was planned with — a resumed run
    /// keeps honouring it (§6.5).
    pub steps_per_cycle: u64,
    /// `(machine graph, application graph)` revisions at capture time:
    /// a resume against mutated graphs must reconcile, not blindly
    /// continue.
    pub revisions: (u64, u64),
    /// Per-vertex core capture (app state, recording buffers + cursors,
    /// provenance, IOBUF, tick counter). Keyed by vertex — not core —
    /// so a restore after a heal can land the same state on a *moved*
    /// vertex's new core.
    pub cores: BTreeMap<VertexId, CoreSnapshot>,
    /// Per-vertex, per-region `(length, FNV-1a digest)` of the SDRAM
    /// bytes at capture time. The bytes themselves live in the
    /// [`Checkpointer`] blob store under the digest.
    pub regions: BTreeMap<VertexId, BTreeMap<u32, (u32, u64)>>,
    /// The host-side store of already-extracted recordings,
    /// `(vertex, channel) -> bytes`.
    pub host_recordings: BTreeMap<(VertexId, u32), Vec<u8>>,
    /// Chaos events that had not yet fired at capture time. Restored on
    /// `resume_from` (a suspended plan keeps its future); *not*
    /// restored by a heal (the live plan has already drained the event
    /// that caused the fault).
    pub pending_chaos: Vec<ChaosEvent>,
    /// The placements at capture time, used to re-seed the mapping
    /// pipeline on `resume_from` so every vertex stays pinned.
    pub placements: Vec<(VertexId, CoreLocation)>,
    /// The key allocations at capture time (same role as
    /// `placements`: surviving partitions keep their exact ranges).
    pub keys: BTreeMap<(VertexId, String), KeyRange>,
    /// The key allocator's high-water mark, so resumed allocations
    /// never collide with suspended ones.
    pub key_cursor: u64,
}

const MAGIC: &[u8; 4] = b"SNAP";
const VERSION: u32 = 1;

fn write_blob(w: &mut ByteWriter, data: &[u8]) {
    w.u32(data.len() as u32);
    w.bytes(data);
}

fn read_blob(r: &mut ByteReader) -> anyhow::Result<Vec<u8>> {
    let n = r.u32()? as usize;
    Ok(r.bytes(n)?.to_vec())
}

fn write_str(w: &mut ByteWriter, s: &str) {
    write_blob(w, s.as_bytes());
}

fn read_str(r: &mut ByteReader) -> anyhow::Result<String> {
    Ok(String::from_utf8(read_blob(r)?)?)
}

fn write_fault(w: &mut ByteWriter, fault: &Fault) {
    match fault {
        Fault::CoreRte(loc) => {
            w.u8(0).u32(loc.x).u32(loc.y).u8(loc.p);
        }
        Fault::CoreStall(loc) => {
            w.u8(1).u32(loc.x).u32(loc.y).u8(loc.p);
        }
        Fault::ChipDeath(c) => {
            w.u8(2).u32(c.0).u32(c.1);
        }
        Fault::LinkDeath(c, d) => {
            w.u8(3).u32(c.0).u32(c.1).u8(d.id());
        }
        Fault::LinkBrownout { board, loss_permille, duration_ns } => {
            w.u8(4).u32(board.0).u32(board.1).u16(*loss_permille).u64(*duration_ns);
        }
        Fault::BoardSilent { board, duration_ns } => {
            w.u8(5).u32(board.0).u32(board.1).u64(*duration_ns);
        }
    }
}

fn read_fault(r: &mut ByteReader) -> anyhow::Result<Fault> {
    Ok(match r.u8()? {
        0 => Fault::CoreRte(CoreLocation::new(r.u32()?, r.u32()?, r.u8()?)),
        1 => Fault::CoreStall(CoreLocation::new(r.u32()?, r.u32()?, r.u8()?)),
        2 => Fault::ChipDeath((r.u32()?, r.u32()?)),
        3 => {
            let c = (r.u32()?, r.u32()?);
            let id = r.u8()?;
            let d = ALL_DIRECTIONS
                .into_iter()
                .find(|d| d.id() == id)
                .ok_or_else(|| anyhow::anyhow!("bad direction id {id} in snapshot"))?;
            Fault::LinkDeath(c, d)
        }
        4 => Fault::LinkBrownout {
            board: (r.u32()?, r.u32()?),
            loss_permille: r.u16()?,
            duration_ns: r.u64()?,
        },
        5 => Fault::BoardSilent { board: (r.u32()?, r.u32()?), duration_ns: r.u64()? },
        t => anyhow::bail!("bad fault tag {t} in snapshot"),
    })
}

impl RunSnapshot {
    /// Serialize to the little-endian snapshot format (magic `SNAP`,
    /// version 1). The format is self-contained except for region
    /// bytes, which live in the blob store under the digests recorded
    /// in [`Self::regions`].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.bytes(MAGIC).u32(VERSION);
        w.u64(self.tick)
            .u64(self.steps_per_cycle)
            .u64(self.revisions.0)
            .u64(self.revisions.1)
            .u64(self.key_cursor);

        w.u32(self.cores.len() as u32);
        for (vid, core) in &self.cores {
            w.u32(vid.0);
            match &core.app_state {
                Some(state) => {
                    w.u8(1);
                    write_blob(&mut w, state);
                }
                None => {
                    w.u8(0);
                }
            }
            w.u32(core.recordings.len() as u32);
            for (ch, (data, lost)) in &core.recordings {
                w.u32(*ch);
                write_blob(&mut w, data);
                w.u64(*lost);
            }
            w.u32(core.provenance.len() as u32);
            for (k, v) in &core.provenance {
                write_str(&mut w, k);
                w.u64(*v);
            }
            write_str(&mut w, &core.iobuf);
            w.u64(core.ticks_done);
        }

        w.u32(self.regions.len() as u32);
        for (vid, regions) in &self.regions {
            w.u32(vid.0).u32(regions.len() as u32);
            for (id, (len, digest)) in regions {
                w.u32(*id).u32(*len).u64(*digest);
            }
        }

        w.u32(self.host_recordings.len() as u32);
        for ((vid, ch), data) in &self.host_recordings {
            w.u32(vid.0).u32(*ch);
            write_blob(&mut w, data);
        }

        w.u32(self.pending_chaos.len() as u32);
        for ev in &self.pending_chaos {
            w.u64(ev.at_tick);
            write_fault(&mut w, &ev.fault);
        }

        w.u32(self.placements.len() as u32);
        for (vid, loc) in &self.placements {
            w.u32(vid.0).u32(loc.x).u32(loc.y).u8(loc.p);
        }

        w.u32(self.keys.len() as u32);
        for ((vid, partition), range) in &self.keys {
            w.u32(vid.0);
            write_str(&mut w, partition);
            w.u32(range.base).u32(range.mask);
        }
        w.finish()
    }

    /// Decode [`Self::to_bytes`]' output.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        let mut r = ByteReader::new(bytes);
        anyhow::ensure!(r.bytes(4)? == MAGIC, "not a run snapshot (bad magic)");
        let version = r.u32()?;
        anyhow::ensure!(version == VERSION, "unsupported snapshot version {version}");
        let tick = r.u64()?;
        let steps_per_cycle = r.u64()?;
        let revisions = (r.u64()?, r.u64()?);
        let key_cursor = r.u64()?;

        let mut cores = BTreeMap::new();
        for _ in 0..r.u32()? {
            let vid = VertexId(r.u32()?);
            let app_state = match r.u8()? {
                0 => None,
                _ => Some(read_blob(&mut r)?),
            };
            let mut recordings = BTreeMap::new();
            for _ in 0..r.u32()? {
                let ch = r.u32()?;
                let data = read_blob(&mut r)?;
                let lost = r.u64()?;
                recordings.insert(ch, (data, lost));
            }
            let mut provenance = BTreeMap::new();
            for _ in 0..r.u32()? {
                let k = read_str(&mut r)?;
                let v = r.u64()?;
                provenance.insert(k, v);
            }
            let iobuf = read_str(&mut r)?;
            let ticks_done = r.u64()?;
            cores.insert(
                vid,
                CoreSnapshot { app_state, recordings, provenance, iobuf, ticks_done },
            );
        }

        let mut regions = BTreeMap::new();
        for _ in 0..r.u32()? {
            let vid = VertexId(r.u32()?);
            let mut per_vertex = BTreeMap::new();
            for _ in 0..r.u32()? {
                let id = r.u32()?;
                let len = r.u32()?;
                let digest = r.u64()?;
                per_vertex.insert(id, (len, digest));
            }
            regions.insert(vid, per_vertex);
        }

        let mut host_recordings = BTreeMap::new();
        for _ in 0..r.u32()? {
            let vid = VertexId(r.u32()?);
            let ch = r.u32()?;
            host_recordings.insert((vid, ch), read_blob(&mut r)?);
        }

        let mut pending_chaos = Vec::new();
        for _ in 0..r.u32()? {
            let at_tick = r.u64()?;
            let fault = read_fault(&mut r)?;
            pending_chaos.push(ChaosEvent { at_tick, fault });
        }

        let mut placements = Vec::new();
        for _ in 0..r.u32()? {
            let vid = VertexId(r.u32()?);
            let loc = CoreLocation::new(r.u32()?, r.u32()?, r.u8()?);
            placements.push((vid, loc));
        }

        let mut keys = BTreeMap::new();
        for _ in 0..r.u32()? {
            let vid = VertexId(r.u32()?);
            let partition = read_str(&mut r)?;
            let base = r.u32()?;
            let mask = r.u32()?;
            keys.insert((vid, partition), KeyRange { base, mask });
        }
        anyhow::ensure!(r.remaining() == 0, "trailing bytes after snapshot");
        Ok(Self {
            tick,
            steps_per_cycle,
            revisions,
            cores,
            regions,
            host_recordings,
            pending_chaos,
            placements,
            keys,
            key_cursor,
        })
    }
}

/// Pluggable snapshot storage. Two stores in one: a content-addressed
/// blob store for SDRAM region bytes (shared between snapshots — a
/// region that has not changed since the last capture is never stored
/// twice) and a per-tick snapshot store for the serialized
/// [`RunSnapshot`]s.
///
/// Blobs are deliberately not garbage-collected when snapshots are
/// pruned: the digest space is shared, collection would need reference
/// counting across every retained snapshot, and the store is bounded by
/// the working set of distinct region contents anyway.
pub trait Checkpointer {
    /// Store region bytes under their digest (idempotent).
    fn put_blob(&mut self, digest: u64, bytes: &[u8]) -> anyhow::Result<()>;
    fn has_blob(&self, digest: u64) -> bool;
    fn get_blob(&self, digest: u64) -> anyhow::Result<Vec<u8>>;
    /// Store a snapshot under its tick (replacing any previous capture
    /// at the same tick).
    fn put_snapshot(&mut self, snapshot: &RunSnapshot) -> anyhow::Result<()>;
    fn get_snapshot(&self, tick: u64) -> anyhow::Result<RunSnapshot>;
    fn remove_snapshot(&mut self, tick: u64) -> anyhow::Result<()>;
    /// Ticks of every stored snapshot, ascending.
    fn snapshot_ticks(&self) -> Vec<u64>;

    /// The newest stored snapshot at or before `tick`, if any.
    fn newest_at_or_before(&self, tick: u64) -> Option<u64> {
        self.snapshot_ticks().into_iter().filter(|t| *t <= tick).max()
    }

    /// Drop all but the newest `keep` snapshots.
    fn prune(&mut self, keep: usize) -> anyhow::Result<()> {
        let ticks = self.snapshot_ticks();
        if ticks.len() > keep {
            for t in &ticks[..ticks.len() - keep] {
                self.remove_snapshot(*t)?;
            }
        }
        Ok(())
    }
}

/// In-memory snapshot storage: the default store the run driver creates
/// when checkpointing is enabled without an explicit store. Snapshots
/// are held *serialized*, so the codec is exercised on every capture
/// and restore, not only by the file-backed store.
#[derive(Debug, Default)]
pub struct MemoryCheckpointer {
    blobs: BTreeMap<u64, Vec<u8>>,
    snapshots: BTreeMap<u64, Vec<u8>>,
}

impl MemoryCheckpointer {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Checkpointer for MemoryCheckpointer {
    fn put_blob(&mut self, digest: u64, bytes: &[u8]) -> anyhow::Result<()> {
        self.blobs.entry(digest).or_insert_with(|| bytes.to_vec());
        Ok(())
    }

    fn has_blob(&self, digest: u64) -> bool {
        self.blobs.contains_key(&digest)
    }

    fn get_blob(&self, digest: u64) -> anyhow::Result<Vec<u8>> {
        self.blobs
            .get(&digest)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("blob {digest:#018x} not in checkpoint store"))
    }

    fn put_snapshot(&mut self, snapshot: &RunSnapshot) -> anyhow::Result<()> {
        self.snapshots.insert(snapshot.tick, snapshot.to_bytes());
        Ok(())
    }

    fn get_snapshot(&self, tick: u64) -> anyhow::Result<RunSnapshot> {
        let bytes = self
            .snapshots
            .get(&tick)
            .ok_or_else(|| anyhow::anyhow!("no snapshot at tick {tick}"))?;
        RunSnapshot::from_bytes(bytes)
    }

    fn remove_snapshot(&mut self, tick: u64) -> anyhow::Result<()> {
        self.snapshots.remove(&tick);
        Ok(())
    }

    fn snapshot_ticks(&self) -> Vec<u64> {
        self.snapshots.keys().copied().collect()
    }
}

/// File-backed snapshot storage: snapshots survive the process.
/// `dir/snap-<tick>.snap` holds each serialized snapshot;
/// `dir/blobs/<digest>.blob` holds each region blob.
#[derive(Debug)]
pub struct FileCheckpointer {
    dir: PathBuf,
}

impl FileCheckpointer {
    pub fn new(dir: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(dir.join("blobs"))?;
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn blob_path(&self, digest: u64) -> PathBuf {
        self.dir.join("blobs").join(format!("{digest:016x}.blob"))
    }

    fn snapshot_path(&self, tick: u64) -> PathBuf {
        self.dir.join(format!("snap-{tick:020}.snap"))
    }
}

impl Checkpointer for FileCheckpointer {
    fn put_blob(&mut self, digest: u64, bytes: &[u8]) -> anyhow::Result<()> {
        let path = self.blob_path(digest);
        if !path.exists() {
            std::fs::write(path, bytes)?;
        }
        Ok(())
    }

    fn has_blob(&self, digest: u64) -> bool {
        self.blob_path(digest).exists()
    }

    fn get_blob(&self, digest: u64) -> anyhow::Result<Vec<u8>> {
        std::fs::read(self.blob_path(digest))
            .map_err(|e| anyhow::anyhow!("blob {digest:#018x} not in checkpoint store: {e}"))
    }

    fn put_snapshot(&mut self, snapshot: &RunSnapshot) -> anyhow::Result<()> {
        std::fs::write(self.snapshot_path(snapshot.tick), snapshot.to_bytes())?;
        Ok(())
    }

    fn get_snapshot(&self, tick: u64) -> anyhow::Result<RunSnapshot> {
        let bytes = std::fs::read(self.snapshot_path(tick))
            .map_err(|e| anyhow::anyhow!("no snapshot at tick {tick}: {e}"))?;
        RunSnapshot::from_bytes(&bytes)
    }

    fn remove_snapshot(&mut self, tick: u64) -> anyhow::Result<()> {
        let path = self.snapshot_path(tick);
        if path.exists() {
            std::fs::remove_file(path)?;
        }
        Ok(())
    }

    fn snapshot_ticks(&self) -> Vec<u64> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut ticks: Vec<u64> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let tick = name.strip_prefix("snap-")?.strip_suffix(".snap")?;
                tick.parse().ok()
            })
            .collect();
        ticks.sort_unstable();
        ticks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::Direction;

    fn sample_snapshot(tick: u64) -> RunSnapshot {
        let mut cores = BTreeMap::new();
        cores.insert(
            VertexId(3),
            CoreSnapshot {
                app_state: Some(vec![1, 2, 3]),
                recordings: BTreeMap::from([(0, (vec![9, 8], 4u64))]),
                provenance: BTreeMap::from([("spikes_out".to_string(), 17u64)]),
                iobuf: "hello\n".to_string(),
                ticks_done: tick,
            },
        );
        cores.insert(
            VertexId(4),
            CoreSnapshot {
                app_state: None,
                recordings: BTreeMap::new(),
                provenance: BTreeMap::new(),
                iobuf: String::new(),
                ticks_done: tick,
            },
        );
        RunSnapshot {
            tick,
            steps_per_cycle: 8,
            revisions: (5, 0),
            cores,
            regions: BTreeMap::from([(
                VertexId(3),
                BTreeMap::from([(0u32, (12u32, 0xfeed_beefu64))]),
            )]),
            host_recordings: BTreeMap::from([((VertexId(3), 0u32), vec![5, 6, 7])]),
            pending_chaos: vec![
                ChaosEvent { at_tick: tick + 2, fault: Fault::ChipDeath((1, 0)) },
                ChaosEvent {
                    at_tick: tick + 3,
                    fault: Fault::LinkDeath((0, 0), Direction::NorthEast),
                },
                ChaosEvent {
                    at_tick: tick + 4,
                    fault: Fault::CoreRte(CoreLocation::new(1, 1, 5)),
                },
            ],
            placements: vec![
                (VertexId(3), CoreLocation::new(0, 0, 1)),
                (VertexId(4), CoreLocation::new(1, 0, 2)),
            ],
            keys: BTreeMap::from([(
                (VertexId(3), "spikes".to_string()),
                KeyRange { base: 0x100, mask: 0xffff_ff00 },
            )]),
            key_cursor: 0x200,
        }
    }

    #[test]
    fn codec_round_trips() {
        let snap = sample_snapshot(7);
        let decoded = RunSnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(decoded, snap);
    }

    #[test]
    fn codec_rejects_garbage() {
        assert!(RunSnapshot::from_bytes(b"not a snapshot").is_err());
        let mut bytes = sample_snapshot(1).to_bytes();
        bytes.push(0); // trailing byte
        assert!(RunSnapshot::from_bytes(&bytes).is_err());
    }

    #[test]
    fn memory_store_round_trips_and_prunes() {
        let mut store = MemoryCheckpointer::new();
        for tick in [2u64, 4, 6, 8] {
            store.put_snapshot(&sample_snapshot(tick)).unwrap();
        }
        store.put_blob(0xabc, &[1, 2, 3]).unwrap();
        assert!(store.has_blob(0xabc));
        assert_eq!(store.get_blob(0xabc).unwrap(), vec![1, 2, 3]);
        assert!(store.get_blob(0xdef).is_err());
        assert_eq!(store.newest_at_or_before(7), Some(6));
        assert_eq!(store.newest_at_or_before(1), None);
        store.prune(2).unwrap();
        assert_eq!(store.snapshot_ticks(), vec![6, 8]);
        assert_eq!(store.get_snapshot(8).unwrap(), sample_snapshot(8));
        assert!(store.get_snapshot(2).is_err());
        // Pruning never drops blobs (content-addressed, shared).
        assert!(store.has_blob(0xabc));
    }

    #[test]
    fn file_store_round_trips_and_prunes() {
        let dir = std::env::temp_dir().join(format!(
            "spinntools-ckpt-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = FileCheckpointer::new(&dir).unwrap();
        for tick in [3u64, 5, 9] {
            store.put_snapshot(&sample_snapshot(tick)).unwrap();
        }
        store.put_blob(0x77, &[4, 5]).unwrap();
        assert!(store.has_blob(0x77));
        assert_eq!(store.get_blob(0x77).unwrap(), vec![4, 5]);
        assert_eq!(store.snapshot_ticks(), vec![3, 5, 9]);
        assert_eq!(store.newest_at_or_before(8), Some(5));
        store.prune(1).unwrap();
        assert_eq!(store.snapshot_ticks(), vec![9]);
        assert_eq!(store.get_snapshot(9).unwrap(), sample_snapshot(9));
        // A second handle on the same directory sees the same state —
        // the restart-survival property.
        let reopened = FileCheckpointer::new(&dir).unwrap();
        assert_eq!(reopened.snapshot_ticks(), vec![9]);
        assert_eq!(reopened.get_snapshot(9).unwrap(), sample_snapshot(9));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
