//! Host-side data extraction (§6.8, Figure 11): the slow SCAMP path and
//! the fast multicast-stream path, behind one interface.
//!
//! The fast path installs system-level cores outside the user graph —
//! one reader per used chip, one gatherer on the Ethernet chip — plus
//! routing entries in a reserved key region, then drives transfers by
//! SDP command + UDP reassembly with missing-sequence re-requests.

use std::collections::BTreeMap;

use crate::apps::speedup::{
    self, DataSpeedUpGathererApp, DataSpeedUpReaderApp, GATHERER_BINARY, READER_BINARY,
    READER_SDP_PORT,
};
use crate::machine::router::{Route, RoutingEntry};
use crate::machine::{ChipCoord, CoreLocation};
use crate::mapping::router::build_tree;
use crate::simulator::{scamp, SimMachine};
use crate::transport::{SdpHeader, SdpMessage};
use crate::util::bytes::ByteWriter;

/// Reserved top-of-keyspace region for extraction streams; user key
/// allocation grows from 0, so collision means ~2^31 partitions exist.
pub const STREAM_KEY_BASE: u32 = 0xFF00_0000;

/// The installed fast path.
pub struct FastPath {
    /// chip -> (reader core, stream key base).
    readers: BTreeMap<ChipCoord, (CoreLocation, u32)>,
    gatherer_port: u16,
}

impl FastPath {
    /// Install readers on `chips`, a gatherer on the Ethernet chip, and
    /// the stream routing entries. `free_core` picks an unused core per
    /// chip (the tools know placement occupancy); chips with no spare
    /// core are skipped — reads from them fall back to the SCAMP path
    /// (`has_reader` tells the caller which chips are covered).
    pub fn install(
        sim: &mut SimMachine,
        chips: &[ChipCoord],
        mut free_core: impl FnMut(ChipCoord) -> Option<u8>,
        host_port: u16,
        iptag: u8,
    ) -> anyhow::Result<FastPath> {
        let machine = sim.machine.clone();
        let eth = machine
            .ethernet_chips()
            .next()
            .map(|c| (c.x, c.y))
            .ok_or_else(|| anyhow::anyhow!("machine has no ethernet chip"))?;

        // Gatherer core on the Ethernet chip (required: without it there
        // is no fast path at all).
        let gatherer_core = CoreLocation::new(
            eth.0,
            eth.1,
            free_core(eth).ok_or_else(|| {
                anyhow::anyhow!("no free core on ethernet chip {eth:?} for the gatherer")
            })?,
        );
        scamp::set_iptag(sim, eth, iptag, "host", host_port, true)?;
        let mut gregion = BTreeMap::new();
        let mut w = ByteWriter::new();
        w.u32(iptag as u32);
        gregion.insert(0u32, w.finish());
        scamp::load_app_named(
            sim,
            gatherer_core,
            GATHERER_BINARY,
            Box::new(DataSpeedUpGathererApp::new()),
            gregion,
            BTreeMap::new(),
        )?;

        // One reader per chip + stream routing to the gatherer.
        let mut readers = BTreeMap::new();
        let mut extra_entries: BTreeMap<ChipCoord, Vec<RoutingEntry>> = BTreeMap::new();
        for (i, chip) in chips.iter().enumerate() {
            let Some(p) = free_core(*chip) else {
                continue; // fully-packed chip: SCAMP fallback
            };
            let core = CoreLocation::new(chip.0, chip.1, p);
            let key = STREAM_KEY_BASE + (i as u32) * 2;
            let mut region = BTreeMap::new();
            let mut w = ByteWriter::new();
            w.u32(key);
            region.insert(0u32, w.finish());
            scamp::load_app_named(
                sim,
                core,
                READER_BINARY,
                Box::new(DataSpeedUpReaderApp::new()),
                region,
                BTreeMap::new(),
            )?;
            // Route {key, key|1} from this chip to the gatherer core.
            let mut dests = BTreeMap::new();
            dests.insert(eth, std::iter::once(gatherer_core.p).collect());
            let tree = build_tree(&machine, *chip, &dests)?;
            for (node_chip, node) in &tree.nodes {
                let mut route = Route::EMPTY;
                for d in &node.out_links {
                    route.add_link(*d);
                }
                for p in &node.local_cores {
                    route.add_processor(*p);
                }
                if route.is_empty() {
                    continue;
                }
                extra_entries
                    .entry(*node_chip)
                    .or_default()
                    .push(RoutingEntry::new(key, !1u32, route));
            }
            readers.insert(*chip, (core, key));
        }
        // Append the stream entries to the already-loaded tables.
        for (chip, entries) in extra_entries {
            let mut table = sim.chip(chip)?.table.clone();
            for e in entries {
                table.push(e);
            }
            scamp::load_routing_table(sim, chip, table)?;
        }
        Ok(FastPath { readers, gatherer_port: host_port })
    }

    /// Read `len` bytes from `addr` on `chip` through the stream
    /// protocol, re-requesting missing frames up to 3 times.
    pub fn read(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        addr: u32,
        len: usize,
    ) -> anyhow::Result<Vec<u8>> {
        let (reader, _key) = self
            .readers
            .get(&chip)
            .ok_or_else(|| anyhow::anyhow!("no fast-path reader on {chip:?}"))?;
        let header = SdpHeader::to_core(*reader, READER_SDP_PORT);
        sim.host_send_sdp(SdpMessage::new(
            header,
            speedup::encode_read_command(addr, len as u32),
        ))?;
        sim.run_until_idle()?;
        let mut frames = sim.take_host_udp(self.gatherer_port);
        for _attempt in 0..3 {
            let (data, missing) = speedup::reassemble(&frames, len);
            if missing.is_empty() {
                return Ok(data);
            }
            // "The missing sequences are then requested again" (§6.8),
            // batched to fit the SDP payload limit.
            for chunk in missing.chunks(60) {
                sim.host_send_sdp(SdpMessage::new(
                    header,
                    speedup::encode_rerequest(addr, len as u32, chunk),
                ))?;
                sim.run_until_idle()?;
                frames.extend(sim.take_host_udp(self.gatherer_port));
            }
        }
        let (data, missing) = speedup::reassemble(&frames, len);
        anyhow::ensure!(
            missing.is_empty(),
            "fast read from {chip:?} still missing {} frames after retries",
            missing.len()
        );
        Ok(data)
    }

    pub fn has_reader(&self, chip: ChipCoord) -> bool {
        self.readers.contains_key(&chip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;
    use crate::simulator::SimConfig;

    fn free_core_picker() -> impl FnMut(ChipCoord) -> Option<u8> {
        let mut used: BTreeMap<ChipCoord, u8> = BTreeMap::new();
        move |chip| {
            let next = used.entry(chip).or_insert(17);
            let c = *next;
            *next -= 1;
            Some(c)
        }
    }

    #[test]
    fn fast_read_round_trips_data() {
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        // Data on a far, non-ethernet chip.
        let chip = (7, 7);
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
        scamp::write_sdram(&mut sim, chip, addr, &data).unwrap();
        let fp = FastPath::install(&mut sim, &[chip], free_core_picker(), 17895, 7).unwrap();
        scamp::signal_start(&mut sim).unwrap();
        let got = fp.read(&mut sim, chip, addr, data.len()).unwrap();
        assert_eq!(got, data);
    }

    #[test]
    fn fast_path_beats_scamp_from_any_chip() {
        // Experiment E1's claim, as a test: fast reads are faster than
        // SCAMP reads, and chip distance does not matter for fast reads.
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let len = 64 * 1024;
        let far = (7, 7);
        let near = (0, 0);
        let a_far = scamp::alloc_sdram(&mut sim, far, len as u32).unwrap();
        let a_near = scamp::alloc_sdram(&mut sim, near, len as u32).unwrap();
        let fp =
            FastPath::install(&mut sim, &[far, near], free_core_picker(), 17895, 7).unwrap();
        scamp::signal_start(&mut sim).unwrap();

        let t0 = sim.now_ns();
        scamp::read_sdram(&mut sim, far, a_far, len).unwrap();
        let scamp_far = sim.now_ns() - t0;

        let t1 = sim.now_ns();
        fp.read(&mut sim, far, a_far, len).unwrap();
        let fast_far = sim.now_ns() - t1;

        let t2 = sim.now_ns();
        fp.read(&mut sim, near, a_near, len).unwrap();
        let fast_near = sim.now_ns() - t2;

        assert!(
            fast_far < scamp_far / 10,
            "fast {fast_far} ns vs scamp {scamp_far} ns"
        );
        // "no penalty for reading from a non-Ethernet chip"
        let ratio = fast_far as f64 / fast_near as f64;
        assert!((0.8..1.2).contains(&ratio), "far/near = {ratio}");
    }

    #[test]
    fn missing_reader_errors() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let fp = FastPath::install(&mut sim, &[(0, 0)], free_core_picker(), 17895, 7).unwrap();
        assert!(fp.read(&mut sim, (1, 1), 0x6000_0000, 4).is_err());
    }
}
