//! The bulk data plane (§6.8, Figure 11): fast multicast paths for both
//! *extraction* (machine → host) and *loading* (host → machine), with
//! the SCAMP request/response protocol as the slow fallback — all
//! behind one interface.
//!
//! The fast plane installs system-level cores outside the user graph,
//! **per board**:
//!
//! - a *gatherer* on every Ethernet chip, reassembling the word streams
//!   of that board's chips into sequence-numbered UDP frames for the
//!   host (extraction);
//! - a *dispatcher* on every Ethernet chip, fanning the host's
//!   sequence-numbered UDP frames out as multicast words to the target
//!   chip (loading);
//! - a *reader* and a *writer* core on every covered chip, each with a
//!   2-key-wide stream in a reserved top-of-keyspace region routed
//!   to/from its board's Ethernet chip.
//!
//! Chips are assigned to their **nearest** Ethernet chip
//! ([`crate::machine::Machine::nearest_ethernet`]), so on a multi-board
//! machine every board's uplink carries only its own traffic and
//! transfers to/from different boards overlap in simulated time — the
//! scaling the E12 benchmark measures. Host-side per-board drains
//! (frame reassembly) fan out on the [`crate::util::par`] worker pool.
//!
//! Both directions recover from frame loss by re-requesting missing
//! sequence numbers (§6.8: "the missing sequences are then requested
//! again"); the loss-injection entry points ([`FastPath::read_with_loss`],
//! [`FastPath::write_with_loss`]) exist so tests can prove recovery is
//! byte-identical.

use std::collections::{BTreeMap, VecDeque};

use crate::apps::speedup::{
    self, DataInDispatcherApp, DataInWriterApp, DataSpeedUpGathererApp, DataSpeedUpReaderApp,
    DISPATCHER_BINARY, GATHERER_BINARY, READER_BINARY, READER_SDP_PORT, WRITER_BINARY,
    WRITER_SDP_PORT,
};
use crate::machine::router::{Route, RoutingEntry};
use crate::machine::{ChipCoord, CoreLocation, ROUTER_ENTRIES};
use crate::mapping::router::build_tree;
use crate::mapping::tags::SystemTagAllocator;
use crate::simulator::{scamp, SimMachine};
use crate::transport::{bulk, SdpHeader, SdpMessage};
use crate::util::bytes::ByteWriter;

/// Reserved top-of-keyspace region for extraction streams; user key
/// allocation grows from 0, so collision means ~2^31 partitions exist.
pub const STREAM_KEY_BASE: u32 = 0xFF00_0000;

/// Reserved key region for data-in streams (disjoint from extraction;
/// both sit above `SimConfig::lossless_key_min`, so the fabric treats
/// the whole plane as flow-controlled, never dropped).
pub const DATA_IN_KEY_BASE: u32 = 0xFF80_0000;

/// Re-request rounds before a transfer is declared failed, from the
/// wire configuration (always at least one round).
fn retry_rounds(sim: &SimMachine) -> u32 {
    sim.config.wire.bulk_retry_rounds.max(1)
}

/// Pay for one fruitless bulk-plane retry round in simulated time: the
/// per-request timeout plus capped exponential backoff, mirroring
/// `scamp::scp_exchange`. Without this, a total blackout
/// ([`crate::simulator::chaos::Fault::LinkBrownout`] at full loss, or a
/// `BoardSilent` episode) freezes the retry loop at one instant — every
/// round re-draws inside the same fault window, so only the SCP path
/// could ride an episode out. Gated on `wire_active` so the clean wire
/// stays draw-free and timing-identical.
fn pay_retry_backoff(sim: &mut SimMachine, attempt: u32) {
    if !sim.wire_active() {
        return;
    }
    let timeout = sim.config.wire.scp_timeout_ns;
    let backoff = timeout.saturating_mul(1 << attempt.min(6));
    sim.advance_host_time(timeout + backoff);
    let stats = sim.wire_stats_mut();
    stats.backoff_wait_ns += backoff;
    stats.bulk_retry_waits += 1;
}

/// Installation options for the bulk data plane.
#[derive(Debug, Clone)]
pub struct DataPlaneOptions {
    /// First UDP port of the per-board pair: board `i` receives
    /// extraction frames on `port_base + 2i` and exchanges data-in
    /// frames/reports on `port_base + 2i + 1`.
    pub port_base: u16,
    /// Install the extraction half (gatherers + readers). A
    /// loading-only plane leaves those cores free.
    pub extraction: bool,
    /// Install the data-in half (dispatchers + writers). An
    /// extraction-only plane leaves those cores free.
    pub data_in: bool,
    /// Worker threads for the host-side per-board drains (frame
    /// reassembly); `0` = one per hardware thread.
    pub threads: usize,
}

impl Default for DataPlaneOptions {
    fn default() -> Self {
        Self { port_base: 17895, extraction: true, data_in: true, threads: 0 }
    }
}

/// Statistics of one fast write (or batch of writes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WriteStats {
    /// Frames sent in first-attempt streams.
    pub frames_sent: u64,
    /// Frames re-sent after missing-sequence reports.
    pub frames_resent: u64,
}

/// The data-in half of one board's plane.
struct BoardDataIn {
    /// The dispatcher core itself (a system core placement must avoid).
    dispatcher: CoreLocation,
    /// Reverse-IP-tagged port the dispatcher receives frames on; also
    /// the (forward) tag port the board's writers report missing
    /// sequences to.
    port: u16,
    /// IP tag id for writer → host report messages.
    reply_tag: u8,
}

/// The per-board system cores and host ports of the plane.
struct BoardPlane {
    /// Extraction gatherer, when the extraction half is installed.
    gatherer: Option<CoreLocation>,
    /// The IP tag the gatherer forwards extraction frames through.
    extract_tag: Option<u8>,
    extract_port: u16,
    data_in: Option<BoardDataIn>,
}

/// The installed bulk data plane.
pub struct FastPath {
    /// Ethernet chip -> that board's plane.
    boards: BTreeMap<ChipCoord, BoardPlane>,
    /// chip -> (reader core, extraction stream key).
    readers: BTreeMap<ChipCoord, (CoreLocation, u32)>,
    /// chip -> (writer core, data-in stream key).
    writers: BTreeMap<ChipCoord, (CoreLocation, u32)>,
    /// chip -> the plane's stream routing entries on it. Kept so an
    /// incremental re-map can reinstall a chip's *user* table and
    /// re-append these without reinstalling the plane.
    stream_entries: BTreeMap<ChipCoord, Vec<RoutingEntry>>,
    /// Host-side drain pool width.
    threads: usize,
}

/// Simulated-time gap the host leaves between successive frames to one
/// board: the dispatcher must have fanned a frame's words onto the
/// fabric before the next frame arrives, or two streams' words would
/// interleave at their writers. 64 words + header at the core's packet
/// emission spacing, plus margin. An unreliable wire widens the gap by
/// the worst-case delivery skew (latency jitter plus the duplicate
/// reordering window on each side) so a delayed frame still lands
/// before its successor's fan-out begins.
fn dispatch_frame_gap_ns(sim: &SimMachine) -> u64 {
    let f = &sim.config.wire.faults;
    (bulk::WORDS_PER_FRAME as u64 + 4) * sim.config.send_spacing_ns.max(1)
        + f.jitter_ns
        + 2 * f.reorder_window_ns
}

impl FastPath {
    /// Install the plane: per-board gatherers (and dispatchers, when
    /// `opts.data_in`), per-chip readers (and writers) for `chips`, and
    /// the stream routing entries. `free_core` picks an unused core per
    /// chip (the tools know placement occupancy); chips with no spare
    /// core — or whose board's Ethernet chip could not host its system
    /// cores — are skipped, and transfers there fall back to the SCAMP
    /// path ([`Self::has_reader`] / [`Self::has_writer`] tell the caller
    /// which chips are covered). Errors only if *no* board could be set
    /// up at all.
    pub fn install(
        sim: &mut SimMachine,
        chips: &[ChipCoord],
        mut free_core: impl FnMut(ChipCoord) -> Option<u8>,
        opts: &DataPlaneOptions,
    ) -> anyhow::Result<FastPath> {
        let machine = sim.machine.clone();
        // In a multi-tenant session the sim is scoped to one partition:
        // the plane only installs on that tenant's boards (and the
        // per-tenant `port_base` keeps host UDP ports disjoint).
        let eths: Vec<ChipCoord> = machine
            .ethernet_chips()
            .map(|c| (c.x, c.y))
            .filter(|c| sim.in_scope(*c))
            .collect();
        anyhow::ensure!(!eths.is_empty(), "machine has no ethernet chip");

        // System tags must coexist with the graph tags already installed.
        let mut tags = SystemTagAllocator::new();
        for &eth in &eths {
            for t in sim.chip(eth)?.iptags.keys() {
                tags.mark_used(eth, *t);
            }
        }

        let mut boards: BTreeMap<ChipCoord, BoardPlane> = BTreeMap::new();
        let mut board_errors: Vec<String> = Vec::new();
        for (i, &eth) in eths.iter().enumerate() {
            let extract_port = opts.port_base + 2 * i as u16;
            let mut install_gatherer = || -> Result<(CoreLocation, u8), String> {
                let p = free_core(eth).ok_or_else(|| {
                    format!("no free core on ethernet chip {eth:?} for the gatherer")
                })?;
                let extract_tag = tags.alloc(eth).map_err(|e| e.to_string())?;
                let gatherer = CoreLocation::new(eth.0, eth.1, p);
                scamp::set_iptag(sim, eth, extract_tag, "host", extract_port, true)
                    .map_err(|e| e.to_string())?;
                let mut gregion = BTreeMap::new();
                let mut w = ByteWriter::new();
                w.u32(extract_tag as u32);
                gregion.insert(0u32, w.finish());
                scamp::load_app_named(
                    sim,
                    gatherer,
                    GATHERER_BINARY,
                    Box::new(DataSpeedUpGathererApp::new()),
                    gregion,
                    BTreeMap::new(),
                )
                .map_err(|e| e.to_string())?;
                Ok((gatherer, extract_tag))
            };
            let (gatherer, extract_tag) = if opts.extraction {
                match install_gatherer() {
                    Ok((g, t)) => (Some(g), Some(t)),
                    Err(e) => {
                        board_errors.push(e);
                        // Extraction was asked for and this board cannot
                        // serve it: skip the board entirely rather than
                        // leave it half-installed.
                        continue;
                    }
                }
            } else {
                (None, None)
            };
            let mut install_data_in = || -> Result<BoardDataIn, String> {
                let p = free_core(eth).ok_or_else(|| {
                    format!("no free core on ethernet chip {eth:?} for the data-in dispatcher")
                })?;
                let reply_tag = tags.alloc(eth).map_err(|e| e.to_string())?;
                let dispatcher = CoreLocation::new(eth.0, eth.1, p);
                let port = opts.port_base + 2 * i as u16 + 1;
                // Never clobber a reverse tag the user graph registered.
                let taken = sim
                    .chip(eth)
                    .map_err(|e| e.to_string())?
                    .reverse_iptags
                    .contains_key(&port);
                if taken {
                    return Err(format!(
                        "UDP port {port} on board {eth:?} already has a reverse IP tag"
                    ));
                }
                scamp::set_iptag(sim, eth, reply_tag, "host", port, true)
                    .map_err(|e| e.to_string())?;
                scamp::set_reverse_iptag(sim, eth, port, dispatcher).map_err(|e| e.to_string())?;
                scamp::load_app_named(
                    sim,
                    dispatcher,
                    DISPATCHER_BINARY,
                    Box::new(DataInDispatcherApp),
                    BTreeMap::new(),
                    BTreeMap::new(),
                )
                .map_err(|e| e.to_string())?;
                Ok(BoardDataIn { dispatcher, port, reply_tag })
            };
            let data_in = if opts.data_in {
                match install_data_in() {
                    Ok(din) => Some(din),
                    Err(e) => {
                        board_errors.push(e);
                        None
                    }
                }
            } else {
                None
            };
            if gatherer.is_none() && data_in.is_none() {
                continue; // nothing was installed on this board
            }
            boards.insert(eth, BoardPlane { gatherer, extract_tag, extract_port, data_in });
        }
        anyhow::ensure!(
            !boards.is_empty(),
            "bulk data plane unavailable on every board: {}",
            board_errors.join("; ")
        );

        // Per-chip readers/writers + stream routing, batched into one
        // table reload per touched chip. A chip is covered only if its
        // stream's tree can be planned and every touched routing table
        // still has TCAM room; otherwise the chip is skipped (SCAMP
        // fallback) — coverage problems never abort the whole plane.
        let mut readers = BTreeMap::new();
        let mut writers = BTreeMap::new();
        let mut extra_entries: BTreeMap<ChipCoord, Vec<RoutingEntry>> = BTreeMap::new();
        let plan_tree = |source: ChipCoord,
                         dest: CoreLocation,
                         key: u32|
         -> anyhow::Result<Vec<(ChipCoord, RoutingEntry)>> {
            let mut dests = BTreeMap::new();
            dests.insert(dest.chip(), std::iter::once(dest.p).collect());
            let tree = build_tree(&machine, source, &dests)?;
            let mut out = Vec::new();
            for (node_chip, node) in &tree.nodes {
                let mut route = Route::EMPTY;
                for d in &node.out_links {
                    route.add_link(*d);
                }
                for p in &node.local_cores {
                    route.add_processor(*p);
                }
                if route.is_empty() {
                    continue;
                }
                out.push((*node_chip, RoutingEntry::new(key, !1u32, route)));
            }
            Ok(out)
        };
        let fits = |sim: &SimMachine,
                    extra: &BTreeMap<ChipCoord, Vec<RoutingEntry>>,
                    planned: &[(ChipCoord, RoutingEntry)]|
         -> bool {
            let mut add: BTreeMap<ChipCoord, usize> = BTreeMap::new();
            for (c, _) in planned {
                *add.entry(*c).or_default() += 1;
            }
            add.iter().all(|(c, n)| {
                let loaded = sim.chip(*c).map(|ch| ch.table.len()).unwrap_or(ROUTER_ENTRIES);
                let pending = extra.get(c).map(Vec::len).unwrap_or(0);
                loaded + pending + n <= ROUTER_ENTRIES
            })
        };
        for (i, chip) in chips.iter().enumerate() {
            let Some(board) = machine.nearest_ethernet(*chip) else {
                continue;
            };
            let Some(plane) = boards.get(&board) else {
                continue; // board without system cores: SCAMP fallback
            };
            // Extraction reader: chip -> board gatherer. A stream whose
            // route would clip a chip outside the session scope is
            // skipped (SCAMP fallback): a tenant must never append
            // entries to another tenant's tables.
            if let Some(gatherer) = plane.gatherer {
                let key = STREAM_KEY_BASE + (i as u32) * 2;
                if let Ok(planned) = plan_tree(*chip, gatherer, key) {
                    if planned.iter().all(|(c, _)| sim.in_scope(*c))
                        && fits(sim, &extra_entries, &planned)
                    {
                        if let Some(p) = free_core(*chip) {
                            let core = CoreLocation::new(chip.0, chip.1, p);
                            let mut region = BTreeMap::new();
                            let mut w = ByteWriter::new();
                            w.u32(key);
                            region.insert(0u32, w.finish());
                            scamp::load_app_named(
                                sim,
                                core,
                                READER_BINARY,
                                Box::new(DataSpeedUpReaderApp::new()),
                                region,
                                BTreeMap::new(),
                            )?;
                            for (c, e) in planned {
                                extra_entries.entry(c).or_default().push(e);
                            }
                            readers.insert(*chip, (core, key));
                        }
                    }
                }
            }
            // Data-in writer: board dispatcher -> chip.
            if let Some(din) = &plane.data_in {
                if let Some(p) = free_core(*chip) {
                    let core = CoreLocation::new(chip.0, chip.1, p);
                    let key = DATA_IN_KEY_BASE + (i as u32) * 2;
                    if let Ok(planned) = plan_tree(board, core, key) {
                        if planned.iter().all(|(c, _)| sim.in_scope(*c))
                            && fits(sim, &extra_entries, &planned)
                        {
                            let mut region = BTreeMap::new();
                            let mut w = ByteWriter::new();
                            w.u32(key);
                            w.u32(din.reply_tag as u32);
                            region.insert(0u32, w.finish());
                            scamp::load_app_named(
                                sim,
                                core,
                                WRITER_BINARY,
                                Box::new(DataInWriterApp::new()),
                                region,
                                BTreeMap::new(),
                            )?;
                            for (c, e) in planned {
                                extra_entries.entry(c).or_default().push(e);
                            }
                            writers.insert(*chip, (core, key));
                        }
                    }
                }
            }
        }
        // Append the stream entries to the already-loaded tables; the
        // capacity planning above guarantees these reloads fit.
        for (chip, entries) in &extra_entries {
            let mut table = sim.chip(*chip)?.table.clone();
            for e in entries {
                table.push(*e);
            }
            scamp::load_routing_table(sim, *chip, table)?;
        }
        Ok(FastPath {
            boards,
            readers,
            writers,
            stream_entries: extra_entries,
            threads: opts.threads,
        })
    }

    /// The board (Ethernet chip) serving `chip`, with its plane.
    fn plane_of(&self, sim: &SimMachine, chip: ChipCoord) -> anyhow::Result<(ChipCoord, &BoardPlane)> {
        let board = sim
            .machine
            .nearest_ethernet(chip)
            .ok_or_else(|| anyhow::anyhow!("no ethernet chip for {chip:?}"))?;
        let plane = self
            .boards
            .get(&board)
            .ok_or_else(|| anyhow::anyhow!("no data plane on board {board:?}"))?;
        Ok((board, plane))
    }

    // -- extraction (machine -> host) ----------------------------------------

    /// Read `len` bytes from `addr` on `chip` through the stream
    /// protocol, re-requesting missing frames for up to
    /// `wire.bulk_retry_rounds` rounds.
    pub fn read(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        addr: u32,
        len: usize,
    ) -> anyhow::Result<Vec<u8>> {
        self.read_with_loss(sim, chip, addr, len, |_, _| false)
    }

    /// [`Self::read`] with fault injection: `drop(seq, attempt)` returning
    /// `true` discards that received frame, as if the UDP datagram had
    /// been lost on the wire. Recovery must still produce byte-identical
    /// data — the loss suite proves it does.
    pub fn read_with_loss(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        addr: u32,
        len: usize,
        mut drop: impl FnMut(u32, u32) -> bool,
    ) -> anyhow::Result<Vec<u8>> {
        let (reader, _key) = *self
            .readers
            .get(&chip)
            .ok_or_else(|| anyhow::anyhow!("no fast-path reader on {chip:?}"))?;
        let (board, plane) = self.plane_of(sim, chip)?;
        let port = plane.extract_port;
        let header = SdpHeader::to_core(reader, READER_SDP_PORT);
        sim.host_send_sdp(SdpMessage::new(
            header,
            speedup::encode_read_command(addr, len as u32),
        ))?;
        sim.run_until_idle()?;
        let mut frames = filter_dropped(sim.take_host_udp(port), 0, &mut drop);
        for attempt in 1..=retry_rounds(sim) {
            let (data, missing) = speedup::reassemble(&frames, len);
            if missing.is_empty() {
                return Ok(data);
            }
            let before = frames.len();
            if frames.is_empty() {
                // Nothing arrived at all: the read command itself was
                // lost on the wire, so the gatherer never saw the stream
                // header and a re-request could not flush a partial last
                // frame. Wait out the timeout + backoff, then replay the
                // whole command — a blackout episode expires under the
                // advancing clock instead of eating every round.
                pay_retry_backoff(sim, attempt - 1);
                sim.host_send_sdp(SdpMessage::new(
                    header,
                    speedup::encode_read_command(addr, len as u32),
                ))?;
                sim.run_until_idle()?;
                frames.extend(filter_dropped(sim.take_host_udp(port), attempt, &mut drop));
                continue;
            }
            // "The missing sequences are then requested again" (§6.8),
            // batched to fit the SDP payload limit.
            for chunk in missing.chunks(60) {
                sim.host_send_sdp(SdpMessage::new(
                    header,
                    speedup::encode_rerequest(addr, len as u32, chunk),
                ))?;
                sim.run_until_idle()?;
                frames.extend(filter_dropped(sim.take_host_udp(port), attempt, &mut drop));
            }
            if frames.len() == before {
                // A whole re-request round produced nothing (the wire is
                // dark, not merely lossy): pay the backoff before trying
                // again.
                pay_retry_backoff(sim, attempt - 1);
            }
        }
        let (data, missing) = speedup::reassemble(&frames, len);
        if !missing.is_empty() && sim.wire_active() {
            sim.note_wire_escalation(board);
            anyhow::bail!(
                "fast read from {chip:?} still missing {} frames after retries \
                 (escalated to the supervisor)",
                missing.len()
            );
        }
        anyhow::ensure!(
            missing.is_empty(),
            "fast read from {chip:?} still missing {} frames after retries",
            missing.len()
        );
        Ok(data)
    }

    /// Read a batch of transfers, sharded per board: one transfer per
    /// board streams at a time, so on a multi-board machine every
    /// board's uplink is busy concurrently (the simulated-time scaling
    /// of E12), and the host-side frame reassembly of each round fans
    /// out on the [`crate::util::par`] pool. Results come back in
    /// request order.
    pub fn read_many(
        &self,
        sim: &mut SimMachine,
        reqs: &[(ChipCoord, u32, usize)],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); reqs.len()];
        let mut by_board: BTreeMap<ChipCoord, VecDeque<usize>> = BTreeMap::new();
        for (idx, (chip, _, _)) in reqs.iter().enumerate() {
            anyhow::ensure!(
                self.readers.contains_key(chip),
                "no fast-path reader on {chip:?}"
            );
            let (board, _) = self.plane_of(sim, *chip)?;
            by_board.entry(board).or_default().push_back(idx);
        }
        loop {
            // One transfer per board this round.
            let mut round: Vec<(usize, u16)> = Vec::new();
            for (board, queue) in by_board.iter_mut() {
                let Some(idx) = queue.pop_front() else { continue };
                let (chip, addr, len) = reqs[idx];
                let (reader, _) = self.readers[&chip];
                let header = SdpHeader::to_core(reader, READER_SDP_PORT);
                sim.host_send_sdp(SdpMessage::new(
                    header,
                    speedup::encode_read_command(addr, len as u32),
                ))?;
                round.push((idx, self.boards[board].extract_port));
            }
            if round.is_empty() {
                break;
            }
            // All boards stream concurrently in simulated time.
            sim.run_until_idle()?;
            let collected: Vec<(usize, Vec<Vec<u8>>)> = round
                .iter()
                .map(|(idx, port)| (*idx, sim.take_host_udp(*port)))
                .collect();
            // Host-side per-board drains on the worker pool.
            let assembled = crate::util::par::par_map(self.threads, &collected, |_, item| {
                let (idx, frames) = item;
                (*idx, speedup::reassemble(frames, reqs[*idx].2))
            });
            for (idx, (data, missing)) in assembled {
                if missing.is_empty() {
                    out[idx] = data;
                } else {
                    // Rare (the plane's keys are lossless on the fabric):
                    // finish this transfer serially with re-requests.
                    let (chip, addr, len) = reqs[idx];
                    out[idx] = self.read(sim, chip, addr, len)?;
                }
            }
        }
        Ok(out)
    }

    // -- loading (host -> machine) -------------------------------------------

    /// Write `data` to `addr` on `chip` through the data-in stream.
    pub fn write(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        addr: u32,
        data: &[u8],
    ) -> anyhow::Result<WriteStats> {
        self.write_with_loss(sim, chip, addr, data, |_, _| false)
    }

    /// [`Self::write`] with fault injection: `drop(seq, attempt)`
    /// returning `true` suppresses that outbound frame, as if the UDP
    /// datagram had been lost. The writer's missing-sequence report
    /// drives re-sends until the SDRAM image is complete.
    pub fn write_with_loss(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        addr: u32,
        data: &[u8],
        mut drop: impl FnMut(u32, u32) -> bool,
    ) -> anyhow::Result<WriteStats> {
        let mut stats = WriteStats::default();
        if data.is_empty() {
            return Ok(stats);
        }
        let (writer, key) = *self
            .writers
            .get(&chip)
            .ok_or_else(|| anyhow::anyhow!("no data-in writer on {chip:?}"))?;
        let (board, plane) = self.plane_of(sim, chip)?;
        let din = plane
            .data_in
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no data-in dispatcher on board {board:?}"))?;
        let port = din.port;
        sim.host_send_sdp(SdpMessage::new(
            SdpHeader::to_core(writer, WRITER_SDP_PORT),
            bulk::encode_write_command(addr, data.len() as u32),
        ))?;
        sim.run_until_idle()?;
        self.ensure_session(sim, chip, addr, data.len())?;
        let frame_gap = dispatch_frame_gap_ns(sim);
        let mut slot = 0u64;
        for seq in 0..bulk::frames_of(data.len()) as u32 {
            if !drop(seq, 0) {
                sim.host_send_udp_after(
                    board,
                    port,
                    bulk::encode_data_frame(key, seq, &data[bulk::frame_range(seq, data.len())]),
                    slot,
                )?;
                stats.frames_sent += 1;
            }
            // A lost frame still occupied its slot on the wire.
            slot += frame_gap;
        }
        sim.run_until_idle()?;
        self.finish_write(sim, chip, data, &mut drop, &mut stats)
    }

    /// Confirm a writer actually holds the session the host just opened.
    ///
    /// The session-open command crosses the unreliable wire like any
    /// other frame: if it is lost, the writer holds no (or a stale,
    /// fully-acknowledged) session, and a later missing-sequence query
    /// would report "nothing missing" for data that was never written —
    /// a silently corrupt load. A freshly opened session is
    /// unmistakable: every one of its frames is still missing. Anything
    /// else means the command was lost, so re-send it, bounded by
    /// `wire.bulk_retry_rounds`. The clean wire cannot lose the command
    /// and skips the check entirely (keeping its timing identical).
    fn ensure_session(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        addr: u32,
        len: usize,
    ) -> anyhow::Result<()> {
        if !sim.wire_active() {
            return Ok(());
        }
        let (writer, _) = self.writers[&chip];
        let (board, plane) = self.plane_of(sim, chip)?;
        let port = plane
            .data_in
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no data-in dispatcher on board {board:?}"))?
            .port;
        let total = bulk::frames_of(len) as u32;
        for attempt in 0..retry_rounds(sim) {
            sim.host_send_sdp(SdpMessage::new(
                SdpHeader::to_core(writer, WRITER_SDP_PORT),
                bulk::encode_check_command(),
            ))?;
            sim.run_until_idle()?;
            // Every report frame of one reply carries the same claimed
            // missing total, so a single surviving frame settles the
            // question — no need for the whole set to cross the wire.
            let mut claimed = None;
            for m in &sim.take_host_udp(port) {
                claimed = Some(bulk::decode_missing_report(m)?.0);
            }
            match claimed {
                // All frames of a fresh session are still missing.
                Some(t) if t == total => return Ok(()),
                // No session (or a stale, fully-acked one): re-open.
                Some(_) => {
                    sim.host_send_sdp(SdpMessage::new(
                        SdpHeader::to_core(writer, WRITER_SDP_PORT),
                        bulk::encode_write_command(addr, len as u32),
                    ))?;
                    sim.run_until_idle()?;
                }
                // Check command or every report frame lost: wait out the
                // timeout + backoff (a blackout expires under the
                // advancing clock), then ask again.
                None => pay_retry_backoff(sim, attempt),
            }
        }
        sim.note_wire_escalation(board);
        anyhow::bail!(
            "write session to {chip:?} could not be opened after retries \
             (escalated to the supervisor)"
        )
    }

    /// Drive one open write session to completion: query the writer for
    /// missing sequences and re-send them, up to `wire.bulk_retry_rounds`
    /// rounds. A bounded loop: exhaustion surfaces a transport error
    /// rather than retrying forever.
    fn finish_write(
        &self,
        sim: &mut SimMachine,
        chip: ChipCoord,
        data: &[u8],
        drop: &mut impl FnMut(u32, u32) -> bool,
        stats: &mut WriteStats,
    ) -> anyhow::Result<WriteStats> {
        let (writer, key) = self.writers[&chip];
        let (board, plane) = self.plane_of(sim, chip)?;
        let port = plane
            .data_in
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no data-in dispatcher on board {board:?}"))?
            .port;
        let frame_gap = dispatch_frame_gap_ns(sim);
        for attempt in 1..=retry_rounds(sim) {
            let missing = self.query_missing(sim, writer, port)?;
            if missing.is_empty() {
                return Ok(*stats);
            }
            let mut slot = 0u64;
            for seq in missing {
                if !drop(seq, attempt) {
                    sim.host_send_udp_after(
                        board,
                        port,
                        bulk::encode_data_frame(
                            key,
                            seq,
                            &data[bulk::frame_range(seq, data.len())],
                        ),
                        slot,
                    )?;
                    stats.frames_resent += 1;
                }
                slot += frame_gap;
            }
            sim.run_until_idle()?;
        }
        let missing = self.query_missing(sim, writer, port)?;
        anyhow::ensure!(
            missing.is_empty(),
            "fast write to {chip:?} still missing {} frames after retries",
            missing.len()
        );
        Ok(*stats)
    }

    /// Ask a writer for the missing sequences of its current session.
    ///
    /// The report itself crosses the unreliable wire: a lost report
    /// frame truncates the sequence set and a duplicated check command
    /// (or report frame) repeats it, so the query re-asks — bounded by
    /// `wire.bulk_retry_rounds` — until a self-consistent report arrives,
    /// deduplicating repeated sequences along the way.
    fn query_missing(
        &self,
        sim: &mut SimMachine,
        writer: CoreLocation,
        port: u16,
    ) -> anyhow::Result<Vec<u32>> {
        let mut last_err = None;
        for attempt in 0..retry_rounds(sim) {
            sim.host_send_sdp(SdpMessage::new(
                SdpHeader::to_core(writer, WRITER_SDP_PORT),
                bulk::encode_check_command(),
            ))?;
            sim.run_until_idle()?;
            let msgs = sim.take_host_udp(port);
            if msgs.is_empty() {
                // The check command (or every report frame) vanished:
                // wait out the timeout + backoff so a dark wire gets a
                // chance to come back before the next round.
                pay_retry_backoff(sim, attempt);
                last_err = Some(anyhow::anyhow!("no missing-sequence report from {writer}"));
                continue;
            }
            let mut total = 0u32;
            let mut seqs = Vec::new();
            for m in &msgs {
                let (t, s) = bulk::decode_missing_report(m)?;
                total = t;
                seqs.extend(s);
            }
            seqs.sort_unstable();
            seqs.dedup();
            if seqs.len() == total as usize {
                return Ok(seqs);
            }
            last_err = Some(anyhow::anyhow!(
                "incomplete missing-sequence report ({} of {total}) from {writer}",
                seqs.len()
            ));
        }
        if sim.wire_active() {
            let board = sim.machine.nearest_ethernet(writer.chip()).unwrap_or(writer.chip());
            sim.note_wire_escalation(board);
        }
        Err(last_err.expect("retry_rounds is at least 1"))
    }

    /// Write a batch of transfers through the data-in streams. Transfers
    /// to *different* chips are interleaved frame-by-frame: each board's
    /// dispatcher paces its own stream (so per-board throughput is the
    /// dispatcher fan-out rate) while the host NIC paces the aggregate —
    /// on a multi-board machine the boards load concurrently in
    /// simulated time. Multiple transfers to one chip run as successive
    /// write sessions.
    pub fn write_many(
        &self,
        sim: &mut SimMachine,
        reqs: &[(ChipCoord, u32, &[u8])],
    ) -> anyhow::Result<WriteStats> {
        let mut stats = WriteStats::default();
        let mut by_chip: BTreeMap<ChipCoord, VecDeque<usize>> = BTreeMap::new();
        for (idx, (chip, _, data)) in reqs.iter().enumerate() {
            if data.is_empty() {
                continue;
            }
            anyhow::ensure!(
                self.writers.contains_key(chip),
                "no data-in writer on {chip:?}"
            );
            let (_board, plane) = self.plane_of(sim, *chip)?;
            anyhow::ensure!(
                plane.data_in.is_some(),
                "no data-in dispatcher for {chip:?}"
            );
            by_chip.entry(*chip).or_default().push_back(idx);
        }
        loop {
            // One open session per chip per wave.
            let wave: Vec<usize> = by_chip.values_mut().filter_map(VecDeque::pop_front).collect();
            if wave.is_empty() {
                break;
            }
            self.write_wave(sim, reqs, &wave, &mut stats)?;
        }
        Ok(stats)
    }

    fn write_wave(
        &self,
        sim: &mut SimMachine,
        reqs: &[(ChipCoord, u32, &[u8])],
        wave: &[usize],
        stats: &mut WriteStats,
    ) -> anyhow::Result<()> {
        // Open every session.
        for &idx in wave {
            let (chip, addr, data) = reqs[idx];
            let (writer, _) = self.writers[&chip];
            sim.host_send_sdp(SdpMessage::new(
                SdpHeader::to_core(writer, WRITER_SDP_PORT),
                bulk::encode_write_command(addr, data.len() as u32),
            ))?;
        }
        sim.run_until_idle()?;
        for &idx in wave {
            let (chip, addr, data) = reqs[idx];
            self.ensure_session(sim, chip, addr, data.len())?;
        }
        // Lay the frame schedule out as future events: per-board cursors
        // keep one board's frames a dispatcher-window apart, the host
        // cursor models NIC serialisation across boards. One
        // run_until_idle then lets all boards stream concurrently.
        struct Cursor {
            idx: usize,
            board: ChipCoord,
            port: u16,
            key: u32,
            next: u32,
            frames: u32,
        }
        let frame_gap = dispatch_frame_gap_ns(sim);
        let host_gap = sim.config.wire.host_udp_gap_ns;
        let mut cursors = Vec::with_capacity(wave.len());
        for &idx in wave {
            let (chip, _, data) = reqs[idx];
            let (board, plane) = self.plane_of(sim, chip)?;
            cursors.push(Cursor {
                idx,
                board,
                port: plane
                    .data_in
                    .as_ref()
                    .ok_or_else(|| {
                        anyhow::anyhow!("no data-in dispatcher on board {board:?}")
                    })?
                    .port,
                key: self.writers[&chip].1,
                next: 0,
                frames: bulk::frames_of(data.len()) as u32,
            });
        }
        let mut host_free = 0u64;
        let mut board_free: BTreeMap<ChipCoord, u64> = BTreeMap::new();
        let mut active = true;
        while active {
            active = false;
            for cur in cursors.iter_mut() {
                if cur.next >= cur.frames {
                    continue;
                }
                active = true;
                let slot = host_free.max(board_free.get(&cur.board).copied().unwrap_or(0));
                let (_, _, data) = reqs[cur.idx];
                sim.host_send_udp_after(
                    cur.board,
                    cur.port,
                    bulk::encode_data_frame(
                        cur.key,
                        cur.next,
                        &data[bulk::frame_range(cur.next, data.len())],
                    ),
                    slot,
                )?;
                host_free = slot + host_gap;
                board_free.insert(cur.board, slot + frame_gap);
                stats.frames_sent += 1;
                cur.next += 1;
            }
        }
        sim.run_until_idle()?;
        // Verify every session (normally one empty report each).
        for &idx in wave {
            let (chip, _, data) = reqs[idx];
            self.finish_write(sim, chip, data, &mut |_, _| false, stats)?;
        }
        Ok(())
    }

    // -- coverage ------------------------------------------------------------

    /// Whether `chip` has a fast extraction reader.
    pub fn has_reader(&self, chip: ChipCoord) -> bool {
        self.readers.contains_key(&chip)
    }

    /// Whether `chip` has a fast data-in writer.
    pub fn has_writer(&self, chip: ChipCoord) -> bool {
        self.writers.contains_key(&chip)
    }

    /// The reader core on `chip`, if covered (tests, provenance).
    pub fn reader_of(&self, chip: ChipCoord) -> Option<CoreLocation> {
        self.readers.get(&chip).map(|(c, _)| *c)
    }

    /// The writer core on `chip`, if covered (tests, provenance).
    pub fn writer_of(&self, chip: ChipCoord) -> Option<CoreLocation> {
        self.writers.get(&chip).map(|(c, _)| *c)
    }

    /// Boards with an installed plane.
    pub fn n_boards(&self) -> usize {
        self.boards.len()
    }

    /// Every core the plane occupies (gatherers, dispatchers, readers,
    /// writers). The incremental placer reserves these so a re-map can
    /// never hand a new vertex a system core.
    pub fn system_cores(&self) -> std::collections::BTreeSet<CoreLocation> {
        let mut out = std::collections::BTreeSet::new();
        for plane in self.boards.values() {
            if let Some(g) = plane.gatherer {
                out.insert(g);
            }
            if let Some(din) = &plane.data_in {
                out.insert(din.dispatcher);
            }
        }
        out.extend(self.readers.values().map(|(c, _)| *c));
        out.extend(self.writers.values().map(|(c, _)| *c));
        out
    }

    /// The plane's stream routing entries on `chip` (empty slice when
    /// the plane has none there). An incremental re-map appends these
    /// after a user-table reinstall so the streams keep flowing.
    pub fn stream_entries(&self, chip: ChipCoord) -> &[RoutingEntry] {
        self.stream_entries
            .get(&chip)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// The (board, IP tag) pairs the plane owns — the extraction tag
    /// and the data-in report tag per board. An incremental re-map must
    /// not hand these to user vertices: the tag allocator knows nothing
    /// of the plane, so the front end checks for collisions and falls
    /// back to a full re-map (which re-seeds the plane's allocator from
    /// the user tags) when one appears.
    pub fn system_tags(&self) -> std::collections::BTreeSet<(ChipCoord, u8)> {
        let mut out = std::collections::BTreeSet::new();
        for (board, plane) in &self.boards {
            if let Some(t) = plane.extract_tag {
                out.insert((*board, t));
            }
            if let Some(din) = &plane.data_in {
                out.insert((*board, din.reply_tag));
            }
        }
        out
    }

    /// The (board, UDP port) pairs carrying the plane's reverse IP tags
    /// (the per-board data-in dispatcher ports). Same collision rule as
    /// [`Self::system_tags`].
    pub fn system_reverse_ports(&self) -> std::collections::BTreeSet<(ChipCoord, u16)> {
        self.boards
            .iter()
            .filter_map(|(board, plane)| {
                plane.data_in.as_ref().map(|din| (*board, din.port))
            })
            .collect()
    }
}

/// Apply host-side loss injection to a batch of received frames.
fn filter_dropped(
    frames: Vec<Vec<u8>>,
    attempt: u32,
    drop: &mut impl FnMut(u32, u32) -> bool,
) -> Vec<Vec<u8>> {
    frames
        .into_iter()
        .filter(|f| {
            let seq = f
                .get(..4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .unwrap_or(u32::MAX);
            !drop(seq, attempt)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineBuilder;
    use crate::simulator::SimConfig;

    fn free_core_picker() -> impl FnMut(ChipCoord) -> Option<u8> {
        let mut used: BTreeMap<ChipCoord, u8> = BTreeMap::new();
        move |chip| {
            let next = used.entry(chip).or_insert(17);
            let c = *next;
            *next -= 1;
            Some(c)
        }
    }

    #[test]
    fn fast_read_round_trips_data() {
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        // Data on a far, non-ethernet chip.
        let chip = (7, 7);
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
        scamp::write_sdram(&mut sim, chip, addr, &data).unwrap();
        let fp = FastPath::install(
            &mut sim,
            &[chip],
            free_core_picker(),
            &DataPlaneOptions::default(),
        )
        .unwrap();
        scamp::signal_start(&mut sim).unwrap();
        let got = fp.read(&mut sim, chip, addr, data.len()).unwrap();
        assert_eq!(got, data);
    }

    #[test]
    fn fast_write_round_trips_data() {
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let chip = (6, 3);
        let data: Vec<u8> = (0..70_001u32).map(|i| (i % 249) as u8).collect();
        let addr = scamp::alloc_sdram(&mut sim, chip, data.len() as u32).unwrap();
        let fp = FastPath::install(
            &mut sim,
            &[chip],
            free_core_picker(),
            &DataPlaneOptions::default(),
        )
        .unwrap();
        scamp::signal_start(&mut sim).unwrap();
        let stats = fp.write(&mut sim, chip, addr, &data).unwrap();
        assert_eq!(stats.frames_sent as usize, bulk::frames_of(data.len()));
        assert_eq!(stats.frames_resent, 0, "lossless fabric needs no re-sends");
        assert_eq!(
            scamp::read_sdram(&mut sim, chip, addr, data.len()).unwrap(),
            data
        );
    }

    #[test]
    fn fast_path_beats_scamp_from_any_chip() {
        // Experiment E1's claim, as a test: fast reads are faster than
        // SCAMP reads, and chip distance does not matter for fast reads.
        let m = MachineBuilder::spinn5().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let len = 64 * 1024;
        let far = (7, 7);
        let near = (0, 0);
        let a_far = scamp::alloc_sdram(&mut sim, far, len as u32).unwrap();
        let a_near = scamp::alloc_sdram(&mut sim, near, len as u32).unwrap();
        let fp = FastPath::install(
            &mut sim,
            &[far, near],
            free_core_picker(),
            &DataPlaneOptions::default(),
        )
        .unwrap();
        scamp::signal_start(&mut sim).unwrap();

        let t0 = sim.now_ns();
        scamp::read_sdram(&mut sim, far, a_far, len).unwrap();
        let scamp_far = sim.now_ns() - t0;

        let t1 = sim.now_ns();
        fp.read(&mut sim, far, a_far, len).unwrap();
        let fast_far = sim.now_ns() - t1;

        let t2 = sim.now_ns();
        fp.read(&mut sim, near, a_near, len).unwrap();
        let fast_near = sim.now_ns() - t2;

        assert!(
            fast_far < scamp_far / 10,
            "fast {fast_far} ns vs scamp {scamp_far} ns"
        );
        // "no penalty for reading from a non-Ethernet chip"
        let ratio = fast_far as f64 / fast_near as f64;
        assert!((0.8..1.2).contains(&ratio), "far/near = {ratio}");
    }

    #[test]
    fn missing_reader_errors() {
        let m = MachineBuilder::spinn3().build();
        let mut sim = SimMachine::boot(m, SimConfig::default());
        let fp = FastPath::install(
            &mut sim,
            &[(0, 0)],
            free_core_picker(),
            &DataPlaneOptions::default(),
        )
        .unwrap();
        assert!(fp.read(&mut sim, (1, 1), 0x6000_0000, 4).is_err());
        assert!(fp.write(&mut sim, (1, 1), 0x6000_0000, &[1, 2, 3]).is_err());
    }

    #[test]
    fn every_board_gets_a_plane() {
        let m = MachineBuilder::triads(1, 1).build();
        let mut sim = SimMachine::boot(m.clone(), SimConfig::default());
        let chips: Vec<ChipCoord> = m.ethernet_chips().map(|c| (c.x, c.y)).collect();
        let fp = FastPath::install(
            &mut sim,
            &chips,
            free_core_picker(),
            &DataPlaneOptions::default(),
        )
        .unwrap();
        assert_eq!(fp.n_boards(), 3, "one plane per ethernet chip");
        for chip in &chips {
            assert!(fp.has_reader(*chip));
            assert!(fp.has_writer(*chip));
        }
    }
}
