//! The buffer manager's run-cycle planning (§6.3.5, Figure 9).
//!
//! "The SDRAM remaining on each chip after it has been allocated for
//! other things is divided up between the vertices on that chip. Each is
//! then asked for the number of time steps it can be run for before
//! filling up the SDRAM. The minimum number of time steps is taken over
//! all chips and the total run time is split into smaller chunks."

use std::collections::BTreeMap;

use crate::graph::{MachineGraph, VertexId};
use crate::machine::{ChipCoord, Machine};
use crate::mapping::Placements;

/// The plan for a requested run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunCyclePlan {
    /// Ticks per cycle (the Figure-9 chunk); `requested` if everything
    /// fits in one cycle.
    pub steps_per_cycle: u64,
    /// Cycle lengths summing to the requested run time.
    pub cycles: Vec<u64>,
    /// Recording-buffer bytes granted to each recording vertex.
    pub recording_bytes: BTreeMap<VertexId, u64>,
}

/// Compute the Figure-9 plan. `data_bytes` is each vertex's generated
/// (non-recording) SDRAM footprint, already known after data generation.
pub fn plan_run_cycles(
    machine: &Machine,
    graph: &MachineGraph,
    placements: &Placements,
    data_bytes: &BTreeMap<VertexId, u64>,
    requested_steps: u64,
    slack_bytes: u64,
) -> anyhow::Result<RunCyclePlan> {
    let mut recording_bytes = BTreeMap::new();
    let mut min_steps: Option<u64> = None;

    let chips: Vec<ChipCoord> = placements.used_chips().into_iter().collect();
    for chip in chips {
        let chip_info = machine
            .chip(chip)
            .ok_or_else(|| anyhow::anyhow!("placement on missing chip {chip:?}"))?;
        if chip_info.is_virtual {
            continue;
        }
        let on_chip = placements.on_chip(chip);
        let used: u64 = on_chip
            .iter()
            .map(|(v, _)| data_bytes.get(v).copied().unwrap_or(0))
            .sum();
        let total = chip_info.sdram.user_size() as u64;
        let free = total
            .checked_sub(used + slack_bytes)
            .ok_or_else(|| anyhow::anyhow!("chip {chip:?} SDRAM oversubscribed by data"))?;

        let recorders: Vec<VertexId> = on_chip
            .iter()
            .map(|(v, _)| *v)
            .filter(|v| graph.vertex(*v).steps_per_recording_space(1 << 30).is_some())
            .collect();
        if recorders.is_empty() {
            continue;
        }
        // "divided up between the vertices on that chip".
        let share = free / recorders.len() as u64;
        for v in recorders {
            let vertex = graph.vertex(v);
            let min_bytes = vertex.min_recording_bytes();
            anyhow::ensure!(
                share >= min_bytes,
                "chip {chip:?}: {} bytes/vertex below the {} byte reservation of {}",
                share,
                min_bytes,
                vertex.label()
            );
            let steps = vertex
                .steps_per_recording_space(share)
                .expect("filtered to recording vertices");
            anyhow::ensure!(
                steps > 0,
                "vertex {} cannot record even one step in {} bytes",
                vertex.label(),
                share
            );
            min_steps = Some(min_steps.map_or(steps, |m| m.min(steps)));
            recording_bytes.insert(v, share);
        }
    }

    let steps_per_cycle = min_steps.unwrap_or(requested_steps).min(requested_steps).max(1);
    let mut cycles = Vec::new();
    let mut remaining = requested_steps;
    while remaining > 0 {
        let c = steps_per_cycle.min(remaining);
        cycles.push(c);
        remaining -= c;
    }
    Ok(RunCyclePlan { steps_per_cycle, cycles, recording_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::machine_graph::test_support::TestVertex;
    use crate::graph::{
        DataGenContext, DataRegion, MachineVertexImpl, ResourceRequirements,
    };
    use crate::machine::MachineBuilder;
    use crate::mapping::placer;
    use std::any::Any;
    use std::sync::Arc;

    /// Records `bytes_per_step` bytes every step.
    #[derive(Debug)]
    struct Recorder {
        name: String,
        bytes_per_step: u64,
    }

    impl Recorder {
        fn arc(name: &str, bytes_per_step: u64) -> Arc<dyn MachineVertexImpl> {
            Arc::new(Self { name: name.into(), bytes_per_step })
        }
    }

    impl MachineVertexImpl for Recorder {
        fn label(&self) -> String {
            self.name.clone()
        }
        fn resources(&self) -> ResourceRequirements {
            ResourceRequirements::with_sdram(1024)
        }
        fn binary_name(&self) -> String {
            "r.aplx".into()
        }
        fn generate_data(&self, _: &DataGenContext) -> Vec<DataRegion> {
            vec![]
        }
        fn steps_per_recording_space(&self, bytes: u64) -> Option<u64> {
            Some(bytes / self.bytes_per_step)
        }
        fn min_recording_bytes(&self) -> u64 {
            self.bytes_per_step
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    #[test]
    fn single_cycle_when_memory_ample() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        let v = g.add_vertex(Recorder::arc("r", 4));
        let p = placer::place(&m, &g).unwrap();
        let mut data = BTreeMap::new();
        data.insert(v, 1024u64);
        let plan = plan_run_cycles(&m, &g, &p, &data, 1000, 1024).unwrap();
        assert_eq!(plan.cycles, vec![1000]);
    }

    #[test]
    fn chunked_when_memory_tight() {
        // Grid machine with tiny SDRAM so buffers limit the run.
        let mut m = MachineBuilder::spinn3().build();
        for c in m.chip_coords().collect::<Vec<_>>() {
            m.chip_mut(c).unwrap().sdram.size = 2 * 1024 * 1024;
            m.chip_mut(c).unwrap().sdram.system_reserved = 0;
        }
        let mut g = MachineGraph::new();
        // 1 KiB per step per vertex; 17 on one chip.
        for i in 0..17 {
            g.add_vertex(Recorder::arc(&format!("r{i}"), 1024));
        }
        let p = placer::place(&m, &g).unwrap();
        let data: BTreeMap<VertexId, u64> =
            g.vertex_ids().map(|v| (v, 0u64)).collect();
        let plan = plan_run_cycles(&m, &g, &p, &data, 1000, 1024 * 1024).unwrap();
        // free = 2 MiB - 1 MiB slack = 1 MiB; share = 1 MiB/17 ≈ 61 KiB
        // -> ~61 steps per cycle.
        assert!(plan.steps_per_cycle < 70, "{}", plan.steps_per_cycle);
        assert!(plan.cycles.len() > 10);
        let total: u64 = plan.cycles.iter().sum();
        assert_eq!(total, 1000);
        // Final (leftover) cycle is the remainder.
        assert!(*plan.cycles.last().unwrap() <= plan.steps_per_cycle);
    }

    #[test]
    fn non_recording_graph_single_cycle() {
        let m = MachineBuilder::spinn3().build();
        let mut g = MachineGraph::new();
        g.add_vertex(TestVertex::arc("plain"));
        let p = placer::place(&m, &g).unwrap();
        let plan =
            plan_run_cycles(&m, &g, &p, &BTreeMap::new(), 500, 1024).unwrap();
        assert_eq!(plan.cycles, vec![500]);
        assert!(plan.recording_bytes.is_empty());
    }

    #[test]
    fn min_reservation_enforced() {
        let mut m = MachineBuilder::spinn3().build();
        for c in m.chip_coords().collect::<Vec<_>>() {
            m.chip_mut(c).unwrap().sdram.size = 1024 * 1024;
            m.chip_mut(c).unwrap().sdram.system_reserved = 0;
        }
        let mut g = MachineGraph::new();
        g.add_vertex(Recorder::arc("big", 10 * 1024 * 1024)); // absurd per-step
        let p = placer::place(&m, &g).unwrap();
        assert!(plan_run_cycles(&m, &g, &p, &BTreeMap::new(), 10, 0).is_err());
    }
}
